"""Shared benchmark helpers: subprocess multi-device runs + timing."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_tc_subprocess(
    graph: str,
    grid: int,
    *,
    schedule: str = "cannon",
    method: str = "search",
    pods: int = 1,
    chunk: int = 512,
    extra=(),
    timeout: int = 1200,
) -> dict:
    """Run tc_run in a subprocess with grid*grid*pods host devices."""
    ndev = grid * grid * pods
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    cmd = [
        sys.executable, "-m", "repro.launch.tc_run",
        "--graph", graph, "--grid", str(grid), "--pods", str(pods),
        "--schedule", schedule, "--method", method, "--chunk", str(chunk),
        "--json", *extra,
    ]
    out = subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=timeout
    )
    if out.returncode != 0:
        raise RuntimeError(out.stdout[-1000:] + out.stderr[-1000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_py_subprocess(code: str, ndev: int, timeout: int = 1200) -> str:
    """Run a python snippet with ndev host devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stdout[-800:] + out.stderr[-800:])
    return out.stdout


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
