"""Dry-run sweep driver: run every (arch × shape × mesh) cell in its own
subprocess (the XLA 512-device flag must be set before jax init, and a
failing cell must not kill the sweep).  Results append to a JSONL file.

Usage:
    PYTHONPATH=src python benchmarks/dryrun_sweep.py \
        --out results/dryrun.jsonl [--only lm|gnn|recsys|tc] [--mesh pod]
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def list_cells():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True,
        text=True,
    )
    return [l.strip() for l in out.stdout.splitlines() if l.strip()]


FAMILY = {
    "chatglm3-6b": "lm", "qwen2-0.5b": "lm", "qwen1.5-110b": "lm",
    "grok-1-314b": "lm", "deepseek-v3-671b": "lm",
    "nequip": "gnn", "graphcast": "gnn", "gat-cora": "gnn",
    "equiformer-v2": "gnn", "dlrm-mlperf": "recsys",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--only", default=None)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--skip-done", action="store_true", default=True)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("status") == "ok":
                    done.add(r["name"])
            except json.JSONDecodeError:
                pass

    cells = list_cells()
    todo = []
    for c in cells:
        arch, shape, mesh = c.split(":")
        fam = FAMILY.get(arch, "tc")
        if args.only and fam != args.only:
            continue
        if args.mesh and mesh != args.mesh:
            continue
        if c in done:
            continue
        todo.append(c)

    print(f"{len(todo)} cells to run ({len(done)} already done)")
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    for i, cell in enumerate(todo):
        t0 = time.time()
        try:
            proc = subprocess.run(
                [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--cell", cell, "--out", args.out,
                ],
                env=env,
                capture_output=True,
                text=True,
                timeout=args.timeout,
            )
            status = "ok" if proc.returncode == 0 else "error"
            if status == "error":
                sys.stderr.write(proc.stdout[-500:] + proc.stderr[-500:])
        except subprocess.TimeoutExpired:
            status = "timeout"
            with open(args.out, "a") as f:
                f.write(
                    json.dumps({"name": cell, "status": "timeout"}) + "\n"
                )
        dt = time.time() - t0
        print(
            f"[{i+1}/{len(todo)}] {cell}: {status} ({dt:.0f}s)", flush=True
        )


if __name__ == "__main__":
    main()
