"""Engine benchmark baseline: per-schedule wall-time on RMAT graphs.

Records ``BENCH_engine.json`` — per-schedule triangle-count wall-time
(tct_seconds, plus preprocess ppt_seconds) on RMAT scales 12-16 at q=3
(9 XLA host devices per subprocess), each cell annotated with the
engine's sparsity-skip accounting (``skipped_steps`` of
``schedule_steps`` per-(device, step) mask entries) — plus a
``block_sparse`` fixture section measuring the two engine levers in
isolation:

* ``skip``    — masked vs unmasked wall-time on a block-diagonal graph
  (``cliques:3,60``) where all but q of the q^3 (device, shift) pairs
  are provably empty;
* ``overlap`` — double-buffered vs single-buffered Cannon body on the
  same fixture (communication/compute overlap).

    python -m benchmarks.engine_baseline [--quick] [--out BENCH_engine.json]
    python -m benchmarks.engine_baseline --smoke   # CI guard: fails if the
        masked engine miscounts or skips zero steps on the fixture
"""
from __future__ import annotations

import json
import sys
import time

from .common import csv_row, run_tc_subprocess

GRID = 3  # q=3 -> 9 ranks
SCALES_FULL = [12, 13, 14, 15, 16]
SCALES_QUICK = [12, 13]
SCHEDULES = ["cannon", "summa", "oned"]
BLOCK_SPARSE_GRAPH = "cliques:3,60"


def _cell(r: dict) -> dict:
    cell = dict(
        tct_seconds=r["tct_seconds"],
        ppt_seconds=r["ppt_seconds"],
        triangles=r["triangles"],
    )
    if "schedule_steps" in r:
        cell["schedule_steps"] = r["schedule_steps"]
        cell["skipped_steps"] = r["skipped_steps"]
    return cell


def block_sparse_fixture(graph: str = BLOCK_SPARSE_GRAPH, grid: int = GRID):
    """Measure the skip and overlap levers in isolation on the
    block-diagonal fixture; verifies every variant against the oracle."""
    runs = {
        "masked": (),
        "unmasked": ("--no-skip-mask",),
        "single_buffer": ("--no-double-buffer",),
    }
    out = {"graph": graph, "grid": grid}
    counts = {}
    for name, extra in runs.items():
        # --repeat 3: tct is the warm third count (pure dispatch) so the
        # skip/overlap comparison is not drowned in trace+compile time
        r = run_tc_subprocess(
            graph, grid, extra=("--verify", "--repeat", "3") + extra
        )
        counts[name] = r["triangles"]
        out[name] = _cell(r)
        print(csv_row(f"engine/block_sparse/{name}", r["tct_seconds"] * 1e6,
                      f"triangles={r['triangles']}"))
    assert len(set(counts.values())) == 1, (
        f"masked engine miscounts on {graph}: {counts}"
    )
    out["skip"] = dict(
        skipped_steps=out["masked"]["skipped_steps"],
        schedule_steps=out["masked"]["schedule_steps"],
        tct_masked=out["masked"]["tct_seconds"],
        tct_unmasked=out["unmasked"]["tct_seconds"],
    )
    out["overlap"] = dict(
        tct_double_buffer=out["masked"]["tct_seconds"],
        tct_single_buffer=out["single_buffer"]["tct_seconds"],
    )
    return out


def smoke() -> dict:
    """CI guard: the masked+double-buffered engine must count the
    block-sparse fixture correctly (asserted via --verify inside each
    subprocess and cross-variant agreement here) and must actually skip
    steps on it."""
    bs = block_sparse_fixture()
    skipped = bs["skip"]["skipped_steps"]
    if skipped <= 0:
        raise SystemExit(
            f"engine smoke FAILED: skipped_steps={skipped} on the "
            f"block-sparse fixture {bs['graph']} (expected > 0)"
        )
    print(
        f"# engine smoke ok: {skipped}/{bs['skip']['schedule_steps']} "
        "device-steps skipped, all variants agree"
    )
    return bs


def run(quick: bool = False, out: str = "BENCH_engine.json") -> dict:
    scales = SCALES_QUICK if quick else SCALES_FULL
    report = {
        "grid": GRID,
        "ranks": GRID * GRID,
        "unix_time": time.time(),
        "quick": quick,
        "schedules": {s: {} for s in SCHEDULES},
    }
    for scale in scales:
        graph = f"rmat:{scale}"
        for sched in SCHEDULES:
            r = run_tc_subprocess(graph, GRID, schedule=sched)
            cell = _cell(r)
            report["schedules"][sched][str(scale)] = cell
            print(
                csv_row(
                    f"engine/{sched}/rmat{scale}",
                    r["tct_seconds"] * 1e6,
                    f"triangles={r['triangles']}",
                )
            )
        counts = {
            report["schedules"][s][str(scale)]["triangles"] for s in SCHEDULES
        }
        assert len(counts) == 1, f"schedules disagree at scale {scale}: {counts}"
    report["block_sparse"] = block_sparse_fixture()
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")
    return report


def main(quick: bool = False, out: str = "BENCH_engine.json"):
    return run(quick=quick, out=out)


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = "BENCH_engine.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    if "--smoke" in argv:
        smoke()
    else:
        main(quick="--quick" in argv or "--full" not in argv, out=out)
