"""Engine benchmark baseline: per-schedule wall-time on RMAT graphs.

Records ``BENCH_engine.json`` — per-schedule triangle-count wall-time
(tct_seconds, plus preprocess ppt_seconds) on RMAT scales 12-16 at q=3
(9 XLA host devices per subprocess) — so subsequent perf PRs have a
trajectory to compare against.

    python -m benchmarks.engine_baseline [--quick] [--out BENCH_engine.json]
"""
from __future__ import annotations

import json
import sys
import time

from .common import csv_row, run_tc_subprocess

GRID = 3  # q=3 -> 9 ranks
SCALES_FULL = [12, 13, 14, 15, 16]
SCALES_QUICK = [12, 13]
SCHEDULES = ["cannon", "summa", "oned"]


def run(quick: bool = False, out: str = "BENCH_engine.json") -> dict:
    scales = SCALES_QUICK if quick else SCALES_FULL
    report = {
        "grid": GRID,
        "ranks": GRID * GRID,
        "unix_time": time.time(),
        "quick": quick,
        "schedules": {s: {} for s in SCHEDULES},
    }
    for scale in scales:
        graph = f"rmat:{scale}"
        for sched in SCHEDULES:
            r = run_tc_subprocess(graph, GRID, schedule=sched)
            cell = dict(
                tct_seconds=r["tct_seconds"],
                ppt_seconds=r["ppt_seconds"],
                triangles=r["triangles"],
            )
            report["schedules"][sched][str(scale)] = cell
            print(
                csv_row(
                    f"engine/{sched}/rmat{scale}",
                    r["tct_seconds"] * 1e6,
                    f"triangles={r['triangles']}",
                )
            )
        counts = {
            report["schedules"][s][str(scale)]["triangles"] for s in SCHEDULES
        }
        assert len(counts) == 1, f"schedules disagree at scale {scale}: {counts}"
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")
    return report


def main(quick: bool = False, out: str = "BENCH_engine.json"):
    return run(quick=quick, out=out)


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = "BENCH_engine.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    main(quick="--quick" in argv or "--full" not in argv, out=out)
