"""Engine benchmark baseline: per-schedule wall-time on RMAT graphs.

Records ``BENCH_engine.json`` — per-schedule triangle-count wall-time
(tct_seconds, plus preprocess ppt_seconds) on RMAT scales 12-16 at q=3
(9 XLA host devices per subprocess), each cell annotated with the
engine's sparsity-skip accounting (``skipped_steps`` of
``schedule_steps`` per-(device, step) mask entries and
``elided_steps``/``live_steps`` of the compacted schedule) — plus a
``block_sparse`` fixture section measuring the engine levers in
isolation:

* ``skip``    — compacted vs cond-only-masked vs unmasked wall-time on
  a block-diagonal graph (``cliques:3,60``): the cond-only row is the
  PR-3 path (every scan iteration runs, counts skipped per device), the
  compacted row executes only the globally-live steps under the σ
  visit order (DESIGN.md §4.4);
* ``overlap`` — double- vs single-buffered scan body, *attributed* via
  shift-only (all-False mask) and count-only (shifts elided) probe
  runs: the buffer can only buy ``min(shift_only, count_only)``, so on
  fixtures where either term vanishes ``double_buffer=False`` is the
  right call (one payload generation less memory, no discarded shift);
* ``autotune`` — ``--method auto`` (deterministic kernel shapes) vs
  fixed ``chunk=512`` search on the skewed ``powerlaw:600,2.2``;
* ``fused``   — fused-vs-search2-vs-tile count-kernel comparison on the
  block fixture with the fused tile shape picked by the measured
  autotune table (:func:`benchmarks.kernels.fused_fixture`);
* ``hubsplit`` — hub-split planning vs rebalance-only on the
  heavy-tailed powerlaw fixtures (DESIGN.md §4.8): masked critical
  path (the rebalancer's own objective) and wall-time per variant,
  counts byte-identical; the smoke guard requires the hub residual's
  masked critical path to beat rebalance-only by ≥1.5×;
* ``collectives`` — the communication-avoiding collectives A/B
  (DESIGN.md §4.5): 2.5D tree vs flat reduction on a 2-pod mesh and
  ppermute-chain vs one-hot SUMMA broadcasts, each cell annotated with
  the per-phase HLO byte attribution (``coll_reduce_bytes`` /
  ``coll_broadcast_bytes`` — pairs-aware, so the masked rounds are
  charged only their participating fraction).

    python -m benchmarks.engine_baseline [--quick] [--out BENCH_engine.json]
    python -m benchmarks.engine_baseline --smoke   # CI guard: fails if the
        engine miscounts, elides zero steps, or the compacted schedule
        regresses vs the cond-only masked path on the fixture
"""
from __future__ import annotations

import json
import sys
import time

from .common import csv_row, run_tc_subprocess

GRID = 3  # q=3 -> 9 ranks
SCALES_FULL = [12, 13, 14, 15, 16]
SCALES_QUICK = [12, 13]
SCHEDULES = ["cannon", "summa", "oned"]
BLOCK_SPARSE_GRAPH = "cliques:3,60"
POWERLAW_GRAPH = "powerlaw:600,2.2"
HUB_GRAPHS = ["powerlaw:600,2.2", "powerlaw:600,1.8"]
COLLECTIVES_GRAPH = "er:400,16,3"
# the hub residual's masked critical path must beat rebalance-only by
# at least this factor on the heavy-tailed fixtures (DESIGN.md §4.8
# records ~9.5-10x; 1.5x is the don't-regress floor)
HUB_MCP_GAIN = 1.5
# compacted tct must not exceed cond-only tct by more than this (both
# are warm dispatch times; small slack absorbs host-device timer noise)
COMPACT_REGRESSION_SLACK = 1.05


def _cell(r: dict) -> dict:
    cell = dict(
        tct_seconds=r["tct_seconds"],
        ppt_seconds=r["ppt_seconds"],
        triangles=r["triangles"],
    )
    for key in ("schedule_steps", "skipped_steps", "live_steps",
                "elided_steps", "autotuned_chunk", "tct_shift_only",
                "tct_broadcast_only", "tct_count_only", "method",
                "coll_shift_bytes", "coll_broadcast_bytes",
                "coll_reduce_bytes", "coll_other_bytes"):
        if key in r:
            cell[key] = r[key]
    return cell


def block_sparse_fixture(graph: str = BLOCK_SPARSE_GRAPH, grid: int = GRID):
    """Measure the skip, compaction and overlap levers in isolation on
    the block-diagonal fixture; verifies every variant against the
    oracle."""
    runs = {
        "masked": (),  # compacted kept-step schedule (the default)
        "cond_only": ("--no-compact",),  # PR-3 masked scan body
        "unmasked": ("--no-compact", "--no-skip-mask"),
        "single_buffer": ("--no-compact", "--no-double-buffer"),
        # cond-only again, with the shift/count attribution probes
        "split": ("--no-compact", "--time-split"),
    }
    out = {"graph": graph, "grid": grid}
    counts = {}
    for name, extra in runs.items():
        # --repeat 5: tct is the min over the warm runs (pure dispatch)
        # so the skip/overlap comparison is neither drowned in
        # trace+compile time nor skewed by host timer noise
        r = run_tc_subprocess(
            graph, grid, extra=("--verify", "--repeat", "5") + extra
        )
        counts[name] = r["triangles"]
        out[name] = _cell(r)
        print(csv_row(f"engine/block_sparse/{name}", r["tct_seconds"] * 1e6,
                      f"triangles={r['triangles']}"))
    assert len(set(counts.values())) == 1, (
        f"masked engine miscounts on {graph}: {counts}"
    )
    out["skip"] = dict(
        skipped_steps=out["masked"]["skipped_steps"],
        schedule_steps=out["masked"]["schedule_steps"],
        elided_steps=out["masked"]["elided_steps"],
        live_steps=out["masked"]["live_steps"],
        tct_compacted=out["masked"]["tct_seconds"],
        tct_cond_only=out["cond_only"]["tct_seconds"],
        tct_unmasked=out["unmasked"]["tct_seconds"],
    )
    out["overlap"] = dict(
        tct_double_buffer=out["cond_only"]["tct_seconds"],
        tct_single_buffer=out["single_buffer"]["tct_seconds"],
        tct_shift_only=out["split"]["tct_shift_only"],
        tct_count_only=out["split"]["tct_count_only"],
        note=(
            "overlap headroom = min(shift_only, count_only); when either "
            "term is negligible (or the schedule is compacted away) "
            "double_buffer=False trades nothing and halves the carried "
            "payload"
        ),
    )
    return out


def hubsplit_fixture(graphs=tuple(HUB_GRAPHS), grid: int = GRID):
    """Hub-split vs rebalance-only on the heavy-tailed fixtures
    (DESIGN.md §4.8), counts verified against the oracle per
    subprocess and cross-variant here.

    Both variants run the same 3-seed rebalance; the hub-split cell
    additionally takes the hub rows off the 2D path, so its
    ``residual_mcp`` (the masked critical path the residual actually
    schedules) is directly comparable to the rebalance-only
    ``rebalance_masked_critical_path``.
    """
    out = {"grid": grid, "graphs": {}}
    for graph in graphs:
        cell = {}
        r = run_tc_subprocess(
            graph, grid,
            extra=("--verify", "--repeat", "5", "--rebalance", "3"),
        )
        cell["rebalance_only"] = _cell(r)
        cell["rebalance_only"]["masked_critical_path"] = (
            r["rebalance_masked_critical_path"]
        )
        print(csv_row(f"engine/hubsplit/{graph}/rebalance_only",
                      r["tct_seconds"] * 1e6,
                      f"mcp={r['rebalance_masked_critical_path']}"))
        r = run_tc_subprocess(
            graph, grid,
            extra=("--verify", "--repeat", "5", "--rebalance", "3",
                   "--hub-split"),
        )
        cell["hub_split"] = _cell(r)
        cell["hub_split"].update(
            masked_critical_path=r["residual_mcp"],
            hub_rows=r["hub_rows"],
            hub_nnz_frac=r["hub_nnz_frac"],
        )
        print(csv_row(f"engine/hubsplit/{graph}/hub_split",
                      r["tct_seconds"] * 1e6,
                      f"mcp={r['residual_mcp']} hub_rows={r['hub_rows']}"))
        assert (
            cell["rebalance_only"]["triangles"]
            == cell["hub_split"]["triangles"]
        ), f"hub-split miscounts on {graph}: {cell}"
        out["graphs"][graph] = cell
    return out


def collectives_fixture(graph: str = COLLECTIVES_GRAPH, grid: int = GRID):
    """A/B the communication-avoiding collectives in isolation
    (DESIGN.md §4.5), verifying every variant against the oracle:

    * ``reduce`` — flat psum-per-axis vs the 2.5D staged tree on a
      q=2, 2-pod mesh (8 ranks): wall-time plus attributed reduce
      bytes (the tree must move strictly fewer);
    * ``broadcast`` — one-hot psum vs the masked ppermute doubling
      chain for SUMMA panel broadcasts at q=3: wall-time plus
      attributed broadcast bytes (the chain halves them).
    """
    out = {"graph": graph, "reduce": {}, "broadcast": {}}
    for strat in ("flat", "tree"):
        r = run_tc_subprocess(
            graph, 2, pods=2,
            extra=("--verify", "--repeat", "5", "--time-split",
                   "--reduce-strategy", strat),
        )
        out["reduce"][strat] = _cell(r)
        print(csv_row(f"engine/collectives/reduce/{strat}",
                      r["tct_seconds"] * 1e6,
                      f"reduce_bytes={r['coll_reduce_bytes']}"))
    assert (
        out["reduce"]["flat"]["triangles"]
        == out["reduce"]["tree"]["triangles"]
    ), f"tree reduction miscounts on {graph}: {out['reduce']}"
    for strat in ("onehot", "chain"):
        r = run_tc_subprocess(
            graph, grid, schedule="summa",
            extra=("--verify", "--repeat", "5", "--time-split",
                   "--broadcast", strat),
        )
        out["broadcast"][strat] = _cell(r)
        print(csv_row(f"engine/collectives/broadcast/{strat}",
                      r["tct_seconds"] * 1e6,
                      f"broadcast_bytes={r['coll_broadcast_bytes']}"))
    assert (
        out["broadcast"]["onehot"]["triangles"]
        == out["broadcast"]["chain"]["triangles"]
    ), f"chain broadcast miscounts on {graph}: {out['broadcast']}"
    return out


def autotune_fixture(graph: str = POWERLAW_GRAPH, grid: int = GRID):
    """``--method auto`` vs fixed ``chunk=512`` search per schedule on
    the skewed fixture; every cell verified against the oracle."""
    out = {"graph": graph, "grid": grid, "schedules": {}}
    for sched in SCHEDULES:
        cell = {}
        for name, method in (("fixed", "search"), ("auto", "auto")):
            # --repeat 10: fixed and auto often resolve to the *same*
            # executable on small fixtures, so the comparison needs the
            # min-of-warm estimator to converge below timer noise
            r = run_tc_subprocess(
                graph, grid, schedule=sched, method=method,
                extra=("--verify", "--repeat", "10"),
            )
            cell[name] = _cell(r)
            print(csv_row(f"engine/autotune/{sched}/{name}",
                          r["tct_seconds"] * 1e6,
                          f"triangles={r['triangles']}"))
        assert cell["fixed"]["triangles"] == cell["auto"]["triangles"]
        out["schedules"][sched] = cell
    return out


def smoke() -> dict:
    """CI guard: the compacted engine must count the block-sparse
    fixture correctly (asserted via --verify inside each subprocess and
    cross-variant agreement here), must actually skip *and* elide steps
    on it, and must not regress against the cond-only masked path."""
    bs = block_sparse_fixture()
    skipped = bs["skip"]["skipped_steps"]
    if skipped <= 0:
        raise SystemExit(
            f"engine smoke FAILED: skipped_steps={skipped} on the "
            f"block-sparse fixture {bs['graph']} (expected > 0)"
        )
    elided = bs["skip"]["elided_steps"]
    if elided <= 0:
        raise SystemExit(
            f"engine smoke FAILED: elided_steps={elided} on the "
            f"block-sparse fixture {bs['graph']} (expected > 0 — the "
            "compaction stage found no globally-dead steps)"
        )
    compacted = bs["skip"]["tct_compacted"]
    cond_only = bs["skip"]["tct_cond_only"]
    if compacted > cond_only * COMPACT_REGRESSION_SLACK:
        # single-dispatch wall times on shared CI hosts are noisy; one
        # re-measure before declaring a regression
        bs2 = block_sparse_fixture()
        compacted = min(compacted, bs2["skip"]["tct_compacted"])
        cond_only = max(cond_only, bs2["skip"]["tct_cond_only"])
        if compacted > cond_only * COMPACT_REGRESSION_SLACK:
            raise SystemExit(
                f"engine smoke FAILED: compacted tct {compacted:.4f}s "
                f"regresses vs cond-only masked {cond_only:.4f}s "
                f"(slack {COMPACT_REGRESSION_SLACK}x)"
            )
    print(
        f"# engine smoke ok: {skipped}/{bs['skip']['schedule_steps']} "
        f"device-steps skipped, {elided} elided "
        f"({bs['skip']['live_steps']} live), compacted "
        f"{compacted:.4f}s <= cond-only {cond_only:.4f}s, all variants "
        "agree"
    )
    co = collectives_fixture()
    flat_b = co["reduce"]["flat"]["coll_reduce_bytes"]
    tree_b = co["reduce"]["tree"]["coll_reduce_bytes"]
    if tree_b >= flat_b:
        raise SystemExit(
            f"engine smoke FAILED: tree reduce moves {tree_b} bytes vs "
            f"flat {flat_b} (expected strictly fewer — the staged "
            "reduce is not communication-avoiding)"
        )
    one_b = co["broadcast"]["onehot"]["coll_broadcast_bytes"]
    chain_b = co["broadcast"]["chain"]["coll_broadcast_bytes"]
    if chain_b > one_b:
        raise SystemExit(
            f"engine smoke FAILED: chain broadcast moves {chain_b} "
            f"bytes vs one-hot {one_b} (expected no more)"
        )
    tree_t = co["reduce"]["tree"]["tct_seconds"]
    flat_t = co["reduce"]["flat"]["tct_seconds"]
    if tree_t > flat_t * COMPACT_REGRESSION_SLACK:
        # same noise policy as the compaction guard: one re-measure
        co2 = collectives_fixture()
        tree_t = min(tree_t, co2["reduce"]["tree"]["tct_seconds"])
        flat_t = max(flat_t, co2["reduce"]["flat"]["tct_seconds"])
        if tree_t > flat_t * COMPACT_REGRESSION_SLACK:
            raise SystemExit(
                f"engine smoke FAILED: tree reduction tct {tree_t:.4f}s "
                f"regresses vs flat psum {flat_t:.4f}s "
                f"(slack {COMPACT_REGRESSION_SLACK}x)"
            )
    print(
        f"# collectives smoke ok: tree reduce {tree_b} < flat {flat_b} "
        f"bytes ({tree_t:.4f}s vs {flat_t:.4f}s), chain broadcast "
        f"{chain_b} <= one-hot {one_b} bytes"
    )
    hs = hubsplit_fixture()
    for graph, cell in hs["graphs"].items():
        rb_mcp = cell["rebalance_only"]["masked_critical_path"]
        hub_mcp = cell["hub_split"]["masked_critical_path"]
        if hub_mcp * HUB_MCP_GAIN > rb_mcp:
            raise SystemExit(
                f"engine smoke FAILED: hub-split residual masked "
                f"critical path {hub_mcp} on {graph} does not beat "
                f"rebalance-only {rb_mcp} by {HUB_MCP_GAIN}x — the hub "
                "stage is no longer pulling the tail off the 2D path"
            )
        print(
            f"# hubsplit smoke ok: {graph} mcp {rb_mcp} -> {hub_mcp} "
            f"({rb_mcp / max(1.0, hub_mcp):.1f}x, "
            f"{cell['hub_split']['hub_rows']} hub rows), counts agree"
        )
    return bs


def run(quick: bool = False, out: str = "BENCH_engine.json") -> dict:
    scales = SCALES_QUICK if quick else SCALES_FULL
    report = {
        "grid": GRID,
        "ranks": GRID * GRID,
        "unix_time": time.time(),
        "quick": quick,
        "schedules": {s: {} for s in SCHEDULES},
    }
    for scale in scales:
        graph = f"rmat:{scale}"
        for sched in SCHEDULES:
            r = run_tc_subprocess(graph, GRID, schedule=sched)
            cell = _cell(r)
            report["schedules"][sched][str(scale)] = cell
            print(
                csv_row(
                    f"engine/{sched}/rmat{scale}",
                    r["tct_seconds"] * 1e6,
                    f"triangles={r['triangles']}",
                )
            )
        counts = {
            report["schedules"][s][str(scale)]["triangles"] for s in SCHEDULES
        }
        assert len(counts) == 1, f"schedules disagree at scale {scale}: {counts}"
    report["block_sparse"] = block_sparse_fixture()
    report["autotune"] = autotune_fixture()
    report["hubsplit"] = hubsplit_fixture()
    report["collectives"] = collectives_fixture()
    from .kernels import fused_fixture

    report["fused"] = fused_fixture()
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")
    return report


def main(quick: bool = False, out: str = "BENCH_engine.json"):
    return run(quick=quick, out=out)


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = "BENCH_engine.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    if "--smoke" in argv:
        smoke()
    else:
        main(quick="--quick" in argv or "--full" not in argv, out=out)
