"""Paper Fig. 1: parallel efficiency (q0^2·T_q0 / p·T_p) for ppt/tct."""
from __future__ import annotations

import sys

from .common import csv_row
from .table2_scaling import run as run_table2


def main(quick=False):
    rows = run_table2(quick=quick)
    p0, t0_ppt, t0_tct = (
        rows[0]["ranks"],
        rows[0]["ppt"],
        rows[0]["tct"],
    )
    out = []
    for r in rows:
        p = r["ranks"]
        eff_ppt = (p0 * t0_ppt) / (p * r["ppt"])
        eff_tct = (p0 * t0_tct) / (p * r["tct"])
        out.append((p, eff_ppt, eff_tct))
        print(
            csv_row(
                f"fig1/ranks{p}",
                0.0,
                f"eff_ppt={eff_ppt:.3f};eff_tct={eff_tct:.3f}",
            )
        )
    return out


if __name__ == "__main__":
    main("--quick" in sys.argv)
