"""Paper Fig. 2: operation rate (kOps/s) of the tct phase across ranks.

"Ops" = the paper's probe count — we use the plan's exact per-device probe
work (sum over shifts of min-fragment lengths) divided by measured tct
wall time."""
from __future__ import annotations

import sys

from .common import csv_row, run_tc_subprocess


def main(quick=False):
    from repro.core import build_plan, preprocess, rmat

    scale = 11 if quick else 13
    g, _ = preprocess(rmat(scale, 16))
    grids = (1, 2) if quick else (1, 2, 3, 4)
    out = []
    for q in grids:
        plan = build_plan(g, q)
        ops = float(plan.stats.probe_work_per_device_shift.sum())
        r = run_tc_subprocess(f"rmat:{scale}", q)
        rate = ops / max(r["tct_seconds"], 1e-9) / 1e3
        out.append((q * q, rate))
        print(
            csv_row(
                f"fig2/ranks{q*q}",
                r["tct_seconds"] * 1e6,
                f"kops_per_s={rate:.0f}",
            )
        )
    return out


if __name__ == "__main__":
    main("--quick" in sys.argv)
