"""Paper Fig. 3: fraction of runtime attributable to communication.

Derived from the roofline terms of the compiled program at each grid size:
comm_fraction = t_collective / (t_collective + max(t_compute, t_memory)) —
the same quantity the paper measures by timing MPI calls, here from the
loop-aware HLO parse (per-shift blob bytes x shifts / ICI bw)."""
from __future__ import annotations

import sys

from .common import csv_row


_CODE = """
import json
from repro.core import build_plan, preprocess, rmat
from repro.core.api import get_schedule, make_grid_mesh
from repro.launch.roofline import HW, hlo_cost
build_cannon_fn = get_schedule("cannon").build_fn

g, _ = preprocess(rmat({scale}, 16))
plan = build_plan(g, {q})
fn = build_cannon_fn(plan, make_grid_mesh({q}))
comp = fn.lower(**plan.shape_structs()).compile()
cost = hlo_cost(comp.as_text())
t_coll = sum(cost["collectives"].values()) / HW["link_bw"]
t_mem = cost["bytes"] / HW["hbm_bw"]
print(json.dumps({{"frac": t_coll / max(t_coll + t_mem, 1e-12)}}))
"""


def main(quick=False):
    import json

    from .common import run_py_subprocess

    scale = 11 if quick else 13
    out = []
    for q in (2,) if quick else (2, 3, 4):
        r = json.loads(
            run_py_subprocess(_CODE.format(scale=scale, q=q), ndev=q * q)
            .strip()
            .splitlines()[-1]
        )
        out.append((q * q, r["frac"]))
        print(
            csv_row(f"fig3/ranks{q*q}", 0.0, f"comm_fraction={r['frac']:.3f}")
        )
    return out


if __name__ == "__main__":
    main("--quick" in sys.argv)
