"""Kernel microbenchmark: tc_tile popcount vs MXU vs jnp ref (interpret
mode timing on CPU is directional only; the BlockSpec/VMEM structure is
what the TPU target consumes), plus the fused-vs-search2-vs-tile
count-kernel comparison on the dense-ish block fixture.

    python -m benchmarks.kernels [--quick]
    python -m benchmarks.kernels --smoke   # CI guard: fails if the fused
        kernel miscounts on the fixture or its warm count-side tct
        regresses more than FUSED_REGRESSION_SLACK vs search2
"""
from __future__ import annotations

import sys
import tempfile

import jax
import jax.numpy as jnp

from .common import csv_row, run_tc_subprocess, timeit

# dense-ish block fixture: every block-pair task is a real clique
# intersection, so the short bucket dominates and the fused panel is on
# its home turf (the same fixture engine_baseline uses for the skip A/B)
FUSED_GRAPH = "cliques:3,60"
# fused warm tct must not exceed search2's by more than this (both are
# min-over-warm dispatch times; small slack absorbs host timer noise)
FUSED_REGRESSION_SLACK = 1.05


def main(quick=False):
    from repro.kernels.tc_tile.ops import tile_pair_count
    from repro.kernels.tc_tile.ref import tile_triple_counts_ref

    nt, ntr = (4, 8) if quick else (16, 64)
    ka, kb, km = jax.random.split(jax.random.key(0), 3)
    A = jax.random.bits(ka, (nt, 128, 4), dtype=jnp.uint32)
    B = jax.random.bits(kb, (nt, 128, 4), dtype=jnp.uint32)
    M = jax.random.bits(km, (nt, 128, 4), dtype=jnp.uint32)
    trips = jnp.concatenate(
        [
            jax.random.randint(jax.random.key(1), (ntr, 3), 0, nt),
            jnp.ones((ntr, 1), jnp.int32),
        ],
        axis=1,
    ).astype(jnp.int32)

    rows = []
    for mode in ("popcount", "mxu"):
        t = timeit(
            lambda: tile_pair_count(
                trips, A, B, M, mode=mode, interpret=True
            ).block_until_ready()
        )
        rows.append((f"kernels/tc_tile_{mode}", t * 1e6))
    t = timeit(
        lambda: jnp.sum(
            tile_triple_counts_ref(trips, A, B, M)
        ).block_until_ready()
    )
    rows.append(("kernels/tc_tile_ref", t * 1e6))
    for name, us in rows:
        print(csv_row(name, us, f"triples={ntr}"))
    fused_fixture(repeat=3 if quick else 5)
    return rows


def fused_fixture(
    graph: str = FUSED_GRAPH,
    grid: int = 1,
    table_dir: "str | None" = None,
    repeat: int = 5,
) -> dict:
    """Warm count-side tct of the three count kernels on the dense-ish
    fixture, every run oracle-verified in its subprocess:

    * ``fused``   — the Pallas mega-kernel with its tile shape selected
      by the measured-autotune table (``--autotune measured``; the first
      run pays the cold timing pass, the table persists in
      ``table_dir``);
    * ``search2`` — the two-level bucketed search (the incumbent);
    * ``tile``    — the bit-packed 128x128 tile join.
    """
    table_dir = table_dir or tempfile.mkdtemp(prefix="tc_measured_bench_")
    runs = {
        "fused": ("--autotune", "measured", "--measured-dir", table_dir),
        "search2": (),
        "tile": (),
    }
    out = {"graph": graph, "grid": grid}
    counts = {}
    for name, extra in runs.items():
        r = run_tc_subprocess(
            graph, grid, method=name,
            extra=("--verify", "--repeat", str(repeat)) + extra,
        )
        counts[name] = r["triangles"]
        cell = dict(
            tct_seconds=r["tct_seconds"],
            triangles=r["triangles"],
            method=r["method"],
        )
        for key in ("autotune_mode", "measured_table_hit",
                    "autotuned_d_small", "autotuned_chunk"):
            if key in r:
                cell[key] = r[key]
        out[name] = cell
        print(csv_row(f"kernels/fused_fixture/{name}",
                      r["tct_seconds"] * 1e6,
                      f"triangles={r['triangles']}"))
    assert len(set(counts.values())) == 1, (
        f"count kernels disagree on {graph}: {counts}"
    )
    return out


def fused_smoke() -> dict:
    """CI guard: the fused kernel must count the fixture correctly
    (asserted via --verify inside each subprocess plus cross-kernel
    agreement) and must not regress vs search2 beyond the slack."""
    table_dir = tempfile.mkdtemp(prefix="tc_measured_smoke_")
    fx = fused_fixture(table_dir=table_dir)
    fused_t = fx["fused"]["tct_seconds"]
    search2_t = fx["search2"]["tct_seconds"]
    if fused_t > search2_t * FUSED_REGRESSION_SLACK:
        # single-host wall times on shared CI machines are noisy; one
        # re-measure (warm measured table) before declaring a regression
        fx2 = fused_fixture(table_dir=table_dir)
        fused_t = min(fused_t, fx2["fused"]["tct_seconds"])
        search2_t = max(search2_t, fx2["search2"]["tct_seconds"])
        if fused_t > search2_t * FUSED_REGRESSION_SLACK:
            raise SystemExit(
                f"kernels smoke FAILED: fused tct {fused_t:.4f}s "
                f"regresses vs search2 {search2_t:.4f}s on "
                f"{fx['graph']} (slack {FUSED_REGRESSION_SLACK}x)"
            )
    print(
        f"# kernels smoke ok: fused {fused_t:.4f}s vs search2 "
        f"{search2_t:.4f}s vs tile {fx['tile']['tct_seconds']:.4f}s on "
        f"{fx['graph']}, all kernels agree "
        f"({fx['fused']['triangles']} triangles)"
    )
    return fx


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        fused_smoke()
    else:
        main("--quick" in sys.argv)
