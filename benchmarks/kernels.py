"""Kernel microbenchmark: tc_tile popcount vs MXU vs jnp ref (interpret
mode timing on CPU is directional only; the BlockSpec/VMEM structure is
what the TPU target consumes)."""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from .common import csv_row, timeit


def main(quick=False):
    from repro.kernels.tc_tile.ops import tile_pair_count
    from repro.kernels.tc_tile.ref import tile_triple_counts_ref

    nt, ntr = (4, 8) if quick else (16, 64)
    ka, kb, km = jax.random.split(jax.random.key(0), 3)
    A = jax.random.bits(ka, (nt, 128, 4), dtype=jnp.uint32)
    B = jax.random.bits(kb, (nt, 128, 4), dtype=jnp.uint32)
    M = jax.random.bits(km, (nt, 128, 4), dtype=jnp.uint32)
    trips = jnp.concatenate(
        [
            jax.random.randint(jax.random.key(1), (ntr, 3), 0, nt),
            jnp.ones((ntr, 1), jnp.int32),
        ],
        axis=1,
    ).astype(jnp.int32)

    rows = []
    for mode in ("popcount", "mxu"):
        t = timeit(
            lambda: tile_pair_count(
                trips, A, B, M, mode=mode, interpret=True
            ).block_until_ready()
        )
        rows.append((f"kernels/tc_tile_{mode}", t * 1e6))
    t = timeit(
        lambda: jnp.sum(
            tile_triple_counts_ref(trips, A, B, M)
        ).block_until_ready()
    )
    rows.append(("kernels/tc_tile_ref", t * 1e6))
    for name, us in rows:
        print(csv_row(name, us, f"triples={ntr}"))
    return rows


if __name__ == "__main__":
    main("--quick" in sys.argv)
