"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun.jsonl (run after benchmarks/dryrun_sweep.py)."""
import json
import sys


def fmt_t(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def main(path="results/dryrun.jsonl"):
    rows = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("status") == "ok":
            rows[r["name"]] = r

    def emit(names, title):
        print(f"\n### {title}\n")
        print(
            "| cell | mesh | t_compute | t_memory | t_collective |"
            " bottleneck | useful_frac | HBM/device |"
        )
        print("|---|---|---|---|---|---|---|---|")
        for n in names:
            r = rows.get(n)
            if not r:
                print(f"| {n} | — | missing | | | | | |")
                continue
            mem = r.get("memory_per_device") or {}
            hbm = (
                mem.get("args", 0)
                + mem.get("outputs", 0)
                + mem.get("temps", 0)
                - mem.get("aliased", 0)
            )
            print(
                f"| {n.rsplit(':',1)[0]} | {r['mesh']} |"
                f" {fmt_t(r['t_compute'])} | {fmt_t(r['t_memory'])} |"
                f" {fmt_t(r['t_collective'])} | {r['bottleneck']} |"
                f" {r.get('useful_fraction', 0):.3f} | {fmt_b(hbm)} |"
            )

    lm = ["chatglm3-6b", "qwen2-0.5b", "qwen1.5-110b", "grok-1-314b",
          "deepseek-v3-671b"]
    lm_shapes = ["train_4k", "prefill_32k", "decode_32k"]
    gnn = ["nequip", "graphcast", "gat-cora", "equiformer-v2"]
    gnn_shapes = ["full_graph_sm", "minibatch_lg", "ogb_products", "molecule"]
    rec_shapes = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]
    tc = ["tc-twitter", "tc-friendster", "tc-g500-s26", "tc-g500-s27",
          "tc-g500-s28", "tc-g500-s29"]

    for mesh in ("pod", "multipod"):
        emit(
            [f"{a}:{s}:{mesh}" for a in lm for s in lm_shapes],
            f"LM family — {mesh} ({256 if mesh=='pod' else 512} chips)",
        )
        emit(
            [f"{a}:{s}:{mesh}" for a in gnn for s in gnn_shapes],
            f"GNN family — {mesh}",
        )
        emit(
            [f"dlrm-mlperf:{s}:{mesh}" for s in rec_shapes],
            f"recsys — {mesh}",
        )
    emit(
        [f"{g}:{s}:{'multipod' if s=='cannon25d' else 'pod'}"
         for g in tc
         for s in ("cannon", "cannonopt", "cannon2l", "cannon25d", "oned")],
        "Triangle counting — paper graphs (2D Cannon / +H1b blob-compress /"
        " +H1a bucketed / 2.5D multi-pod / 1D baseline)",
    )


if __name__ == "__main__":
    main(*sys.argv[1:])
