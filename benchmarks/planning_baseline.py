"""Planning benchmark baseline: cold plan vs warm cache, batched vs loop.

Records ``BENCH_planning.json``:

* ``planning`` — per-RMAT-scale (12-16) cold pipeline plan wall time vs
  warm (cache-hit) re-plan, and the speedup (acceptance: warm >= 10x);
* ``batch`` — a 4-graph mixed batch through ``count_triangles_many``
  (one compiled call, then a warm cached round) vs the per-graph
  ``count_triangles`` loop, with exact-match verification of the counts
  and the measured batched-padding overhead (DESIGN.md §10.5).

    python -m benchmarks.planning_baseline [--smoke] [--out BENCH_planning.json]

``--smoke`` runs scale 12 only and *fails* (exit 1) if the warm-cache
speedup drops below 10x or the batched counts diverge — the CI guard
against planning regressions.
"""
from __future__ import annotations

import json
import sys
import time

GRID = 3  # planning grid (q x q blocks; planning is host-side, no devices)
SCALES_FULL = [12, 13, 14, 15, 16]
SCALES_SMOKE = [12]
WARM_REPS = 5
MIN_WARM_SPEEDUP = 10.0


def _time_planning(scale: int) -> dict:
    from repro.core import rmat
    from repro.pipeline import PlanCache, plan_cannon

    g = rmat(scale)
    cache = PlanCache()
    t0 = time.perf_counter()
    art = plan_cannon(g, GRID, cache=cache)
    cold = time.perf_counter() - t0

    warm = float("inf")
    for _ in range(WARM_REPS):
        t0 = time.perf_counter()
        hit = plan_cannon(g, GRID, cache=cache)
        warm = min(warm, time.perf_counter() - t0)
    assert hit is art and hit.cache_hit
    return dict(
        n=g.n,
        m=g.m,
        cold_seconds=round(cold, 6),
        warm_seconds=round(warm, 6),
        warm_speedup=round(cold / max(warm, 1e-9), 1),
        stage_seconds={k: round(v, 6) for k, v in art.stage_seconds.items()},
    )


def _time_batch() -> dict:
    from repro.core import (
        count_triangles,
        named_graph,
        rmat,
        triangle_count_oracle,
    )
    from repro.pipeline import PlanCache, count_triangles_many

    graphs = [rmat(10, seed=s) for s in range(3)] + [named_graph("karate")]
    expected = [triangle_count_oracle(g) for g in graphs]

    t0 = time.perf_counter()
    loop = [
        count_triangles(g, q=1, cache=PlanCache(maxsize=0)).triangles
        for g in graphs
    ]
    loop_seconds = time.perf_counter() - t0

    cache = PlanCache()
    t0 = time.perf_counter()
    res = count_triangles_many(graphs, q=1, cache=cache)
    batched_seconds = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = count_triangles_many(graphs, q=1, cache=cache)
    warm_seconds = time.perf_counter() - t0

    matches = bool(res.triangles == loop == expected and
                   warm.triangles == expected)
    return dict(
        batch=len(graphs),
        graphs=[g.name for g in graphs],
        triangles=res.triangles,
        matches_individual=matches,
        loop_seconds=round(loop_seconds, 4),
        batched_seconds=round(batched_seconds, 4),
        batched_warm_seconds=round(warm_seconds, 4),
        batched_speedup_vs_loop=round(
            loop_seconds / max(batched_seconds, 1e-9), 2
        ),
        warm_cache_hit=bool(warm.cache_hit),
        padding_overhead=round(res.padding_overhead, 4),
    )


def run(smoke: bool = False, out: str = "BENCH_planning.json") -> dict:
    scales = SCALES_SMOKE if smoke else SCALES_FULL
    report = {
        "grid": GRID,
        "unix_time": time.time(),
        "smoke": smoke,
        "planning": {},
    }
    for scale in scales:
        cell = _time_planning(scale)
        report["planning"][str(scale)] = cell
        print(
            f"planning/rmat{scale},cold={cell['cold_seconds']*1e3:.1f}ms,"
            f"warm={cell['warm_seconds']*1e6:.0f}us,"
            f"speedup={cell['warm_speedup']}x"
        )
    report["batch"] = _time_batch()
    print(
        f"batch/loop={report['batch']['loop_seconds']}s,"
        f"batched={report['batch']['batched_seconds']}s,"
        f"warm={report['batch']['batched_warm_seconds']}s,"
        f"matches={report['batch']['matches_individual']}"
    )
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")

    failures = []
    for scale, cell in report["planning"].items():
        if cell["warm_speedup"] < MIN_WARM_SPEEDUP:
            failures.append(
                f"warm-cache speedup at rmat{scale} is "
                f"{cell['warm_speedup']}x < {MIN_WARM_SPEEDUP}x"
            )
    if not report["batch"]["matches_individual"]:
        failures.append("batched counts diverge from per-graph counts")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    return report


def main(smoke: bool = False, out: str = "BENCH_planning.json"):
    return run(smoke=smoke, out=out)


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = "BENCH_planning.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    main(smoke="--smoke" in argv, out=out)
