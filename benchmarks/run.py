"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Defaults to --quick scales
on this CPU box; ``--full`` reproduces the EXPERIMENTS.md settings.
"""
import sys


def main() -> None:
    quick = "--full" not in sys.argv
    from . import (
        engine_baseline,
        fig1_efficiency,
        fig2_oprate,
        fig3_commfraction,
        kernels,
        planning_baseline,
        table2_scaling,
        table3_imbalance,
        table4_taskgrowth,
        table56_vs1d,
    )

    print("name,us_per_call,derived")
    table2_scaling.main(quick=quick)
    table3_imbalance.main(quick=quick)
    table4_taskgrowth.main(quick=quick)
    table56_vs1d.main(quick=quick)
    fig1_efficiency.main(quick=quick)
    fig2_oprate.main(quick=quick)
    fig3_commfraction.main(quick=quick)
    kernels.main(quick=quick)
    # per-schedule wall-time baseline -> BENCH_engine.json
    engine_baseline.main(quick=quick)
    # cold/warm planning + batched-vs-loop -> BENCH_planning.json
    planning_baseline.main(smoke=quick)


if __name__ == "__main__":
    main()
