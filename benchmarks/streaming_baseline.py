"""Streaming benchmark baseline: incremental delta re-plan vs cold re-plan.

Records ``BENCH_streaming.json``:

* ``localized`` — the streaming workload the splice path is built for
  (DESIGN.md §4.7): a 1%-of-edges delta confined to two residue classes
  of the 2D-cyclic decomposition, so only a handful of the ``q x q``
  blocks dirty.  Reports delta-apply vs cold-re-plan wall time, the
  dirty block/cell fractions, and plan parity (every spliced array
  byte-identical to a cold re-pack of the mutated graph under the same
  σ — byte-identical plans count byte-identically);
* ``uniform`` — the honest adversarial row: the same edge budget spread
  uniformly at random dirties most blocks and falls back to the repack
  ladder rung, so its speedup is structural (skipped σ search /
  relabel / digest), not proportional to the dirty fraction;
* ``count_parity`` — a small-fixture device check: streaming counts
  through ``count_triangles_delta`` match the host oracle exactly.

    python -m benchmarks.streaming_baseline [--smoke] [--out BENCH_streaming.json]

``--smoke`` is the CI guard: it *fails* (exit 1) on any parity/count
mismatch or if the localized 1% delta re-plan is not >= 5x faster than
the cold re-plan.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

N = 4096
AVG_DEGREE = 24
GRID = 8  # q x q planning grid (host-side; no devices needed)
RESIDUES = (1, 3)  # delta edges confined to these classes mod GRID
DELTA_FRACTION = 0.01
COLD_REPS = 3
DELTA_REPS = 5
MIN_SPEEDUP = 5.0

_ARRAYS = (
    "a_indptr", "a_indices", "b_indptr", "b_indices",
    "m_ti", "m_tj", "m_cnt",
)


def _localized_flips(g, k: int, seed: int):
    """k deterministic edge flips with both endpoints in RESIDUES mod
    GRID — every flip lands in one of ``len(RESIDUES)^2`` blocks of the
    q x q decomposition, the block-local shape of a streaming update."""
    from repro.pipeline import EdgeDelta

    rng = np.random.default_rng(seed)
    lo, hi = np.minimum(g.edges[:, 0], g.edges[:, 1]), np.maximum(
        g.edges[:, 0], g.edges[:, 1]
    )
    base = set((lo * g.n + hi).tolist())
    classes = np.concatenate(
        [np.arange(r, g.n, GRID) for r in RESIDUES]
    )
    add, remove, seen = [], [], set()
    while len(add) + len(remove) < k:
        u, v = rng.choice(classes, size=2, replace=False)
        u, v = (int(u), int(v)) if u < v else (int(v), int(u))
        key = u * g.n + v
        if key in seen:
            continue
        seen.add(key)
        (remove if key in base else add).append((u, v))
    return EdgeDelta(add=add, remove=remove)


def _uniform_flips(g, k: int, seed: int):
    from repro.pipeline import EdgeDelta

    add, remove = __import__(
        "repro.core.generators", fromlist=["random_edge_flips"]
    ).random_edge_flips(g, k, seed=seed)
    return EdgeDelta(add=add, remove=remove)


def _plan_parity(plan, ref) -> bool:
    for name in _ARRAYS:
        if not np.array_equal(getattr(plan, name), getattr(ref, name)):
            return False
    if (ref.step_keep is None) != (plan.step_keep is None):
        return False
    if ref.step_keep is not None and not np.array_equal(
        plan.step_keep, ref.step_keep
    ):
        return False
    return True


def _time_delta(g, art, delta, label: str) -> dict:
    from repro.pipeline import PlanCache, apply_delta, plan_cannon
    from repro.pipeline.stages import pack_tc_plan

    cold = float("inf")
    for _ in range(COLD_REPS):
        t0 = time.perf_counter()
        cold_art = plan_cannon(
            delta.apply_to(g), GRID, reorder=False,
            cache=PlanCache(maxsize=0),
        )
        cold = min(cold, time.perf_counter() - t0)

    inc = float("inf")
    for _ in range(DELTA_REPS):
        t0 = time.perf_counter()
        art2 = apply_delta(art, delta, cache=PlanCache(maxsize=0))
        inc = min(inc, time.perf_counter() - t0)
    rep = art2.delta_report

    # parity vs a cold re-pack under the *kept* σ: byte-identical plan
    # arrays make count parity structural rather than sampled
    ref = pack_tc_plan(
        art2.graph, GRID, skew_perm=art2.plan.skew_perm, keep_blocks=True
    )
    parity = _plan_parity(art2.plan, ref)
    # and the cold driver agrees on totals (its σ may differ, so compare
    # schedule-invariant aggregates, not raw arrays)
    cold_tasks = cold_art.plan.stats.intersection_tasks_total
    parity = parity and (
        cold_tasks == art2.plan.stats.intersection_tasks_total
    )
    return dict(
        label=label,
        edges_flipped=int(delta.k),
        level=rep["level"],
        dirty_blocks=rep["dirty_blocks"],
        dirty_block_fraction=rep["dirty_block_fraction"],
        dirty_cells=rep["dirty_cells"],
        dirty_cell_fraction=rep["dirty_cell_fraction"],
        replanned_stages=rep["replanned_stages"],
        cold_replan_seconds=round(cold, 6),
        delta_replan_seconds=round(inc, 6),
        speedup=round(cold / max(inc, 1e-9), 1),
        plan_parity=bool(parity),
    )


def _count_parity() -> dict:
    """Small-fixture device check: streaming counts are exact."""
    from repro.core import (
        count_triangles_delta,
        graph_from_spec,
        triangle_count_oracle,
    )
    from repro.pipeline import EdgeDelta, PlanCache

    g = graph_from_spec("er:300,8,3")
    cache = PlanCache(maxsize=8)
    art, ok, rounds = None, True, []
    for i in range(3):
        d = EdgeDelta.random_flips(g, 6, seed=20 + i)
        res = count_triangles_delta(g, d, q=1, artifact=art, cache=cache)
        g = d.apply_to(g)
        exp = triangle_count_oracle(g)
        ok = ok and res.triangles == exp
        rounds.append(dict(
            round=i, triangles=res.triangles, expected=exp,
            level=res.delta["level"],
        ))
        art = res.artifact
    return dict(exact=bool(ok), rounds=rounds)


def run(smoke: bool = False, out: str = "BENCH_streaming.json") -> dict:
    from repro.core import graph_from_spec
    from repro.pipeline import PlanCache, plan_cannon

    g = graph_from_spec(f"er:{N},{AVG_DEGREE},2")
    k = max(1, int(round(g.m * DELTA_FRACTION)))
    # the base artifact plans with reorder=False: streaming deltas are
    # residue-localized in *original* vertex ids, and the identity
    # relabeling keeps them block-local under the cyclic decomposition
    art = plan_cannon(g, GRID, reorder=False, cache=PlanCache(maxsize=2))

    report = {
        "graph": f"er:{N},{AVG_DEGREE},2",
        "n": g.n,
        "m": g.m,
        "grid": GRID,
        "delta_fraction": DELTA_FRACTION,
        "unix_time": time.time(),
        "smoke": smoke,
    }
    loc = _time_delta(g, art, _localized_flips(g, k, seed=7), "localized")
    report["localized"] = loc
    print(
        f"localized/{loc['edges_flipped']}flips,level={loc['level']},"
        f"dirty={loc['dirty_blocks']}/{GRID * GRID},"
        f"cold={loc['cold_replan_seconds'] * 1e3:.1f}ms,"
        f"delta={loc['delta_replan_seconds'] * 1e3:.1f}ms,"
        f"speedup={loc['speedup']}x,parity={loc['plan_parity']}"
    )
    uni = _time_delta(g, art, _uniform_flips(g, k, seed=7), "uniform")
    report["uniform"] = uni
    print(
        f"uniform/{uni['edges_flipped']}flips,level={uni['level']},"
        f"dirty={uni['dirty_blocks']}/{GRID * GRID},"
        f"speedup={uni['speedup']}x,parity={uni['plan_parity']}"
    )
    report["count_parity"] = _count_parity()
    print(f"count_parity/exact={report['count_parity']['exact']}")

    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")

    failures = []
    if loc["level"] != "splice":
        failures.append(
            f"localized delta fell off the splice path ({loc['level']})"
        )
    if loc["speedup"] < MIN_SPEEDUP:
        failures.append(
            f"localized delta re-plan speedup {loc['speedup']}x < "
            f"{MIN_SPEEDUP}x vs cold re-plan"
        )
    for row in (loc, uni):
        if not row["plan_parity"]:
            failures.append(f"{row['label']} delta plan diverges from "
                            "the cold re-pack")
    if not report["count_parity"]["exact"]:
        failures.append("streaming counts diverge from the host oracle")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    return report


def main(smoke: bool = False, out: str = "BENCH_streaming.json"):
    return run(smoke=smoke, out=out)


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = "BENCH_streaming.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    main(smoke="--smoke" in argv, out=out)
