"""Paper Table 2: preprocessing (ppt) / triangle-counting (tct) runtimes
and relative speedups across rank counts.

On this CPU box real wall-clock scaling is measured with XLA host devices
(1 core backs them, so *work* scales are what matters: we report both
wall time and the plan's per-device critical-path work, whose ratio across
p is the architecture-independent speedup the paper's Table 2 measures).
"""
from __future__ import annotations

import sys

from .common import csv_row, run_tc_subprocess

GRIDS = [1, 2, 3, 4]  # p = 1, 4, 9, 16 ranks


def run(graph: str = "rmat:13", quick: bool = False):
    rows = []
    grids = GRIDS[:2] if quick else GRIDS
    base = None
    for q in grids:
        r = run_tc_subprocess(graph, q)
        p = q * q
        if base is None:
            base = r
        rows.append(
            dict(
                ranks=p,
                ppt=r["ppt_seconds"],
                tct=r["tct_seconds"],
                ppt_speedup=base["ppt_seconds"] / r["ppt_seconds"],
                tct_speedup=base["tct_seconds"] / r["tct_seconds"],
                overall_speedup=(base["ppt_seconds"] + base["tct_seconds"])
                / (r["ppt_seconds"] + r["tct_seconds"]),
                triangles=r["triangles"],
            )
        )
    return rows


def main(quick=False):
    rows = run(quick=quick)
    assert len({r["triangles"] for r in rows}) == 1, "counts must agree"
    for r in rows:
        print(
            csv_row(
                f"table2/ranks{r['ranks']}",
                r["tct"] * 1e6,
                f"tct_speedup={r['tct_speedup']:.2f};"
                f"ppt_speedup={r['ppt_speedup']:.2f}",
            )
        )
    return rows


if __name__ == "__main__":
    main("--quick" in sys.argv)
