"""Paper Table 3: per-shift load imbalance (max/avg) on 25 and 36 ranks —
computed from the plan's per-device per-shift probe work, in both the
*unmasked* (all steps) and *masked* (kept steps only — what the engine
executes with sparsity-aware step skipping) views, plus the beyond-paper
skip-aware rebalancer's masked-critical-path improvement.

``--smoke`` runs a small fixture through all three schedules and fails if
the rebalancer ever *increases* the masked critical path (CI guard for
the cost model / seed-0-baseline invariant).
"""
from __future__ import annotations

import sys

import numpy as np

from .common import csv_row


def _per_shift_imbalance(probe: np.ndarray, step_keep=None) -> float:
    """Mean over steps of (max / avg) per-device probe work."""
    kept = probe if step_keep is None else np.where(step_keep, probe, 0)
    flat = kept.reshape(-1, kept.shape[-1]).astype(np.float64)
    return float(np.mean(flat.max(axis=0) / np.maximum(flat.mean(axis=0), 1)))


def run(scale: int = 13, trials: int = 6):
    from repro.core import rmat
    from repro.pipeline import PlanCache, plan_cannon

    g = rmat(scale, 16)
    rows = []
    for q in (5, 6):  # p = 25, 36 as in the paper
        cache = PlanCache(maxsize=0)  # cold planning, nothing pinned
        plan = plan_cannon(g, q, keep_blocks=False, cache=cache).plan
        probe = plan.stats.probe_work_per_device_shift
        rb_art = plan_cannon(
            g, q, keep_blocks=False, rebalance_trials=trials, cache=cache
        )
        best = rb_art.plan
        rb = rb_art.rebalance
        probe_b = best.stats.probe_work_per_device_shift
        rows.append(
            dict(
                ranks=q * q,
                imbalance=_per_shift_imbalance(probe),
                masked_imbalance=_per_shift_imbalance(probe, plan.step_keep),
                task_imbalance=plan.stats.task_imbalance,
                rebalanced_imbalance=_per_shift_imbalance(probe_b),
                rebalanced_masked_imbalance=_per_shift_imbalance(
                    probe_b, best.step_keep
                ),
                masked_critical_path=rb["baseline_masked_critical_path"],
                rebalanced_masked_critical_path=rb[
                    "best_masked_critical_path"
                ],
                improvement=rb["improvement"],
                best_seed=rb["best_seed"],
                paper_reference=1.05 if q == 5 else 1.14,
            )
        )
    return rows


def smoke() -> int:
    """CI guard: on a skewed fixture, rebalance must never increase the
    masked critical path (seed 0 is the baseline, so best <= baseline by
    construction — a violation means the cost model or the seed-0
    identity regressed), and the winning relabel must preserve counts."""
    from repro.core import powerlaw, triangle_count_oracle
    from repro.pipeline import PlanCache, plan_cannon, plan_oned, plan_summa

    g = powerlaw(600, 2.2, seed=0)
    exp = triangle_count_oracle(g)
    cache = PlanCache(maxsize=0)
    planners = dict(
        cannon=lambda: plan_cannon(
            g, 3, keep_blocks=False, rebalance_trials=4, cache=cache
        ),
        summa=lambda: plan_summa(g, 2, 3, rebalance_trials=4, cache=cache),
        oned=lambda: plan_oned(g, 4, rebalance_trials=4, cache=cache),
    )
    failed = 0
    for name, planner in planners.items():
        art = planner()
        rb = art.rebalance
        best = rb["best_masked_critical_path"]
        base = rb["baseline_masked_critical_path"]
        got = triangle_count_oracle(art.graph)
        ok = best <= base and got == exp
        print(
            f"table3-smoke/{name}: baseline={base} best={best} "
            f"seed={rb['best_seed']} skipped={rb['skipped_steps']} "
            f"count={got}/{exp} {'OK' if ok else 'FAIL'}"
        )
        failed += not ok
    return failed


def main(quick=False):
    rows = run(scale=11 if quick else 13, trials=3 if quick else 6)
    for r in rows:
        print(
            csv_row(
                f"table3/ranks{r['ranks']}",
                0.0,
                f"imbalance={r['imbalance']:.3f};"
                f"masked={r['masked_imbalance']:.3f};"
                f"paper={r['paper_reference']};"
                f"rebalanced={r['rebalanced_imbalance']:.3f};"
                f"rebalanced_masked={r['rebalanced_masked_imbalance']:.3f};"
                f"mcp_improvement={r['improvement']:.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    main("--quick" in sys.argv)
