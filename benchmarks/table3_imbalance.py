"""Paper Table 3: per-shift load imbalance (max/avg) on 25 and 36 ranks —
computed from the plan's per-device per-shift probe work, plus the
beyond-paper rebalancer's improvement."""
from __future__ import annotations

import sys

import numpy as np

from .common import csv_row


def run(scale: int = 13, trials: int = 6):
    from repro.core import preprocess, rmat, build_plan
    from repro.runtime.rebalance import rebalance_plan

    g = rmat(scale, 16)
    g2, _ = preprocess(g)
    rows = []
    for q in (5, 6):  # p = 25, 36 as in the paper
        plan = build_plan(g2, q)
        probe = plan.stats.probe_work_per_device_shift
        per_shift = probe.reshape(q * q, q)
        imb_shift = float(
            np.mean(per_shift.max(axis=0) / np.maximum(per_shift.mean(axis=0), 1))
        )
        best, report = rebalance_plan(g, q, trials=trials)
        probe_b = best.stats.probe_work_per_device_shift.reshape(q * q, q)
        imb_best = float(
            np.mean(probe_b.max(axis=0) / np.maximum(probe_b.mean(axis=0), 1))
        )
        rows.append(
            dict(
                ranks=q * q,
                imbalance=imb_shift,
                task_imbalance=plan.stats.task_imbalance,
                rebalanced_imbalance=imb_best,
                paper_reference=1.05 if q == 5 else 1.14,
            )
        )
    return rows


def main(quick=False):
    rows = run(scale=11 if quick else 13, trials=3 if quick else 6)
    for r in rows:
        print(
            csv_row(
                f"table3/ranks{r['ranks']}",
                0.0,
                f"imbalance={r['imbalance']:.3f};paper={r['paper_reference']};"
                f"rebalanced={r['rebalanced_imbalance']:.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    main("--quick" in sys.argv)
