"""Paper Table 4: growth of map-intersection task count with rank count
(redundant work).  Paper measures +25% (16->25 ranks) and +20% (25->36)
on g500-s29; we measure the identical statistic on generated RMAT scales
and report growth percentages for direct comparison."""
from __future__ import annotations

import sys

from .common import csv_row


def run(scale: int = 13):
    from repro.core import build_plan, preprocess, rmat

    g, _ = preprocess(rmat(scale, 16))
    counts = {}
    for q in (4, 5, 6):  # p = 16, 25, 36 (paper's rank points)
        plan = build_plan(g, q)
        counts[q * q] = plan.stats.intersection_tasks_total
    growth = {
        "16->25": counts[25] / counts[16] - 1.0,
        "25->36": counts[36] / counts[25] - 1.0,
    }
    return counts, growth


def main(quick=False):
    counts, growth = run(scale=11 if quick else 13)
    for p, c in counts.items():
        print(csv_row(f"table4/ranks{p}", 0.0, f"tasks={c}"))
    print(
        csv_row(
            "table4/growth",
            0.0,
            f"g16_25={growth['16->25']*100:.0f}%;g25_36={growth['25->36']*100:.0f}%;"
            "paper=25%/20%",
        )
    )
    return counts, growth


if __name__ == "__main__":
    main("--quick" in sys.argv)
