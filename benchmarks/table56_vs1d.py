"""Paper Tables 5/6: the 2D algorithm vs 1D-decomposition baselines.

Two comparisons on the same device count p:
  * measured wall-clock (CPU host devices) cannon-2D vs 1D ring;
  * communication volume per device (the structural claim): 2D moves
    2·nnz/√p vs 1D's nnz — measured exactly from the loop-aware HLO
    collective-byte parse of both compiled programs.
"""
from __future__ import annotations

import sys

from .common import csv_row, run_tc_subprocess


def run(graph: str = "rmat:13", grid: int = 4):
    rows = {}
    for sched in ("cannon", "oned"):
        r = run_tc_subprocess(graph, grid, schedule=sched)
        rows[sched] = r
    speedup = rows["oned"]["tct_seconds"] / max(
        rows["cannon"]["tct_seconds"], 1e-9
    )
    return rows, speedup


_COMM_CODE = """
import json, jax
from repro.core import build_plan, preprocess, rmat
from repro.core.api import get_schedule, make_grid_mesh
from repro.core.onedim import build_oned_plan
from repro.launch.roofline import hlo_cost
from repro import compat
build_cannon_fn = get_schedule("cannon").build_fn
build_oned_fn = get_schedule("oned").build_fn

scale, q = {scale}, {grid}
g, _ = preprocess(rmat(scale, 16))
plan = build_plan(g, q)
fn = build_cannon_fn(plan, make_grid_mesh(q))
comp = fn.lower(**plan.shape_structs()).compile()
c2d = sum(hlo_cost(comp.as_text())["collectives"].values())
p = q * q
oplan = build_oned_plan(g, p)
mesh1 = compat.make_mesh((p,), ("flat",))
fn1 = build_oned_fn(oplan, mesh1)
comp1 = fn1.lower(**oplan.shape_structs()).compile()
c1d = sum(hlo_cost(comp1.as_text())["collectives"].values())
print(json.dumps({{"c2d": c2d, "c1d": c1d}}))
"""


def comm_volumes(scale: int = 12, grid: int = 4):
    """Collective bytes per device, 2D vs 1D, from compiled HLO
    (subprocess: needs grid^2 host devices)."""
    import json

    from .common import run_py_subprocess

    out = run_py_subprocess(
        _COMM_CODE.format(scale=scale, grid=grid), ndev=grid * grid
    )
    r = json.loads(out.strip().splitlines()[-1])
    return r["c2d"], r["c1d"]


def main(quick=False):
    graph = "rmat:12" if quick else "rmat:13"
    rows, speedup = run(graph=graph, grid=2 if quick else 4)
    print(
        csv_row(
            "table56/wallclock",
            rows["cannon"]["tct_seconds"] * 1e6,
            f"2d_vs_1d_speedup={speedup:.2f}",
        )
    )
    c2d, c1d = comm_volumes(scale=11 if quick else 12, grid=2 if quick else 4)
    print(
        csv_row(
            "table56/comm_bytes",
            0.0,
            f"bytes2d={c2d:.3g};bytes1d={c1d:.3g};ratio={c1d/max(c2d,1):.2f}",
        )
    )
    return rows, (c2d, c1d)


if __name__ == "__main__":
    main("--quick" in sys.argv)
