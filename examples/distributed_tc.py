"""End-to-end distributed triangle counting (the paper's application).

Spawns itself with 16 XLA host devices and runs the 4x4 Cannon grid, the
SUMMA rectangular schedule, the 2.5D two-pod variant, and the 1D baseline
on the same graph — all must agree with the oracle.

    PYTHONPATH=src python examples/distributed_tc.py
"""
import os
import subprocess
import sys

CHILD = """
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import count_triangles, rmat, triangle_count_oracle

g = rmat(12, 16, seed=3)
exp = triangle_count_oracle(g)
print(f"graph n={g.n} m={g.m} expected={exp}")

r = count_triangles(g, q=4, schedule="cannon")
print(f"cannon 4x4      : {r.triangles}  tct={r.count_seconds:.3f}s")
assert r.triangles == exp

r = count_triangles(g, q=2, npods=2, schedule="cannon")
print(f"2.5D 2x(2x2)    : {r.triangles}  tct={r.count_seconds:.3f}s")
assert r.triangles == exp

from repro import compat
mesh = compat.make_mesh((2, 8), ("data", "model"))
r = count_triangles(g, mesh=mesh, schedule="summa")
print(f"summa 2x8       : {r.triangles}  tct={r.count_seconds:.3f}s")
assert r.triangles == exp

r = count_triangles(g, q=4, schedule="oned")
print(f"1D baseline p=16: {r.triangles}  tct={r.count_seconds:.3f}s")
assert r.triangles == exp
print("all schedules agree ✓")
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    proc = subprocess.run([sys.executable, "-c", CHILD], env=env)
    sys.exit(proc.returncode)


if __name__ == "__main__":
    main()
