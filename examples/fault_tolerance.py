"""Fault-tolerance demo: kill the Cannon loop mid-run, resume from the
shift-level checkpoint, and still produce the exact count.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CKPT = "/tmp/repro_tc_ft_demo"


def run(extra, ndev=4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    cmd = [
        sys.executable, "-m", "repro.launch.tc_run",
        "--graph", "rmat:11,8", "--grid", "2",
        "--ckpt-dir", CKPT, "--verify", *extra,
    ]
    return subprocess.run(cmd, env=env, capture_output=True, text=True)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)

    print("run 1: failure injected at shift 1 (restores mid-loop) ...")
    p = run(["--fail-at-shift", "1"])
    print(p.stdout)
    assert p.returncode == 0, p.stderr[-500:]

    print("run 2: fresh run, then resume-from-checkpoint replay ...")
    shutil.rmtree(CKPT, ignore_errors=True)
    p = run([])
    assert p.returncode == 0, p.stderr[-500:]
    # resume again: checkpoint holds the final state; re-running verifies
    # restore path end-to-end (it resumes at shift q and just re-verifies)
    p = run([])
    print(p.stdout)
    assert p.returncode == 0, p.stderr[-500:]
    print("fault-tolerance demo passed ✓")


if __name__ == "__main__":
    main()
