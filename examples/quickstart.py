"""Quickstart: count triangles with the paper's 2D algorithm.

    PYTHONPATH=src python examples/quickstart.py

Runs the full pipeline (degree-order preprocess -> 2D-cyclic plan ->
Cannon schedule) on a generated Graph500 RMAT graph and verifies against
the exact host oracle.  On one device the grid degenerates to 1x1 but the
code path is identical to the 256-chip production mesh.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import count_triangles, rmat, triangle_count_oracle


def main():
    g = rmat(scale=12, edge_factor=16, seed=7)
    print(f"graph: {g.name}  n={g.n}  m={g.m}")

    res = count_triangles(g, q=1, schedule="cannon", method="search")
    print(f"triangles           : {res.triangles}")
    print(f"preprocess seconds  : {res.preprocess_seconds:.3f}")
    print(f"count seconds       : {res.count_seconds:.3f}")

    expected = triangle_count_oracle(g)
    assert res.triangles == expected, (res.triangles, expected)
    print(f"verified against host oracle: {expected} ✓")

    # the ⟨i,j,k⟩ probe direction (paper §3) gives the same count
    res2 = count_triangles(g, q=1, probe_shorter=False)
    assert res2.triangles == expected
    print("⟨j,i,k⟩ and ⟨i,j,k⟩ enumeration agree ✓")


if __name__ == "__main__":
    main()
