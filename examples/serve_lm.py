"""Serve a small LM with batched requests: prefill + KV-cached greedy
decode through the production serving path (per the paper's kind, the
primary end-to-end driver is distributed_tc.py; this exercises deliverable
(b)'s serving scenario on the LM family).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.steps import build_lm_decode_step
from repro.models.transformer import init_kv_cache, lm_init


def main():
    cfg = get_config("qwen2-0.5b-smoke")  # reduced dims, same architecture
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm_init(jax.random.key(0), cfg)
    decode, _ = build_lm_decode_step(cfg, mesh)

    batch, max_len, gen = 8, 64, 24
    cache = init_kv_cache(cfg, batch, max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(batch, 8)).astype(np.int32)

    # prefill via repeated decode (teacher-forcing the prompt tokens)
    cache_len = jnp.zeros((batch,), jnp.int32)
    tok = jnp.asarray(prompts[:, 0])
    for i in range(1, prompts.shape[1]):
        _, cache = decode(params, cache, tok, cache_len)
        cache_len = cache_len + 1
        tok = jnp.asarray(prompts[:, i])

    # timed batched greedy decode
    outs = []
    t0 = time.perf_counter()
    for _ in range(gen):
        tok, cache = decode(params, cache, tok, cache_len)
        cache_len = cache_len + 1
        outs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    toks = np.stack(outs, 1)
    print(f"generated {batch}x{gen} tokens in {dt:.2f}s "
          f"({batch*gen/dt:.0f} tok/s on CPU)")
    print("sample:", toks[0][:12])
    assert np.all(toks < cfg.vocab) and np.all(toks >= 0)
    print("ok ✓")


if __name__ == "__main__":
    main()
