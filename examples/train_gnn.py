"""Train a GAT on a synthetic Cora-like citation graph to convergence,
with checkpoints and restart-safe data state.

    PYTHONPATH=src python examples/train_gnn.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.ckpt import CheckpointManager
from repro.models.gnn_steps import build_gnn_train_step, gnn_init, gnn_loss


def synthetic_cora(rng, n=600, classes=7, d=64, intra=0.02, inter=0.002):
    """Stochastic block model + class-correlated features."""
    labels = rng.integers(0, classes, n)
    same = labels[:, None] == labels[None, :]
    p = np.where(same, intra, inter)
    adj = rng.random((n, n)) < p
    adj = np.triu(adj, 1)
    src, dst = np.nonzero(adj | adj.T)
    # self loops
    src = np.concatenate([src, np.arange(n)])
    dst = np.concatenate([dst, np.arange(n)])
    feats = 0.5 * rng.normal(size=(n, d))
    feats[:, :classes] += 2.5 * np.eye(classes)[labels]
    return feats, labels, src, dst


def main():
    rng = np.random.default_rng(0)
    cfg = get_config("gat-cora")  # the real 2-layer 8-head config
    feats, labels, src, dst = synthetic_cora(rng)
    n, d = feats.shape
    train_mask = (rng.random(n) < 0.6).astype(np.float32)

    batch = dict(
        feats=jnp.asarray(feats, jnp.float32),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        labels=jnp.asarray(labels, jnp.int32),
        label_mask=jnp.asarray(train_mask),
    )
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = gnn_init(jax.random.key(0), cfg, d)
    build, info = build_gnn_train_step(cfg, mesh, d)
    fn = build(jax.eval_shape(lambda: batch))
    opt = info["opt_init"](params)
    mgr = CheckpointManager("/tmp/repro_gat_ckpt", keep=2, async_save=False)

    loss0 = float(gnn_loss(params, cfg, batch)[0])
    for step in range(200):
        params, opt, m = fn(params, opt, batch, step)
        if step % 50 == 0:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}")
            mgr.save(step, {"params": params}, extra={"next_step": step + 1})

    from repro.models.gnn.gat import gat_apply

    logits = gat_apply(params, cfg, batch["feats"], batch["edge_src"], batch["edge_dst"])
    pred = np.asarray(jnp.argmax(logits, -1))
    test = train_mask < 0.5
    acc = float((pred[test] == labels[test]).mean())
    print(f"held-out accuracy: {acc:.3f} (loss {loss0:.3f} -> {float(m['loss']):.3f})")
    assert acc > 0.7, "GAT failed to learn the SBM task"
    print("ok ✓")


if __name__ == "__main__":
    main()
