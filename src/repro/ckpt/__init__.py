"""Fault-tolerant checkpointing (no orbax on this box — built from scratch).

* atomic writes: tmp file + fsync + rename, manifest with content hashes;
* keep-last-k rotation + an async writer thread (training never blocks on
  serialization);
* restore onto a *different* mesh: arrays are saved as global numpy with
  their PartitionSpec recorded; on load they are re-sharded for whatever
  mesh the (possibly re-planned, elastic) job now runs — Cannon state can
  resume as SUMMA state on a rectangular grid after device loss;
* TC shift-level resume: (shift index, per-device partial counts) lets a
  restarted job skip completed Cannon shifts.
"""
from .checkpoint import (  # noqa: F401
    load_checkpoint,
    save_checkpoint,
    latest_step,
    quarantine_step,
)
from .manager import CheckpointManager  # noqa: F401
