"""Atomic sharded checkpoint save/restore."""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "quarantine_step",
]


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree, *, extra: Optional[dict] = None):
    """Atomically save a pytree: npz payload + manifest with sha256."""
    os.makedirs(directory, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    payload_name = f"step_{step:010d}.npz"
    manifest_name = f"step_{step:010d}.json"

    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **{k.replace("/", "__"): v for k, v in flat.items()})
        f.flush()
        os.fsync(f.fileno())
    digest = hashlib.sha256(open(tmp, "rb").read()).hexdigest()
    os.replace(tmp, os.path.join(directory, payload_name))

    manifest = {
        "step": step,
        "payload": payload_name,
        "sha256": digest,
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, manifest_name))
    return os.path.join(directory, manifest_name)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("step_") : -len(".json")])
        for f in os.listdir(directory)
        if f.startswith("step_") and f.endswith(".json")
    ]
    return max(steps) if steps else None


def quarantine_step(directory: str, step: int) -> list:
    """Rename a damaged step's files to ``*.corrupt`` so it stops being
    the latest checkpoint (``latest_step`` matches the ``.json`` suffix)
    while keeping the bytes on disk for post-mortems.  Returns the
    quarantined paths."""
    moved = []
    for suffix in (".json", ".npz"):
        p = os.path.join(directory, f"step_{step:010d}{suffix}")
        if os.path.exists(p):
            os.replace(p, p + ".corrupt")
            moved.append(p + ".corrupt")
    return moved


def load_checkpoint(
    directory: str,
    step: int,
    like,
    *,
    mesh=None,
    specs=None,
    verify: bool = True,
):
    """Restore a pytree saved by save_checkpoint.

    ``like`` provides the structure; if ``mesh``+``specs`` are given the
    arrays are placed with those shardings (elastic restore re-shards
    transparently — the payload holds global arrays).
    """
    manifest = json.load(
        open(os.path.join(directory, f"step_{step:010d}.json"))
    )
    path = os.path.join(directory, manifest["payload"])
    if verify:
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(
                f"checkpoint corruption detected: {path} sha mismatch"
            )
    data = np.load(path)
    flat_like = _flatten(like)
    flat_specs = _flatten(specs) if specs is not None else None
    out = {}
    for key in flat_like:
        arr = data[key.replace("/", "__")]
        if mesh is not None and flat_specs is not None:
            sharding = jax.sharding.NamedSharding(mesh, flat_specs[key])
            out[key] = jax.device_put(arr, sharding)
        else:
            out[key] = jax.numpy.asarray(arr)
    # unflatten along `like`'s treedef
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in leaves_like:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), ordered
    ), manifest["extra"]
