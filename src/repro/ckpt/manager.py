"""Checkpoint manager: rotation + async writer thread."""
from __future__ import annotations

import os
import queue
import threading
from typing import Optional

from .checkpoint import latest_step, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager"]


class CheckpointManager:
    """keep-last-k rotation with an optional background writer.

    The async path snapshots device arrays to host (blocking only on the
    transfer), then serializes + fsyncs on a worker thread so the train
    loop overlaps the write with the next steps.
    """

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._errors = []
        if async_save:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save_checkpoint(self.directory, step, tree, extra=extra)
                self._rotate()
            except Exception as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, tree, *, extra=None):
        if self.async_save:
            import jax
            import numpy as np

            host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
            self._q.put((step, host_tree, extra))
        else:
            save_checkpoint(self.directory, step, tree, extra=extra)
            self._rotate()

    def wait(self):
        if self.async_save:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def _rotate(self):
        steps = sorted(
            int(f[len("step_") : -len(".json")])
            for f in os.listdir(self.directory)
            if f.startswith("step_") and f.endswith(".json")
        )
        for s in steps[: -self.keep]:
            for suffix in (".json", ".npz"):
                p = os.path.join(self.directory, f"step_{s:010d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    def restore_latest(self, like, *, mesh=None, specs=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, extra = load_checkpoint(
            self.directory, step, like, mesh=mesh, specs=specs
        )
        return step, tree, extra

    def close(self):
        if self.async_save and self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=10)
