"""Checkpoint manager: rotation + async writer thread + quarantine."""
from __future__ import annotations

import logging
import os
import queue
import threading
import zipfile
from typing import Optional

from .checkpoint import (
    latest_step,
    load_checkpoint,
    quarantine_step,
    save_checkpoint,
)

log = logging.getLogger(__name__)

__all__ = ["CheckpointManager"]

# restore failures that mean "this checkpoint is damaged" (sha mismatch,
# truncated/unreadable payload, mangled manifest) — NOT structural
# mismatches like KeyError, which callers use to detect cross-mode
# resumes and must keep seeing
_CORRUPTION_ERRORS = (
    OSError,  # includes the IOError sha-mismatch raise
    ValueError,  # np.load on a mangled zip / json decode errors
    EOFError,
    zipfile.BadZipFile,
)


class CheckpointManager:
    """keep-last-k rotation with an optional background writer.

    The async path snapshots device arrays to host (blocking only on the
    transfer), then serializes + fsyncs on a worker thread so the train
    loop overlaps the write with the next steps.  Writer errors surface
    on the *next* ``save()`` (and on ``wait()``/``close()``) — a dying
    writer must not silently drop every subsequent checkpoint.
    """

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._errors = []
        if async_save:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _save_now(self, step: int, tree, extra):
        from ..runtime import faultinject

        # raising faults fire before the write; a CkptCorrupt site fires
        # on the post-write call (passing the payload path) and flips a
        # byte so restore exercises verify + quarantine
        faultinject.fire("ckpt_save", step=step)
        save_checkpoint(self.directory, step, tree, extra=extra)
        self._rotate()
        faultinject.fire(
            "ckpt_save",
            step=step,
            path=os.path.join(self.directory, f"step_{step:010d}.npz"),
        )

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                self._save_now(step, tree, extra)
            except Exception as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._errors:
            err = self._errors[0]
            self._errors = []
            raise RuntimeError(
                "checkpoint writer failed on an earlier save; later "
                "checkpoints would be silently dropped"
            ) from err

    def save(self, step: int, tree, *, extra=None):
        self._raise_pending()
        if self.async_save:
            import jax
            import numpy as np

            host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
            self._q.put((step, host_tree, extra))
        else:
            self._save_now(step, tree, extra)

    def wait(self):
        if self.async_save:
            self._q.join()
        self._raise_pending()

    def _rotate(self):
        steps = sorted(
            int(f[len("step_") : -len(".json")])
            for f in os.listdir(self.directory)
            if f.startswith("step_") and f.endswith(".json")
        )
        for s in steps[: -self.keep]:
            for suffix in (".json", ".npz"):
                p = os.path.join(self.directory, f"step_{s:010d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    def restore_latest(self, like, *, mesh=None, specs=None,
                       quarantine: bool = True):
        """Restore the newest *intact* checkpoint.

        A step whose payload fails digest verification (or is
        unreadable) is quarantined — renamed to ``*.corrupt`` so it
        stops being the latest — and the previous step is tried, until
        one restores or none remain.  ``quarantine=False`` restores the
        pre-PR-10 crash-on-corruption behavior.
        """
        while True:
            step = latest_step(self.directory)
            if step is None:
                return None, None, None
            try:
                tree, extra = load_checkpoint(
                    self.directory, step, like, mesh=mesh, specs=specs
                )
                return step, tree, extra
            except _CORRUPTION_ERRORS as e:
                if not quarantine:
                    raise
                quarantine_step(self.directory, step)
                log.warning(
                    "checkpoint step %d is corrupt (%s); quarantined, "
                    "falling back to the previous step",
                    step, e,
                )

    def close(self):
        if self.async_save and self._worker is not None:
            self._q.put(None)
            self._worker.join(timeout=10)
        self._raise_pending()
