"""JAX-version compatibility layer (DESIGN.md §7).

The repo targets the mesh/SPMD API surface of jax >= 0.5 (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``) but must
run on jax 0.4.x where those names either do not exist or have different
signatures (``jax.experimental.shard_map.shard_map`` with ``check_rep``,
``jax.make_mesh`` without ``axis_types``).  Every call site in ``src/`` and
``tests/`` goes through this module instead of touching the moving API
directly; supporting a new jax release means updating this file only.

Shimmed surface:

* :func:`shard_map`    — ``jax.shard_map`` | ``jax.experimental.shard_map``;
  the ``check_vma``/``check_rep`` rename is absorbed here.
* :func:`make_mesh`    — ``axis_types`` forwarded when supported, dropped
  otherwise (0.4.x meshes have no axis types; all axes behave as Auto).
* :data:`AxisType`     — real enum when available, else a stand-in with the
  same member names so call sites never branch.
* :func:`ppermute`     — stable today; routed here so a future signature
  change has a single home.
* :func:`x64_enabled` / :func:`default_count_dtype` — robust replacement
  for the deprecated ``jax.config.read("jax_enable_x64")``.
* :func:`check_count_overflow` — the int32 fallback guard used by
  :func:`repro.core.api.count_triangles`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "AxisType",
    "axis_size",
    "canonical_count_dtype",
    "check_count_overflow",
    "cost_analysis",
    "default_count_dtype",
    "make_mesh",
    "ppermute",
    "shard_map",
    "x64_enabled",
]


# ----------------------------------------------------------------------
# AxisType
# ----------------------------------------------------------------------
class _AxisTypeStub:
    """Stand-in for ``jax.sharding.AxisType`` on jax < 0.5.

    Member values are only ever compared/forwarded, never interpreted, so
    plain strings suffice.  On old jax the mesh constructor ignores them.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeStub)


# ----------------------------------------------------------------------
# mesh construction
# ----------------------------------------------------------------------
def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` across versions.

    ``axis_types`` defaults to all-Auto (the repo's convention); it is
    forwarded on jax >= 0.5 and dropped on 0.4.x, where meshes carry no
    axis types and every axis already behaves as Auto.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(tuple(axis_shapes))
    try:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), axis_types=tuple(axis_types), **kwargs
        )
    except TypeError:  # jax 0.4.x: no axis_types kwarg
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# ----------------------------------------------------------------------
# shard_map
# ----------------------------------------------------------------------
_new_shard_map = getattr(jax, "shard_map", None)
if _new_shard_map is None:
    from jax.experimental.shard_map import shard_map as _old_shard_map
else:
    _old_shard_map = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` (>= 0.5, ``check_vma``) or the 0.4.x
    ``jax.experimental.shard_map.shard_map`` (``check_rep``)."""
    if _new_shard_map is not None:
        try:
            return _new_shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # transitional releases spell it check_rep
            return _new_shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    return _old_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


# ----------------------------------------------------------------------
# collectives
# ----------------------------------------------------------------------
def ppermute(x, axis_name, perm):
    """``jax.lax.ppermute`` — stable across supported versions."""
    return jax.lax.ppermute(x, axis_name, perm=perm)


def axis_size(axis_name) -> int:
    """Size of a mapped mesh axis, as a static int.

    ``jax.lax.axis_size`` is recent; on older jax ``psum(1, axis)`` is
    constant-folded to the axis size at trace time.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ----------------------------------------------------------------------
# compiled-executable introspection
# ----------------------------------------------------------------------
def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as one flat dict across versions.

    jax 0.4.x returns a list with one per-program dict (possibly empty);
    jax >= 0.5 returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ----------------------------------------------------------------------
# x64 / count dtype
# ----------------------------------------------------------------------
def x64_enabled() -> bool:
    """Whether 64-bit mode is on, without the deprecated config.read."""
    try:
        return bool(jax.config.jax_enable_x64)
    except AttributeError:
        try:
            return bool(jax.config.read("jax_enable_x64"))
        except Exception:  # noqa: BLE001 — any failure means default off
            return False


def default_count_dtype():
    """int64 when x64 is enabled, else int32 (callers must then guard the
    final count with :func:`check_count_overflow`)."""
    return jnp.int64 if x64_enabled() else jnp.int32


def canonical_count_dtype(dtype=None):
    """Resolve a requested count dtype to what this process supports.

    ``None`` means :func:`default_count_dtype`.  An explicit int64 request
    under x64-off is canonicalized to int32 *here*, once, at the build
    boundary — XLA would truncate it anyway, but doing it eagerly keeps
    every ``jnp.zeros``/``astype`` in the kernels warning-free, which in
    turn lets the test suite treat the "Explicitly requested dtype ...
    truncated" UserWarning as an error (an accidental-truncation tripwire).
    The int32 fallback stays guarded by :func:`check_count_overflow`.
    """
    if dtype is None:
        return default_count_dtype()
    return jnp.dtype(jax.dtypes.canonicalize_dtype(jnp.dtype(dtype)))


_INT32_MAX = 2**31 - 1


def check_count_overflow(total: int, count_dtype) -> int:
    """Validate a final triangle count accumulated in ``count_dtype``.

    int32 accumulation wraps silently in XLA; a negative or saturated
    total is unambiguous evidence of overflow, so fail loudly instead of
    returning garbage.  Returns ``total`` unchanged when plausible.
    """
    if jnp.dtype(count_dtype) == jnp.dtype(jnp.int32) and (
        total < 0 or total >= _INT32_MAX
    ):
        raise OverflowError(
            f"triangle count overflowed int32 (got {total}); enable x64 "
            "(jax.config.update('jax_enable_x64', True)) or pass "
            "count_dtype=jnp.int64"
        )
    return total
