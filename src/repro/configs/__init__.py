"""Architecture + graph configs with a name registry (``--arch <id>``)."""
from .base import (  # noqa: F401
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    GNNConfig,
    LMConfig,
    RecsysConfig,
    TCGraphConfig,
    get_config,
)

ASSIGNED_ARCHS = [
    "chatglm3-6b",
    "qwen2-0.5b",
    "qwen1.5-110b",
    "grok-1-314b",
    "deepseek-v3-671b",
    "nequip",
    "graphcast",
    "gat-cora",
    "equiformer-v2",
    "dlrm-mlperf",
]

TC_GRAPHS = [
    "tc-twitter",
    "tc-friendster",
    "tc-g500-s26",
    "tc-g500-s27",
    "tc-g500-s28",
    "tc-g500-s29",
]
