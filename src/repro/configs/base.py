"""Config system: dataclasses + registry + per-arch input specs.

Every assigned architecture registers a full config (exact published
hyper-parameters) and a ``smoke`` variant (same family, tiny dims) used by
the CPU smoke tests.  ``input_specs(cfg, shape_name)`` returns
``jax.ShapeDtypeStruct`` stand-ins for each input of the corresponding
step function — the dry-run lowers against these (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "LMConfig",
    "GNNConfig",
    "RecsysConfig",
    "TCGraphConfig",
    "register",
    "get_config",
    "list_configs",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
]

_REGISTRY: Dict[str, Callable[[], object]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str):
    if name not in _REGISTRY:
        # import side-effect registration
        from . import (  # noqa: F401
            chatglm3_6b,
            qwen2_0_5b,
            qwen1_5_110b,
            grok1_314b,
            deepseek_v3_671b,
            nequip,
            graphcast,
            gat_cora,
            equiformer_v2,
            dlrm_mlperf,
            tc_graphs,
        )
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    get_config.__wrapped__ = None  # force import side effects via get_config
    try:
        get_config("__none__")
    except KeyError:
        pass
    return sorted(_REGISTRY)


# ----------------------------------------------------------------------
# shape sets (assignment-specified)
# ----------------------------------------------------------------------
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    # long_500k requires sub-quadratic attention; all five assigned LM archs
    # are pure full-attention -> skipped per assignment (DESIGN.md §5).
    "long_500k": dict(
        kind="decode", seq_len=524288, global_batch=1, skip_full_attention=True
    ),
}

GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="full", n_nodes=2708, n_edges=10556, d_feat=1433
    ),
    "minibatch_lg": dict(
        kind="sampled",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1024,
        fanout=(15, 10),
    ),
    "ogb_products": dict(
        kind="full", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100
    ),
    "molecule": dict(kind="batched", n_nodes=30, n_edges=64, batch=128),
}

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


# ----------------------------------------------------------------------
# config dataclasses
# ----------------------------------------------------------------------
@dataclasses.dataclass
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_fraction: float = 1.0  # chatglm "RoPE 2d" = rotary on half dims
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False  # multi-token-prediction auxiliary head
    # runtime knobs
    dtype: str = "bfloat16"
    remat: bool = True
    microbatch_size: int = 16  # tokens dim of grad-accumulation microbatch
    optimizer: str = "adamw"
    kv_quant: Optional[str] = None  # "int8" to quantize decode KV cache
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    shapes = LM_SHAPES
    family: str = "lm"

    def __post_init__(self):
        if self.d_head == 0:
            self.d_head = self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        if self.mla:
            attn = (
                self.d_model * self.q_lora_rank
                + self.q_lora_rank * h * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * h * (self.qk_nope_dim + self.v_head_dim)
                + h * self.v_head_dim * d
            )
        else:
            attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        dense_ffn = 3 * d * self.d_ff
        if self.moe:
            moe_ffn = self.n_experts * 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            n_moe = self.n_layers - self.first_dense_layers
            layers = self.n_layers * attn + self.first_dense_layers * dense_ffn
            layers += n_moe * (moe_ffn + shared + d * self.n_experts)
        else:
            layers = self.n_layers * (attn + dense_ffn)
        return layers + 2 * self.vocab * d

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d = self.d_model
        h, dh = self.n_heads, self.d_head
        if self.mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * h * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * h * (self.qk_nope_dim + self.v_head_dim)
                + h * self.v_head_dim * d
            )
        else:
            attn = d * h * dh + 2 * d * self.n_kv_heads * dh + h * dh * d
        act_ffn = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        n_moe = self.n_layers - self.first_dense_layers
        total = (
            self.n_layers * attn
            + self.first_dense_layers * 3 * d * self.d_ff
            + n_moe * (act_ffn + d * self.n_experts)
            + 2 * self.vocab * d
        )
        return total


@dataclasses.dataclass
class GNNConfig:
    name: str
    arch: str  # "nequip" | "graphcast" | "gat" | "equiformer_v2"
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    l_max: int = 0
    m_max: int = 0
    n_rbf: int = 0
    cutoff: float = 0.0
    aggregator: str = "sum"
    mesh_refinement: int = 0
    n_vars: int = 0
    d_out: int = 7  # classes / target dim
    dtype: str = "float32"
    remat: bool = True
    shapes = GNN_SHAPES
    family: str = "gnn"


@dataclasses.dataclass
class RecsysConfig:
    name: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    bot_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]
    interaction: str
    table_sizes: Tuple[int, ...]
    multi_hot: int = 1  # ids per sparse field (bag size)
    dtype: str = "float32"
    shapes = RECSYS_SHAPES
    family: str = "recsys"

    @property
    def total_rows(self) -> int:
        return sum(self.table_sizes)


@dataclasses.dataclass
class TCGraphConfig:
    """The paper's own evaluation graphs (Table 1)."""

    name: str
    n_vertices: int
    n_edges: int
    n_triangles: int
    dmax_block_est: int  # planner estimate for the analytic dry-run plan
    family: str = "tc"
