"""chatglm3-6b [arXiv:2406.12793]: 28L d4096 32H GQA(kv=2) ff13696 v65024.

"RoPE 2d": ChatGLM applies rotary embeddings to half of the head
dimensions (partial rotary factor 0.5).  QKV uses bias (ChatGLM uses
add_qkv_bias=True); attention/MLP output projections do not.
"""
from .base import LMConfig, register


@register("chatglm3-6b")
def full() -> LMConfig:
    return LMConfig(
        name="chatglm3-6b",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab=65024,
        qkv_bias=True,
        rope_fraction=0.5,
    )


@register("chatglm3-6b-smoke")
def smoke() -> LMConfig:
    return LMConfig(
        name="chatglm3-6b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        rope_fraction=0.5,
        microbatch_size=2,
    )
