"""deepseek-v3-671b [arXiv:2412.19437]: 61L d7168, MLA (128 heads),
MoE 1 shared + 256 routed top-8 (moe_d_ff=2048), first 3 layers dense
(d_ff=18432), vocab 129280, MTP auxiliary head.

Note the assignment writes "GQA kv=128": DeepSeek-V3 uses MLA whose latent
KV is shared across all 128 heads (effectively kv=128 at the head level);
we implement true MLA with the published low-rank dims (q_lora 1536,
kv_lora 512, qk_nope 128, qk_rope 64, v_head 128).
"""
from .base import LMConfig, register


@register("deepseek-v3-671b")
def full() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,  # dense layers (first 3)
        vocab=129280,
        d_head=192,  # qk_nope + qk_rope
        moe=True,
        n_experts=256,
        top_k=8,
        n_shared_experts=1,
        moe_d_ff=2048,
        first_dense_layers=3,
        mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        mtp=True,
        microbatch_size=8,
        optimizer="adafactor",
        kv_quant="int8",
    )


@register("deepseek-v3-671b-smoke")
def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v3-671b-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab=256,
        d_head=24,
        moe=True,
        n_experts=8,
        top_k=2,
        n_shared_experts=1,
        moe_d_ff=48,
        first_dense_layers=1,
        mla=True,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        mtp=True,
        microbatch_size=2,
    )
