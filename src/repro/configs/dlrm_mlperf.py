"""dlrm-mlperf [arXiv:1906.00091]: the MLPerf DLRM benchmark config
(Criteo Terabyte): 13 dense + 26 sparse features, embed_dim 128,
bottom MLP 13-512-256-128, top MLP 1024-1024-512-256-1, dot interaction.

Table sizes are the Criteo Terabyte cardinalities used by the MLPerf
reference implementation (~882M rows total, ~113 GB at fp32/128d)."""
from .base import RecsysConfig, register

CRITEO_TB_TABLE_SIZES = (
    45833188, 36746, 17245, 7413, 20243, 3, 7114, 1441, 62, 29275261,
    1572176, 345138, 10, 2209, 11267, 128, 4, 974, 14, 48937457, 11316796,
    40094537, 452104, 12606, 104, 35,
)


@register("dlrm-mlperf")
def full() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-mlperf",
        n_dense=13,
        n_sparse=26,
        embed_dim=128,
        bot_mlp=(13, 512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1),
        interaction="dot",
        table_sizes=CRITEO_TB_TABLE_SIZES,
    )


@register("dlrm-mlperf-smoke")
def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-mlperf-smoke",
        n_dense=13,
        n_sparse=8,
        embed_dim=16,
        bot_mlp=(13, 32, 16),
        top_mlp=(64, 32, 1),
        interaction="dot",
        table_sizes=(100, 50, 200, 30, 10, 80, 60, 40),
    )
