"""equiformer-v2 [arXiv:2306.12059]: 12 blocks, 128 channels, l_max=6,
m_max=2 eSCN SO(2) convolutions, 8 attention heads."""
from .base import GNNConfig, register


@register("equiformer-v2")
def full() -> GNNConfig:
    return GNNConfig(
        name="equiformer-v2",
        arch="equiformer_v2",
        n_layers=12,
        d_hidden=128,
        l_max=6,
        m_max=2,
        n_heads=8,
        n_rbf=8,
        cutoff=5.0,
        d_out=1,
    )


@register("equiformer-v2-smoke")
def smoke() -> GNNConfig:
    return GNNConfig(
        name="equiformer-v2-smoke",
        arch="equiformer_v2",
        n_layers=2,
        d_hidden=16,
        l_max=2,
        m_max=1,
        n_heads=2,
        n_rbf=4,
        cutoff=5.0,
        d_out=1,
    )
