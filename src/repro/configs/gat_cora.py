"""gat-cora [arXiv:1710.10903]: 2 layers, 8 hidden per head, 8 heads,
attention aggregation (the original Cora transductive config)."""
from .base import GNNConfig, register


@register("gat-cora")
def full() -> GNNConfig:
    return GNNConfig(
        name="gat-cora",
        arch="gat",
        n_layers=2,
        d_hidden=8,
        n_heads=8,
        aggregator="attn",
        d_out=7,
    )


@register("gat-cora-smoke")
def smoke() -> GNNConfig:
    return GNNConfig(
        name="gat-cora-smoke",
        arch="gat",
        n_layers=2,
        d_hidden=4,
        n_heads=2,
        aggregator="attn",
        d_out=3,
    )
