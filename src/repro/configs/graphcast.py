"""graphcast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN,
16 processor layers, d_hidden=512, sum aggregation, 227 variables.

The modality frontend (lat/lon grid <-> icosahedral mesh bipartite
encoders) is a STUB per the assignment: ``input_specs`` provides node
features directly on the processing mesh; mesh_refinement=6 is recorded
for the config's provenance."""
from .base import GNNConfig, register


@register("graphcast")
def full() -> GNNConfig:
    return GNNConfig(
        name="graphcast",
        arch="graphcast",
        n_layers=16,
        d_hidden=512,
        mesh_refinement=6,
        n_vars=227,
        aggregator="sum",
        d_out=227,
    )


@register("graphcast-smoke")
def smoke() -> GNNConfig:
    return GNNConfig(
        name="graphcast-smoke",
        arch="graphcast",
        n_layers=2,
        d_hidden=32,
        mesh_refinement=1,
        n_vars=11,
        d_out=11,
    )
