"""grok-1-314b [hf:xai-org/grok-1]: 64L d6144 48H GQA(kv=8) ff32768
v131072, MoE 8 experts top-2."""
from .base import LMConfig, register


@register("grok-1-314b")
def full() -> LMConfig:
    return LMConfig(
        name="grok-1-314b",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab=131072,
        moe=True,
        n_experts=8,
        top_k=2,
        moe_d_ff=32768,
        microbatch_size=8,
        optimizer="adafactor",
    )


@register("grok-1-314b-smoke")
def smoke() -> LMConfig:
    return LMConfig(
        name="grok-1-314b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        moe=True,
        n_experts=4,
        top_k=2,
        moe_d_ff=128,
        microbatch_size=2,
    )
