"""nequip [arXiv:2101.03164]: 5 interaction layers, 32 hidden channels,
l_max=2 E(3) tensor products, 8 radial Bessel functions, cutoff 5 Å."""
from .base import GNNConfig, register


@register("nequip")
def full() -> GNNConfig:
    return GNNConfig(
        name="nequip",
        arch="nequip",
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        aggregator="sum",
        d_out=1,  # energy
    )


@register("nequip-smoke")
def smoke() -> GNNConfig:
    return GNNConfig(
        name="nequip-smoke",
        arch="nequip",
        n_layers=2,
        d_hidden=8,
        l_max=2,
        n_rbf=4,
        cutoff=5.0,
        d_out=1,
    )
