"""qwen1.5-110b: 80L d8192 64H GQA(kv=8) ff49152 v152064, QKV bias."""
from .base import LMConfig, register


@register("qwen1.5-110b")
def full() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab=152064,
        qkv_bias=True,
        microbatch_size=8,
        optimizer="adafactor",  # AdamW fp32 states exceed v5e HBM at 256 chips
    )


@register("qwen1.5-110b-smoke")
def smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b-smoke",
        n_layers=2,
        d_model=96,
        n_heads=8,
        n_kv_heads=2,
        d_ff=192,
        vocab=512,
        qkv_bias=True,
        microbatch_size=2,
    )
