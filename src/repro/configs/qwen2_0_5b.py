"""qwen2-0.5b [arXiv:2407.10671]: 24L d896 14H GQA(kv=2) ff4864 v151936.

QKV bias on (Qwen2 uses attention QKV bias), tied embeddings in the real
model (we keep untied lm_head for sharding clarity; noted in DESIGN.md).
"""
from .base import LMConfig, register


@register("qwen2-0.5b")
def full() -> LMConfig:
    return LMConfig(
        name="qwen2-0.5b",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151936,
        qkv_bias=True,
        d_head=64,
    )


@register("qwen2-0.5b-smoke")
def smoke() -> LMConfig:
    return LMConfig(
        name="qwen2-0.5b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        qkv_bias=True,
        d_head=16,
        microbatch_size=2,
    )
