"""The paper's evaluation graphs (Table 1) as dry-run configs.

``dmax_block_est`` is the planner's estimate of the max adjacency-fragment
length per 16x16 block: after degree ordering, U-row lengths are bounded by
O(sqrt(m)) (arboricity bound); per block they shrink by ~sqrt(p) (the
paper's own observation, §5.2).  We budget 4*sqrt(m)/q."""
import math

from .base import TCGraphConfig, register


def _mk(name, n, m, tri):
    q = 16
    dmax = max(64, int(4 * math.sqrt(m) / q))
    return TCGraphConfig(
        name=name,
        n_vertices=n,
        n_edges=m,
        n_triangles=tri,
        dmax_block_est=dmax,
    )


@register("tc-twitter")
def twitter():
    return _mk("tc-twitter", 41_652_230, 1_202_513_046, 34_824_916_864)


@register("tc-friendster")
def friendster():
    return _mk("tc-friendster", 119_432_957, 1_799_999_986, 191_716)


@register("tc-g500-s26")
def s26():
    return _mk("tc-g500-s26", 67_108_864, 1_073_741_824, 49_158_464_716)


@register("tc-g500-s27")
def s27():
    return _mk("tc-g500-s27", 134_217_728, 2_147_483_648, 106_858_898_940)


@register("tc-g500-s28")
def s28():
    return _mk("tc-g500-s28", 268_435_456, 4_294_967_296, 231_425_307_324)


@register("tc-g500-s29")
def s29():
    return _mk("tc-g500-s29", 536_870_912, 8_589_934_592, 499_542_556_876)
