"""Core: the paper's 2D distributed triangle-counting algorithm.

Public surface:

* :func:`count_triangles` — full pipeline (preprocess -> plan -> schedule).
* :class:`Graph`, generators (:func:`rmat`, :func:`erdos_renyi`, ...).
* :func:`build_plan` / :func:`analytic_plan` — host planner.
* schedules: :mod:`.cannon` (paper), :mod:`.summa` (rectangular/elastic),
  :mod:`.onedim` (1D-decomposition baseline the paper compares against).
"""
from .api import (  # noqa: F401
    TCResult,
    available_schedules,
    count_triangles,
    count_triangles_delta,
    count_triangles_many,
    get_schedule,
    make_grid_mesh,
    register_schedule,
)
from .graph import Graph, triangle_count_oracle  # noqa: F401
from .generators import (  # noqa: F401
    erdos_renyi,
    graph_from_spec,
    named_graph,
    powerlaw,
    residue_cliques,
    rmat,
    star,
)
from .plan import TCPlan, analytic_plan, as_plan, build_plan  # noqa: F401
from .preprocess import degree_order, preprocess  # noqa: F401
