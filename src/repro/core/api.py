"""Top-level triangle-counting API.

``count_triangles(graph, mesh=...)`` runs the full pipeline of the paper:
degree-order preprocessing -> 2D-cyclic plan -> schedule -> global count,
on whatever mesh is supplied (including a 1x1 mesh for single-device use).

Schedules resolve via a registry: :func:`register_schedule` makes a new
schedule one registration away (DESIGN.md §6) — the bundled ones are
``cannon`` (the paper), ``summa`` (rectangular/elastic), and ``oned``
(the 1D baseline the paper beats).  The per-block count path is selected
with ``method`` (any registered CSR kernel, plus the ``dense`` and
``tile`` operand-store paths on the Cannon schedule).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from .. import compat
from . import cannon as cannon_mod
from .graph import Graph
from .plan import TCPlan, build_plan
from .preprocess import preprocess

__all__ = [
    "TCResult",
    "count_triangles",
    "make_grid_mesh",
    "register_schedule",
    "get_schedule",
    "available_schedules",
]


@dataclasses.dataclass
class TCResult:
    triangles: int
    plan: TCPlan
    preprocess_seconds: float
    count_seconds: float
    method: str
    schedule: str
    grid: tuple


def make_grid_mesh(q: int, row_axis="data", col_axis="model", npods=1, pod_axis="pod"):
    """A q x q (optionally x pods) mesh from the available devices."""
    import jax

    n_needed = q * q * npods
    devs = jax.devices()
    assert len(devs) >= n_needed, f"need {n_needed} devices, have {len(devs)}"
    if npods > 1:
        return compat.make_mesh((npods, q, q), (pod_axis, row_axis, col_axis))
    return compat.make_mesh((q, q), (row_axis, col_axis))


# ----------------------------------------------------------------------
# schedule registry
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """One registered schedule: how to plan and how to run.

    ``runner(graph, mesh, ctx) -> (total, plan)`` does planning + array
    staging + engine-fn build + execution; ``ctx`` is the
    :class:`RunContext` of the current ``count_triangles`` call.
    ``build_fn`` exposes the raw engine-fn builder for dry runs /
    lowering-only callers (benchmarks, roofline).
    """

    name: str
    runner: Callable
    build_fn: Optional[Callable] = None


@dataclasses.dataclass
class RunContext:
    q: int
    npods: int
    method: str
    chunk: int
    probe_shorter: bool
    count_dtype: object
    plan: Optional[TCPlan] = None
    # set via mark_counting(): host-side planning/staging before this
    # point is reported as preprocess time, not count time
    counting_started_at: Optional[float] = None

    def mark_counting(self) -> None:
        self.counting_started_at = time.perf_counter()


_SCHEDULES: Dict[str, ScheduleSpec] = {}


def register_schedule(
    name: str, runner: Callable, *, build_fn: Optional[Callable] = None
) -> None:
    """Register a schedule; ``count_triangles(..., schedule=name)`` then
    resolves to ``runner``.  Overwrites any previous registration."""
    _SCHEDULES[name] = ScheduleSpec(name=name, runner=runner, build_fn=build_fn)


def get_schedule(name: str) -> ScheduleSpec:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; registered: {available_schedules()}"
        ) from None


def available_schedules():
    return sorted(_SCHEDULES)


# ----------------------------------------------------------------------
# bundled schedule runners
# ----------------------------------------------------------------------
def _run_cannon(graph: Graph, mesh, ctx: RunContext):
    plan = ctx.plan
    if plan is None:
        plan = build_plan(graph, ctx.q, skew=True, chunk=ctx.chunk)

    if ctx.method == "dense":
        from .cannon import build_cannon_dense_fn

        dense = plan.dense_blocks()
        ctx.mark_counting()
        fn = build_cannon_dense_fn(plan, mesh)
        total = int(fn(**{k: jnp.asarray(v) for k, v in dense.items()}))
        return total, plan
    if ctx.method == "tile":
        import jax

        from .cannon import build_cannon_tile_fn
        from .tiles import build_tile_plan

        tp = build_tile_plan(plan)
        ctx.mark_counting()
        # interpret mode only off-TPU: Mosaic lowering needs real hardware,
        # and silently interpreting on TPU would be orders of magnitude slow
        fn = build_cannon_tile_fn(
            plan, tp, mesh,
            interpret=jax.default_backend() != "tpu",
            count_dtype=ctx.count_dtype,
        )
        total = int(fn(**{k: jnp.asarray(v) for k, v in tp.device_arrays().items()}))
        return total, plan

    if ctx.method == "search2" and not hasattr(plan, "n_long"):
        from .plan import bucketize_plan

        plan = bucketize_plan(plan)

    arrays = plan.device_arrays()
    pod_axis = None
    if ctx.npods > 1:
        arrays = cannon_mod.pod_stack_arrays(arrays, ctx.npods, plan.q)
        pod_axis = "pod"
    ctx.mark_counting()
    fn = cannon_mod.build_cannon_fn(
        plan,
        mesh,
        pod_axis=pod_axis,
        method=ctx.method,
        probe_shorter=ctx.probe_shorter,
        count_dtype=ctx.count_dtype,
    )
    total = int(fn(**{k: jnp.asarray(v) for k, v in arrays.items()}))
    return total, plan


def _run_summa(graph: Graph, mesh, ctx: RunContext):
    from .summa import build_summa_fn, build_summa_plan

    names = list(mesh.axis_names)
    r, c = mesh.shape[names[-2]], mesh.shape[names[-1]]
    splan = build_summa_plan(graph, r, c, chunk=ctx.chunk)
    ctx.mark_counting()
    fn = build_summa_fn(
        splan,
        mesh,
        method=ctx.method,
        probe_shorter=ctx.probe_shorter,
        count_dtype=ctx.count_dtype,
    )
    total = int(fn(**{k: jnp.asarray(v) for k, v in splan.device_arrays().items()}))
    return total, splan


def _run_oned(graph: Graph, mesh, ctx: RunContext):
    from .onedim import build_oned_fn, build_oned_plan

    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    flat_mesh = compat.make_mesh((p,), ("flat",))
    oplan = build_oned_plan(graph, p, chunk=ctx.chunk)
    ctx.mark_counting()
    fn = build_oned_fn(
        oplan,
        flat_mesh,
        method=ctx.method,
        probe_shorter=ctx.probe_shorter,
        count_dtype=ctx.count_dtype,
    )
    total = int(fn(**{k: jnp.asarray(v) for k, v in oplan.device_arrays().items()}))
    return total, oplan


def _register_bundled():
    from .cannon import build_cannon_fn
    from .onedim import build_oned_fn
    from .summa import build_summa_fn

    register_schedule("cannon", _run_cannon, build_fn=build_cannon_fn)
    register_schedule("summa", _run_summa, build_fn=build_summa_fn)
    register_schedule("oned", _run_oned, build_fn=build_oned_fn)


_register_bundled()


# ----------------------------------------------------------------------
# top-level entry point
# ----------------------------------------------------------------------
def count_triangles(
    graph: Graph,
    mesh=None,
    *,
    q: Optional[int] = None,
    method: str = "search",
    schedule: str = "cannon",
    npods: int = 1,
    probe_shorter: bool = True,
    chunk: int = 512,
    reorder: bool = True,
    count_dtype=None,
    plan: Optional[TCPlan] = None,
) -> TCResult:
    """Count triangles with the paper's 2D algorithm.

    With no mesh, a 1x1 grid on the default device is used (degenerate but
    identical code path).  ``schedule`` resolves via the registry (see
    :func:`available_schedules`); ``method`` picks the count kernel
    ("search", "search2", "global", and on Cannon also "dense"/"tile").
    """
    t0 = time.perf_counter()
    if reorder:
        g2, _ = preprocess(graph)
    else:
        g2 = graph

    if mesh is None:
        q = q or 1
        mesh = make_grid_mesh(q, npods=npods)
    else:
        names = list(mesh.axis_names)
        if "pod" in names:
            npods = mesh.shape["pod"]
        q = mesh.shape[names[-1]]

    if count_dtype is None:
        count_dtype = compat.default_count_dtype()

    spec = get_schedule(schedule)
    ctx = RunContext(
        q=q,
        npods=npods,
        method=method,
        chunk=chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        plan=plan,
    )
    total, out_plan = spec.runner(g2, mesh, ctx)
    total = compat.check_count_overflow(total, count_dtype)
    t2 = time.perf_counter()
    # host-side planning/staging counts as preprocessing (paper's ppt),
    # like the pre-engine code; counting starts at the runner's mark
    t1 = ctx.counting_started_at or t0

    return TCResult(
        triangles=total,
        plan=out_plan,
        preprocess_seconds=t1 - t0,
        count_seconds=t2 - t1,
        method=method,
        schedule=schedule,
        grid=(npods, q, q) if npods > 1 else (q, q),
    )
