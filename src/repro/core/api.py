"""Top-level triangle-counting API.

``count_triangles(graph, mesh=...)`` runs the full pipeline of the paper:
degree-order preprocessing -> 2D-cyclic plan -> Cannon (or SUMMA / 1D)
schedule -> global count, on whatever mesh is supplied (including a 1x1
mesh for single-device use).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import cannon as cannon_mod
from .graph import Graph
from .plan import TCPlan, build_plan
from .preprocess import preprocess

__all__ = ["TCResult", "count_triangles", "make_grid_mesh"]


@dataclasses.dataclass
class TCResult:
    triangles: int
    plan: TCPlan
    preprocess_seconds: float
    count_seconds: float
    method: str
    schedule: str
    grid: tuple


def make_grid_mesh(q: int, row_axis="data", col_axis="model", npods=1, pod_axis="pod"):
    """A q x q (optionally x pods) mesh from the available devices."""
    n_needed = q * q * npods
    devs = jax.devices()
    assert len(devs) >= n_needed, f"need {n_needed} devices, have {len(devs)}"
    if npods > 1:
        return jax.make_mesh(
            (npods, q, q),
            (pod_axis, row_axis, col_axis),
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (q, q),
        (row_axis, col_axis),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def count_triangles(
    graph: Graph,
    mesh=None,
    *,
    q: Optional[int] = None,
    method: str = "search",
    schedule: str = "cannon",
    npods: int = 1,
    probe_shorter: bool = True,
    chunk: int = 512,
    reorder: bool = True,
    count_dtype=None,
    plan: Optional[TCPlan] = None,
) -> TCResult:
    """Count triangles with the paper's 2D algorithm.

    With no mesh, a 1x1 grid on the default device is used (degenerate but
    identical code path).  ``schedule`` in {"cannon", "summa", "oned"}.
    """
    t0 = time.perf_counter()
    if reorder:
        g2, _ = preprocess(graph)
    else:
        g2 = graph

    if mesh is None:
        q = q or 1
        mesh = make_grid_mesh(q, npods=npods)
    else:
        names = list(mesh.axis_names)
        if "pod" in names:
            npods = mesh.shape["pod"]
        q = mesh.shape[names[-1]]

    if count_dtype is None:
        count_dtype = jnp.int64 if jax.config.read("jax_enable_x64") else jnp.int32

    if schedule == "cannon":
        if plan is None:
            plan = build_plan(g2, q, skew=True, chunk=chunk)
        arrays = plan.device_arrays()
        pod_axis = None
        if npods > 1:
            arrays = cannon_mod.pod_stack_arrays(arrays, npods, q)
            pod_axis = "pod"
        t1 = time.perf_counter()
        fn = cannon_mod.build_cannon_fn(
            plan,
            mesh,
            pod_axis=pod_axis,
            method=method,
            probe_shorter=probe_shorter,
            count_dtype=count_dtype,
        )
        total = int(fn(**{k: jnp.asarray(v) for k, v in arrays.items()}))
        t2 = time.perf_counter()
    elif schedule == "summa":
        from .summa import build_summa_plan, build_summa_fn

        names = list(mesh.axis_names)
        r, c = mesh.shape[names[-2]], mesh.shape[names[-1]]
        splan = build_summa_plan(g2, r, c, chunk=chunk)
        t1 = time.perf_counter()
        fn = build_summa_fn(
            splan, mesh, probe_shorter=probe_shorter, count_dtype=count_dtype
        )
        total = int(fn(**{k: jnp.asarray(v) for k, v in splan.device_arrays().items()}))
        plan = splan
        t2 = time.perf_counter()
    elif schedule == "oned":
        from .onedim import build_oned_plan, build_oned_fn

        p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        flat_mesh = jax.make_mesh(
            (p,), ("flat",), axis_types=(jax.sharding.AxisType.Auto,)
        )
        oplan = build_oned_plan(g2, p, chunk=chunk)
        t1 = time.perf_counter()
        fn = build_oned_fn(oplan, flat_mesh, count_dtype=count_dtype)
        total = int(fn(**{k: jnp.asarray(v) for k, v in oplan.device_arrays().items()}))
        plan = oplan
        t2 = time.perf_counter()
    else:
        raise ValueError(f"unknown schedule {schedule!r}")

    return TCResult(
        triangles=total,
        plan=plan,
        preprocess_seconds=t1 - t0,
        count_seconds=t2 - t1,
        method=method,
        schedule=schedule,
        grid=(npods, q, q) if npods > 1 else (q, q),
    )
