"""Top-level triangle-counting API.

``count_triangles(graph, mesh=...)`` runs the full pipeline of the paper:
host planning (ingest → relabel → decompose → pack → stage, cached —
DESIGN.md §3) -> schedule -> global count, on whatever mesh is supplied
(including a 1x1 mesh for single-device use).  The bundled runners plan
through :mod:`repro.pipeline`, so repeated counts of an already-seen
graph hit the content-addressed plan cache and skip planning, staging,
and retracing entirely; ``count_triangles_many`` batches several graphs
into one compiled engine call.

Schedules resolve via a registry: :func:`register_schedule` makes a new
schedule one registration away (DESIGN.md §6) — the bundled ones are
``cannon`` (the paper), ``summa`` (rectangular/elastic), and ``oned``
(the 1D baseline the paper beats).  The per-block count path is selected
with ``method`` (any registered CSR kernel, plus the ``dense`` and
``tile`` operand-store paths on the Cannon schedule).  Runners receive
the *raw* graph plus the relabel options on the :class:`RunContext`
(``reorder``/``cyclic_p``) — relabeling happens inside the pipeline so
the cache can skip it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from .. import compat
from . import cannon as cannon_mod
from .graph import Graph
from .plan import TCPlan

__all__ = [
    "TCResult",
    "count_triangles",
    "count_triangles_delta",
    "count_triangles_many",
    "make_grid_mesh",
    "register_schedule",
    "get_schedule",
    "available_schedules",
]


@dataclasses.dataclass
class TCResult:
    triangles: int
    plan: TCPlan
    preprocess_seconds: float
    count_seconds: float
    method: str
    schedule: str
    grid: tuple
    # skip-aware rebalance search report (set when rebalance_trials > 0
    # and the schedule plans through the pipeline): best seed, baseline/
    # best masked critical path, improvement, skipped steps
    rebalance: Optional[dict] = None
    # hub-split report (DESIGN.md §4.8) when the plan carries a hub
    # side: hub_rows / hub_nnz_frac / hub_tasks plus residual_mcp (the
    # masked critical path of the residual the 2D path actually runs)
    hub: Optional[dict] = None
    # which autotune flavor governed kernel-shape selection for this run
    # ("percentile" | "measured"; None when the method was explicit and
    # no autotune stage ran — DESIGN.md §4.6)
    autotune_mode: Optional[str] = None
    # measured mode only: did the shape-bucket entry come off disk?
    measured_table_hit: Optional[bool] = None
    # the PlanArtifact this count ran from (None for caller-supplied raw
    # plans or schedules registered without plans_itself) — streaming
    # callers thread it into the next count_triangles_delta call
    artifact: Optional[object] = None
    # apply_delta report (level, dirty blocks/cells, replanned stages,
    # rebased) when the count came through count_triangles_delta
    delta: Optional[dict] = None
    # structured attempt/demotion/regrid record attached by
    # repro.runtime.supervisor.supervised_count; None on unsupervised
    # runs (DESIGN.md §8)
    supervision: Optional[dict] = None


def make_grid_mesh(q: int, row_axis="data", col_axis="model", npods=1, pod_axis="pod"):
    """A q x q (optionally x pods) mesh from the available devices."""
    import jax

    n_needed = q * q * npods
    devs = jax.devices()
    assert len(devs) >= n_needed, f"need {n_needed} devices, have {len(devs)}"
    if npods > 1:
        return compat.make_mesh((npods, q, q), (pod_axis, row_axis, col_axis))
    return compat.make_mesh((q, q), (row_axis, col_axis))


# ----------------------------------------------------------------------
# schedule registry
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """One registered schedule: how to plan and how to run.

    ``runner(graph, mesh, ctx) -> (total, plan)`` does planning + array
    staging + engine-fn build + execution; ``ctx`` is the
    :class:`RunContext` of the current ``count_triangles`` call.
    ``build_fn`` exposes the raw engine-fn builder for dry runs /
    lowering-only callers (benchmarks, roofline).

    ``plans_itself`` marks runners that route the *raw* graph through
    :mod:`repro.pipeline` themselves (reading ``ctx.reorder`` /
    ``ctx.cyclic_p`` / ``ctx.cache``), which is what lets cache hits
    skip the relabel too.  Runners registered without it keep the
    pre-pipeline contract: ``count_triangles`` relabels the graph
    before dispatch and hands them the preprocessed graph.
    """

    name: str
    runner: Callable
    build_fn: Optional[Callable] = None
    plans_itself: bool = False


@dataclasses.dataclass
class RunContext:
    q: int
    npods: int
    method: str
    chunk: int
    probe_shorter: bool
    count_dtype: object
    plan: Optional[TCPlan] = None
    # engine knobs: sparsity-aware step skipping (None = auto from the
    # plan's staged masks), the double-buffered Cannon scan body, and
    # schedule compaction (None = auto from the plan's staged live list)
    use_step_mask: Optional[bool] = None
    double_buffer: bool = True
    compact: Optional[bool] = None
    # communication-avoiding collective strategies (DESIGN.md §4.5):
    # the final reduction ("auto" = 2.5D tree when a power-of-two pod
    # axis is present, else flat psums) and SUMMA's panel broadcast
    # (None/"auto" = ppermute chain for plain engines, one-hot psum for
    # batched)
    reduce_strategy: str = "auto"
    broadcast: Optional[str] = None
    # pipeline options: runners plan the *raw* graph through
    # repro.pipeline with these, so cache hits skip the relabel too
    reorder: bool = True
    cyclic_p: Optional[int] = None
    # skip-aware rebalance (DESIGN.md §4.3): search this many relabeling
    # seeds for the lowest masked critical path (0 = off)
    rebalance_trials: int = 0
    # hub-split stage (DESIGN.md §4.8): False = off, True = default
    # threshold, a number = the threshold multiplier c
    hub_split: object = False
    cache: Optional[object] = None  # PlanCache; None -> default_cache()
    # autotune flavor for method 'auto'/'fused' (DESIGN.md §4.6):
    # "percentile" = the analytic PR 5 stage; "measured" = consult (and
    # populate) the persisted timing table keyed per shape bucket
    autotune: str = "percentile"
    measured_dir: Optional[str] = None  # measured-table dir override
    # fused-kernel backend ("auto" | "pallas" | "pallas-interpret" |
    # "lax") and an optional tile override (measured mode feeds the
    # table's best shape through here)
    fused_impl: str = "auto"
    fused_tile: Optional[int] = None
    # resolved reporting fields (land on TCResult)
    autotune_mode: Optional[str] = None
    measured_table_hit: Optional[bool] = None
    artifact: Optional[object] = None  # PlanArtifact set by the runner
    # set via mark_counting(): host-side planning/staging before this
    # point is reported as preprocess time, not count time
    counting_started_at: Optional[float] = None

    def mark_counting(self, plan=None) -> None:
        """Host planning/staging is done; counting starts now.  Also the
        fault-injection window for this count: ``device_stage`` fires
        here, and with a ``plan`` each live original step index fires a
        ``step`` point before dispatch — so a fault armed at an elided
        step never fires, composing with schedule compaction."""
        from ..runtime import faultinject

        if faultinject.is_armed():
            faultinject.fire("device_stage")
            if plan is not None:
                compacted = self.compact is not False
                for s in faultinject.live_step_indices(plan, compacted):
                    faultinject.fire("step", step=s)
        self.counting_started_at = time.perf_counter()

    def memo(self, key, build: Callable):
        """Per-artifact build-once helper (falls through when the runner
        has no artifact, e.g. a caller-supplied plan)."""
        if self.artifact is None:
            return build()
        return self.artifact.memo(key, build)


_SCHEDULES: Dict[str, ScheduleSpec] = {}


def register_schedule(
    name: str,
    runner: Callable,
    *,
    build_fn: Optional[Callable] = None,
    plans_itself: bool = False,
) -> None:
    """Register a schedule; ``count_triangles(..., schedule=name)`` then
    resolves to ``runner``.  Overwrites any previous registration.

    Pass ``plans_itself=True`` only if the runner plans the raw graph
    through :mod:`repro.pipeline` (honoring ``ctx.reorder`` /
    ``ctx.cyclic_p``); otherwise it receives the already-relabeled
    graph, as before the pipeline existed.
    """
    _SCHEDULES[name] = ScheduleSpec(
        name=name, runner=runner, build_fn=build_fn, plans_itself=plans_itself
    )


def get_schedule(name: str) -> ScheduleSpec:
    try:
        return _SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; registered: {available_schedules()}"
        ) from None


def available_schedules():
    return sorted(_SCHEDULES)


# ----------------------------------------------------------------------
# bundled schedule runners
# ----------------------------------------------------------------------
def _resolve_auto_method(plan, fallback: str = "search") -> str:
    """Resolve ``method='auto'`` from the plan's autotune report:
    ``search2`` when the probe-length tail is heavy (and the plan
    carries the two-level split), plain ``search`` otherwise."""
    at = getattr(plan, "autotune", None)
    if (
        at
        and at.get("tail_heavy")
        and getattr(plan, "n_long", None) is not None
    ):
        return "search2"
    return fallback


def _consult_measured(ctx: RunContext, plan) -> Optional[dict]:
    """Measured-autotune table lookup for a maxfrag-split plan: records
    ``autotune_mode``/``measured_table_hit`` on the context and returns
    the entry (timing it into the table on a miss — the one-time cost
    measured mode trades for shape-bucket-warm later runs)."""
    from ..kernels.tc_fused import measured_entry

    entry, hit = measured_entry(plan, table_dir=ctx.measured_dir)
    ctx.autotune_mode = "measured"
    ctx.measured_table_hit = hit
    return entry


def _run_cannon(graph: Graph, mesh, ctx: RunContext):
    plan = ctx.plan  # a caller-supplied plan is already relabeled and
    if plan is None:  # wins over the pipeline (reorder/cyclic_p unused)
        from ..pipeline import plan_cannon

        def plan_with(aug: bool, method: str):
            # the fused panel needs the two-sided maxfrag split; the
            # measured table is only defined over such plans, so
            # method='auto' under measured mode plans the same way
            fused_split = method == "fused" or (
                method == "auto" and ctx.autotune == "measured"
            )
            return plan_cannon(
                graph,
                ctx.q,
                chunk=ctx.chunk,
                reorder=ctx.reorder,
                cyclic_p=ctx.cyclic_p,
                # blocks are only consumed by the tile join (and
                # search2's bucketizer, which the planner forces);
                # skipping them keeps cached artifacts lean on the
                # common CSR paths
                keep_blocks=(method == "tile"),
                bucketize=(method == "search2"),
                rebalance_trials=ctx.rebalance_trials,
                compact=ctx.compact is not False,
                autotune="fused" if fused_split else (method == "auto"),
                aug_keys=aug,
                hub_split=ctx.hub_split,
                cache=ctx.cache,
            )

        ctx.artifact = plan_with(
            ctx.method in ("global", "search2"), ctx.method
        )
        plan = ctx.artifact.plan
        if ctx.method in ("auto", "fused") and ctx.autotune_mode is None:
            ctx.autotune_mode = "percentile"
        if ctx.method == "auto":
            if ctx.autotune == "measured":
                entry = _consult_measured(ctx, plan)
                from ..kernels.tc_fused import predict_fused_wins

                if predict_fused_wins(entry):
                    ctx.method = "fused"
                    ctx.fused_tile = entry["best"]["tile"]
                else:
                    ctx.method = _resolve_auto_method(plan)
            else:
                ctx.method = _resolve_auto_method(plan)
            if ctx.method == "search2":
                # auto resolved to a key-consuming kernel: re-plan with
                # staged aug keys (deterministic, so only aug differs;
                # its own cache entry serves repeat counts warm) — the
                # common search resolution never pays for unused keys
                ctx.artifact = plan_with(True, "auto")
                plan = ctx.artifact.plan
        elif ctx.method == "fused" and ctx.autotune == "measured":
            entry = _consult_measured(ctx, plan)
            ctx.fused_tile = entry["best"]["tile"]
        if ctx.method == "fused" and (plan.n_long or 0) > 0:
            # only the long-row fallback consumes staged keys: re-plan
            # with aug like the search2 resolution above, but skip it
            # entirely on panel-only plans (n_long == 0)
            ctx.artifact = plan_with(True, "fused")
            plan = ctx.artifact.plan
    elif ctx.method == "auto":
        ctx.method = _resolve_auto_method(plan)

    if ctx.method == "dense":
        from .cannon import build_cannon_dense_fn

        dense = ctx.memo("dense_blocks", plan.dense_blocks)
        staged = ctx.memo(
            "dense_staged",
            lambda: {k: jnp.asarray(v) for k, v in dense.items()},
        )
        ctx.mark_counting(plan)
        fn = ctx.memo(
            ("dense_fn", mesh, ctx.use_step_mask, ctx.double_buffer,
             ctx.compact, ctx.reduce_strategy),
            lambda: build_cannon_dense_fn(
                plan, mesh,
                use_step_mask=ctx.use_step_mask,
                double_buffer=ctx.double_buffer,
                compact=ctx.compact,
                reduce_strategy=ctx.reduce_strategy,
            ),
        )
        return int(fn(**staged)), plan
    if ctx.method == "tile":
        import jax

        from .cannon import build_cannon_tile_fn
        from .tiles import build_tile_plan

        tp = ctx.memo("tile_plan", lambda: build_tile_plan(plan))
        staged = ctx.memo(
            "tile_staged",
            lambda: {k: jnp.asarray(v) for k, v in tp.device_arrays().items()},
        )
        # interpret mode only off-TPU: Mosaic lowering needs real hardware,
        # and silently interpreting on TPU would be orders of magnitude slow
        interpret = jax.default_backend() != "tpu"
        ctx.mark_counting(plan)
        fn = ctx.memo(
            ("tile_fn", mesh, interpret, str(ctx.count_dtype),
             ctx.use_step_mask, ctx.double_buffer, ctx.compact),
            lambda: build_cannon_tile_fn(
                plan, tp, mesh, interpret=interpret,
                count_dtype=ctx.count_dtype,
                use_step_mask=ctx.use_step_mask,
                double_buffer=ctx.double_buffer,
                compact=ctx.compact,
            ),
        )
        return int(fn(**staged)), plan

    if ctx.method == "search2" and not hasattr(plan, "n_long"):
        from .plan import bucketize_plan

        plan = bucketize_plan(plan)

    pod_axis = None
    if ctx.npods > 1:
        pod_axis = "pod"
        staged = ctx.memo(
            ("pod_staged", ctx.npods),
            lambda: {
                k: jnp.asarray(v)
                for k, v in cannon_mod.pod_stack_arrays(
                    plan.device_arrays(), ctx.npods, plan.q
                ).items()
            },
        )
    elif ctx.artifact is not None:
        staged = ctx.artifact.staged()
    else:
        staged = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
    ctx.mark_counting(plan)
    fn = ctx.memo(
        ("fn", mesh, ctx.method, ctx.probe_shorter, str(ctx.count_dtype),
         pod_axis, ctx.use_step_mask, ctx.double_buffer, ctx.compact,
         ctx.reduce_strategy, ctx.fused_impl, ctx.fused_tile),
        lambda: cannon_mod.build_cannon_fn(
            plan,
            mesh,
            pod_axis=pod_axis,
            method=ctx.method,
            probe_shorter=ctx.probe_shorter,
            count_dtype=ctx.count_dtype,
            use_step_mask=ctx.use_step_mask,
            double_buffer=ctx.double_buffer,
            compact=ctx.compact,
            reduce_strategy=ctx.reduce_strategy,
            fused_impl=ctx.fused_impl,
            fused_tile=ctx.fused_tile,
        ),
    )
    return int(fn(**staged)), plan


def _run_summa(graph: Graph, mesh, ctx: RunContext):
    from ..pipeline import plan_summa
    from .summa import build_summa_fn

    names = list(mesh.axis_names)
    r, c = mesh.shape[names[-2]], mesh.shape[names[-1]]
    splan = ctx.plan  # a caller-supplied plan (or delta-derived
    if splan is None:  # artifact) wins over the pipeline, like Cannon's
        fused_split = ctx.method == "fused" or (
            ctx.method == "auto" and ctx.autotune == "measured"
        )
        ctx.artifact = plan_summa(
            graph, r, c, chunk=ctx.chunk, reorder=ctx.reorder,
            cyclic_p=ctx.cyclic_p, rebalance_trials=ctx.rebalance_trials,
            compact=ctx.compact is not False,
            autotune="fused" if fused_split else (ctx.method == "auto"),
            broadcast=ctx.broadcast or "auto",
            hub_split=ctx.hub_split,
            cache=ctx.cache,
        )
        splan = ctx.artifact.plan
        if ctx.method in ("auto", "fused") and ctx.autotune_mode is None:
            ctx.autotune_mode = "percentile"
        if ctx.method == "auto":
            if ctx.autotune == "measured":
                entry = _consult_measured(ctx, splan)
                from ..kernels.tc_fused import predict_fused_wins

                if predict_fused_wins(entry):
                    ctx.method = "fused"
                    ctx.fused_tile = entry["best"]["tile"]
                else:
                    ctx.method = _resolve_auto_method(splan)
            else:
                ctx.method = _resolve_auto_method(splan)
        elif ctx.method == "fused" and ctx.autotune == "measured":
            entry = _consult_measured(ctx, splan)
            ctx.fused_tile = entry["best"]["tile"]
    elif ctx.method == "auto":
        ctx.method = _resolve_auto_method(splan)
    if ctx.artifact is not None:
        staged = ctx.artifact.staged()
    else:
        staged = {
            k: jnp.asarray(v) for k, v in splan.device_arrays().items()
        }
    ctx.mark_counting(splan)
    fn = ctx.memo(
        ("fn", mesh, ctx.method, ctx.probe_shorter, str(ctx.count_dtype),
         ctx.use_step_mask, ctx.compact, ctx.broadcast,
         ctx.reduce_strategy, ctx.fused_impl, ctx.fused_tile),
        lambda: build_summa_fn(
            splan,
            mesh,
            method=ctx.method,
            probe_shorter=ctx.probe_shorter,
            count_dtype=ctx.count_dtype,
            use_step_mask=ctx.use_step_mask,
            compact=ctx.compact,
            broadcast=ctx.broadcast,
            fused_impl=ctx.fused_impl,
            fused_tile=ctx.fused_tile,
        ),
    )
    return int(fn(**staged)), splan


def _run_oned(graph: Graph, mesh, ctx: RunContext):
    from ..pipeline import plan_oned
    from .onedim import build_oned_fn

    p = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    flat_mesh = compat.make_mesh((p,), ("flat",))
    oplan = ctx.plan  # caller-supplied plan / delta artifact wins
    if oplan is None:
        fused_split = ctx.method == "fused" or (
            ctx.method == "auto" and ctx.autotune == "measured"
        )
        ctx.artifact = plan_oned(
            graph, p, chunk=ctx.chunk, reorder=ctx.reorder,
            cyclic_p=ctx.cyclic_p, rebalance_trials=ctx.rebalance_trials,
            compact=ctx.compact is not False,
            autotune="fused" if fused_split else (ctx.method == "auto"),
            hub_split=ctx.hub_split,
            cache=ctx.cache,
        )
        oplan = ctx.artifact.plan
        if ctx.method in ("auto", "fused") and ctx.autotune_mode is None:
            ctx.autotune_mode = "percentile"
        if ctx.method == "auto":
            if ctx.autotune == "measured":
                entry = _consult_measured(ctx, oplan)
                from ..kernels.tc_fused import predict_fused_wins

                if predict_fused_wins(entry):
                    ctx.method = "fused"
                    ctx.fused_tile = entry["best"]["tile"]
                else:
                    # the ring's global-id columns rule out the two-level
                    # kernel; the percentile fallback is plain search
                    ctx.method = "search"
            else:
                # the ring's global-id columns rule out the two-level
                # kernel
                ctx.method = "search"
        elif ctx.method == "fused" and ctx.autotune == "measured":
            entry = _consult_measured(ctx, oplan)
            ctx.fused_tile = entry["best"]["tile"]
    elif ctx.method == "auto":
        # the ring's global-id columns rule out the two-level kernel
        ctx.method = "search"
    if ctx.artifact is not None:
        staged = ctx.artifact.staged()
    else:
        staged = {
            k: jnp.asarray(v) for k, v in oplan.device_arrays().items()
        }
    ctx.mark_counting(oplan)
    fn = ctx.memo(
        ("fn", flat_mesh, ctx.method, ctx.probe_shorter,
         str(ctx.count_dtype), ctx.use_step_mask, ctx.compact,
         ctx.reduce_strategy, ctx.fused_impl, ctx.fused_tile),
        lambda: build_oned_fn(
            oplan,
            flat_mesh,
            method=ctx.method,
            probe_shorter=ctx.probe_shorter,
            count_dtype=ctx.count_dtype,
            use_step_mask=ctx.use_step_mask,
            compact=ctx.compact,
            reduce_strategy=ctx.reduce_strategy,
            fused_impl=ctx.fused_impl,
            fused_tile=ctx.fused_tile,
        ),
    )
    return int(fn(**staged)), oplan


def _register_bundled():
    from .cannon import build_cannon_fn
    from .onedim import build_oned_fn
    from .summa import build_summa_fn

    register_schedule(
        "cannon", _run_cannon, build_fn=build_cannon_fn, plans_itself=True
    )
    register_schedule(
        "summa", _run_summa, build_fn=build_summa_fn, plans_itself=True
    )
    register_schedule(
        "oned", _run_oned, build_fn=build_oned_fn, plans_itself=True
    )


_register_bundled()


# ----------------------------------------------------------------------
# top-level entry point
# ----------------------------------------------------------------------
def count_triangles(
    graph: Graph,
    mesh=None,
    *,
    q: Optional[int] = None,
    method: str = "search",
    schedule: str = "cannon",
    npods: int = 1,
    probe_shorter: bool = True,
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    count_dtype=None,
    plan: Optional[TCPlan] = None,
    use_step_mask: Optional[bool] = None,
    double_buffer: bool = True,
    compact: Optional[bool] = None,
    reduce_strategy: str = "auto",
    broadcast: Optional[str] = None,
    rebalance_trials: int = 0,
    hub_split: object = False,
    cache=None,
    autotune: str = "percentile",
    measured_dir: Optional[str] = None,
    fused_impl: str = "auto",
    fault_plan=None,
) -> TCResult:
    """Count triangles with the paper's 2D algorithm.

    With no mesh, a 1x1 grid on the default device is used (degenerate but
    identical code path).  ``schedule`` resolves via the registry (see
    :func:`available_schedules`); ``method`` picks the count kernel
    ("search", "search2", "global", and on Cannon also "dense"/"tile");
    ``method="auto"`` plans through the deterministic autotune stage and
    resolves to ``search2`` when the probe-length tail is heavy
    (``TCResult.method`` reports the resolution).
    ``cyclic_p`` enables the paper's initial cyclic redistribution
    (§5.3 step 1) as the pipeline's first relabel stage.
    ``use_step_mask`` controls sparsity-aware step skipping (None =
    auto: on when the plan staged ``step_keep`` masks; False forces the
    unmasked engine); ``double_buffer`` selects Cannon's
    communication-overlapped scan body; ``compact`` controls the
    compacted kept-step schedule (None = auto: on when the planner's
    compaction stage elided a step — DESIGN.md §4.4; False keeps the
    full scan body).  ``reduce_strategy`` selects the final reduction
    (``"flat"`` psums per axis, ``"tree"`` = the 2.5D staged reduce,
    ``"auto"`` = tree whenever a power-of-two pod axis is present) and
    ``broadcast`` SUMMA's panel broadcast (``"onehot"`` psum,
    ``"chain"`` ppermute chains, ``None``/``"auto"`` = chain for plain
    engines) — DESIGN.md §4.5.  ``rebalance_trials > 0`` runs
    the skip-aware rebalance stage (DESIGN.md §4.3) during planning —
    it needs a pipeline-backed schedule and a pipeline-made plan, so it
    is rejected alongside a caller-supplied ``plan`` or a schedule
    registered without ``plans_itself``.  ``hub_split`` turns on the
    hub-split stage (DESIGN.md §4.8) for heavy-tailed graphs: hub rows
    above ``c ×`` the average degree (``True`` = the default ``c``, a
    number = an explicit ``c``) are counted as replicated column-strided
    fragments outside the 2D schedule and the residual flows through the
    normal path — same pipeline requirement as the rebalancer, so it too
    needs ``plans_itself`` and no caller plan.  Planning goes
    through the content-addressed plan cache (``cache=None`` uses the
    process-wide default — pass a ``repro.pipeline.PlanCache`` to
    isolate, or one with ``maxsize=0`` to disable): repeated counts of
    an already-seen graph skip relabel/plan/stage/compile entirely.

    ``method="fused"`` runs the Pallas equality-panel kernel with its
    long-row fallback (DESIGN.md §5.1) — planning switches to the
    two-sided maxfrag autotune split it requires; ``fused_impl`` picks
    its backend (``"auto"`` = Pallas on TPU, the lax reference
    elsewhere; ``"pallas-interpret"`` for CPU parity checks).
    ``autotune`` selects the shape-selection flavor for
    ``method in ("auto", "fused")``: ``"percentile"`` (the analytic
    stage) or ``"measured"`` (consult/populate the persisted timing
    table of DESIGN.md §4.6, under which ``method="auto"`` resolves to
    ``fused`` exactly where measurement says it beats the incumbent;
    ``measured_dir`` overrides the table directory).

    ``fault_plan`` arms a :class:`repro.runtime.FaultPlan` of
    deterministic typed faults for the duration of this call (testing
    the recovery paths without real hardware faults — DESIGN.md §8);
    recovery itself lives in
    :func:`repro.runtime.supervisor.supervised_count`, which retries,
    demotes and regrids around this function.
    """
    if autotune not in ("percentile", "measured"):
        raise ValueError(
            f"unknown autotune mode {autotune!r}: "
            "expected percentile | measured"
        )
    if autotune == "measured" and plan is not None:
        raise ValueError(
            "autotune='measured' needs pipeline planning (the table is "
            "keyed off the planned shape bucket); drop the "
            "caller-supplied plan"
        )
    artifact = None
    if plan is not None and hasattr(plan, "staged") and hasattr(plan, "plan"):
        # a PlanArtifact (e.g. from apply_delta) supplied as the plan:
        # run its plan and reuse its staged device buffers / fn memos
        artifact = plan
        plan = artifact.plan
    t0 = time.perf_counter()
    if mesh is None:
        q = q or 1
        mesh = make_grid_mesh(q, npods=npods)
    else:
        names = list(mesh.axis_names)
        if "pod" in names:
            npods = mesh.shape["pod"]
        q = mesh.shape[names[-1]]

    if count_dtype is None:
        count_dtype = compat.default_count_dtype()

    spec = get_schedule(schedule)
    if rebalance_trials and (plan is not None or not spec.plans_itself):
        raise ValueError(
            "rebalance_trials requires planning through the pipeline: "
            "drop the caller-supplied plan and use a schedule registered "
            "with plans_itself=True"
        )
    from ..pipeline.hubsplit import normalize_hub_split

    if normalize_hub_split(hub_split) is not None and (
        plan is not None or not spec.plans_itself
    ):
        raise ValueError(
            "hub_split requires planning through the pipeline: drop the "
            "caller-supplied plan (it already carries — or lacks — its "
            "hub side) and use a schedule registered with "
            "plans_itself=True"
        )
    if not spec.plans_itself and (reorder or cyclic_p is not None):
        # pre-pipeline runner contract: hand it the relabeled graph
        from ..pipeline import relabel_stage

        graph, _ = relabel_stage(graph, reorder=reorder, cyclic_p=cyclic_p)
        reorder, cyclic_p = False, None
    ctx = RunContext(
        q=q,
        npods=npods,
        method=method,
        chunk=chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        plan=plan,
        use_step_mask=use_step_mask,
        double_buffer=double_buffer,
        compact=compact,
        reduce_strategy=reduce_strategy,
        broadcast=broadcast,
        reorder=reorder,
        cyclic_p=cyclic_p,
        rebalance_trials=rebalance_trials,
        hub_split=hub_split,
        cache=cache,
        autotune=autotune,
        measured_dir=measured_dir,
        fused_impl=fused_impl,
    )
    if artifact is not None:
        ctx.artifact = artifact
    from ..runtime import faultinject

    with faultinject.armed(fault_plan):
        total, out_plan = spec.runner(graph, mesh, ctx)
    total = compat.check_count_overflow(total, count_dtype)
    t2 = time.perf_counter()
    # host-side planning/staging counts as preprocessing (paper's ppt),
    # like the pre-engine code; counting starts at the runner's mark
    t1 = ctx.counting_started_at or t0

    hub_side = getattr(out_plan, "hub", None)
    hub_rep = None
    if hub_side is not None:
        hub_rep = hub_side.report()
        rb = getattr(ctx.artifact, "rebalance", None)
        stats = getattr(out_plan, "stats", None)
        if rb is not None:
            hub_rep["residual_mcp"] = rb.get("best_masked_critical_path")
        elif stats is not None:
            from ..pipeline.rebalance import masked_critical_path

            hub_rep["residual_mcp"] = masked_critical_path(
                stats.probe_work_per_device_shift,
                getattr(out_plan, "step_keep", None),
            )
        else:
            hub_rep["residual_mcp"] = None

    return TCResult(
        triangles=total,
        plan=out_plan,
        preprocess_seconds=t1 - t0,
        count_seconds=t2 - t1,
        method=ctx.method,  # "auto" reports its per-schedule resolution
        schedule=schedule,
        grid=(npods, q, q) if npods > 1 else (q, q),
        rebalance=getattr(ctx.artifact, "rebalance", None),
        hub=hub_rep,
        autotune_mode=ctx.autotune_mode,
        measured_table_hit=ctx.measured_table_hit,
        artifact=ctx.artifact,
    )


def count_triangles_delta(
    graph: Graph,
    delta,
    mesh=None,
    *,
    artifact=None,
    cache=None,
    rebase_every: int = 8,
    **kwargs,
) -> TCResult:
    """Count triangles of ``graph`` mutated by ``delta``, incrementally.

    ``delta`` is a :class:`repro.pipeline.EdgeDelta` in **original**
    vertex ids.  The base plan is taken from ``artifact`` (the
    ``TCResult.artifact`` of a previous count — thread it through to
    stream deltas) or planned fresh from ``graph``;
    :func:`repro.pipeline.apply_delta` then splices / re-packs only the
    dirty blocks (DESIGN.md §4.7) and the count runs from the derived
    artifact, reusing unchanged device buffers and compiled engines.
    The result's ``delta`` field carries the apply report and its
    ``artifact`` the derived artifact for the next round; ``triangles``
    is exact — identical to a cold count of the mutated graph.
    """
    from ..pipeline.delta import apply_delta

    if kwargs.get("autotune") == "measured":
        raise ValueError(
            "autotune='measured' re-times shapes per plan; the delta "
            "path reuses engines and is keyed analytically — use the "
            "default percentile mode"
        )
    if artifact is None:
        base = count_triangles(graph, mesh, cache=cache, **kwargs)
        artifact = base.artifact
        if artifact is None:
            raise ValueError(
                "count_triangles_delta needs a pipeline-planned base "
                "(schedule registered with plans_itself=True and no "
                "caller-supplied raw plan)"
            )
    art2 = apply_delta(
        artifact, delta, cache=cache, rebase_every=rebase_every
    )
    # the derived artifact already fixed its relabeling, rebalance seed
    # and hub cut at plan time — re-count kwargs that would re-plan are
    # dropped (hub_split included: the derived plan either carries its
    # repacked hub side or was rebased with the cfg's knob)
    for drop in ("reorder", "cyclic_p", "rebalance_trials", "hub_split"):
        kwargs.pop(drop, None)
    res = count_triangles(
        art2.graph, mesh, plan=art2, reorder=False, rebalance_trials=0,
        cache=cache, **kwargs,
    )
    res.delta = art2.delta_report
    return res


def count_triangles_many(graphs, mesh=None, **kwargs):
    """Count triangles of many graphs in one compiled engine call.

    Thin re-export of :func:`repro.pipeline.count_triangles_many` (the
    batched front-end): graphs are padded to shared shapes, stacked on a
    leading batch axis, and run through the engine once; results match
    the per-graph :func:`count_triangles` totals exactly.
    """
    from ..pipeline import count_triangles_many as _many

    return _many(graphs, mesh, **kwargs)
