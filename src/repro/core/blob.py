"""Single-buffer ("blob") packing for shift communication.

The paper eliminates MPI (de)serialization cost by storing each block's
arrays inside one contiguous allocation and sending that blob.  The JAX
analogue: concatenate all per-block arrays into one flat int32 buffer so a
shift is exactly **one** ``ppermute`` per operand instead of one per array.
Offsets are static (plan maxima), so packing/unpacking are free reshapes in
XLA (fused with the collective).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

__all__ = ["pack_blob", "unpack_blob", "blob_layout"]


def blob_layout(shapes: Sequence[Tuple[int, ...]]):
    """Static (offset, size, shape) triples for a list of array shapes."""
    layout = []
    off = 0
    for shp in shapes:
        size = 1
        for d in shp:
            size *= d
        layout.append((off, size, tuple(shp)))
        off += size
    return layout, off


def pack_blob(arrays):
    """Flatten + concatenate int32 arrays into one buffer."""
    return jnp.concatenate([a.reshape(-1) for a in arrays])


def unpack_blob(blob, layout):
    return [
        blob[off : off + size].reshape(shape) for off, size, shape in layout
    ]
