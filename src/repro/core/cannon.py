"""Cannon-schedule distributed triangle counting (the paper's §5.1).

Device ``(x, y)`` of the ``q x q`` grid (mesh axes ``(row_axis, col_axis)``)
starts with the pre-skewed blocks ``A = U_{x,(x+y)%q}`` and
``B = U_{y,(x+y)%q}`` (see :mod:`repro.core.plan`) and performs ``q`` steps
of {count local pair against the static task list, shift A left, shift B
up}.  Shifts are single-blob ``ppermute`` collectives (paper's
serialization optimization); the next blocks are requested *before* the
local count so XLA can overlap communication with compute.

Multi-pod (2.5D, beyond-paper): with ``npods`` pods the blocks are
replicated across the ``pod`` axis, pod ``t`` starts at skew offset ``t``
and executes every ``npods``-th shift; the final count is a global psum.
Memory ×npods, shift traffic ÷npods — the communication-avoiding trade.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import count as count_mod
from .blob import blob_layout, pack_blob, unpack_blob

__all__ = ["build_cannon_fn", "cannon_in_specs", "pod_stack_arrays"]


def _shift_perm(q: int, k: int):
    """ppermute pairs shifting *towards lower index* by k (left/up)."""
    return [(s, (s - k) % q) for s in range(q)]


def cannon_in_specs(
    row_axis: str, col_axis: str, pod_axis: Optional[str] = None
) -> Dict[str, P]:
    """PartitionSpecs for the plan's stacked device arrays."""
    ab = (
        P(pod_axis, row_axis, col_axis)
        if pod_axis
        else P(row_axis, col_axis)
    )
    m = P(row_axis, col_axis)
    return dict(
        a_indptr=ab,
        a_indices=ab,
        b_indptr=ab,
        b_indices=ab,
        m_ti=m,
        m_tj=m,
        m_cnt=m,
    )


def pod_stack_arrays(arrays: Dict, npods: int, q: int) -> Dict:
    """Stack A/B operands with per-pod skew offsets (numpy, host side).

    ``A0_t[x, y] = A0[x, (y+t) % q]`` and ``B0_t[x, y] = B0[(x+t) % q, y]``
    put pod ``t`` at Cannon skew offset ``t`` so it can execute shifts
    ``t, t+npods, ...`` only.
    """
    import numpy as np

    out = dict(arrays)
    for key in ("a_indptr", "a_indices"):
        out[key] = np.stack(
            [np.roll(arrays[key], -t, axis=1) for t in range(npods)]
        )
    for key in ("b_indptr", "b_indices"):
        out[key] = np.stack(
            [np.roll(arrays[key], -t, axis=0) for t in range(npods)]
        )
    return out


def build_cannon_fn(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    pod_axis: Optional[str] = None,
    method: str = "search",
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    use_blob: bool = True,
    reduce_global: bool = True,
    tile_kernel_mode: Optional[str] = None,
    compress_lengths: bool = False,
):
    """Build the jitted SPMD counting function for ``plan`` on ``mesh``.

    Returns ``(fn, in_specs)``; ``fn(**device_arrays)`` yields the global
    triangle count (scalar) or per-device counts if ``reduce_global=False``.
    ``method``: ``"search"`` (flat padding), ``"search2"`` (two-level
    length-bucketed — §Perf H1a; requires ``bucketize_plan``).
    ``compress_lengths`` (§Perf H1b) ships row *lengths as uint16 pairs*
    instead of the int32 indptr inside the shift blob (the indptr is
    rebuilt with one cumsum after each receive), cutting shifted bytes by
    ~(nb*2)/(nb*4+nnz*4).
    """
    q = plan.q
    npods = mesh.shape[pod_axis] if pod_axis else 1
    assert q % npods == 0, "pods must divide the grid dimension"
    nshifts = q // npods
    if compress_lengths:
        assert plan.dmax < 65536, "uint16 length compression needs d < 2^16"

    axes = (
        (pod_axis, row_axis, col_axis) if pod_axis else (row_axis, col_axis)
    )

    def _count_pair(a_ptr, a_idx, b_ptr, b_idx, m_ti, m_tj, m_cnt):
        if method == "search":
            return count_mod.count_pair_search(
                a_ptr,
                a_idx,
                b_ptr,
                b_idx,
                m_ti,
                m_tj,
                m_cnt,
                dpad=plan.dmax,
                chunk=plan.chunk,
                probe_shorter=probe_shorter,
                count_dtype=count_dtype,
            )
        if method == "search2":
            return count_mod.count_pair_search_two_level(
                a_ptr,
                a_idx,
                b_ptr,
                b_idx,
                m_ti,
                m_tj,
                m_cnt,
                plan.n_long,
                dpad_long=plan.dmax,
                dpad_short=plan.d_small,
                chunk=plan.chunk,
                probe_shorter=probe_shorter,
                count_dtype=count_dtype,
            )
        raise ValueError(f"unknown method {method!r} for CSR operands")

    def _pack_lengths(ptr):
        """(nb+1,) indptr -> (ceil(nb/2),) int32 of uint16 length pairs."""
        lens = jnp.diff(ptr).astype(jnp.int32)
        if lens.shape[0] % 2:
            lens = jnp.concatenate([lens, jnp.zeros((1,), jnp.int32)])
        return lens[0::2] | (lens[1::2] << 16)

    def _unpack_lengths(packed, nb):
        lo = packed & 0xFFFF
        hi = (packed >> 16) & 0xFFFF
        lens = jnp.stack([lo, hi], axis=1).reshape(-1)[:nb]
        return jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(lens, dtype=jnp.int32)]
        )

    def spmd(a_indptr, a_indices, b_indptr, b_indices, m_ti, m_tj, m_cnt):
        # strip the leading (pod,) r, c block dims added by shard_map;
        # mask arrays are replicated over the pod axis (no pod dim).
        lead = 3 if pod_axis else 2
        sq = lambda a: a.reshape(a.shape[lead:])
        sqm = lambda a: a.reshape(a.shape[2:])
        a_ptr, a_idx = sq(a_indptr), sq(a_indices)
        b_ptr, b_idx = sq(b_indptr), sq(b_indices)
        ti, tj, cnt = sqm(m_ti), sqm(m_tj), sqm(m_cnt)

        nb = a_ptr.shape[0] - 1
        if compress_lengths:
            a_head = _pack_lengths(a_ptr)
            b_head = _pack_lengths(b_ptr)
            expand = lambda head: _unpack_lengths(head, nb)
        else:
            a_head, b_head = a_ptr, b_ptr
            expand = lambda head: head
        a_layout, _ = blob_layout([a_head.shape, a_idx.shape])
        b_layout, _ = blob_layout([b_head.shape, b_idx.shape])

        def body_blob(carry, _):
            a_blob, b_blob = carry
            # issue the shift for the *next* step first: independent of the
            # local count below, so XLA may overlap collective + compute.
            a_next = jax.lax.ppermute(
                a_blob, col_axis, perm=_shift_perm(q, npods)
            )
            b_next = jax.lax.ppermute(
                b_blob, row_axis, perm=_shift_perm(q, npods)
            )
            a_head_s, a_idx_s = unpack_blob(a_blob, a_layout)
            b_head_s, b_idx_s = unpack_blob(b_blob, b_layout)
            c = _count_pair(
                expand(a_head_s), a_idx_s, expand(b_head_s), b_idx_s,
                ti, tj, cnt,
            )
            return (a_next, b_next), c

        def body_noblob(carry, _):
            ap, ai, bp, bi = carry
            nxt = tuple(
                jax.lax.ppermute(arr, ax, perm=_shift_perm(q, npods))
                for arr, ax in (
                    (ap, col_axis),
                    (ai, col_axis),
                    (bp, row_axis),
                    (bi, row_axis),
                )
            )
            c = _count_pair(ap, ai, bp, bi, ti, tj, cnt)
            return nxt, c

        if use_blob:
            init = (pack_blob([a_head, a_idx]), pack_blob([b_head, b_idx]))
            _, per_shift = jax.lax.scan(body_blob, init, None, length=nshifts)
        else:  # one collective per array (blob ablation)
            init = (a_ptr, a_idx, b_ptr, b_idx)
            _, per_shift = jax.lax.scan(
                body_noblob, init, None, length=nshifts
            )
        total = jnp.sum(per_shift, dtype=count_dtype)
        if reduce_global:
            total = jax.lax.psum(total, row_axis)
            total = jax.lax.psum(total, col_axis)
            if pod_axis:
                total = jax.lax.psum(total, pod_axis)
            return total
        return total.reshape((1,) * len(axes))

    in_specs = cannon_in_specs(row_axis, col_axis, pod_axis)
    ordered = [
        "a_indptr",
        "a_indices",
        "b_indptr",
        "b_indices",
        "m_ti",
        "m_tj",
        "m_cnt",
    ]
    out_specs = P() if reduce_global else P(*axes)
    fn = jax.jit(
        jax.shard_map(
            spmd,
            mesh=mesh,
            in_specs=tuple(in_specs[k] for k in ordered),
            out_specs=out_specs,
            check_vma=False,
        )
    )

    def call(**arrays):
        return fn(*(arrays[k] for k in ordered))

    call.lower = lambda **arrays: fn.lower(*(arrays[k] for k in ordered))
    call.in_specs = in_specs
    call.ordered = ordered
    return call


def build_cannon_stepper(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    method: str = "search",
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
):
    """Shift-at-a-time Cannon for fault-tolerant runs.

    Returns ``one_shift(state) -> state`` (jitted SPMD) where state =
    (a_ptr, a_idx, b_ptr, b_idx, partial_counts).  The host loop owns the
    shift index, checkpointing state between shifts so a restarted job
    resumes mid-loop (EXPERIMENTS.md §Fault-tolerance).
    """
    q = plan.q

    def _count_pair(a_ptr, a_idx, b_ptr, b_idx, m_ti, m_tj, m_cnt):
        return count_mod.count_pair_search(
            a_ptr, a_idx, b_ptr, b_idx, m_ti, m_tj, m_cnt,
            dpad=plan.dmax, chunk=plan.chunk,
            probe_shorter=probe_shorter, count_dtype=count_dtype,
        )

    def spmd(a_indptr, a_indices, b_indptr, b_indices, m_ti, m_tj, m_cnt, acc):
        sq = lambda a: a.reshape(a.shape[2:])
        a_ptr, a_idx = sq(a_indptr), sq(a_indices)
        b_ptr, b_idx = sq(b_indptr), sq(b_indices)
        ti, tj, cnt = sq(m_ti), sq(m_tj), sq(m_cnt)
        acc_l = acc.reshape(())
        a_ptr_n = jax.lax.ppermute(a_ptr, col_axis, perm=_shift_perm(q, 1))
        a_idx_n = jax.lax.ppermute(a_idx, col_axis, perm=_shift_perm(q, 1))
        b_ptr_n = jax.lax.ppermute(b_ptr, row_axis, perm=_shift_perm(q, 1))
        b_idx_n = jax.lax.ppermute(b_idx, row_axis, perm=_shift_perm(q, 1))
        c = _count_pair(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt)
        one = lambda a: a.reshape((1, 1) + a.shape)
        return (
            one(a_ptr_n),
            one(a_idx_n),
            one(b_ptr_n),
            one(b_idx_n),
            (acc_l + c).reshape(1, 1),
        )

    spec = P(row_axis, col_axis)
    fn = jax.jit(
        jax.shard_map(
            spmd,
            mesh=mesh,
            in_specs=(spec,) * 8,
            out_specs=(spec,) * 5,
            check_vma=False,
        )
    )

    def one_shift(state, masks):
        a_ptr, a_idx, b_ptr, b_idx, acc = state
        return fn(
            a_ptr, a_idx, b_ptr, b_idx,
            masks["m_ti"], masks["m_tj"], masks["m_cnt"], acc,
        )

    return one_shift


def build_cannon_tile_fn(
    plan,
    tile_plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    mode: str = "popcount",
    interpret: bool = True,
    count_dtype=jnp.int32,
    reduce_global: bool = True,
):
    """Cannon schedule with the Pallas bit-tile kernel as the count path.

    Tile stores shift exactly like the CSR blobs; the per-(device, shift)
    active-triple lists are static (planner-joined) and drive the kernel's
    scalar-prefetch grid.  ``interpret=True`` validates on CPU; on TPU pass
    ``interpret=False`` to run the Mosaic-lowered kernel.
    """
    from ..kernels.tc_tile.tc_tile import tile_triple_counts

    q = plan.q
    nshifts = q

    def spmd(a_tiles, b_tiles, m_tiles, triples):
        sq = lambda a: a.reshape(a.shape[2:])
        a_t, b_t = sq(a_tiles), sq(b_tiles)
        m_t, trips = sq(m_tiles), sq(triples)  # trips: (q, trip_pad, 4)

        def body(carry, s):
            a_cur, b_cur = carry
            a_next = jax.lax.ppermute(
                a_cur, col_axis, perm=_shift_perm(q, 1)
            )
            b_next = jax.lax.ppermute(
                b_cur, row_axis, perm=_shift_perm(q, 1)
            )
            per = tile_triple_counts(
                trips[s], a_cur, b_cur, m_t, mode=mode, interpret=interpret
            )
            return (a_next, b_next), jnp.sum(per, dtype=count_dtype)

        (_, _), per_shift = jax.lax.scan(
            body, (a_t, b_t), jnp.arange(nshifts)
        )
        total = jnp.sum(per_shift, dtype=count_dtype)
        if reduce_global:
            total = jax.lax.psum(total, row_axis)
            total = jax.lax.psum(total, col_axis)
            return total
        return total.reshape((1, 1))

    spec = P(row_axis, col_axis)
    fn = jax.jit(
        jax.shard_map(
            spmd,
            mesh=mesh,
            in_specs=(spec,) * 4,
            out_specs=P() if reduce_global else spec,
            check_vma=False,
        )
    )
    ordered = ["a_tiles", "b_tiles", "m_tiles", "triples"]

    def call(**arrays):
        return fn(*(arrays[k] for k in ordered))

    call.lower = lambda **arrays: fn.lower(*(arrays[k] for k in ordered))
    return call


def build_cannon_dense_fn(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    pod_axis: Optional[str] = None,
    acc_dtype=jnp.float32,
    reduce_global: bool = True,
):
    """Dense-operand Cannon (oracle path): blocks as 0/1 float matrices."""
    q = plan.q
    npods = mesh.shape[pod_axis] if pod_axis else 1
    assert q % npods == 0
    nshifts = q // npods
    axes = (
        (pod_axis, row_axis, col_axis) if pod_axis else (row_axis, col_axis)
    )

    def spmd(a_dense, b_dense, m_dense):
        lead = 3 if pod_axis else 2
        sq = lambda a: a.reshape(a.shape[lead:])
        a, b, msk = sq(a_dense), sq(b_dense), sq(m_dense)

        def body(carry, _):
            a_cur, b_cur = carry
            a_next = jax.lax.ppermute(
                a_cur, col_axis, perm=_shift_perm(q, npods)
            )
            b_next = jax.lax.ppermute(
                b_cur, row_axis, perm=_shift_perm(q, npods)
            )
            c = count_mod.count_pair_dense(a_cur, b_cur, msk, acc_dtype=acc_dtype)
            return (a_next, b_next), c

        (_, _), per_shift = jax.lax.scan(body, (a, b), None, length=nshifts)
        total = jnp.sum(per_shift, dtype=acc_dtype)
        if reduce_global:
            for ax in axes:
                total = jax.lax.psum(total, ax)
            return total
        return total.reshape((1,) * len(axes))

    ab = P(pod_axis, row_axis, col_axis) if pod_axis else P(row_axis, col_axis)
    fn = jax.jit(
        jax.shard_map(
            spmd,
            mesh=mesh,
            in_specs=(ab, ab, P(row_axis, col_axis)),
            out_specs=P() if reduce_global else P(*axes),
            check_vma=False,
        )
    )

    def call(a_dense, b_dense, m_dense):
        return fn(a_dense, b_dense, m_dense)

    call.lower = fn.lower
    return call
