"""Cannon-schedule distributed triangle counting (the paper's §5.1).

Device ``(x, y)`` of the ``q x q`` grid (mesh axes ``(row_axis, col_axis)``)
starts with the pre-skewed blocks ``A = U_{x,(x+y)%q}`` and
``B = U_{y,(x+y)%q}`` (see :mod:`repro.core.plan`) and performs ``q`` steps
of {count local pair against the static task list, shift A left, shift B
up}.  Shifts are single-blob ``ppermute`` collectives (paper's
serialization optimization); the next blocks are requested *before* the
local count so XLA can overlap communication with compute.

Multi-pod (2.5D, beyond-paper): with ``npods`` pods the blocks are
replicated across the ``pod`` axis, pod ``t`` starts at skew offset ``t``
and executes every ``npods``-th shift; the final count is a global psum.
Memory ×npods, shift traffic ÷npods — the communication-avoiding trade.

This module is a thin *configuration* of :mod:`repro.core.engine`: every
builder below just composes an OperandStore (CSR blob / dense / bit-tile),
the :class:`~repro.core.engine.CannonSchedule`, a count kernel, and a
Reduction — the scan/ppermute schedule body lives in the engine, once.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from . import engine
from .engine import (
    CannonSchedule,
    CSRStore,
    DenseStore,
    GridAxes,
    Reduction,
    TileStore,
    make_csr_kernel,
)

__all__ = [
    "build_cannon_fn",
    "build_cannon_stepper",
    "build_cannon_tile_fn",
    "build_cannon_dense_fn",
    "cannon_in_specs",
    "pod_stack_arrays",
]


def cannon_in_specs(
    row_axis: str, col_axis: str, pod_axis: Optional[str] = None
) -> Dict:
    """PartitionSpecs for the plan's stacked device arrays."""
    axes = GridAxes(row_axis, col_axis, pod_axis)
    return CSRStore(kernel=None).in_specs(axes)


def pod_stack_arrays(arrays: Dict, npods: int, q: int) -> Dict:
    """Stack A/B operands with per-pod skew offsets (numpy, host side).

    ``A0_t[x, y] = A0[x, (y+t) % q]`` and ``B0_t[x, y] = B0[(x+t) % q, y]``
    put pod ``t`` at Cannon skew offset ``t`` so it can execute shifts
    ``t, t+npods, ...`` only.  The planner's ``step_keep`` mask is
    pod-strided the same way: pod ``t``'s local step ``s`` is global
    shift ``t + s * npods``, so its mask slice is ``step_keep[..., t::npods]``.
    """
    import numpy as np

    out = dict(arrays)
    for key in ("a_indptr", "a_indices"):
        out[key] = np.stack(
            [np.roll(arrays[key], -t, axis=1) for t in range(npods)]
        )
    for key in ("b_indptr", "b_indices", "b_aug"):
        if key not in arrays:
            continue
        out[key] = np.stack(
            [np.roll(arrays[key], -t, axis=0) for t in range(npods)]
        )
    if "step_keep" in arrays:
        out["step_keep"] = np.stack(
            [arrays["step_keep"][:, :, t::npods] for t in range(npods)]
        )
    return out


def _cannon_parts(plan, mesh, *, row_axis, col_axis, pod_axis,
                  double_buffer=True, live_steps=None, elide_shifts=False):
    axes = GridAxes(row_axis, col_axis, pod_axis)
    npods = mesh.shape[pod_axis] if pod_axis else 1
    return axes, CannonSchedule(
        q=plan.q, axes=axes, npods=npods, double_buffer=double_buffer,
        live_steps=live_steps, elide_shifts=elide_shifts,
    )


def _coerce(plan):
    from .plan import as_plan

    return as_plan(plan)


def build_cannon_fn(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    pod_axis: Optional[str] = None,
    method: str = "search",
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    use_blob: bool = True,
    reduce_global: bool = True,
    tile_kernel_mode: Optional[str] = None,
    compress_lengths: bool = False,
    batched: bool = False,
    use_step_mask: Optional[bool] = None,
    double_buffer: bool = True,
    compact: Optional[bool] = None,
    elide_shifts: bool = False,
    reduce_strategy: str = "auto",
    fused_impl: str = "auto",
    fused_tile: Optional[int] = None,
):
    """Build the jitted SPMD counting function for ``plan`` on ``mesh``.

    ``plan`` may be a raw :class:`~repro.core.plan.TCPlan` or a pipeline
    :class:`~repro.pipeline.artifact.PlanArtifact`.  Returns a callable
    ``fn(**device_arrays)`` yielding the global triangle count (scalar)
    or per-device counts if ``reduce_global=False``; with
    ``batched=True`` the arrays carry a leading batch axis and the call
    returns per-graph counts (see ``engine.build_engine_fn``).
    ``method``: any registered CSR kernel — ``"search"`` (flat padding),
    ``"search2"`` (two-level length-bucketed — §Perf H1a; requires
    ``bucketize_plan``), ``"global"`` (gather-free keys), ``"fused"``
    (Pallas equality-panel + long fallback, DESIGN.md §5.1; requires a
    maxfrag-split plan from ``autotune='fused'``, and ``fused_impl``
    picks its backend: ``auto``/``pallas``/``pallas-interpret``/
    ``lax``).
    ``compress_lengths`` (§Perf H1b) ships row *lengths as uint16 pairs*
    instead of the int32 indptr inside the shift blob, cutting shifted
    bytes by ~(nb*2)/(nb*4+nnz*4).
    ``use_step_mask=None`` auto-enables sparsity-aware step skipping
    when the plan carries ``step_keep``; ``double_buffer`` selects the
    communication-overlapped two-generation scan body (default on).
    ``compact=None`` auto-enables the compacted kept-step schedule
    (dead-shift elision + fused multi-hop ppermutes, DESIGN.md §4.4)
    when the plan staged one that elides a step; the global/search2
    kernels additionally pick up planner-staged ``b_aug`` intersection
    keys when the plan carries them.  ``elide_shifts`` is a timing probe
    (counts are wrong for q > 1) used by the benchmark's shift/count
    attribution.  ``reduce_strategy`` selects the final reduction:
    ``"flat"`` (one psum per mesh axis), ``"tree"`` (the 2.5D staged
    reduce — joint grid psum + cross-pod binomial ppermute tree,
    DESIGN.md §4.5), or ``"auto"`` (tree whenever a power-of-two pod
    axis is present).
    """
    del tile_kernel_mode  # tile path has its own builder below
    plan = _coerce(plan)
    from .plan import resolve_compact_steps, resolve_step_mask

    use_step_mask = resolve_step_mask(plan, use_step_mask)
    npods = mesh.shape[pod_axis] if pod_axis else 1
    live = resolve_compact_steps(plan, compact, batched=batched, npods=npods)
    axes, schedule = _cannon_parts(
        plan, mesh, row_axis=row_axis, col_axis=col_axis, pod_axis=pod_axis,
        double_buffer=double_buffer, live_steps=live,
        elide_shifts=elide_shifts,
    )
    if method == "fused":
        engine.check_fused_split(plan)
    kernel = make_csr_kernel(
        method,
        dpad=plan.dmax,
        chunk=plan.chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        n_long=getattr(plan, "n_long", None),
        d_small=getattr(plan, "d_small", None),
        fused_impl=fused_impl,
        fused_tile=fused_tile,
    )
    # fused consumes staged keys only in its long-row fallback — with
    # n_long == 0 shipping the aug blob would be pure shift bytes
    fused_wants_aug = (
        method == "fused" and (getattr(plan, "n_long", None) or 0) > 0
    )
    store = CSRStore(
        kernel,
        use_blob=use_blob,
        compress_lengths=compress_lengths,
        dmax=plan.dmax,
        with_aug=(
            (method in ("global", "search2") or fused_wants_aug)
            and getattr(plan, "b_aug", None) is not None
        ),
    )
    return engine.build_engine_fn(
        mesh, axes, store, schedule,
        count_dtype=count_dtype,
        reduction=Reduction(
            global_sum=reduce_global, strategy=reduce_strategy
        ),
        batched=batched,
        use_step_mask=use_step_mask,
        hub=engine.HubCount.from_plan(plan, probe_shorter=probe_shorter),
    )


def build_cannon_stepper(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    method: str = "search",
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    use_step_mask: Optional[bool] = None,
    double_buffer: bool = True,
    compact: Optional[bool] = None,
):
    """Shift-at-a-time Cannon for fault-tolerant runs.

    Returns ``one_shift(state, masks, step=s) -> state`` (jitted SPMD)
    where ``state = (*carry_arrays, partial_counts)`` — with the default
    double-buffered schedule the carry is two payload generations
    ``(a_ptr, a_idx, b_ptr, b_idx) x 2``, built once from the plan
    arrays by ``one_shift.prime`` (which issues the prologue shift).
    The host loop owns the shift index, checkpointing state between
    shifts so a restarted job resumes mid-loop (EXPERIMENTS.md
    §Fault-tolerance).  Same engine body as :func:`build_cannon_fn` —
    only the loop owner differs.

    With a compacted plan (``compact=None`` auto, DESIGN.md §4.4) the
    host loop iterates ``one_shift.live_steps`` only — still passing
    original step indices, so checkpointed indices round-trip unchanged
    — and the carry is a *single* payload generation (4 arrays): each
    call's fused multi-hop shift lands exactly on the next live step, so
    there is no in-flight second buffer to keep.
    """
    plan = _coerce(plan)
    from .plan import resolve_compact_steps, resolve_step_mask

    if getattr(plan, "hub", None) is not None:
        raise ValueError(
            "the checkpointed stepper counts one schedule shift at a "
            "time and has no slot for the hub-split partial; plan with "
            "hub_split=False for fault-tolerant runs"
        )
    use_step_mask = resolve_step_mask(plan, use_step_mask)
    live = resolve_compact_steps(plan, compact)
    axes, schedule = _cannon_parts(
        plan, mesh, row_axis=row_axis, col_axis=col_axis, pod_axis=None,
        double_buffer=double_buffer and live is None, live_steps=live,
    )
    kernel = make_csr_kernel(
        method,
        dpad=plan.dmax,
        chunk=plan.chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
    )
    store = CSRStore(kernel, use_blob=False)
    # count_dtype binds the kernel and the masked-step zero; the
    # accumulator dtype follows the caller's acc array (the checkpointed
    # state owns it)
    return engine.build_engine_stepper(
        mesh, axes, store, schedule,
        count_dtype=count_dtype, use_step_mask=use_step_mask,
    )


def build_cannon_tile_fn(
    plan,
    tile_plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    mode: str = "popcount",
    interpret: bool = True,
    count_dtype=jnp.int32,
    reduce_global: bool = True,
    use_step_mask: Optional[bool] = None,
    double_buffer: bool = True,
    compact: Optional[bool] = None,
    reduce_strategy: str = "auto",
):
    """Cannon schedule with the Pallas bit-tile kernel as the count path.

    Tile stores shift exactly like the CSR blobs; the per-(device, shift)
    active-triple lists are static (planner-joined) and drive the kernel's
    scalar-prefetch grid.  ``interpret=True`` validates on CPU; on TPU pass
    ``interpret=False`` to run the Mosaic-lowered kernel.  The skip mask
    comes from the *CSR* plan (``plan.step_keep``); callers stage it
    alongside the tile arrays.  Under a compacted schedule the unrolled
    body selects each live step's triple list with a *static* index.
    """
    del tile_plan  # shapes travel with the device arrays
    plan = _coerce(plan)
    from .plan import resolve_compact_steps, resolve_step_mask

    if getattr(plan, "hub", None) is not None:
        raise ValueError(
            "the bit-tile path stages its own arrays and would drop the "
            "hub-split partial; plan with hub_split=False for method "
            "'tile'"
        )
    use_step_mask = resolve_step_mask(plan, use_step_mask)
    live = resolve_compact_steps(plan, compact)
    axes, schedule = _cannon_parts(
        plan, mesh, row_axis=row_axis, col_axis=col_axis, pod_axis=None,
        double_buffer=double_buffer, live_steps=live,
    )
    store = TileStore(mode=mode, interpret=interpret, count_dtype=count_dtype)
    return engine.build_engine_fn(
        mesh, axes, store, schedule,
        count_dtype=count_dtype,
        reduction=Reduction(
            global_sum=reduce_global, strategy=reduce_strategy
        ),
        use_step_mask=use_step_mask,
    )


def build_cannon_dense_fn(
    plan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    pod_axis: Optional[str] = None,
    acc_dtype=jnp.float32,
    reduce_global: bool = True,
    use_step_mask: Optional[bool] = None,
    double_buffer: bool = True,
    compact: Optional[bool] = None,
    reduce_strategy: str = "auto",
):
    """Dense-operand Cannon (oracle path): blocks as 0/1 float matrices."""
    plan = _coerce(plan)
    from .plan import resolve_compact_steps, resolve_step_mask

    if getattr(plan, "hub", None) is not None:
        raise ValueError(
            "the dense oracle path stages its own blocks and would drop "
            "the hub-split partial; plan with hub_split=False for "
            "method 'dense'"
        )
    use_step_mask = resolve_step_mask(plan, use_step_mask)
    npods = mesh.shape[pod_axis] if pod_axis else 1
    live = resolve_compact_steps(plan, compact, npods=npods)
    axes, schedule = _cannon_parts(
        plan, mesh, row_axis=row_axis, col_axis=col_axis, pod_axis=pod_axis,
        double_buffer=double_buffer, live_steps=live,
    )
    store = DenseStore(acc_dtype=acc_dtype)
    return engine.build_engine_fn(
        mesh, axes, store, schedule,
        count_dtype=acc_dtype,
        reduction=Reduction(
            global_sum=reduce_global, strategy=reduce_strategy
        ),
        use_step_mask=use_step_mask,
    )
