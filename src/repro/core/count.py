"""Per-shift block-pair counting — the compute hot spot, three paths.

All paths compute, for a static task list ``(ti, tj)`` (the nonzeros of the
device's mask block), ``sum_t |row_A(ti_t)  ∩  row_B(tj_t)|`` where A and B
are the two CSR blocks the device holds at the current Cannon/SUMMA step.

Paths (DESIGN.md §2):

* ``dense``   — ``sum((A @ Bᵀ) ⊙ M)``; MXU-shaped; oracle + small blocks.
* ``search``  — vectorized binary-search intersection, chunked over tasks;
  the scalable path for hyper-sparse giant blocks.  ``probe_shorter=True``
  probes the shorter fragment into the longer (the TPU re-expression of the
  paper's ⟨j,i,k⟩ hash-the-longer-list rule).
* ``tile``    — bit-packed 128×128 tile kernel (``repro.kernels.tc_tile``),
  wired in by :mod:`repro.core.cannon` when the plan carries tile stores.
* ``fused``   — the Pallas probe-gather + intersection + accumulate
  mega-kernel (``repro.kernels.tc_fused``, DESIGN.md §5.1); its long-row
  fallback reuses :func:`count_pair_search` /
  :func:`count_pair_search_global` from this module, so the fused path
  stays count-equivalent to ``search2`` by construction.

Everything here is pure ``jnp`` and shape-static, usable inside
``shard_map`` and under ``lax.scan``.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import compat

__all__ = [
    "aug_key_dtype",
    "count_pair_dense",
    "count_pair_search",
    "gather_rows",
]


def aug_key_dtype(base: int):
    """Dtype wide enough for row-encoded keys ``row * base + col``.

    Rows and cols are block-local (``< base``), so the largest key is
    ``base**2 - 1``.  int32 covers ``base <= 46340``; beyond that the key
    needs int64 — and if x64 is off, jax would *silently truncate* the
    ``astype(int64)`` back to int32, wrapping keys into collisions and
    corrupting counts (the historical bug this guard exists for).  Fail
    loudly instead of returning garbage.
    """
    if base * base - 1 <= np.iinfo(np.int32).max:
        return jnp.int32
    if not compat.x64_enabled():
        raise OverflowError(
            f"row-encoded intersection keys for block size nb={base - 1} "
            "exceed int32 (row * base + col needs int64); enable x64 "
            "(jax.config.update('jax_enable_x64', True)) to use the "
            "'global'/'search2' count paths on blocks this large"
        )
    return jnp.int64


def count_pair_dense(a_dense, b_dense, m_dense, *, acc_dtype=jnp.float32):
    """``sum((A @ Bᵀ) ⊙ M)`` — exact for 0/1 blocks.

    ``A: (nb, nb)`` rows=i cols=k; ``B: (nb, nb)`` rows=j cols=k;
    ``M: (nb, nb)`` mask at (i_local, j_local).
    """
    prod = jax.lax.dot_general(
        a_dense,
        b_dense,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    return jnp.sum(prod * m_dense, dtype=acc_dtype)


def gather_rows(indptr, indices, rows, dpad: int, sentinel: int):
    """Gather padded adjacency fragments ``(T, dpad)`` for ``rows`` (T,).

    Padding positions are filled with ``sentinel`` (greater than any valid
    local column id) so each returned row stays sorted — required by the
    binary-search probe.
    """
    start = indptr[rows]
    length = indptr[rows + 1] - start
    offs = jnp.arange(dpad, dtype=indptr.dtype)
    idx = start[:, None] + offs[None, :]
    valid = offs[None, :] < length[:, None]
    vals = indices[jnp.clip(idx, 0, indices.shape[0] - 1)]
    return jnp.where(valid, vals, sentinel), length


def _searchsorted_rows(keys, queries):
    """Row-wise searchsorted: keys (T, Dk) sorted rows; queries (T, Dq)."""
    return jax.vmap(
        lambda k, q: jnp.searchsorted(k, q, side="left")
    )(keys, queries)


def count_pair_search(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    *,
    dpad: int,
    chunk: int,
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    sentinel: Optional[int] = None,
):
    """Chunked vectorized set-intersection over the device's task list.

    ``ti, tj: (tmax,)`` local row ids into A / B; only the first ``tcount``
    are real (the rest are padding and masked out).  Tasks are processed in
    ``tmax / chunk`` chunks under ``lax.scan`` so the working set stays at
    ``O(chunk * dpad)`` regardless of block size.
    """
    tmax = ti.shape[0]
    nchunk = -(-tmax // chunk)
    pad = nchunk * chunk - tmax
    if pad:
        ti = jnp.concatenate([ti, jnp.zeros((pad,), ti.dtype)])
        tj = jnp.concatenate([tj, jnp.zeros((pad,), tj.dtype)])
    ti_c = ti.reshape(nchunk, chunk)
    tj_c = tj.reshape(nchunk, chunk)
    base = jnp.arange(nchunk)[:, None] * chunk + jnp.arange(chunk)[None, :]
    tvalid_c = base < tcount

    if sentinel is None:
        sentinel = a_indptr.shape[0]  # nb + 1 > any local col id

    def one_chunk(acc, args):
        rows_i, rows_j, valid = args
        a_vals, a_len = gather_rows(a_indptr, a_indices, rows_i, dpad, sentinel)
        b_vals, b_len = gather_rows(b_indptr, b_indices, rows_j, dpad, sentinel)
        if probe_shorter:
            swap = (a_len > b_len)[:, None]
            probe = jnp.where(swap, b_vals, a_vals)
            keys = jnp.where(swap, a_vals, b_vals)
            probe_len = jnp.minimum(a_len, b_len)
        else:
            probe, keys, probe_len = a_vals, b_vals, a_len
        pos = _searchsorted_rows(keys, probe)
        hit = (
            jnp.take_along_axis(
                keys, jnp.clip(pos, 0, keys.shape[1] - 1), axis=1
            )
            == probe
        )
        hit &= jnp.arange(dpad)[None, :] < probe_len[:, None]
        per_task = jnp.sum(hit, axis=1, dtype=count_dtype)
        per_task = jnp.where(valid, per_task, 0)
        return acc + jnp.sum(per_task, dtype=count_dtype), None

    acc0 = jnp.zeros((), dtype=count_dtype)
    acc, _ = jax.lax.scan(one_chunk, acc0, (ti_c, tj_c, tvalid_c))
    return acc


def count_pair_search_global(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    *,
    dpad: int,
    chunk: int,
    count_dtype=jnp.int32,
    aug_b=None,
    row_base: Optional[int] = None,
):
    """Gather-free-keys intersection: probe A fragments into a row-encoded
    *global* sorted view of B (``aug_b[e] = row(e) * (nb+1) + col(e)``).

    Only the probe side is gathered (padded to ``dpad``); the keys side is
    searched in place regardless of row length — so probe padding can be
    sized to the PROBE distribution alone (the §Perf H1a bucketing lever),
    and truncation bugs on long key rows are structurally impossible.
    """
    nb = b_indptr.shape[0] - 1
    base = row_base or (nb + 1)
    if aug_b is None:
        aug_b = build_aug_keys(b_indptr, b_indices)
    tmax = ti.shape[0]
    nchunk = -(-tmax // chunk)
    pad = nchunk * chunk - tmax
    if pad:
        ti = jnp.concatenate([ti, jnp.zeros((pad,), ti.dtype)])
        tj = jnp.concatenate([tj, jnp.zeros((pad,), tj.dtype)])
    ti_c = ti.reshape(nchunk, chunk)
    tj_c = tj.reshape(nchunk, chunk)
    pos0 = jnp.arange(nchunk)[:, None] * chunk + jnp.arange(chunk)[None, :]
    tvalid_c = pos0 < tcount
    sentinel = base - 1  # never a valid column id

    key_dtype = aug_key_dtype(base)

    def one_chunk(acc, args):
        rows_i, rows_j, valid = args
        a_vals, a_len = gather_rows(a_indptr, a_indices, rows_i, dpad, sentinel)
        keys = rows_j[:, None].astype(key_dtype) * base + a_vals.astype(
            key_dtype
        )
        pos = jnp.searchsorted(aug_b, keys.reshape(-1)).reshape(keys.shape)
        hit = (
            aug_b[jnp.clip(pos, 0, aug_b.shape[0] - 1)] == keys
        )
        hit &= jnp.arange(dpad)[None, :] < a_len[:, None]
        per_task = jnp.sum(hit, axis=1, dtype=count_dtype)
        per_task = jnp.where(valid, per_task, 0)
        return acc + jnp.sum(per_task, dtype=count_dtype), None

    acc0 = jnp.zeros((), dtype=count_dtype)
    acc, _ = jax.lax.scan(one_chunk, acc0, (ti_c, tj_c, tvalid_c))
    return acc


def build_aug_keys(b_indptr, b_indices):
    """Row-encoded global key array for count_pair_search_global."""
    nb = b_indptr.shape[0] - 1
    base = nb + 1
    key_dtype = aug_key_dtype(base)
    nnz = b_indices.shape[0]
    row_of = (
        jnp.searchsorted(
            b_indptr, jnp.arange(nnz, dtype=b_indptr.dtype), side="right"
        )
        - 1
    )
    return row_of.astype(key_dtype) * base + b_indices.astype(key_dtype)


_TWO_LEVEL_KW_WARNED = False


def _warn_two_level_kwargs(probe_shorter, sentinel) -> None:
    """One-time notice that the two-level path ignores search-only knobs.

    The global-key formulation *always* probes the A side into the
    row-encoded B keys and needs no padding sentinel, so
    ``probe_shorter``/``sentinel`` are accepted for signature
    compatibility with :func:`count_pair_search` but have no effect —
    callers porting from ``search`` must not believe the flags are
    honored.
    """
    global _TWO_LEVEL_KW_WARNED
    if _TWO_LEVEL_KW_WARNED:
        return
    ignored = []
    if probe_shorter is not True:
        ignored.append(f"probe_shorter={probe_shorter!r}")
    if sentinel is not None:
        ignored.append(f"sentinel={sentinel!r}")
    if ignored:
        _TWO_LEVEL_KW_WARNED = True
        warnings.warn(
            "count_pair_search_two_level ignores "
            + ", ".join(ignored)
            + ": the global-key path always probes the A side and needs "
            "no sentinel (this notice is emitted once per process)",
            UserWarning,
            stacklevel=3,
        )


def count_pair_search_two_level(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    n_long,
    *,
    dpad_long: int,
    dpad_short: int,
    chunk: int,
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    sentinel: Optional[int] = None,
    aug_b=None,
):
    """Length-bucketed intersection (§Perf hillclimb H1a).

    The planner statically reorders each device's task list so the
    ``n_long`` tasks whose *probe* fragment can exceed ``dpad_short``
    (under any Cannon pairing) come first; long chunks run at
    ``dpad_long`` probe padding, the rest at ``dpad_short``.  Both buckets
    use the gather-free-keys global search, so the keys side needs no
    padding at all.  For power-law graphs this removes the
    ``dmax/avg_len`` probe-padding waste on >90% of tasks
    (measured in EXPERIMENTS.md §Perf).

    ``probe_shorter``/``sentinel`` are search-path knobs the global-key
    formulation structurally ignores — passing non-defaults emits a
    one-time warning rather than silently dropping them.  ``aug_b``
    accepts planner-staged keys (DESIGN.md §5); when ``None`` the keys
    are built on device per call.
    """
    _warn_two_level_kwargs(probe_shorter, sentinel)
    tmax = ti.shape[0]
    n_long_c = -(-max(1, n_long) // chunk) * chunk
    n_long_c = min(n_long_c, tmax)

    long_count = jnp.minimum(tcount, n_long_c)
    short_count = jnp.maximum(tcount - n_long_c, 0)

    if aug_b is None:
        aug_b = build_aug_keys(b_indptr, b_indices)
    acc_long = count_pair_search_global(
        a_indptr,
        a_indices,
        b_indptr,
        b_indices,
        ti[:n_long_c],
        tj[:n_long_c],
        long_count,
        dpad=dpad_long,
        chunk=chunk,
        count_dtype=count_dtype,
        aug_b=aug_b,
    )
    if n_long_c >= tmax:
        return acc_long
    acc_short = count_pair_search_global(
        a_indptr,
        a_indices,
        b_indptr,
        b_indices,
        ti[n_long_c:],
        tj[n_long_c:],
        short_count,
        dpad=dpad_short,
        chunk=chunk,
        count_dtype=count_dtype,
        aug_b=aug_b,
    )
    return acc_long + acc_short
