"""2D cyclic decomposition of the upper-triangular adjacency matrix.

The processor grid is ``r x c`` (square ``q x q`` for Cannon; SUMMA accepts
rectangular).  Following the paper, matrix entry ``(i, j)`` belongs to block
``(i % r, j % c)`` with *transformed* (local) index ``(i // r, j // c)`` —
"the adjacency list of a vertex v_i is accessed using the transformed index
v_i ÷ √p in the per-processor CSR representation".

Because L = Uᵀ, a single cyclic decomposition of U provides everything:

* the task (mask) block of device ``(x, y)`` is ``U_{x,y}``;
* the Cannon "A" operand at shift ``s`` is ``U_{x, (x+y+s) % q}`` (rows i,
  columns k);
* the Cannon "B" operand is ``L_{(x+y+s) % q, y} = (U_{y, (x+y+s) % q})ᵀ`` —
  i.e. the *same* block family, read as rows-j-by-columns-k.  The device
  therefore intersects rows of two U blocks sharing their column range,
  which is exactly Eq. (6) of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .graph import Graph

__all__ = ["BlockCSR", "cyclic_blocks", "block_of", "local_index"]


def block_of(i: np.ndarray, j: np.ndarray, r: int, c: int):
    return i % r, j % c


def local_index(i: np.ndarray, j: np.ndarray, r: int, c: int):
    return i // r, j // c


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """One cyclic block of U in CSR form with a doubly-compressed row list.

    ``active_rows`` lists local rows with non-empty adjacency fragments —
    the paper's doubly-sparse traversal structure; everything else loops
    only over these.
    """

    bx: int
    by: int
    n_rows: int  # local rows = ceil(n / r)
    n_cols: int  # local cols = ceil(n / c)
    indptr: np.ndarray  # (n_rows + 1,) int64
    indices: np.ndarray  # (nnz,) int64 local column ids, sorted per row
    active_rows: np.ndarray  # (n_active,) int64

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def max_row_len(self) -> int:
        if self.n_rows == 0:
            return 0
        return int(np.max(np.diff(self.indptr), initial=0))


def cyclic_blocks(graph: Graph, r: int, c: int) -> List[List[BlockCSR]]:
    """Decompose U(graph) into an ``r x c`` grid of cyclic blocks.

    Assumes the graph is already degree-ordered (the decomposition is valid
    regardless; balance relies on the ordering).  Returns ``blocks[x][y]``.
    """
    n = graph.n
    rows_loc = -(-n // r)
    cols_loc = -(-n // c)
    i = graph.edges[:, 0]
    j = graph.edges[:, 1]
    bx, by = block_of(i, j, r, c)
    li, lj = local_index(i, j, r, c)

    # bucket edges by block id, then build each block's CSR in one pass
    bid = bx * c + by
    order = np.lexsort((lj, li, bid))
    bid_s, li_s, lj_s = bid[order], li[order], lj[order]
    boundaries = np.searchsorted(bid_s, np.arange(r * c + 1))

    out: List[List[BlockCSR]] = []
    for x in range(r):
        row_blocks = []
        for y in range(c):
            b = x * c + y
            lo, hi = boundaries[b], boundaries[b + 1]
            rows = li_s[lo:hi]
            cols = lj_s[lo:hi]
            counts = np.bincount(rows, minlength=rows_loc)
            indptr = np.zeros(rows_loc + 1, dtype=np.int64)
            np.cumsum(counts, out=indptr[1:])
            active = np.nonzero(counts)[0]
            row_blocks.append(
                BlockCSR(
                    bx=x,
                    by=y,
                    n_rows=rows_loc,
                    n_cols=cols_loc,
                    indptr=indptr,
                    indices=cols.astype(np.int64),
                    active_rows=active.astype(np.int64),
                )
            )
        out.append(row_blocks)
    return out
