"""2D cyclic decomposition of the upper-triangular adjacency matrix.

The processor grid is ``r x c`` (square ``q x q`` for Cannon; SUMMA accepts
rectangular).  Following the paper, matrix entry ``(i, j)`` belongs to block
``(i % r, j % c)`` with *transformed* (local) index ``(i // r, j // c)`` —
"the adjacency list of a vertex v_i is accessed using the transformed index
v_i ÷ √p in the per-processor CSR representation".

Because L = Uᵀ, a single cyclic decomposition of U provides everything:

* the task (mask) block of device ``(x, y)`` is ``U_{x,y}``;
* the Cannon "A" operand at shift ``s`` is ``U_{x, (x+y+s) % q}`` (rows i,
  columns k);
* the Cannon "B" operand is ``L_{(x+y+s) % q, y} = (U_{y, (x+y+s) % q})ᵀ`` —
  i.e. the *same* block family, read as rows-j-by-columns-k.  The device
  therefore intersects rows of two U blocks sharing their column range,
  which is exactly Eq. (6) of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .graph import Graph

__all__ = [
    "BlockCSR",
    "CyclicCOO",
    "cyclic_coo",
    "blocks_from_coo",
    "cyclic_blocks",
    "block_of",
    "local_index",
]


def block_of(i: np.ndarray, j: np.ndarray, r: int, c: int):
    return i % r, j % c


def local_index(i: np.ndarray, j: np.ndarray, r: int, c: int):
    return i // r, j // c


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """One cyclic block of U in CSR form with a doubly-compressed row list.

    ``active_rows`` lists local rows with non-empty adjacency fragments —
    the paper's doubly-sparse traversal structure; everything else loops
    only over these.
    """

    bx: int
    by: int
    n_rows: int  # local rows = ceil(n / r)
    n_cols: int  # local cols = ceil(n / c)
    indptr: np.ndarray  # (n_rows + 1,) int64
    indices: np.ndarray  # (nnz,) int64 local column ids, sorted per row
    active_rows: np.ndarray  # (n_active,) int64

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def max_row_len(self) -> int:
        if self.n_rows == 0:
            return 0
        return int(np.max(np.diff(self.indptr), initial=0))


@dataclasses.dataclass(frozen=True)
class CyclicCOO:
    """One lexsorted pass over the 2D-cyclic decomposition of U.

    The single sort by ``(block id, local row, local col)`` is everything
    the packers need: per-block slices are contiguous (``starts``), the
    per-block CSR indptr is a row-count cumsum (``rowcnt``), and block-local
    COO scatter offsets are ``arange(m) - starts[bid_s]``.  This replaces
    the per-block bincount/cumsum loops that used to run q×q times.
    """

    r: int
    c: int
    rows_loc: int  # local rows per block = ceil(n / r)
    cols_loc: int  # local cols per block = ceil(n / c)
    bid_s: np.ndarray  # (m,) block id = bx * c + by, sorted
    li_s: np.ndarray  # (m,) local row, sorted within block
    lj_s: np.ndarray  # (m,) local col, sorted within (block, row)
    counts: np.ndarray  # (r*c,) nnz per block
    starts: np.ndarray  # (r*c + 1,) prefix offsets into the sorted arrays
    rowcnt: np.ndarray  # (r*c, rows_loc) nnz per (block, local row)

    @property
    def nnz_max(self) -> int:
        return int(self.counts.max()) if self.counts.size else 0

    @property
    def row_len_max(self) -> int:
        return int(self.rowcnt.max()) if self.rowcnt.size else 0

    def offsets(self) -> np.ndarray:
        """Position of each sorted entry within its block."""
        return np.arange(self.bid_s.shape[0], dtype=np.int64) - self.starts[
            self.bid_s
        ]


def cyclic_coo(graph: Graph, r: int, c: int) -> CyclicCOO:
    """The lexsort pass: sort U's edges by (block, local row, local col)."""
    n = graph.n
    rows_loc = -(-n // r)
    cols_loc = -(-n // c)
    i = graph.edges[:, 0]
    j = graph.edges[:, 1]
    bx, by = block_of(i, j, r, c)
    li, lj = local_index(i, j, r, c)

    bid = bx * c + by
    order = np.lexsort((lj, li, bid))
    bid_s, li_s, lj_s = bid[order], li[order], lj[order]
    counts = np.bincount(bid_s, minlength=r * c)
    starts = np.zeros(r * c + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    rowcnt = np.bincount(
        bid_s * rows_loc + li_s, minlength=r * c * rows_loc
    ).reshape(r * c, rows_loc)
    return CyclicCOO(
        r=r,
        c=c,
        rows_loc=rows_loc,
        cols_loc=cols_loc,
        bid_s=bid_s,
        li_s=li_s,
        lj_s=lj_s,
        counts=counts,
        starts=starts,
        rowcnt=rowcnt,
    )


def blocks_from_coo(coo: CyclicCOO) -> List[List[BlockCSR]]:
    """Materialize ``BlockCSR`` views of a sorted pass (cheap slicing)."""
    r, c = coo.r, coo.c
    out: List[List[BlockCSR]] = []
    for x in range(r):
        row_blocks = []
        for y in range(c):
            b = x * c + y
            lo, hi = coo.starts[b], coo.starts[b + 1]
            indptr = np.zeros(coo.rows_loc + 1, dtype=np.int64)
            np.cumsum(coo.rowcnt[b], out=indptr[1:])
            row_blocks.append(
                BlockCSR(
                    bx=x,
                    by=y,
                    n_rows=coo.rows_loc,
                    n_cols=coo.cols_loc,
                    indptr=indptr,
                    indices=coo.lj_s[lo:hi].astype(np.int64),
                    active_rows=np.nonzero(coo.rowcnt[b])[0].astype(np.int64),
                )
            )
        out.append(row_blocks)
    return out


def cyclic_blocks(graph: Graph, r: int, c: int) -> List[List[BlockCSR]]:
    """Decompose U(graph) into an ``r x c`` grid of cyclic blocks.

    Assumes the graph is already degree-ordered (the decomposition is valid
    regardless; balance relies on the ordering).  Returns ``blocks[x][y]``.
    """
    return blocks_from_coo(cyclic_coo(graph, r, c))
