"""Unified schedule engine: one pluggable runtime for every distributed
count (DESIGN.md §4-§6).

A distributed triangle count is expressed as the composition

    (OperandStore, ShiftSchedule, CountKernel, Reduction)

and this module generates the jitted ``shard_map`` SPMD function from the
parts — the scan/ppermute schedule bodies that used to be quadruplicated
across ``cannon.py`` / ``summa.py`` / ``onedim.py`` live here exactly once.

* :class:`OperandStore` subclasses encapsulate *payload representation*:
  how per-device blocks are packed for shifting (single-blob CSR with
  optional uint16 length compression, dense 0/1 blocks, bit-packed
  128x128 tiles) and how a payload is unpacked back into count-kernel
  arguments.
* :class:`ShiftSchedule` subclasses encapsulate *permutation structure*:
  Cannon's q-step left/up rotation with 2.5D pod striding, SUMMA's
  one-hot-psum broadcast rounds, and the 1D ring rotation.  Each yields a
  ``(carry0, body, nsteps)`` triple for one shared ``lax.scan`` driver;
  the same body also powers the host-driven stepper used for fault
  tolerance (:func:`build_engine_stepper`).
* CountKernels are the existing :mod:`repro.core.count` paths behind one
  signature ``kernel(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt) -> scalar``
  (see :func:`make_csr_kernel` / :data:`CSR_KERNELS`); dense and tile
  stores carry their own kernels behind the store-level ``count`` hook.
* :class:`Reduction` turns per-device per-step partials into the global
  scalar (psum over every mesh axis) or per-device outputs.

All jax API calls with cross-version drift go through :mod:`repro.compat`
so the engine runs unchanged on jax 0.4.x and >= 0.5.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat
from . import count as count_mod
from .blob import blob_layout, pack_blob, unpack_blob

__all__ = [
    "GridAxes",
    "RingAxes",
    "OperandStore",
    "CSRStore",
    "DenseStore",
    "TileStore",
    "SummaCSRStore",
    "OneDCSRStore",
    "ShiftSchedule",
    "CannonSchedule",
    "SummaSchedule",
    "RingSchedule",
    "Reduction",
    "HubCount",
    "CSR_KERNELS",
    "MASK_NAME",
    "register_csr_kernel",
    "make_csr_kernel",
    "masked_count",
    "build_engine_fn",
    "build_engine_stepper",
    "restage_device_arrays",
    "shift_perm",
    "tree_ppermute",
    "pod_tree_allreduce",
    "chain_broadcast",
]


# ======================================================================
# mesh axes
# ======================================================================
@dataclasses.dataclass(frozen=True)
class GridAxes:
    """Named mesh axes of a 2D (optionally 2.5D) grid."""

    row: str = "data"
    col: str = "model"
    pod: Optional[str] = None

    @property
    def all(self) -> Tuple[str, ...]:
        return (self.pod, self.row, self.col) if self.pod else (self.row, self.col)


@dataclasses.dataclass(frozen=True)
class RingAxes:
    """A single mesh axis forming the 1D ring."""

    axis: str = "flat"

    @property
    def all(self) -> Tuple[str, ...]:
        return (self.axis,)


# ======================================================================
# shared shift helpers
# ======================================================================
def shift_perm(size: int, k: int):
    """ppermute pairs shifting *towards lower index* by ``k`` (left/up)."""
    return [(s, (s - k) % size) for s in range(size)]


def tree_ppermute(tree, axis: str, perm):
    """Shift every leaf of a payload pytree along one mesh axis."""
    return jax.tree.map(lambda a: compat.ppermute(a, axis, perm), tree)


def _squeeze(a, lead: int):
    return a.reshape(a.shape[lead:])


# ======================================================================
# delta re-stage path (DESIGN.md §4.7)
# ======================================================================
def restage_device_arrays(
    prev_host: Dict[str, "jnp.ndarray"],
    prev_staged: Dict[str, "jnp.ndarray"],
    new_host: Dict[str, "jnp.ndarray"],
) -> Tuple[Dict[str, "jnp.ndarray"], int]:
    """Stage ``new_host`` arrays, reusing the parent's device buffers for
    every array an edge delta left unchanged.

    The splice in ``apply_delta`` copies only arrays it touches, so a
    clean array is often the *same object* as the parent's (identity
    fast path); otherwise a value comparison against the parent's host
    array decides — e.g. ``step_keep`` frequently survives a delta
    byte-identical even though it was recomputed.  Returns the staged
    dict and how many device buffers were reused (skipped uploads).
    """
    import numpy as np

    out: Dict[str, jnp.ndarray] = {}
    reused = 0
    for name, host in new_host.items():
        prev = prev_host.get(name)
        staged = prev_staged.get(name)
        same = (
            staged is not None
            and prev is not None
            and prev.shape == host.shape
            and prev.dtype == host.dtype
            and (prev is host or np.array_equal(prev, host))
        )
        if same:
            out[name] = staged
            reused += 1
        else:
            out[name] = jnp.asarray(host)
    return out, reused


# ======================================================================
# CSR count-kernel registry — "behind one signature"
# ======================================================================
# Every CSR kernel factory returns
#   kernel(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt) -> scalar count
# with all plan-derived padding/chunk parameters bound at build time.
CSR_KERNELS: Dict[str, Callable] = {}


def register_csr_kernel(name: str, factory: Callable) -> None:
    """Register a CSR count-kernel factory under ``name``.

    ``factory(dpad=..., chunk=..., probe_shorter=..., count_dtype=...,
    sentinel=..., n_long=..., d_small=..., **extra) -> kernel``.
    ``extra`` carries method-specific knobs (the fused kernel's
    ``fused_tile``/``fused_impl``/``fused_long_fallback``); factories
    must tolerate and ignore keys they don't own.
    """
    CSR_KERNELS[name] = factory


def _search_factory(*, dpad, chunk, probe_shorter, count_dtype, sentinel,
                    n_long, d_small, **extra):
    del n_long, d_small, extra
    return functools.partial(
        count_mod.count_pair_search,
        dpad=dpad,
        chunk=chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        sentinel=sentinel,
    )


def _search2_factory(*, dpad, chunk, probe_shorter, count_dtype, sentinel,
                     n_long, d_small, **extra):
    # sentinel is plan-derived: builders pass it unconditionally with no
    # user intent behind it, so drop it here and spare engine users the
    # one-time ignored-kwarg warning inside count_pair_search_two_level.
    # probe_shorter is deliberately forwarded: a non-default value only
    # ever comes from an explicit user request (count_triangles(
    # probe_shorter=False)) — exactly the search-to-search2 porting
    # mistake the warning exists to surface.
    del sentinel, extra
    if n_long is None or d_small is None:
        raise ValueError(
            "method 'search2' needs a bucketized plan (bucketize_plan) "
            "providing n_long/d_small"
        )

    def kernel(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt, aug_b=None):
        return count_mod.count_pair_search_two_level(
            a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt, n_long,
            dpad_long=dpad,
            dpad_short=d_small,
            chunk=chunk,
            probe_shorter=probe_shorter,
            count_dtype=count_dtype,
            aug_b=aug_b,
        )

    return kernel


def _global_factory(*, dpad, chunk, probe_shorter, count_dtype, sentinel,
                    n_long, d_small, **extra):
    del probe_shorter, sentinel, n_long, d_small, extra
    return functools.partial(
        count_mod.count_pair_search_global,
        dpad=dpad,
        chunk=chunk,
        count_dtype=count_dtype,
    )


def _fused_factory(*, dpad, chunk, probe_shorter, count_dtype, sentinel,
                   n_long, d_small, **extra):
    """Fused panel kernel + long-row fallback (DESIGN.md §5.1).

    Needs the *two-sided* (maxfrag) split: under the probe-only split a
    B fragment longer than ``d_small`` would be silently truncated by
    the equality panel — builders enforce the split provenance, this
    factory only enforces that a split exists at all.
    """
    if n_long is None or d_small is None:
        raise ValueError(
            "method 'fused' needs a maxfrag-split plan: re-plan with "
            "autotune='fused' providing n_long/d_small"
        )
    from ..kernels.tc_fused import count_pair_fused
    from ..runtime import faultinject

    faultinject.fire("fused")

    tile = extra.get("fused_tile")
    impl = extra.get("fused_impl", "auto")
    long_fallback = extra.get("fused_long_fallback", "global")

    def kernel(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt, aug_b=None):
        return count_pair_fused(
            a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt,
            n_long=n_long,
            d_small=d_small,
            dpad_long=dpad,
            chunk=chunk,
            tile=tile,
            count_dtype=count_dtype,
            impl=impl,
            long_fallback=long_fallback,
            probe_shorter=probe_shorter,
            sentinel=sentinel,
            aug_b=aug_b,
        )

    return kernel


register_csr_kernel("search", _search_factory)
register_csr_kernel("search2", _search2_factory)
register_csr_kernel("global", _global_factory)
register_csr_kernel("fused", _fused_factory)


def check_fused_split(plan) -> None:
    """Refuse ``method='fused'`` on plans without the two-sided split.

    ``bucketize_plan`` and the default autotune stage classify tasks by
    the PROBE fragment only — sound for the global-search paths (keys
    are searched unpadded) but NOT for the fused panel, which gathers
    both fragments at ``d_small`` and would silently truncate a long B
    row into a wrong count.  Only plans whose autotune report carries
    ``split='maxfrag'`` (planner ``autotune='fused'``) are accepted.
    """
    report = getattr(plan, "autotune", None) or {}
    if report.get("split") != "maxfrag":
        raise ValueError(
            "method 'fused' requires a plan with the two-sided maxfrag "
            "split (plan with autotune='fused'); got "
            f"split={report.get('split')!r} — a probe-only split would "
            "truncate long B fragments and miscount"
        )


def make_csr_kernel(
    method: str,
    *,
    dpad: int,
    chunk: int,
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    sentinel: Optional[int] = None,
    n_long: Optional[int] = None,
    d_small: Optional[int] = None,
    **extra,
) -> Callable:
    """Build a registered CSR kernel with plan parameters bound."""
    try:
        factory = CSR_KERNELS[method]
    except KeyError:
        raise ValueError(
            f"unknown CSR count method {method!r}; "
            f"registered: {sorted(CSR_KERNELS)}"
        ) from None
    return factory(
        dpad=dpad,
        chunk=chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        sentinel=sentinel,
        n_long=n_long,
        d_small=d_small,
        **extra,
    )


# ======================================================================
# operand stores
# ======================================================================
class OperandStore:
    """Payload representation: pack/unpack + kernel-argument extraction.

    Contract (all methods trace inside ``shard_map``):

    * ``operand_names`` / ``static_names`` — plan device-array names, in
      call order (operands travel; statics stay put).
    * ``in_specs(axes)``  — PartitionSpec per array name.
    * ``lead(name, axes)`` — number of leading mesh block-dims shard_map
      prefixes onto that array (stripped by ``localize``).
    * ``payload(local)``  — packed shiftable state (a pytree; schedules
      treat it opaquely and shift it with :func:`tree_ppermute`).
    * ``count(state, local, step, ctx)`` — unpack ``state`` and run the
      bound count kernel for one schedule step.
    """

    operand_names: Sequence[str] = ()
    static_names: Sequence[str] = ()

    @property
    def names(self):
        return tuple(self.operand_names) + tuple(self.static_names)

    def in_specs(self, axes) -> Dict[str, P]:
        raise NotImplementedError

    def lead(self, name: str, axes) -> int:
        raise NotImplementedError

    def localize(self, named: Dict, axes) -> Dict:
        return {k: _squeeze(v, self.lead(k, axes)) for k, v in named.items()}

    def payload(self, local: Dict):
        raise NotImplementedError

    def count(self, state, local: Dict, step, ctx):
        raise NotImplementedError


class CSRStore(OperandStore):
    """CSR-block operands shifted as single int32 blobs (paper's
    serialization optimization), with optional uint16 length compression
    (§Perf H1b: ship row-length *pairs* instead of the int32 indptr and
    rebuild the indptr with one cumsum after each receive).

    ``with_aug=True`` adds the planner-staged row-encoded intersection
    keys (``b_aug``, DESIGN.md §5) as an extra payload leaf travelling
    with the B operand: the keys shift with the blocks, so the
    ``global``/``search2`` kernels never rebuild them on device.  The
    aug leaf stays outside the int32 blob — its dtype is plan-chosen
    (``aug_key_dtype``) and may be int64.
    """

    operand_names = ("a_indptr", "a_indices", "b_indptr", "b_indices")
    static_names = ("m_ti", "m_tj", "m_cnt")

    def __init__(self, kernel, *, use_blob: bool = True,
                 compress_lengths: bool = False, dmax: Optional[int] = None,
                 with_aug: bool = False):
        if compress_lengths:
            assert use_blob, "length compression only applies to blob shifts"
            assert dmax is not None and dmax < 65536, (
                "uint16 length compression needs d < 2^16"
            )
        self.kernel = kernel
        self.use_blob = use_blob
        self.compress_lengths = compress_lengths
        self.with_aug = with_aug
        if with_aug:
            self.operand_names = self.operand_names + ("b_aug",)
        self._layouts = {}

    def in_specs(self, axes):
        ab = P(*axes.all)
        m = P(axes.row, axes.col)
        specs = dict(
            a_indptr=ab, a_indices=ab, b_indptr=ab, b_indices=ab,
            m_ti=m, m_tj=m, m_cnt=m,
        )
        if self.with_aug:
            specs["b_aug"] = ab
        return specs

    def lead(self, name, axes):
        return len(axes.all) if name in self.operand_names else 2

    # -- uint16 length compression ------------------------------------
    @staticmethod
    def _pack_lengths(ptr):
        """(nb+1,) indptr -> (ceil(nb/2),) int32 of uint16 length pairs."""
        lens = jnp.diff(ptr).astype(jnp.int32)
        if lens.shape[0] % 2:
            lens = jnp.concatenate([lens, jnp.zeros((1,), jnp.int32)])
        return lens[0::2] | (lens[1::2] << 16)

    @staticmethod
    def _unpack_lengths(packed, nb):
        lo = packed & 0xFFFF
        hi = (packed >> 16) & 0xFFFF
        lens = jnp.stack([lo, hi], axis=1).reshape(-1)[:nb]
        return jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(lens, dtype=jnp.int32)]
        )

    # -- pack / unpack -------------------------------------------------
    def payload(self, local):
        a_ptr, a_idx = local["a_indptr"], local["a_indices"]
        b_ptr, b_idx = local["b_indptr"], local["b_indices"]
        aug = local["b_aug"] if self.with_aug else None
        if not self.use_blob:
            b_state = (b_ptr, b_idx) if aug is None else (b_ptr, b_idx, aug)
            return ((a_ptr, a_idx), b_state)
        self._nb = a_ptr.shape[0] - 1
        if self.compress_lengths:
            a_head, b_head = self._pack_lengths(a_ptr), self._pack_lengths(b_ptr)
        else:
            a_head, b_head = a_ptr, b_ptr
        self._layouts["a"], _ = blob_layout([a_head.shape, a_idx.shape])
        self._layouts["b"], _ = blob_layout([b_head.shape, b_idx.shape])
        b_blob = pack_blob([b_head, b_idx])
        b_state = b_blob if aug is None else (b_blob, aug)
        return (pack_blob([a_head, a_idx]), b_state)

    def _unpack(self, blob, side):
        head, idx = unpack_blob(blob, self._layouts[side])
        if self.compress_lengths:
            head = self._unpack_lengths(head, self._nb)
        return head, idx

    def count(self, state, local, step, ctx):
        del step, ctx
        a_state, b_state = state
        aug = None
        if self.use_blob:
            a_ptr, a_idx = self._unpack(a_state, "a")
            if self.with_aug:
                b_blob, aug = b_state
            else:
                b_blob = b_state
            b_ptr, b_idx = self._unpack(b_blob, "b")
        else:
            a_ptr, a_idx = a_state
            if self.with_aug:
                b_ptr, b_idx, aug = b_state
            else:
                b_ptr, b_idx = b_state
        extra = {} if aug is None else dict(aug_b=aug)
        return self.kernel(
            a_ptr, a_idx, b_ptr, b_idx,
            local["m_ti"], local["m_tj"], local["m_cnt"],
            **extra,
        )


class DenseStore(OperandStore):
    """Dense 0/1 block operands (oracle path): count = sum((A@Bᵀ)⊙M)."""

    operand_names = ("a_dense", "b_dense")
    static_names = ("m_dense",)

    def __init__(self, *, acc_dtype=jnp.float32):
        self.acc_dtype = acc_dtype

    def in_specs(self, axes):
        ab = P(*axes.all)
        return dict(a_dense=ab, b_dense=ab, m_dense=P(axes.row, axes.col))

    def lead(self, name, axes):
        return len(axes.all) if name in self.operand_names else 2

    def payload(self, local):
        return (local["a_dense"], local["b_dense"])

    def count(self, state, local, step, ctx):
        del step, ctx
        a, b = state
        return count_mod.count_pair_dense(
            a, b, local["m_dense"], acc_dtype=self.acc_dtype
        )


class TileStore(OperandStore):
    """Bit-packed 128x128 tile operands driving the Pallas kernel.

    Tile stores shift exactly like CSR blobs; the per-(device, shift)
    active-triple lists are static (planner-joined) and selected by the
    schedule's step index.
    """

    operand_names = ("a_tiles", "b_tiles")
    static_names = ("m_tiles", "triples")

    def __init__(self, *, mode: str = "popcount", interpret: bool = True,
                 count_dtype=jnp.int32):
        self.mode = mode
        self.interpret = interpret
        self.count_dtype = count_dtype

    def in_specs(self, axes):
        spec = P(axes.row, axes.col)
        return {k: spec for k in self.names}

    def lead(self, name, axes):
        del name
        return 2

    def payload(self, local):
        return (local["a_tiles"], local["b_tiles"])

    def count(self, state, local, step, ctx):
        del ctx
        from ..kernels.tc_tile.tc_tile import tile_triple_counts

        a_cur, b_cur = state
        per = tile_triple_counts(
            local["triples"][step], a_cur, b_cur, local["m_tiles"],
            mode=self.mode, interpret=self.interpret,
        )
        return jnp.sum(per, dtype=self.count_dtype)


class SummaCSRStore(OperandStore):
    """CSR operands for SUMMA broadcast rounds.

    Nothing is carried between steps; instead the B operand holds
    ``npan = ceil(c/r)`` panels per device and :meth:`select` realizes
    step ``z``'s (A, B) panel pair per the ``broadcast`` strategy:

    * ``"onehot"`` — masked psums (XLA lowers each to an all-reduce
      moving ``2·S·(n-1)/n`` bytes — strictly more than a broadcast);
    * ``"chain"`` — masked ppermute doubling chains
      (:func:`chain_broadcast`, ``S·(n-1)/n`` bytes — half the psum).
      Chain rounds need *static* round indices (the ppermute pairs are
      trace constants), so the schedule must run its unrolled body —
      :func:`~repro.core.summa.build_summa_fn` arranges this.

    ``elide_broadcast=True`` is the count-only timing probe (mirroring
    Cannon's ``elide_shifts``): every device counts its *local* panel
    pair, no collectives — counts are wrong for grids > 1x1.
    """

    operand_names = ("a_indptr", "a_indices", "b_indptr", "b_indices")
    static_names = ("m_ti", "m_tj", "m_cnt")

    def __init__(self, kernel, *, r: int, c: int, broadcast: str = "onehot",
                 elide_broadcast: bool = False):
        if broadcast not in ("onehot", "chain"):
            raise ValueError(
                f"unknown broadcast strategy {broadcast!r}; "
                "expected 'onehot' or 'chain'"
            )
        self.kernel = kernel
        self.r = r
        self.c = c
        self.broadcast = broadcast
        self.elide_broadcast = elide_broadcast

    def in_specs(self, axes):
        spec = P(axes.row, axes.col)
        return {k: spec for k in self.names}

    def lead(self, name, axes):
        del name, axes
        return 2

    def payload(self, local):  # SUMMA carries no shift state
        del local
        return ()

    def select(self, local, z, ctx):
        """Broadcast of step ``z``'s A panel (along the grid row, from
        owner column ``z % c``) and B panel (along the grid column, from
        owner row ``z % r``, local slot ``z // r``)."""
        a_ptr, a_idx = local["a_indptr"], local["a_indices"]
        b_ptr, b_idx = local["b_indptr"], local["b_indices"]
        if self.elide_broadcast:
            return ((a_ptr, a_idx), (b_ptr[z // self.r], b_idx[z // self.r]))
        with jax.named_scope("tc_broadcast"):
            if self.broadcast == "chain":
                if isinstance(z, jax.core.Tracer):
                    raise ValueError(
                        "chain broadcast needs static round indices "
                        "(ppermute pairs are trace constants): run the "
                        "unrolled schedule body (live_steps set)"
                    )
                z = int(z)
                pa_ptr = chain_broadcast(
                    a_ptr, ctx.axes.col, self.c, z % self.c
                )
                pa_idx = chain_broadcast(
                    a_idx, ctx.axes.col, self.c, z % self.c
                )
                slot = z // self.r
                pb_ptr = chain_broadcast(
                    b_ptr[slot], ctx.axes.row, self.r, z % self.r
                )
                pb_idx = chain_broadcast(
                    b_idx[slot], ctx.axes.row, self.r, z % self.r
                )
                return ((pa_ptr, pa_idx), (pb_ptr, pb_idx))
            owna = (
                ctx.axis_index(ctx.axes.col) == z % self.c
            ).astype(a_ptr.dtype)
            pa_ptr = jax.lax.psum(a_ptr * owna, ctx.axes.col)
            pa_idx = jax.lax.psum(a_idx * owna, ctx.axes.col)
            slot = z // self.r
            ownb = (
                ctx.axis_index(ctx.axes.row) == z % self.r
            ).astype(b_ptr.dtype)
            pb_ptr = jax.lax.psum(b_ptr[slot] * ownb, ctx.axes.row)
            pb_idx = jax.lax.psum(b_idx[slot] * ownb, ctx.axes.row)
            return ((pa_ptr, pa_idx), (pb_ptr, pb_idx))

    def count(self, state, local, step, ctx):
        del step, ctx
        (a_ptr, a_idx), (b_ptr, b_idx) = state
        return self.kernel(
            a_ptr, a_idx, b_ptr, b_idx,
            local["m_ti"], local["m_tj"], local["m_cnt"],
        )


class OneDCSRStore(OperandStore):
    """1D-ring operands: each device's own row-block CSR rotates as one
    blob; tasks are grouped by owner-of-j and the group matching the
    currently-held block is selected each step."""

    operand_names = ("indptr", "indices")
    static_names = ("t_i", "t_j", "t_cnt")

    def __init__(self, kernel, *, p: int):
        self.kernel = kernel
        self.p = p
        self._layout = None

    def in_specs(self, axes):
        return {k: P(axes.axis) for k in self.names}

    def lead(self, name, axes):
        del name, axes
        return 1

    def payload(self, local):
        own_ptr, own_idx = local["indptr"], local["indices"]
        self._layout, _ = blob_layout([own_ptr.shape, own_idx.shape])
        return pack_blob([own_ptr, own_idx])

    def count(self, state, local, step, ctx):
        b_ptr, b_idx = unpack_blob(state, self._layout)
        d = ctx.axis_index(ctx.axes.axis)
        o = (d + step) % self.p
        return self.kernel(
            local["indptr"], local["indices"], b_ptr, b_idx,
            jnp.take(local["t_i"], o, axis=0),
            jnp.take(local["t_j"], o, axis=0),
            jnp.take(local["t_cnt"], o, axis=0),
        )


# ======================================================================
# shift schedules
# ======================================================================
@dataclasses.dataclass
class _Ctx:
    """Per-trace context handed to stores (axis introspection)."""

    axes: object

    @staticmethod
    def axis_index(name: str):
        return jax.lax.axis_index(name)


MASK_NAME = "step_keep"


def masked_count(store, state, local, step, ctx, step_keep, count_dtype):
    """One schedule step's count, short-circuited by the planner's mask.

    ``step_keep`` is the device-local ``(nsteps,)`` bool vector staged by
    the planner (True = this step's incoming block pair can contribute);
    ``lax.cond`` with the traced predicate skips the whole count kernel
    on masked-off steps.  Collectives (ppermute shifts, SUMMA's psum
    broadcasts) must stay *outside* — every device participates in the
    exchange even when its own count is skipped, so the SPMD program
    stays uniform.
    """
    if step_keep is None:
        return store.count(state, local, step, ctx)
    return jax.lax.cond(
        step_keep[step],
        lambda: store.count(state, local, step, ctx),
        lambda: jnp.zeros((), jnp.dtype(count_dtype)),
    )


class ShiftSchedule:
    """Permutation structure for the shared ``lax.scan`` driver.

    Split into three hooks so the full-scan engine and the host-driven
    fault-tolerance stepper share one body:

    * ``init_carry(store, local, ctx)`` — the scan carry at step 0
      (may issue prologue collectives, e.g. Cannon's first in-flight
      shift when double-buffered);
    * ``carry_template(payload)`` — the carry's pytree *structure* only
      (no computation; the stepper uses it to rebuild the carry from
      host-checkpointed leaves);
    * ``make_body(store, local, ctx, step_keep=..., count_dtype=...,
      hop=1)`` — ``body(carry, step) -> (carry', count)``, consuming the
      planner's per-step skip mask via :func:`masked_count`; ``hop`` is
      the static shift distance in schedule steps (the stepper compiles
      one body per distinct hop of a compacted schedule).

    ``make_scan`` composes them into the ``(carry0, body, nsteps)``
    triple the engine's scan driver consumes.  ``run`` executes the
    whole schedule: the scan driver normally, or — when ``live_steps``
    is set (a compacted schedule, DESIGN.md §4.4) — an *unrolled* body
    over only the globally-live steps, with the elided unit shifts fused
    into multi-hop ``ppermute``\\ s.  Step indices stay in the original
    numbering, so per-device conds index the staged ``step_keep`` mask
    unremapped and step-selected statics (tile triples, ring task
    groups) keep working.
    """

    live_steps: Optional[Tuple[int, ...]] = None

    def init_carry(self, store: OperandStore, local: Dict, ctx: _Ctx):
        return store.payload(local)

    def carry_template(self, payload):
        return payload

    def make_body(self, store: OperandStore, local: Dict, ctx: _Ctx, *,
                  step_keep=None, count_dtype=jnp.int32, hop: int = 1):
        raise NotImplementedError

    def make_scan(self, store: OperandStore, local: Dict, ctx: _Ctx, *,
                  step_keep=None, count_dtype=jnp.int32):
        body = self.make_body(
            store, local, ctx, step_keep=step_keep, count_dtype=count_dtype
        )
        return self.init_carry(store, local, ctx), body, self.nsteps

    def run_compacted(self, store: OperandStore, local: Dict, ctx: _Ctx, *,
                      step_keep=None, count_dtype=jnp.int32):
        raise NotImplementedError

    def run(self, store: OperandStore, local: Dict, ctx: _Ctx, *,
            step_keep=None, count_dtype=jnp.int32):
        """Execute the whole schedule, returning the device's total."""
        if self.live_steps is not None:
            return self.run_compacted(
                store, local, ctx, step_keep=step_keep,
                count_dtype=count_dtype,
            )
        carry0, body, nsteps = self.make_scan(
            store, local, ctx, step_keep=step_keep, count_dtype=count_dtype
        )
        _, per_step = jax.lax.scan(body, carry0, jnp.arange(nsteps))
        return jnp.sum(per_step, dtype=count_dtype)


@dataclasses.dataclass
class CannonSchedule(ShiftSchedule):
    """Cannon's q-step {count, shift-A-left, shift-B-up} rotation.

    ``double_buffer=True`` (default) runs the communication-overlapped
    body: the carry holds *two* payload generations ``(cur, inflight)``
    — ``cur`` is counted at step ``s`` while ``inflight`` (step s+1's
    blocks, requested one step earlier) is already being shifted toward
    step s+2.  Count and collective touch disjoint buffers, so the
    overlap is structural, not a scheduling hope.  Costs one extra
    (discarded) shift at the end of the rotation.

    Multi-pod (2.5D): blocks are replicated over the pod axis, pod ``t``
    starts at skew offset ``t`` (see ``pod_stack_arrays``) and executes
    every ``npods``-th shift — memory ×npods, shift traffic ÷npods.
    """

    q: int
    axes: GridAxes
    npods: int = 1
    double_buffer: bool = True
    # compacted schedule: original indices of the globally-live steps
    # (strictly increasing).  ``run`` then unrolls over them with fused
    # multi-hop shifts; the stepper compiles one body per distinct hop.
    live_steps: Optional[Tuple[int, ...]] = None
    # timing probe: elide every shift (counts are wrong for q > 1 — used
    # only by the benchmark's count-only attribution run)
    elide_shifts: bool = False

    @property
    def nsteps(self) -> int:
        assert self.q % self.npods == 0, "pods must divide the grid dimension"
        return self.q // self.npods

    def _shift_k(self, payload, hop: int):
        """Fused shift of ``hop`` schedule steps (one ppermute per
        operand regardless of hop — the multi-hop fusion)."""
        k = (hop * self.npods) % self.q
        if k == 0 or self.elide_shifts:
            return payload
        perm = shift_perm(self.q, k)
        a_state, b_state = payload
        with jax.named_scope("tc_shift"):
            return (
                tree_ppermute(a_state, self.axes.col, perm),
                tree_ppermute(b_state, self.axes.row, perm),
            )

    def _shift(self, payload):
        return self._shift_k(payload, 1)

    def init_carry(self, store, local, ctx):
        payload = store.payload(local)
        if self.live_steps is not None:
            # compacted stepper: single-generation carry pre-shifted to
            # the first live step (the prologue hop)
            assert not self.double_buffer, (
                "the compacted stepper runs single-buffered"
            )
            if self.live_steps:
                payload = self._shift_k(payload, self.live_steps[0])
            return payload
        if not self.double_buffer:
            return payload
        # prologue: put step 1's blocks in flight before step 0 counts
        return (payload, self._shift(payload))

    def carry_template(self, payload):
        if self.live_steps is not None:
            return payload
        return (payload, payload) if self.double_buffer else payload

    def make_body(self, store, local, ctx, *, step_keep=None,
                  count_dtype=jnp.int32, hop: int = 1):
        if self.double_buffer:

            def body(carry, s):
                cur, inflight = carry
                # issue step s+2's shift from the independent buffer
                # BEFORE counting step s — collective ∥ intersection.
                nxt = self._shift_k(inflight, hop)
                c = masked_count(
                    store, cur, local, s, ctx, step_keep, count_dtype
                )
                return (inflight, nxt), c

        else:

            def body(carry, s):
                nxt = self._shift_k(carry, hop)
                c = masked_count(
                    store, carry, local, s, ctx, step_keep, count_dtype
                )
                return nxt, c

        return body

    def run_compacted(self, store, local, ctx, *, step_keep=None,
                      count_dtype=jnp.int32):
        """Unrolled kept-step body: count only the live steps, reach
        each via one fused multi-hop ppermute.  In straight-line code
        the shift for the next live step and the current count touch
        independent values, so the communication/compute overlap of the
        double-buffered scan body is structural here without a second
        payload generation (``double_buffer`` is a scan-body knob and is
        ignored)."""
        live = self.live_steps
        total = jnp.zeros((), jnp.dtype(count_dtype))
        if not live:
            return total  # everything elided: no shifts, no counts
        payload = store.payload(local)
        payload = self._shift_k(payload, live[0])
        for i, s in enumerate(live):
            nxt = (
                self._shift_k(payload, live[i + 1] - s)
                if i + 1 < len(live)
                else None
            )
            total = total + masked_count(
                store, payload, local, s, ctx, step_keep, count_dtype
            )
            if nxt is not None:
                payload = nxt
        return total


@dataclasses.dataclass
class SummaSchedule(ShiftSchedule):
    """SUMMA broadcast rounds on an ``r x c`` grid: ``c`` steps, each a
    one-hot-psum panel broadcast realized by the store's ``select``.

    The broadcast itself is unconditional (every device contributes to
    the psum); only the count is skip-masked.
    """

    r: int
    c: int
    axes: GridAxes
    live_steps: Optional[Tuple[int, ...]] = None

    @property
    def nsteps(self) -> int:
        return self.c

    def make_body(self, store, local, ctx, *, step_keep=None,
                  count_dtype=jnp.int32, hop: int = 1):
        del hop  # broadcast rounds carry no shift state

        def body(carry, z):
            state = store.select(local, z, ctx)
            c = masked_count(
                store, state, local, z, ctx, step_keep, count_dtype
            )
            return carry, c

        return body

    def run_compacted(self, store, local, ctx, *, step_keep=None,
                      count_dtype=jnp.int32):
        """Elide whole broadcast rounds: a globally-dead round's one-hot
        psum pair disappears with its count (SUMMA is stateless between
        rounds, so no hop fusion is needed)."""
        total = jnp.zeros((), jnp.dtype(count_dtype))
        for z in self.live_steps:
            state = store.select(local, z, ctx)
            total = total + masked_count(
                store, state, local, z, ctx, step_keep, count_dtype
            )
        return total


@dataclasses.dataclass
class RingSchedule(ShiftSchedule):
    """1D ring rotation over ``p`` devices: the whole payload passes
    through every device once (the baseline's (p-1)/p·nnz volume)."""

    p: int
    axes: RingAxes
    live_steps: Optional[Tuple[int, ...]] = None
    # timing probe: elide every rotation (counts are wrong for p > 1 —
    # used only by the benchmark's count-only attribution run)
    elide_shifts: bool = False

    @property
    def nsteps(self) -> int:
        return self.p

    def _shift_k(self, payload, hop: int):
        k = hop % self.p
        if k == 0 or self.elide_shifts:
            return payload
        with jax.named_scope("tc_shift"):
            return tree_ppermute(
                payload, self.axes.axis, shift_perm(self.p, k)
            )

    def make_body(self, store, local, ctx, *, step_keep=None,
                  count_dtype=jnp.int32, hop: int = 1):
        def body(carry, t):
            nxt = self._shift_k(carry, hop)
            c = masked_count(
                store, carry, local, t, ctx, step_keep, count_dtype
            )
            return nxt, c

        return body

    def run_compacted(self, store, local, ctx, *, step_keep=None,
                      count_dtype=jnp.int32):
        """Unrolled ring: rotate straight to each live step with one
        fused multi-hop ppermute (the elided steps' blob passes are
        gone, cutting the baseline's (p-1)/p·nnz shifted volume to the
        live fraction)."""
        live = self.live_steps
        total = jnp.zeros((), jnp.dtype(count_dtype))
        if not live:
            return total
        payload = store.payload(local)
        payload = self._shift_k(payload, live[0])
        for i, t in enumerate(live):
            nxt = (
                self._shift_k(payload, live[i + 1] - t)
                if i + 1 < len(live)
                else None
            )
            total = total + masked_count(
                store, payload, local, t, ctx, step_keep, count_dtype
            )
            if nxt is not None:
                payload = nxt
        return total


# ======================================================================
# reduction
# ======================================================================
def pod_tree_allreduce(x, axis: str, n: int):
    """Binomial-tree all-reduce over one mesh axis of size ``n`` (a
    power of two): log2(n) masked ppermute rounds funnel partials to
    position 0, log2(n) more broadcast the sum back.

    ``ppermute`` delivers zeros to devices outside a round's receiver
    set, so the reduce rounds add unconditionally; the broadcast rounds
    select receivers by axis index.  Round ``k`` involves ``n / 2k`` of
    the ``n`` positions as senders, so with pairs-aware accounting the
    total moved is ``2·S·(n-1)/n`` — a psum's ring cost, but reached in
    2·log2(n) latency hops instead of 2(n-1), and composable with a
    *joint* grid psum so the 2.5D reduce never all-reduces over the pod
    axis times the grid (see :class:`Reduction`).
    """
    if n == 1:
        return x
    assert n & (n - 1) == 0, "tree reduce needs a power-of-two axis size"
    idx = jax.lax.axis_index(axis)
    rounds = []
    k = 1
    while k < n:
        rounds.append(k)
        k *= 2
    # reduce: round k's senders (t % 2k == k) funnel into t - k
    for k in rounds:
        pairs = [(t, t - k) for t in range(n) if t % (2 * k) == k]
        x = x + compat.ppermute(x, axis, pairs)
    # broadcast back: reversed rounds, receivers replace their stale
    # partials (senders' values pass through ``x`` unchanged)
    for k in reversed(rounds):
        pairs = [(t, t + k) for t in range(n) if t % (2 * k) == 0]
        recv = compat.ppermute(x, axis, pairs)
        x = jnp.where(idx % (2 * k) == k, recv, x)
    return x


def chain_broadcast(x, axis: str, n: int, owner: int):
    """Broadcast ``owner``'s value along one mesh axis of size ``n`` via
    a masked ppermute doubling chain (emulating collective-broadcast
    until jax exposes one).

    Round ``d`` has every already-covered position forward to distance
    ``d`` ahead (mod ``n``, never wrapping past the owner), doubling
    coverage; ``n - 1`` pairs total across all rounds, so the moved
    bytes are ``S·(n-1)/n`` — exactly *half* the one-hot psum's
    all-reduce cost ``2·S·(n-1)/n``, in ceil(log2(n)) hops.  Positions
    outside the covered prefix never send, so their stale values are
    harmless and are replaced on receipt.
    """
    if n == 1:
        return x
    owner = int(owner) % n
    rel = (jax.lax.axis_index(axis) - owner) % n
    cover = 1
    while cover < n:
        pairs = [
            (t, (t + cover) % n)
            for t in range(n)
            if (t - owner) % n < cover and (t - owner) % n + cover < n
        ]
        recv = compat.ppermute(x, axis, pairs)
        x = jnp.where((rel >= cover) & (rel < 2 * cover), recv, x)
        cover *= 2
    return x


@dataclasses.dataclass(frozen=True)
class Reduction:
    """Global sum of the per-device partials, or per-device outputs.

    ``strategy`` selects how the global sum is realized:

    * ``"flat"`` — one psum per mesh axis (the original path; the only
      choice on single-pod grids and rings);
    * ``"tree"`` — the 2.5D staged reduce: one *joint* psum over the
      grid axes (a single all-reduce over the q² group, strictly fewer
      bytes than the per-axis pair), then one cross-pod binomial tree
      via log₂(npods) masked ppermute rounds each way
      (:func:`pod_tree_allreduce`).  Needs a pod axis with a
      power-of-two size > 1 — :meth:`resolve` enforces this;
    * ``"auto"`` — ``tree`` whenever it is applicable, else ``flat``.

    Builders pass the unresolved knob; :func:`build_engine_fn` binds it
    against the mesh via :meth:`resolve`.  An unresolved ``"auto"``
    applies as ``flat`` (the safe default for direct ``apply`` callers).
    """

    global_sum: bool = True
    strategy: str = "auto"  # "flat" | "tree" | "auto"
    npods: int = 1  # pod-axis size, bound by resolve()

    def resolve(self, mesh, axes) -> "Reduction":
        """Bind ``strategy`` and the pod-axis size against the mesh."""
        pod = getattr(axes, "pod", None)
        npods = int(mesh.shape[pod]) if pod else 1
        pow2 = npods > 1 and (npods & (npods - 1)) == 0
        strategy = self.strategy
        if strategy == "auto":
            strategy = "tree" if (pod and pow2) else "flat"
        elif strategy == "tree":
            if not pod or npods <= 1:
                raise ValueError(
                    "reduce strategy 'tree' needs a pod axis with "
                    "npods > 1; use 'flat' (or 'auto') on single-pod "
                    "grids and rings"
                )
            if not pow2:
                raise ValueError(
                    f"reduce strategy 'tree' needs a power-of-two pod "
                    f"count, got npods={npods}"
                )
        elif strategy != "flat":
            raise ValueError(
                f"unknown reduce strategy {strategy!r}; "
                "expected 'flat', 'tree', or 'auto'"
            )
        return dataclasses.replace(self, strategy=strategy, npods=npods)

    def apply(self, total, axes):
        if not self.global_sum:
            return total.reshape((1,) * len(axes.all))
        with jax.named_scope("tc_reduce"):
            if self.strategy == "tree":
                total = jax.lax.psum(total, (axes.row, axes.col))
                return pod_tree_allreduce(total, axes.pod, self.npods)
            for ax in axes.all:
                total = jax.lax.psum(total, ax)
            return total

    def out_specs(self, axes):
        return P() if self.global_sum else P(*axes.all)


# ======================================================================
# hub-split partial count (DESIGN.md §4.8)
# ======================================================================
class HubCount:
    """The replicated hub-fragment partial sum of a hub-split plan.

    Runs *outside* the schedule loop: the planner's hub-split stage
    (:mod:`repro.pipeline.hubsplit`) stages column-strided fragment
    CSRs + task lists per device, each device counts its slice with the
    plain pair-search kernel once, and the partial folds into the same
    :class:`Reduction` as the schedule total — so flat and tree
    reductions, skip masks, and schedule compaction all compose
    untouched (hub work can never revive an elided step).

    Hub arrays ride the *static* partition specs — ``P(row, col)`` on
    grids, ``P(axis)`` on rings — so multi-pod meshes replicate them
    across the pod axis; :meth:`count` zeroes the partial on every pod
    but pod 0 to keep the global sum exact.
    """

    names = ("hub_indptr", "hub_indices", "hub_ti", "hub_tj", "hub_cnt")

    def __init__(self, *, dpad: int, chunk: int, sentinel: int,
                 probe_shorter: bool = True):
        self.dpad = int(dpad)
        self.chunk = int(chunk)
        self.sentinel = int(sentinel)
        self.probe_shorter = probe_shorter

    @classmethod
    def from_plan(cls, plan, *, probe_shorter: bool = True):
        h = getattr(plan, "hub", None)
        if h is None:
            return None
        return cls(
            dpad=h.dpad, chunk=h.chunk, sentinel=h.sentinel,
            probe_shorter=probe_shorter,
        )

    def in_specs(self, axes):
        if getattr(axes, "axis", None) is not None:  # ring
            spec = P(axes.axis)
        else:
            spec = P(axes.row, axes.col)
        return {k: spec for k in self.names}

    def count(self, local, ctx, count_dtype):
        with jax.named_scope("tc_hub"):
            c = count_mod.count_pair_search(
                local["hub_indptr"], local["hub_indices"],
                local["hub_indptr"], local["hub_indices"],
                local["hub_ti"], local["hub_tj"], local["hub_cnt"],
                dpad=self.dpad, chunk=self.chunk,
                probe_shorter=self.probe_shorter,
                count_dtype=count_dtype, sentinel=self.sentinel,
            )
            pod = getattr(ctx.axes, "pod", None)
            if pod is not None:
                c = c * (jax.lax.axis_index(pod) == 0).astype(c.dtype)
            return c


# ======================================================================
# engine builders
# ======================================================================
def _make_call(fn, ordered, in_specs):
    """Keyword/positional call wrapper with ``.lower`` for dry runs."""

    def call(*pos, **arrays):
        if pos:
            return fn(*pos)
        return fn(*(arrays[k] for k in ordered))

    def lower(*pos, **arrays):
        if pos:
            return fn.lower(*pos)
        return fn.lower(*(arrays[k] for k in ordered))

    call.lower = lower
    call.in_specs = in_specs
    call.ordered = list(ordered)
    return call


def build_engine_fn(
    mesh,
    axes,
    store: OperandStore,
    schedule: ShiftSchedule,
    *,
    count_dtype=jnp.int32,
    reduction: Optional[Reduction] = None,
    batched: bool = False,
    use_step_mask: bool = False,
    hub: Optional[HubCount] = None,
):
    """Generate the jitted SPMD counting function for one composition.

    Returns ``call(**device_arrays)`` (also accepts positional arrays in
    ``call.ordered`` order) yielding the global count scalar, or
    per-device counts with ``Reduction(global_sum=False)``.

    ``use_step_mask=True`` adds a ``step_keep`` device array to the call
    (the planner's per-device per-step skip mask, sharded like the grid:
    ``(..., nsteps)`` bools behind ``P(*axes.all)``); the schedule body
    then short-circuits the count kernel on masked-off steps via
    ``lax.cond`` while still performing every exchange collectively.

    ``batched=True`` builds the multi-graph variant: every device array
    carries an unsharded leading batch axis (graphs padded to shared
    maxima and stacked by :mod:`repro.pipeline.batch`), the schedule
    runs per graph under one ``lax.map`` inside the same ``shard_map``,
    and the call returns the ``(batch,)`` vector of global counts — one
    compiled executable and one dispatch for the whole batch.
    """
    reduction = (reduction or Reduction()).resolve(mesh, axes)
    count_dtype = compat.canonical_count_dtype(count_dtype)
    ordered = list(store.names)
    if hub is not None:
        ordered += list(hub.names)
    if use_step_mask:
        ordered.append(MASK_NAME)
    specs = store.in_specs(axes)
    mask_lead = len(axes.all)
    if hub is not None:
        specs = dict(specs, **hub.in_specs(axes))
    if use_step_mask:
        specs = dict(specs, **{MASK_NAME: P(*axes.all)})
    ctx = _Ctx(axes)

    def core(local):
        local = dict(local)
        keep = local.pop(MASK_NAME, None)
        hub_local = (
            {k: local.pop(k) for k in hub.names} if hub is not None else None
        )
        total = schedule.run(
            store, local, ctx, step_keep=keep, count_dtype=count_dtype
        )
        if hub is not None:
            total = total + hub.count(hub_local, ctx, count_dtype)
        return reduction.apply(total, axes)

    if batched:
        assert hub is None, (
            "batched engines do not take hub-split plans (per-graph hub "
            "sides differ; plan with hub_split=False)"
        )
        assert reduction.global_sum, (
            "batched engine returns per-graph global counts"
        )
        assert schedule.live_steps is None, (
            "batched engines use the scan body (per-graph masks differ; "
            "compaction would need their union)"
        )

        def spmd(*args):
            named = dict(zip(ordered, args))
            keep = named.pop(MASK_NAME, None)
            # strip the size-1 mesh block dims that follow the batch axis
            local = {
                k: v.reshape((v.shape[0],) + v.shape[1 + store.lead(k, axes):])
                for k, v in named.items()
            }
            if keep is not None:
                local[MASK_NAME] = keep.reshape(
                    (keep.shape[0],) + keep.shape[1 + mask_lead:]
                )
            return jax.lax.map(core, local)

        in_specs = tuple(P(None, *specs[k]) for k in ordered)
        out_specs = P(None)
    else:

        def spmd(*args):
            named = dict(zip(ordered, args))
            keep = named.pop(MASK_NAME, None)
            local = store.localize(named, axes)
            if keep is not None:
                local[MASK_NAME] = _squeeze(keep, mask_lead)
            return core(local)

        in_specs = tuple(specs[k] for k in ordered)
        out_specs = reduction.out_specs(axes)

    fn = jax.jit(
        compat.shard_map(
            spmd,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    )
    return _make_call(fn, ordered, specs)


def build_engine_stepper(
    mesh,
    axes,
    store: OperandStore,
    schedule: ShiftSchedule,
    *,
    count_dtype=jnp.int32,
    use_step_mask: bool = False,
):
    """One-schedule-step-at-a-time variant for fault-tolerant runs.

    Reuses the exact scan body of ``schedule`` (``make_body``) but
    executes a single step per call with the scan *carry* held by the
    host as explicit arrays, so the host loop owns the shift index and
    can checkpoint state between shifts (a restarted job resumes
    mid-loop).  With a double-buffered :class:`CannonSchedule` the carry
    is two payload generations — both buffers checkpoint and round-trip
    exactly like any other state arrays.

    Requires a store whose payload is identity-structured (raw arrays,
    e.g. ``CSRStore(use_blob=False)``) so checkpointed state round-trips
    exactly.  Returns ``one_shift(state, statics, step=0) -> state``
    where ``state = (*carry_arrays, acc)`` and ``statics`` maps the
    store's static names (plus ``"step_keep"`` when ``use_step_mask``).
    ``one_shift.prime(operand_arrays) -> carry_arrays`` builds the
    step-0 carry (including any prologue shift the schedule issues);
    ``one_shift.n_carry`` is the number of carry arrays.

    With a *compacted* schedule (``schedule.live_steps`` set) the host
    loop iterates ``one_shift.live_steps`` only, still passing the
    **original** step index — mask lookups need no remapping, and a
    checkpointed step index round-trips unchanged (the resume loop just
    filters the live list to ``>= saved``).  Each call shifts by the
    fused hop to the *next* live step; one executable is compiled per
    distinct hop (a handful at most).
    """
    import numpy as np

    count_dtype = compat.canonical_count_dtype(count_dtype)
    ordered_statics = list(store.static_names)
    specs = store.in_specs(axes)
    ctx = _Ctx(axes)
    op_names = list(store.operand_names)
    op_spec = specs[op_names[0]]
    lead = store.lead(op_names[0], axes)
    mask_lead = len(axes.all)
    live = schedule.live_steps

    # carry pytree *structure* from a computation-free dummy payload —
    # only identity-structured stores qualify (same restriction as the
    # checkpoint round-trip itself).
    try:
        dummy = store.payload({k: np.zeros((), np.int32) for k in op_names})
        treedef = jax.tree.structure(schedule.carry_template(dummy))
    except Exception as e:  # noqa: BLE001
        raise ValueError(
            "stepper requires an identity-structured payload "
            "(e.g. CSRStore(use_blob=False))"
        ) from e
    n_state = treedef.num_leaves

    one = lambda a: a.reshape((1,) * lead + a.shape)
    static_specs = tuple(specs[k] for k in ordered_statics)
    mask_specs = (P(*axes.all),) if use_step_mask else ()

    def _make_fn(hop: int):
        def spmd(*args):
            carry_leaves = [_squeeze(a, lead) for a in args[:n_state]]
            pos = n_state
            statics = dict(
                zip(ordered_statics, args[pos:pos + len(ordered_statics)])
            )
            pos += len(ordered_statics)
            keep = None
            if use_step_mask:
                keep = _squeeze(args[pos], mask_lead)
                pos += 1
            acc = _squeeze(args[pos], lead)
            step = args[pos + 1]
            local = store.localize(statics, axes)
            carry = jax.tree.unflatten(treedef, carry_leaves)
            body = schedule.make_body(
                store, local, ctx, step_keep=keep, count_dtype=count_dtype,
                hop=hop,
            )
            carry_next, c = body(carry, step)
            leaves = jax.tree.flatten(carry_next)[0]
            return tuple(one(x) for x in leaves) + (one(acc + c),)

        return jax.jit(
            compat.shard_map(
                spmd,
                mesh=mesh,
                in_specs=(op_spec,) * n_state + static_specs + mask_specs
                + (op_spec, P()),
                out_specs=(op_spec,) * (n_state + 1),
                check_vma=False,
            )
        )

    fns: Dict[int, Callable] = {}

    def _fn_for(hop: int):
        if hop not in fns:
            fns[hop] = _make_fn(hop)
        return fns[hop]

    def spmd_prime(*args):
        local = store.localize(dict(zip(op_names, args)), axes)
        carry0 = schedule.init_carry(store, local, ctx)
        leaves = jax.tree.flatten(carry0)[0]
        assert len(leaves) == n_state, (
            "stepper requires an identity-structured payload "
            "(e.g. CSRStore(use_blob=False))"
        )
        return tuple(one(x) for x in leaves)

    prime_fn = jax.jit(
        compat.shard_map(
            spmd_prime,
            mesh=mesh,
            in_specs=tuple(specs[k] for k in op_names),
            out_specs=(op_spec,) * n_state,
            check_vma=False,
        )
    )

    def one_shift(state, statics, step=0):
        *carry, acc = state
        args = list(carry) + [statics[k] for k in ordered_statics]
        if use_step_mask:
            args.append(statics[MASK_NAME])
        args += [acc, jnp.asarray(step, jnp.int32)]
        hop = 1
        if live is not None:
            i = live.index(int(step))  # host loop must pass a live step
            hop = live[i + 1] - live[i] if i + 1 < len(live) else 0
        return _fn_for(hop)(*args)

    one_shift.prime = lambda operands: prime_fn(
        *(operands[k] for k in op_names)
    )
    one_shift.n_carry = n_state
    one_shift.live_steps = live
    return one_shift
