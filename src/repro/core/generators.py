"""Graph generators: Graph500 RMAT, Erdős–Rényi, and small named graphs.

The paper evaluates on twitter/friendster (real) and graph500 RMAT scales
26–29 (synthetic, generated in memory "prior to calling the triangle
counting routine" — we follow the same pattern).  The RMAT generator here is
fully vectorized numpy and deterministic given a seed, so benchmarks and
tests can regenerate identical graphs.

Graph500 RMAT parameters: (a, b, c, d) = (0.57, 0.19, 0.19, 0.05),
edge factor 16 (directed edge samples; after dedup/symmetrization the
undirected edge count is lower, as in the reference generator).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .graph import Graph

__all__ = [
    "rmat",
    "erdos_renyi",
    "powerlaw",
    "star",
    "residue_cliques",
    "random_edge_flips",
    "flip_edges",
    "named_graph",
    "graph_from_spec",
    "GRAPH500_PARAMS",
]

GRAPH500_PARAMS = (0.57, 0.19, 0.19, 0.05)


def rmat(
    scale: int,
    edge_factor: int = 16,
    params=GRAPH500_PARAMS,
    seed: int = 0,
    name: Optional[str] = None,
) -> Graph:
    """Graph500-style RMAT graph with ``n = 2**scale`` vertices.

    Each of ``edge_factor * n`` directed edge samples picks one quadrant
    per bit level; samples are then symmetrized/deduplicated into a simple
    undirected graph (exactly what the paper does with the graph500
    generator output).
    """
    n = 1 << scale
    m_samples = edge_factor * n
    a, b, c, d = params
    rng = np.random.default_rng(seed)

    src = np.zeros(m_samples, dtype=np.int64)
    dst = np.zeros(m_samples, dtype=np.int64)
    # Per level: choose quadrant with probs (a, b, c, d);
    # bit_i of src += quadrant in {2, 3}; bit_i of dst += quadrant in {1, 3}.
    # Graph500 also perturbs probabilities per level by +-10%; we keep the
    # canonical fixed probabilities for reproducibility.
    for level in range(scale):
        u = rng.random(m_samples)
        quad = (u >= a).astype(np.int64) + (u >= a + b) + (u >= a + b + c)
        src |= (quad >> 1) << level
        dst |= (quad & 1) << level
    return Graph.from_edges(n, src, dst, name=name or f"rmat-s{scale}")


def erdos_renyi(n: int, avg_degree: float, seed: int = 0, name=None) -> Graph:
    """G(n, m) random graph with ~``avg_degree * n / 2`` undirected edges."""
    rng = np.random.default_rng(seed)
    m = int(avg_degree * n / 2)
    src = rng.integers(0, n, size=2 * m)  # oversample to survive dedup
    dst = rng.integers(0, n, size=2 * m)
    g = Graph.from_edges(n, src, dst, name=name or f"er-{n}")
    if g.m > m:
        g = Graph(n=n, edges=g.edges[:m], name=g.name)
    return g


def powerlaw(
    n: int,
    alpha: float = 2.5,
    avg_degree: float = 8.0,
    seed: int = 0,
    name=None,
) -> Graph:
    """Chung–Lu-style skewed-degree fixture, deterministic given ``seed``.

    Endpoint ``v`` is drawn with probability ∝ ``(v + 1)^(-1/(alpha-1))``
    (the expected-degree sequence of a power law with exponent ``alpha``),
    so low ids become hubs and the degree distribution is heavy-tailed —
    the imbalance regime where the skip-aware rebalancer has real ties to
    break (many equal-degree leaves) *and* real stragglers to spread
    (hub-heavy blocks).  Sampled edges are deduplicated/symmetrized like
    :func:`erdos_renyi`.
    """
    assert alpha > 1.0, "powerlaw needs alpha > 1"
    rng = np.random.default_rng(seed)
    w = np.arange(1, n + 1, dtype=np.float64) ** (-1.0 / (alpha - 1.0))
    p = w / w.sum()
    m = int(avg_degree * n / 2)
    src = rng.choice(n, size=2 * m, p=p)  # oversample to survive dedup
    dst = rng.choice(n, size=2 * m, p=p)
    g = Graph.from_edges(n, src, dst, name=name or f"powerlaw-{n}")
    if g.m > m:
        g = Graph(n=n, edges=g.edges[:m], name=g.name)
    return g


def star(n: int, name=None) -> Graph:
    """Hub-and-spoke graph on ``n`` vertices (0 triangles).

    Under the 2D cyclic decomposition every edge lands in the hub's
    block column, leaving most blocks empty — a skip-mask stressor.
    """
    assert n >= 2
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    return Graph.from_edges(n, src, dst, name=name or f"star-{n}")


def residue_cliques(k: int, size: int, name=None) -> Graph:
    """Block-diagonal fixture: ``k`` disjoint cliques of ``size`` vertices,
    clique ``r`` on the residue class ``{v : v % k == r}``.

    On a ``k x k`` grid every edge satisfies ``i ≡ j (mod k)``, so only
    the diagonal blocks of the cyclic decomposition are non-empty and
    each diagonal device has exactly one live Cannon shift — the other
    ``k^3 - k`` (device, shift) pairs are skippable.  Triangle count is
    ``k * C(size, 3)`` (non-zero, unlike a star), so a miscounting
    masked engine cannot hide.
    """
    assert k >= 1 and size >= 1
    n = k * size
    members = np.arange(size, dtype=np.int64)
    iu, ju = np.triu_indices(size, k=1)
    src, dst = [], []
    for r in range(k):
        verts = members * k + r  # residue class r, local order preserved
        src.append(verts[iu])
        dst.append(verts[ju])
    return Graph.from_edges(
        n,
        np.concatenate(src) if src else np.zeros(0, np.int64),
        np.concatenate(dst) if dst else np.zeros(0, np.int64),
        name=name or f"cliques-{k}x{size}",
    )


def random_edge_flips(graph: Graph, k: int, seed: int):
    """Sample ``k`` deterministic random edge flips of ``graph``.

    A sampled vertex pair that is already an edge becomes a removal,
    an absent pair an addition — the mutation model behind the
    ``delta:`` graph spec and ``EdgeDelta.random_flips``.  Pairs are
    distinct (no pair is flipped twice) and self loops are never drawn.
    Returns ``(add, remove)`` as ``(ka, 2)`` / ``(kr, 2)`` int64 arrays
    with ``ka + kr == k``.
    """
    n = graph.n
    assert n >= 2, "random_edge_flips needs at least two vertices"
    k = int(k)
    assert 0 <= k <= (n * (n - 1)) // 2, "more flips than vertex pairs"
    rng = np.random.default_rng(seed)
    chosen: list = []
    seen = set()
    while len(chosen) < k:
        want = k - len(chosen)
        u = rng.integers(0, n, size=2 * want + 8)
        v = rng.integers(0, n, size=2 * want + 8)
        ok = u != v
        lo = np.minimum(u[ok], v[ok])
        hi = np.maximum(u[ok], v[ok])
        for key in (lo * np.int64(n) + hi).tolist():
            if key not in seen:
                seen.add(key)
                chosen.append(key)
                if len(chosen) == k:
                    break
    keys = np.asarray(chosen, dtype=np.int64)
    base = graph.edges[:, 0] * np.int64(n) + graph.edges[:, 1]
    present = np.isin(keys, base)
    rem, add = keys[present], keys[~present]
    return (
        np.stack([add // n, add % n], axis=1),
        np.stack([rem // n, rem % n], axis=1),
    )


def flip_edges(graph: Graph, k: int, seed: int) -> Graph:
    """``graph`` with ``k`` deterministic random edge flips applied."""
    add, rem = random_edge_flips(graph, k, seed)
    n = np.int64(graph.n)
    base = graph.edges[:, 0] * n + graph.edges[:, 1]
    if base.size and not np.all(base[1:] > base[:-1]):
        base = np.sort(base)
    rem_k = rem[:, 0] * n + rem[:, 1]
    kept = base[~np.isin(base, rem_k)] if rem_k.size else base
    add_k = np.sort(add[:, 0] * n + add[:, 1])
    merged = (
        np.insert(kept, np.searchsorted(kept, add_k), add_k)
        if add_k.size
        else kept
    )
    edges = np.stack([merged // n, merged % n], axis=1)
    return Graph(
        n=graph.n, edges=edges, name=f"{graph.name}+flip{k}s{seed}"
    )


def named_graph(which: str) -> Graph:
    """Small graphs with known triangle counts for unit tests."""
    if which == "triangle":
        return Graph.from_edges(3, [0, 1, 2], [1, 2, 0], name="triangle")
    if which == "k4":
        src, dst = zip(*[(i, j) for i in range(4) for j in range(i + 1, 4)])
        return Graph.from_edges(4, src, dst, name="k4")
    if which == "k10":
        src, dst = zip(*[(i, j) for i in range(10) for j in range(i + 1, 10)])
        return Graph.from_edges(10, src, dst, name="k10")
    if which == "path":
        return Graph.from_edges(5, [0, 1, 2, 3], [1, 2, 3, 4], name="path")
    if which == "star":
        return Graph.from_edges(8, [0] * 7, list(range(1, 8)), name="star")
    if which == "karate":
        import networkx as nx

        g = nx.karate_club_graph()
        src, dst = zip(*g.edges())
        return Graph.from_edges(g.number_of_nodes(), src, dst, name="karate")
    if which == "bull":
        return Graph.from_edges(
            5, [0, 0, 1, 1, 2], [1, 2, 2, 3, 4], name="bull"
        )
    raise ValueError(f"unknown graph {which!r}")


def graph_from_spec(spec: str) -> Graph:
    """Parse a command-line graph spec (shared by tc_run / serve / benches).

    Formats: ``rmat:<scale>[,<edge_factor>[,<seed>]]`` |
    ``er:<n>,<avg_degree>[,<seed>]`` |
    ``powerlaw:<n>,<alpha>[,<seed>]`` (skewed-degree rebalance fixture) |
    ``star:<n>`` |
    ``cliques:<k>,<size>`` (block-diagonal skip-mask fixture) |
    ``delta:<k>,<seed>,<base-spec>`` (base spec + ``k`` deterministic
    random edge flips — present pairs removed, absent pairs added; the
    streaming-fixture mutation model) |
    ``named:<id>`` | ``<id>`` (a bare named-graph id such as ``karate``).
    """
    kind, _, rest = spec.partition(":")
    if kind == "delta":
        parts = rest.split(",", 2)  # base spec may itself contain commas
        if len(parts) != 3:
            raise ValueError(f"malformed delta spec {spec!r}")
        return flip_edges(graph_from_spec(parts[2]), int(parts[0]),
                          int(parts[1]))
    if kind == "star":
        return star(int(rest))
    if kind == "cliques":
        parts = rest.split(",")
        return residue_cliques(int(parts[0]), int(parts[1]))
    if kind == "powerlaw":
        parts = rest.split(",")
        return powerlaw(
            int(parts[0]),
            float(parts[1]),
            seed=int(parts[2]) if len(parts) > 2 else 0,
        )
    if kind == "rmat":
        parts = rest.split(",")
        return rmat(
            int(parts[0]),
            int(parts[1]) if len(parts) > 1 else 16,
            seed=int(parts[2]) if len(parts) > 2 else 0,
        )
    if kind == "er":
        parts = rest.split(",")
        return erdos_renyi(
            int(parts[0]),
            float(parts[1]),
            seed=int(parts[2]) if len(parts) > 2 else 0,
        )
    if kind == "named":
        return named_graph(rest)
    if not rest:  # bare named-graph id
        return named_graph(kind)
    raise ValueError(f"unknown graph spec {spec!r}")


_NAMED_IDS = ("triangle", "k4", "k10", "path", "star", "karate", "bull")


def _spec_is_wellformed(spec: str) -> bool:
    """Cheap format check of one spec — no graph is built."""
    kind, _, rest = spec.partition(":")
    if kind == "delta":
        parts = rest.split(",", 2)
        try:
            return (
                len(parts) == 3
                and int(parts[0]) >= 0
                and int(parts[1]) >= 0
                and _spec_is_wellformed(parts[2])
            )
        except ValueError:
            return False
    parts = rest.split(",")
    try:
        if kind == "rmat":
            return 1 <= len(parts) <= 3 and all(int(p) >= 0 for p in parts)
        if kind == "er":
            if len(parts) not in (2, 3):
                return False
            int(parts[0]), float(parts[1])
            return len(parts) == 2 or int(parts[2]) >= 0
        if kind == "star":
            return len(parts) == 1 and int(parts[0]) >= 2
        if kind == "cliques":
            return len(parts) == 2 and all(int(p) >= 1 for p in parts)
        if kind == "powerlaw":
            if len(parts) not in (2, 3):
                return False
            if not (int(parts[0]) >= 2 and float(parts[1]) > 1.0):
                return False
            return len(parts) == 2 or int(parts[2]) >= 0
    except ValueError:
        return False
    if kind == "named":
        return rest in _NAMED_IDS
    return not rest and kind in _NAMED_IDS


def split_specs(specs: str) -> list:
    """Split a spec *list* string into individual spec strings.

    Specs are separated by ``;`` (unambiguous, since specs may contain
    comma parameters: ``rmat:10,8,1;karate``).  Without a ``;`` the
    whole string is tried as a single spec first — so ``rmat:10,8,1``
    stays one graph — and only if it is not well-formed is it
    comma-split by greedy longest-match: each element claims as many
    comma fragments as still parse as ONE well-formed spec, so
    ``karate,powerlaw:600,2.2`` is two specs, not three, and nested
    parameterized specs like ``delta:5,0,powerlaw:600,2.2`` survive in
    a list.  A fragment run that never parses passes through as-is, so
    :func:`graph_from_spec` rejects it loudly instead of this splitter
    silently shredding it.
    """
    if ";" in specs:
        return [s for s in specs.split(";") if s]
    if _spec_is_wellformed(specs):
        return [specs]
    parts = [s for s in specs.split(",") if s]
    out, i = [], 0
    while i < len(parts):
        for j in range(len(parts), i, -1):
            cand = ",".join(parts[i:j])
            if _spec_is_wellformed(cand):
                out.append(cand)
                i = j
                break
        else:
            out.append(parts[i])
            i += 1
    return out


def graphs_from_specs(specs: str) -> list:
    """Parse a spec list (see :func:`split_specs`) into graphs."""
    return [graph_from_spec(s) for s in split_specs(specs)]
