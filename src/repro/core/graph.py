"""Host-side graph containers used by the planner and the preprocessing phase.

The distributed algorithm (``repro.core.cannon`` / ``summa`` / ``onedim``)
operates on fixed-shape device arrays produced by :mod:`repro.core.plan`;
this module holds the *host* representation: a simple undirected graph as a
deduplicated COO edge list plus CSR conversion helpers and exact oracles
used by the tests and benchmarks.

Conventions
-----------
* graphs are simple (no self loops, no duplicate edges) and undirected;
* ``edges`` stores each undirected edge once as ``(min, max)``;
* vertex ids are ``0 .. n-1`` int64 on the host, int32 on device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Graph",
    "CSR",
    "csr_from_edges",
    "triangle_count_dense_oracle",
    "triangle_count_oracle",
]


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row structure over ``n_rows`` rows.

    ``indices[indptr[i]:indptr[i+1]]`` are the (sorted) column ids of row
    ``i``.  ``indices`` is int64 on the host; the planner narrows to int32
    when building device arrays.
    """

    n_rows: int
    n_cols: int
    indptr: np.ndarray  # (n_rows + 1,) int64
    indices: np.ndarray  # (nnz,) int64

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i] : self.indptr[i + 1]]

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)

    def validate(self) -> None:
        assert self.indptr.shape == (self.n_rows + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0)
        if self.nnz:
            assert self.indices.min() >= 0
            assert self.indices.max() < self.n_cols


def csr_from_edges(
    n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray
) -> CSR:
    """Build a CSR with per-row *sorted* column indices from COO pairs."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    counts = np.bincount(rows, minlength=n_rows)
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(n_rows=n_rows, n_cols=n_cols, indptr=indptr, indices=cols)


@dataclasses.dataclass(frozen=True)
class Graph:
    """A simple undirected graph held on the host.

    ``edges`` is an ``(m, 2)`` int64 array with ``edges[:, 0] < edges[:, 1]``
    (each undirected edge stored exactly once).
    """

    n: int
    edges: np.ndarray
    name: str = "graph"

    # ------------------------------------------------------------------
    # constructors
    # ------------------------------------------------------------------
    @staticmethod
    def from_edges(n: int, src, dst, name: str = "graph") -> "Graph":
        """Deduplicate, drop self loops, canonicalize to (min, max)."""
        src = np.asarray(src, dtype=np.int64).ravel()
        dst = np.asarray(dst, dtype=np.int64).ravel()
        keep = src != dst
        src, dst = src[keep], dst[keep]
        lo = np.minimum(src, dst)
        hi = np.maximum(src, dst)
        key = lo * np.int64(n) + hi
        _, first = np.unique(key, return_index=True)
        edges = np.stack([lo[first], hi[first]], axis=1)
        return Graph(n=n, edges=edges, name=name)

    # ------------------------------------------------------------------
    # basic properties
    # ------------------------------------------------------------------
    @property
    def m(self) -> int:
        """Number of undirected edges."""
        return int(self.edges.shape[0])

    def degrees(self) -> np.ndarray:
        d = np.bincount(self.edges[:, 0], minlength=self.n)
        d += np.bincount(self.edges[:, 1], minlength=self.n)
        return d

    def relabel(self, perm: np.ndarray, name: Optional[str] = None) -> "Graph":
        """Return the graph with vertex ``v`` renamed to ``perm[v]``."""
        perm = np.asarray(perm, dtype=np.int64)
        src = perm[self.edges[:, 0]]
        dst = perm[self.edges[:, 1]]
        return Graph.from_edges(self.n, src, dst, name=name or self.name)

    def adjacency_csr(self) -> CSR:
        """Symmetric adjacency as CSR (both directions)."""
        rows = np.concatenate([self.edges[:, 0], self.edges[:, 1]])
        cols = np.concatenate([self.edges[:, 1], self.edges[:, 0]])
        return csr_from_edges(self.n, self.n, rows, cols)

    def upper_csr(self) -> CSR:
        """U: edges (i, j) with i < j, CSR over rows i."""
        return csr_from_edges(self.n, self.n, self.edges[:, 0], self.edges[:, 1])

    def dense_adjacency(self, dtype=np.float64) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=dtype)
        a[self.edges[:, 0], self.edges[:, 1]] = 1
        a[self.edges[:, 1], self.edges[:, 0]] = 1
        return a


# ----------------------------------------------------------------------
# exact oracles
# ----------------------------------------------------------------------
def triangle_count_dense_oracle(graph: Graph) -> int:
    """tr(A^3) / 6 — only usable for small n (dense)."""
    a = graph.dense_adjacency()
    return int(round(np.trace(a @ a @ a) / 6.0))


def triangle_count_oracle(graph: Graph) -> int:
    """Exact sparse host oracle: sum over U edges of |Adj_U(i) ∩ Adj_U(j)|.

    This is Eq. (1)/(2) of the paper evaluated sequentially and is fast
    enough for the RMAT scales used in tests and CPU benchmarks.
    """
    u = graph.upper_csr()
    indptr, indices = u.indptr, u.indices
    total = 0
    for i, j in graph.edges:
        a = indices[indptr[i] : indptr[i + 1]]
        b = indices[indptr[j] : indptr[j + 1]]
        # both lists sorted -> intersect via np.intersect1d on small arrays
        if len(a) and len(b):
            total += np.intersect1d(a, b, assume_unique=True).size
    return int(total)
