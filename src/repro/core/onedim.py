"""1D-decomposition baseline (the class of algorithms the paper beats).

Representative of Arifuzzaman et al.'s space-efficient variant and Kanewala
et al.'s blocked 1D approach: vertices are 1D-cyclically partitioned over
all ``p`` devices, each device stores only its own rows of U, and the row
blocks rotate around a ring for ``p`` steps; a task ``(i, j)`` is counted
at the step when ``owner(j)``'s block arrives.

Per-device communication volume is ``(p-1)/p * nnz(U)`` (the whole matrix
passes through every device) versus the 2D algorithm's
``2 * nnz(U) * (√p-1)/p`` — the ``~√p/2`` communication advantage the paper
claims for the 2D decomposition, which the roofline comparison in
EXPERIMENTS.md quantifies from the compiled HLO of both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np

from .graph import Graph

INT = np.int32

__all__ = ["OneDPlan", "build_oned_plan", "build_oned_fn"]


@dataclasses.dataclass
class OneDPlan:
    n: int
    m: int
    p: int
    nb: int  # local rows = ceil(n / p)
    nnz_pad: int  # padded nnz per device
    gmax: int  # padded tasks per (device, owner-of-j) group
    dmax: int  # max U row length (FULL rows — 1D keeps whole adjacency)
    chunk: int

    indptr: np.ndarray  # (p, nb + 1)
    indices: np.ndarray  # (p, nnz_pad)  LOCAL k ids (k // p) of sorted rows
    # tasks grouped by owner(j): device d, group o holds tasks whose j%p==o
    t_i: np.ndarray  # (p, p, gmax) local i
    t_j: np.ndarray  # (p, p, gmax) local j (= j // p)
    t_cnt: np.ndarray  # (p, p)
    # (p, p) bool: True = device d counts at ring step t
    step_keep: "np.ndarray | None" = None
    # per-step probe work (repro.core.plan.StepStats) when planned
    # with_stats — consumed by the skip-aware rebalancer
    stats: "object | None" = None
    # globally-live ring steps (repro.core.plan.CompactSchedule); dead
    # steps are reached via fused multi-hop blob rotations
    compact: "object | None" = None
    # deterministic kernel-shape autotune report (pipeline stage)
    autotune: "dict | None" = None
    # long/short task split set by the autotune stage (first ``n_long``
    # tasks per device need dmax probes, the rest fit in ``d_small``)
    n_long: "int | None" = None
    d_small: "int | None" = None
    # hub-split side (repro.pipeline.hubsplit.HubSide, DESIGN.md §4.8)
    hub: "object | None" = None

    def device_arrays(self) -> Dict[str, np.ndarray]:
        out = dict(
            indptr=self.indptr,
            indices=self.indices,
            t_i=self.t_i,
            t_j=self.t_j,
            t_cnt=self.t_cnt,
        )
        if self.step_keep is not None:
            out["step_keep"] = self.step_keep
        if self.hub is not None:
            out.update(self.hub.device_arrays())
        return out

    def shape_structs(self):
        import jax

        return {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in self.device_arrays().items()
        }


def build_oned_plan(graph: Graph, p: int, *, chunk: int = 512) -> OneDPlan:
    """1D-cyclic row partition + owner-grouped task lists.

    Adjacency columns are stored sorted by global id; since the probe
    compares k values between two rows, we keep global k ids (int32) —
    both fragments live in the same global column space.  Delegates to
    the pipeline's vectorized packer
    (:func:`repro.pipeline.stages.pack_oned_plan`) — one sort-and-
    scatter per structure, no per-edge Python loop.
    """
    from ..pipeline.stages import pack_oned_plan

    return pack_oned_plan(graph, p, chunk=chunk)


def build_oned_fn(
    plan: OneDPlan,
    mesh,
    *,
    axis: str = None,
    method: str = "search",
    count_dtype=jnp.int32,
    probe_shorter: bool = True,
    batched: bool = False,
    use_step_mask: "bool | None" = None,
    compact: "bool | None" = None,
    elide_shifts: bool = False,
    reduce_strategy: str = "auto",
    fused_impl: str = "auto",
    fused_tile: "int | None" = None,
):
    """Ring algorithm over a 1D view of the mesh.

    For multi-axis meshes the ring runs over the *last* axis only if it
    covers all devices; otherwise callers should pass a flat 1D mesh (the
    baseline is evaluated on its own flat mesh — it exists for comparison,
    not production).  Thin engine configuration: RingSchedule ×
    OneDCSRStore × kernel.  ``compact=None`` auto-enables dead-step
    elision with fused multi-hop ring rotations when the plan staged a
    compacted schedule (DESIGN.md §4.4).  ``elide_shifts`` is the
    count-only timing probe (counts are wrong for p > 1) used by the
    time-split attribution; ``reduce_strategy`` is accepted for API
    symmetry with the 2D builders — rings have no pod axis, so
    ``"auto"`` resolves to the flat psum and an explicit ``"tree"``
    is rejected loudly.
    """
    from . import engine
    from .engine import (
        OneDCSRStore,
        Reduction,
        RingAxes,
        RingSchedule,
        make_csr_kernel,
    )
    from .plan import as_plan, resolve_compact_steps, resolve_step_mask

    plan = as_plan(plan)
    use_step_mask = resolve_step_mask(plan, use_step_mask)
    live = resolve_compact_steps(plan, compact, batched=batched)
    p = plan.p
    if axis is None:
        sizes = {a: mesh.shape[a] for a in mesh.axis_names}
        flat = [a for a in mesh.axis_names if sizes[a] == p]
        assert flat, f"no single mesh axis of size {p}; pass a flat mesh"
        axis = flat[0]

    axes = RingAxes(axis)
    if method == "fused":
        engine.check_fused_split(plan)
    kernel = make_csr_kernel(
        method,
        dpad=plan.dmax,
        chunk=plan.chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        sentinel=plan.n + 1,
        n_long=getattr(plan, "n_long", None),
        d_small=getattr(plan, "d_small", None),
        # the ring rotates whole adjacency rows: columns are global ids,
        # so the long bucket must use the padded search, not row-encoded
        # keys (the equality panel is id-agnostic either way)
        fused_long_fallback="search",
        fused_impl=fused_impl,
        fused_tile=fused_tile,
    )
    store = OneDCSRStore(kernel, p=p)
    schedule = RingSchedule(
        p=p, axes=axes, live_steps=live, elide_shifts=elide_shifts
    )
    return engine.build_engine_fn(
        mesh, axes, store, schedule, count_dtype=count_dtype,
        reduction=Reduction(strategy=reduce_strategy),
        batched=batched, use_step_mask=use_step_mask,
        hub=engine.HubCount.from_plan(plan, probe_shorter=probe_shorter),
    )
