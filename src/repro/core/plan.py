"""Host-side execution plan for the 2D (Cannon/SUMMA/2.5D) algorithm.

The planner turns a degree-ordered :class:`~repro.core.graph.Graph` into
fixed-shape, device-ready numpy arrays, stacked over the processor grid so
that ``shard_map`` with ``P(row_axis, col_axis)`` hands each device exactly
its blocks:

* ``a_*``  — Cannon "A" operand, pre-skewed: device ``(x, y)`` starts with
  block ``U_{x, (x+y) % q}``  (rows *i*, columns *k*);
* ``b_*``  — Cannon "B" operand, pre-skewed: device ``(x, y)`` starts with
  block ``U_{y, (x+y) % q}``  (rows *j*, columns *k*; this is
  ``L_{(x+y)%q, y}`` stored transposed — see DESIGN.md §2);
* ``m_*``  — the static task list: nonzeros ``(i, j)`` of ``U_{x, y}``.

All ragged structures are padded to plan-wide maxima (XLA needs static
shapes); the padding fractions are part of the plan report because they are
*measured overhead* of the TPU adaptation (DESIGN.md §10.4).

The pre-skew implements Cannon's initial alignment at data-distribution
time (the paper performs it as its first communication step; in an SPMD
framework the initial placement is free — we simply *feed* the aligned
blocks).  ``skew=0`` (SUMMA placement) is also available.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .decomp import BlockCSR, cyclic_blocks
from .graph import Graph

__all__ = [
    "TCPlan",
    "build_plan",
    "analytic_plan",
    "PlanStats",
    "StepStats",
    "CompactSchedule",
    "compact_live_steps",
    "as_plan",
    "resolve_step_mask",
    "resolve_compact_steps",
    "host_aug_keys",
]


def as_plan(obj):
    """Coerce a pipeline :class:`~repro.pipeline.artifact.PlanArtifact`
    (or a raw plan) to its plan object — every engine builder accepts
    either."""
    inner = getattr(obj, "plan", None)
    return obj if inner is None else inner


@dataclasses.dataclass(frozen=True)
class CompactSchedule:
    """Globally-live steps of a skip-masked schedule (DESIGN.md §4.4).

    A schedule step is *globally dead* when ``step_keep`` is False on
    every device — no device can contribute, so the whole scan iteration
    (cond *and* collective) is removable.  The compacted engine executes
    only ``live_steps`` (original step indices, strictly increasing),
    replacing the elided unit shifts with fused multi-hop ``ppermute``\\ s
    whose distances are :attr:`hops`.  Keeping a dead step live is always
    correct (its count is provably zero), so any superset of the true
    live set is a valid ``live_steps`` — the stepper tests rely on this.
    """

    n_total: int  # schedule steps before compaction
    live_steps: Tuple[int, ...]  # original indices of the kept steps

    @property
    def n_live(self) -> int:
        return len(self.live_steps)

    @property
    def n_elided(self) -> int:
        return self.n_total - self.n_live

    @property
    def hops(self) -> Tuple[int, ...]:
        """Fused shift distances: ``hops[0]`` is the prologue hop from
        the initial placement to the first live step; ``hops[i]`` moves
        live step ``i-1``'s payload to live step ``i``."""
        prev, out = 0, []
        for s in self.live_steps:
            out.append(s - prev)
            prev = s
        return tuple(out)


def compact_live_steps(step_keep: np.ndarray) -> CompactSchedule:
    """Derive the compacted schedule from a staged skip mask.

    ``step_keep`` is any ``(..., nsteps)`` per-(device, step) bool array;
    a step survives iff *any* device keeps it.
    """
    keep = np.asarray(step_keep, dtype=bool)
    nsteps = keep.shape[-1]
    live = np.flatnonzero(keep.reshape(-1, nsteps).any(axis=0))
    return CompactSchedule(
        n_total=int(nsteps), live_steps=tuple(int(s) for s in live)
    )


def resolve_compact_steps(
    plan, compact, *, batched: bool = False, npods: int = 1
) -> Optional[Tuple[int, ...]]:
    """Resolve a builder's ``compact`` request against the plan.

    ``None`` auto-enables compaction iff the planner staged a
    :class:`CompactSchedule` that actually elides something and the
    build is a plain (non-batched, single-pod) engine — batched engines
    take the union of per-graph masks (not staged) and multi-pod runs
    stride the mask per pod, so both keep the uniform scan body.  An
    explicit ``True`` that cannot be honored is an error.
    """
    cs = getattr(as_plan(plan), "compact", None)
    if compact is None:
        if cs is None or batched or npods != 1 or cs.n_elided == 0:
            return None
    elif not compact:
        return None
    else:
        if cs is None:
            raise ValueError(
                "plan carries no compacted schedule; re-plan through the "
                "pipeline with step_masks=True (or leave compact=None)"
            )
        if batched or npods != 1:
            raise ValueError(
                "compact=True is not supported for batched or multi-pod "
                "engines; pass compact=False (or None for auto)"
            )
    return cs.live_steps


def resolve_broadcast(plan, broadcast, *, batched: bool = False) -> str:
    """Resolve a SUMMA builder's ``broadcast`` request against the plan.

    ``None`` defers to the strategy the plan was staged for (its
    ``broadcast`` field; ``"auto"`` for plans predating the knob).
    ``"auto"`` resolves to the ppermute ``"chain"`` for plain engines —
    half the one-hot psum's bytes, DESIGN.md §4.5 — and to ``"onehot"``
    for batched ones: chain rounds need static round indices (ppermute
    pairs are trace constants), i.e. the unrolled body, which the
    batched engine's shared scan rules out.  An explicit ``"chain"``
    that cannot be honored is an error.
    """
    b = broadcast
    if b is None:
        b = getattr(as_plan(plan), "broadcast", None) or "auto"
    if b == "auto":
        return "onehot" if batched else "chain"
    if b not in ("onehot", "chain"):
        raise ValueError(
            f"unknown broadcast strategy {b!r}; "
            "expected 'onehot', 'chain', or 'auto'"
        )
    if b == "chain" and batched:
        raise ValueError(
            "broadcast='chain' is not supported for batched engines "
            "(chain rounds need the unrolled body); pass 'onehot' "
            "(or 'auto')"
        )
    return b


def resolve_step_mask(plan, use_step_mask) -> bool:
    """Resolve a builder's ``use_step_mask`` request against the plan.

    ``None`` auto-enables skipping iff the planner staged ``step_keep``
    masks; an explicit ``True`` on a mask-less plan is an error (the
    engine would have nothing to consume).
    """
    has = getattr(plan, "step_keep", None) is not None
    if use_step_mask is None:
        return has
    if use_step_mask and not has:
        raise ValueError(
            "plan carries no step_keep masks; re-plan with step_masks=True"
        )
    return bool(use_step_mask)

INT = np.int32


def host_aug_keys(
    indptr: np.ndarray, indices: np.ndarray
) -> Optional[np.ndarray]:
    """Host-side row-encoded intersection keys for stacked CSR blocks.

    The numpy twin of :func:`repro.core.count.build_aug_keys`, applied
    once per block at pack time: for every ``(..., nb + 1)`` indptr /
    ``(..., nnz_pad)`` indices pair, emits ``aug[e] = row(e) * (nb + 1)
    + col(e)`` with padding positions landing on the maximal key (their
    row resolves past the last row and their column holds the ``nb``
    sentinel), so each block's key array is sorted exactly like the
    on-device build.  Returns ``None`` when the key range needs int64
    but x64 is off (the device copy would be silently truncated) — the
    kernels then fall back to building keys on device, which fails
    loudly via :func:`~repro.core.count.aug_key_dtype`.
    """
    from .count import aug_key_dtype

    nb = indptr.shape[-1] - 1
    base = nb + 1
    try:
        key_dtype = np.dtype(aug_key_dtype(base))
    except OverflowError:
        return None
    flat_ptr = indptr.reshape(-1, nb + 1)
    flat_idx = indices.reshape(-1, indices.shape[-1])
    nnz_pad = flat_idx.shape[1]
    # row of entry e per block: searchsorted(indptr, e, 'right') - 1,
    # vectorized over blocks (indptr rows are independently sorted)
    e = np.arange(nnz_pad, dtype=np.int64)
    row_of = (
        np.apply_along_axis(np.searchsorted, 1, flat_ptr, e, side="right")
        - 1
    )
    aug = row_of.astype(key_dtype) * base + flat_idx.astype(key_dtype)
    return aug.reshape(indices.shape)


def _pad_to(x: np.ndarray, size: int, fill) -> np.ndarray:
    out = np.full((size,), fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


@dataclasses.dataclass
class PlanStats:
    """Balance statistics (paper Tables 3/4 analogues), host-computed."""

    tasks_per_device: np.ndarray  # (q, q) int64 — nonzero tasks owned
    nnz_per_block: np.ndarray  # (q, q) int64
    probe_work_per_device_shift: np.ndarray  # (q, q, q) int64
    task_imbalance: float  # max/avg of tasks_per_device
    probe_imbalance: float  # max/avg of per-shift probe work
    intersection_tasks_total: int  # paper Table 4 metric
    padding_fraction_indices: float
    padding_fraction_tasks: float
    # per-(device, shift) intersection-task counts (the summands of
    # ``intersection_tasks_total``).  Staged so the delta path
    # (DESIGN.md §4.7) can update the total exactly from dirty cells
    # alone; None on plans packed by the loop reference.
    itasks_per_cell: Optional[np.ndarray] = None  # (q, q, q) int64


@dataclasses.dataclass
class StepStats:
    """Per-(device, step) probe work for the non-Cannon schedules.

    The lean sibling of :class:`PlanStats`: just enough for the
    skip-aware rebalancer's masked-critical-path cost model (DESIGN.md
    §4.3) — SUMMA broadcast rounds carry a ``(r, c, c)`` array, the 1D
    ring a ``(p, p)`` one; the last axis is always the schedule step.
    """

    probe_work_per_device_shift: np.ndarray  # (..., nsteps) int64
    probe_imbalance: float  # max/avg of per-device total probe work


@dataclasses.dataclass
class TCPlan:
    """Device-ready arrays + metadata for one grid factorization."""

    n: int
    m: int
    q: int  # square grid dimension (Cannon); SUMMA reuses q x q here
    nb: int  # local rows/cols per block = ceil(n / q)
    nnz_pad: int  # padded nnz per block
    tmax: int  # padded tasks per device
    dmax: int  # max adjacency-fragment length over all blocks
    chunk: int  # tasks per searchsorted chunk

    # stacked [q, q, ...] arrays; *_indptr (q,q,nb+1), *_indices (q,q,nnz_pad)
    a_indptr: np.ndarray
    a_indices: np.ndarray
    b_indptr: np.ndarray
    b_indices: np.ndarray
    m_ti: np.ndarray  # (q, q, tmax) task row (local i)
    m_tj: np.ndarray  # (q, q, tmax) task row of B (local j)
    m_cnt: np.ndarray  # (q, q) valid task count

    stats: Optional[PlanStats] = None
    # canonical (un-skewed) blocks kept for SUMMA / 1D comparisons
    blocks: Optional[List[List[BlockCSR]]] = None
    # (q, q, q) bool per-(device, shift) skip mask: True = the incoming
    # block pair can contribute (sparsity-aware step skipping); None for
    # un-skewed (SUMMA-placement) or analytic plans
    step_keep: Optional[np.ndarray] = None
    # (q, q, nnz_pad) host-staged row-encoded intersection keys of the B
    # placement (DESIGN.md §5) — shifted alongside the B blob so the
    # global/search2 kernels skip the per-step on-device key build
    b_aug: Optional[np.ndarray] = None
    # visit-order permutation σ of Cannon's initial alignment: step s
    # hands device (x, y) the k-panel z = σ[(x + y + s) % q] (identity
    # when None).  Chosen by the compaction stage to concentrate live
    # work onto few steps (DESIGN.md §4.4).
    skew_perm: Optional[Tuple[int, ...]] = None
    # globally-live steps + fused hop vector (compaction stage)
    compact: Optional[CompactSchedule] = None
    # deterministic kernel-shape autotune report (chunk, d_small/n_long,
    # tail_heavy) when the plan went through the autotune stage
    autotune: Optional[dict] = None
    # long/short task split from bucketize_plan / the autotune stage:
    # the first ``n_long`` tasks on every device need probes padded to
    # dmax, the rest fit in ``d_small``.  None = plan not bucketized.
    n_long: Optional[int] = None
    d_small: Optional[int] = None
    # padded-probe waste accounting from bucketize_plan
    bucket_stats: Optional[dict] = None
    # hub-split side (repro.pipeline.hubsplit.HubSide) when the planner
    # split the heavy-tailed suffix off the 2D path (DESIGN.md §4.8);
    # its arrays join device_arrays() and the engine folds its partial
    # into the reduction.  The plan's own arrays then cover only the
    # residual graph.
    hub: Optional[object] = None

    # ------------------------------------------------------------------
    def device_arrays(self) -> Dict[str, np.ndarray]:
        out = dict(
            a_indptr=self.a_indptr,
            a_indices=self.a_indices,
            b_indptr=self.b_indptr,
            b_indices=self.b_indices,
            m_ti=self.m_ti,
            m_tj=self.m_tj,
            m_cnt=self.m_cnt,
        )
        if self.step_keep is not None:
            out["step_keep"] = self.step_keep
        if self.b_aug is not None:
            out["b_aug"] = self.b_aug
        if self.hub is not None:
            out.update(self.hub.device_arrays())
        return out

    def shape_structs(self):
        """jax.ShapeDtypeStruct stand-ins for every device array.

        For analytic (shape-only) plans this reflects the *padded* sizes
        without ever allocating them.
        """
        import jax

        shape_only = getattr(self, "_shape_only", None)
        if shape_only is not None:
            return {
                k: jax.ShapeDtypeStruct(shape, dtype)
                for k, (shape, dtype) in shape_only.items()
            }
        return {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in self.device_arrays().items()
        }

    def dense_blocks(self) -> Dict[str, np.ndarray]:
        """Materialize dense block operands (oracle path, small n only)."""
        q, nb = self.q, self.nb
        a = np.zeros((q, q, nb, nb), dtype=np.float32)
        b = np.zeros((q, q, nb, nb), dtype=np.float32)
        msk = np.zeros((q, q, nb, nb), dtype=np.float32)
        for x in range(q):
            for y in range(q):
                for name, arr in (("a", a), ("b", b)):
                    indptr = getattr(self, f"{name}_indptr")[x, y]
                    indices = getattr(self, f"{name}_indices")[x, y]
                    for r in range(nb):
                        lo, hi = indptr[r], indptr[r + 1]
                        cols = indices[lo:hi]
                        arr[x, y, r, cols] = 1.0
                cnt = self.m_cnt[x, y]
                msk[x, y, self.m_ti[x, y, :cnt], self.m_tj[x, y, :cnt]] = 1.0
        out = dict(a_dense=a, b_dense=b, m_dense=msk)
        if self.step_keep is not None:
            out["step_keep"] = self.step_keep
        return out


def _stack_blocks(
    blocks: List[List[BlockCSR]],
    placement,  # (x, y) -> BlockCSR
    q: int,
    nb: int,
    nnz_pad: int,
) -> Tuple[np.ndarray, np.ndarray]:
    indptr = np.zeros((q, q, nb + 1), dtype=INT)
    indices = np.zeros((q, q, nnz_pad), dtype=INT)
    for x in range(q):
        for y in range(q):
            blk = placement(x, y)
            indptr[x, y] = blk.indptr.astype(INT)
            indices[x, y, : blk.nnz] = blk.indices.astype(INT)
            indices[x, y, blk.nnz :] = nb  # sentinel beyond any local col
    return indptr, indices


def build_plan(
    graph: Graph,
    q: int,
    *,
    skew: bool = True,
    chunk: int = 512,
    with_stats: bool = True,
    keep_blocks: bool = True,
    step_masks: bool = True,
    skew_perm: Optional[Tuple[int, ...]] = None,
    aug_keys: bool = False,
) -> TCPlan:
    """Plan the 2D-cyclic execution of a *degree-ordered* graph on q x q.

    ``skew=True`` applies Cannon's initial alignment at placement time;
    ``skew=False`` yields the canonical placement used by SUMMA (A at
    ``(x, y) -> U_{x,y}``, B at ``(x, y) -> U_{y,x}``).  ``skew_perm``
    generalizes the alignment with a visit-order permutation σ (device
    ``(x, y)`` sees panel ``z = σ[(x+y+s) % q]`` at step ``s`` — any σ
    is a correct Cannon schedule; the compaction stage picks one that
    concentrates live work, DESIGN.md §4.4).  ``aug_keys`` stages the
    row-encoded B intersection keys host-side for the global/search2
    kernels.

    The implementation is the pipeline's vectorized packer
    (:func:`repro.pipeline.stages.pack_tc_plan`): one lexsorted pass
    emits the stacked arrays directly.  :func:`_build_plan_loops` keeps
    the original per-block loop semantics as the byte-level reference
    the packer is tested against.
    """
    from ..pipeline.stages import pack_tc_plan

    return pack_tc_plan(
        graph,
        q,
        skew=skew,
        chunk=chunk,
        with_stats=with_stats,
        keep_blocks=keep_blocks,
        step_masks=step_masks,
        skew_perm=skew_perm,
        aug_keys=aug_keys,
    )


def _build_plan_loops(
    graph: Graph,
    q: int,
    *,
    skew: bool = True,
    chunk: int = 512,
    with_stats: bool = True,
    keep_blocks: bool = True,
    step_masks: bool = True,
    skew_perm: Optional[Tuple[int, ...]] = None,
    aug_keys: bool = False,
) -> TCPlan:
    """Loop-based reference planner (the pre-pipeline implementation).

    Retained verbatim so ``tests/test_pipeline.py`` can pin the
    vectorized packer to byte-identical output; not used on any runtime
    path.
    """
    n, m = graph.n, graph.m
    nb = -(-n // q)
    blocks = cyclic_blocks(graph, q, q)

    nnz_pad = max(1, max(blocks[x][y].nnz for x in range(q) for y in range(q)))
    tmax = nnz_pad  # tasks per device == nnz of its mask block

    assert skew_perm is None or skew, "skew_perm is a Cannon-placement knob"
    sp = list(skew_perm) if skew_perm is not None else list(range(q))
    if skew:
        a_place = lambda x, y: blocks[x][sp[(x + y) % q]]
        b_place = lambda x, y: blocks[y][sp[(x + y) % q]]
    else:
        a_place = lambda x, y: blocks[x][y]
        b_place = lambda x, y: blocks[y][x]

    a_indptr, a_indices = _stack_blocks(blocks, a_place, q, nb, nnz_pad)
    b_indptr, b_indices = _stack_blocks(blocks, b_place, q, nb, nnz_pad)

    m_ti = np.zeros((q, q, tmax), dtype=INT)
    m_tj = np.full((q, q, tmax), 0, dtype=INT)
    m_cnt = np.zeros((q, q), dtype=INT)
    for x in range(q):
        for y in range(q):
            blk = blocks[x][y]
            # expand CSR -> COO (ti = local i in grid-row x, tj = local j in
            # grid-row y of the B operand; j's *local* index is j // q which
            # is exactly the stored column's block-local row id)
            rows = np.repeat(
                np.arange(blk.n_rows, dtype=INT), np.diff(blk.indptr)
            )
            cols = blk.indices.astype(INT)
            m_ti[x, y, : rows.shape[0]] = rows
            m_tj[x, y, : cols.shape[0]] = cols
            m_cnt[x, y] = rows.shape[0]

    dmax = max(1, max(blocks[x][y].max_row_len() for x in range(q) for y in range(q)))

    probe = None
    stats = None
    if with_stats:
        tasks = np.array(
            [[blocks[x][y].nnz for y in range(q)] for x in range(q)],
            dtype=np.int64,
        )
        # probe work per (x, y, shift): for each task (i, j) with both
        # fragments non-empty, the map-based intersection is "performed"
        # (paper Table 4 counts these tasks; we also weight by min-fragment
        # length for the imbalance measure of Table 3).
        probe = np.zeros((q, q, q), dtype=np.int64)
        itasks = 0
        rowlen = {
            (x, y): np.diff(blocks[x][y].indptr) for x in range(q) for y in range(q)
        }
        for x in range(q):
            for y in range(q):
                blk = blocks[x][y]
                rows = np.repeat(np.arange(blk.n_rows), np.diff(blk.indptr))
                cols = blk.indices
                for s in range(q):
                    z = sp[(x + y + s) % q] if skew else (x + y + s) % q
                    la = rowlen[(x, z)][rows]
                    lb = rowlen[(y, z)][cols]
                    both = (la > 0) & (lb > 0)
                    itasks += int(both.sum())
                    probe[x, y, s] = int(np.minimum(la, lb)[both].sum())
        tot_idx = q * q * nnz_pad
        stats = PlanStats(
            tasks_per_device=tasks,
            nnz_per_block=tasks.copy(),
            probe_work_per_device_shift=probe,
            task_imbalance=float(tasks.max() / max(1.0, tasks.mean())),
            probe_imbalance=float(
                probe.sum(axis=2).max() / max(1.0, probe.sum(axis=2).mean())
            ),
            intersection_tasks_total=itasks,
            padding_fraction_indices=float(1.0 - m / max(1, tot_idx)),
            padding_fraction_tasks=float(1.0 - m / max(1, q * q * tmax)),
        )

    # per-(device, shift) skip mask — loop reference of the vectorized
    # derivation in pipeline.stages (see DESIGN.md §4): device (x, y) at
    # shift s holds A = U_{x,z} and B = U_{y,z} with z = (x+y+s) % q, so
    # the step contributes only if the task list and both incoming
    # blocks are non-empty (refined to exact per-shift probe work when
    # stats were computed).
    step_keep = None
    if skew and step_masks:
        step_keep = np.zeros((q, q, q), dtype=bool)
        for x in range(q):
            for y in range(q):
                for s in range(q):
                    z = sp[(x + y + s) % q]
                    k = (
                        m_cnt[x, y] > 0
                        and blocks[x][z].nnz > 0
                        and blocks[y][z].nnz > 0
                    )
                    if probe is not None:
                        k = k and probe[x, y, s] > 0
                    step_keep[x, y, s] = k

    b_aug = host_aug_keys(b_indptr, b_indices) if aug_keys else None

    return TCPlan(
        n=n,
        m=m,
        q=q,
        nb=nb,
        nnz_pad=nnz_pad,
        tmax=tmax,
        dmax=dmax,
        chunk=min(chunk, tmax),
        a_indptr=a_indptr,
        a_indices=a_indices,
        b_indptr=b_indptr,
        b_indices=b_indices,
        m_ti=m_ti,
        m_tj=m_tj,
        m_cnt=m_cnt,
        stats=stats,
        blocks=blocks if keep_blocks else None,
        step_keep=step_keep,
        b_aug=b_aug,
        skew_perm=tuple(sp) if skew_perm is not None else None,
    )


def bucketize_plan(plan: TCPlan, d_small: int = 32) -> TCPlan:
    """§Perf H1a: statically reorder each device's tasks into long|short.

    A task is *long* iff under ANY Cannon pairing its probe needs padding
    beyond ``d_small`` (max over shifts of min-fragment length).  The
    planner reorders (m_ti, m_tj) so long tasks come first and records the
    per-plan maximum long-count; the two-level count path then runs long
    chunks at ``dmax`` and the rest at ``d_small``, eliminating the
    ``dmax / avg_len`` padded-probe waste on power-law graphs.
    Returns a new plan with ``n_long``/``d_small`` attributes set.
    """
    plan = as_plan(plan)
    assert plan.blocks is not None
    q = plan.q
    rowlen = {
        (x, y): np.diff(plan.blocks[x][y].indptr)
        for x in range(q)
        for y in range(q)
    }
    m_ti = plan.m_ti.copy()
    m_tj = plan.m_tj.copy()
    n_long_max = 0
    waste_before = 0
    waste_after = 0
    for x in range(q):
        for y in range(q):
            cnt = int(plan.m_cnt[x, y])
            ti = plan.m_ti[x, y, :cnt]
            tj = plan.m_tj[x, y, :cnt]
            # probe side is the A fragment (row i); keys side is searched
            # globally and needs no padding (count_pair_search_global)
            need = np.zeros(cnt, dtype=np.int64)
            for z in range(q):
                need = np.maximum(need, rowlen[(x, z)][ti])
            long_mask = need > d_small
            order = np.argsort(~long_mask, kind="stable")  # long first
            m_ti[x, y, :cnt] = ti[order]
            m_tj[x, y, :cnt] = tj[order]
            n_long = int(long_mask.sum())
            n_long_max = max(n_long_max, n_long)
            waste_before += cnt * plan.dmax
            waste_after += n_long * plan.dmax + (cnt - n_long) * d_small
    return dataclasses.replace(
        plan,
        m_ti=m_ti,
        m_tj=m_tj,
        n_long=n_long_max,
        d_small=d_small,
        bucket_stats=dict(
            padded_probe_before=float(waste_before * q),  # x shifts
            padded_probe_after=float(waste_after * q),
            reduction=float(waste_before / max(1, waste_after)),
        ),
    )


def analytic_plan(
    n: int,
    m: int,
    q: int,
    *,
    dmax_block: int,
    nnz_slack: float = 1.25,
    chunk: int = 512,
    name: str = "analytic",
) -> TCPlan:
    """Shape-only plan for dry runs on graphs too large to materialize.

    Uses the paper's balance argument (cyclic distribution => per-block nnz
    ~ m / p with small slack; Table 3 measured <= 6% imbalance, we budget
    ``nnz_slack``) to size the padded arrays.  Arrays are allocated as
    zero-filled placeholders only if requested via ``device_arrays``; dry
    runs should use :meth:`TCPlan.shape_structs` (no allocation).
    """
    nb = -(-n // q)
    nnz_pad = max(1, int(np.ceil(m / (q * q) * nnz_slack)))
    tmax = nnz_pad
    empty = np.zeros((q, q, 0), dtype=INT)
    plan = TCPlan(
        n=n,
        m=m,
        q=q,
        nb=nb,
        nnz_pad=nnz_pad,
        tmax=tmax,
        dmax=max(1, dmax_block),
        chunk=min(chunk, tmax),
        a_indptr=empty,
        a_indices=empty,
        b_indptr=empty,
        b_indices=empty,
        m_ti=empty,
        m_tj=empty,
        m_cnt=np.zeros((q, q), dtype=INT),
        stats=None,
        blocks=None,
    )
    plan._shape_only = dict(  # type: ignore[attr-defined]
        a_indptr=((q, q, nb + 1), INT),
        a_indices=((q, q, nnz_pad), INT),
        b_indptr=((q, q, nb + 1), INT),
        b_indices=((q, q, nnz_pad), INT),
        m_ti=((q, q, tmax), INT),
        m_tj=((q, q, tmax), INT),
        m_cnt=((q, q), INT),
    )
    return plan
