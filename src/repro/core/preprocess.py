"""Preprocessing phase (paper §5.3).

Steps, mirroring the paper:

1. *initial cyclic redistribution* — in our SPMD formulation the host
   planner feeds pre-placed blocks, so the "redistribution" is a relabeling
   choice; :func:`cyclic_relabel` implements it and the planning pipeline
   wires it in as the optional first relabel stage
   (``count_triangles(..., cyclic_p=p)`` /
   ``repro.pipeline.stages.relabel_stage``).
2. *reorder vertices in non-decreasing degree* via counting sort.  The host
   path (:func:`degree_order`) is a stable counting sort; the distributed
   formulation the paper describes (local histograms, global max-degree
   reduction, prefix sums over degree buckets) is implemented faithfully in
   JAX in :func:`distributed_degree_rank` and verified equivalent in tests.
3. *split the adjacency matrix into U and L*.  Because L = Uᵀ, the planner
   only materializes U blocks; the ⟨j,i,k⟩ task set over L's nonzeros is the
   transposed view of the same blocks (see DESIGN.md §2).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import Graph

__all__ = [
    "degree_order",
    "cyclic_relabel",
    "preprocess",
    "distributed_degree_rank",
]


def degree_order(graph: Graph) -> np.ndarray:
    """Return ``perm`` with ``perm[v]`` = new id of vertex ``v``.

    Vertices are ranked by non-decreasing degree; ties broken by original
    id (stable sort — the same ranks the paper's counting sort yields).
    """
    deg = graph.degrees()
    order = np.argsort(deg, kind="stable")  # vertex ids sorted by degree
    perm = np.empty(graph.n, dtype=np.int64)
    perm[order] = np.arange(graph.n, dtype=np.int64)
    return perm


def cyclic_relabel(n: int, p: int) -> np.ndarray:
    """The paper's initial cyclic redistribution as a relabeling.

    Vertex ``v`` (owned contiguously in a 1D input distribution) moves to
    position ``(v % p) * ceil(n/p) + v // p`` — round-robin over ranks.
    When ``p`` does not divide ``n`` the trailing slots of the last rank's
    chunk are empty; they are compacted away so the result is a true
    permutation of ``[0, n)`` (safe for :meth:`Graph.relabel`), identical
    to the raw positions whenever ``p | n``.
    """
    chunk = -(-n // p)
    v = np.arange(n, dtype=np.int64)
    pos = (v % p) * chunk + v // p
    perm = np.empty(n, dtype=np.int64)
    perm[np.argsort(pos)] = v
    return perm


def preprocess(graph: Graph) -> Tuple[Graph, np.ndarray]:
    """Degree-order the graph; return (relabeled graph, perm)."""
    perm = degree_order(graph)
    return graph.relabel(perm, name=graph.name + "+degord"), perm


# ----------------------------------------------------------------------
# Distributed counting sort (JAX) — faithful to paper §5.3/§5.4
# ----------------------------------------------------------------------
def distributed_degree_rank(degrees, axis_name: str):
    """Per-shard degree ranks via the paper's distributed counting sort.

    Runs inside ``shard_map`` over a 1D axis.  Each shard holds a chunk of
    the degree array.  Implements: local histogram -> global histogram
    (psum, the paper's all-reduce) -> exclusive scan over degree buckets ->
    within-bucket offsets via local cumsum + exclusive psum-scan over shards
    (the paper's prefix sum, cost d_max log p).

    Returns the global rank (= new vertex id) of each local vertex, stable
    by (shard index, local position).
    """
    import jax
    import jax.numpy as jnp

    from .. import compat

    degrees = jnp.asarray(degrees)
    p = compat.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    # static bucket bound: a vertex degree is < n = chunk * p
    nbuckets = degrees.shape[0] * p + 1

    # (a) local histogram; (b) the paper's global max-degree reduction is
    # subsumed by the static bucket bound but kept for parity with the cost
    # model (it appears in T_preprocessing as the `log p` reduction term).
    hist = jnp.zeros(nbuckets, dtype=jnp.int32).at[degrees].add(1)
    _ = jax.lax.pmax(jnp.max(degrees, initial=0), axis_name)

    # (c) global histogram + exclusive scan over degree buckets
    ghist = jax.lax.psum(hist, axis_name)
    bucket_starts = jnp.cumsum(ghist) - ghist

    # (d) the paper's distributed prefix sum (cost d_max * log p): counts of
    # each degree value held by *earlier* shards.
    all_hists = jax.lax.all_gather(hist, axis_name)  # (p, nbuckets)
    before = jnp.sum(
        jnp.where((jnp.arange(p) < idx)[:, None], all_hists, 0), axis=0
    )

    # (e) stable within-shard offsets: #earlier local vertices of same
    # degree.  Sort-based rank instead of a one-hot/cumsum matrix: the
    # one-hot materialized a (chunk, n+1) intermediate — O(n_local × n)
    # memory — where a stable argsort plus the shard's own exclusive
    # bucket starts gives the same rank in O(n_local log n_local) time
    # and O(n_local + n) memory.
    nloc = degrees.shape[0]
    order = jnp.argsort(degrees, stable=True)
    pos = jnp.zeros(nloc, dtype=jnp.int32).at[order].set(
        jnp.arange(nloc, dtype=jnp.int32)
    )
    local_starts = jnp.cumsum(hist) - hist
    within_count = pos - local_starts[degrees]

    return bucket_starts[degrees] + before[degrees] + within_count
