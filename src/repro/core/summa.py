"""SUMMA-schedule triangle counting on rectangular ``r x c`` grids.

The paper notes (§8) that the algorithm "can be easily extended to deal
with rectangular processor grids using the SUMMA algorithm" — this module
is that extension, and it is also the framework's *elasticity* mechanism:
after device loss, any ``r x c`` factorization of the surviving devices can
be replanned (Cannon requires a square grid).

Formulation: tasks (i, j) live on device ``(i % r, j % c)``; the reduction
index k is classed by ``k % c`` into ``c`` panels.  Step ``z``:

* panel ``A_{x,z}``  (rows i%r==x, cols k%c==z)  is broadcast along grid
  row ``x`` from its owner ``(x, z)``;
* panel ``B_{y,z}``  (rows j%c==y, cols k%c==z)  is broadcast along grid
  column ``y`` from its owner ``(z % r, y)`` (each device stores
  ``ceil(c/r)`` B panels).

Broadcasts are expressed as masked ``psum`` (a one-hot contribution per
step).  On real hardware XLA lowers this to an all-reduce; a dedicated
collective-broadcast would move strictly fewer bytes — we account for this
honestly in the roofline (see EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np

from .graph import Graph

INT = np.int32

__all__ = ["SummaPlan", "build_summa_plan", "build_summa_fn"]


@dataclasses.dataclass
class SummaPlan:
    n: int
    m: int
    r: int
    c: int
    nb_r: int  # local rows of A / mask = ceil(n / r)
    nb_c: int  # local rows of B and local k-cols = ceil(n / c)
    npan: int  # B panels per device = ceil(c / r)
    a_nnz_pad: int
    b_nnz_pad: int
    tmax: int
    dmax: int
    chunk: int

    a_indptr: np.ndarray  # (r, c, nb_r + 1)
    a_indices: np.ndarray  # (r, c, a_nnz_pad)
    b_indptr: np.ndarray  # (r, c, npan, nb_c + 1)
    b_indices: np.ndarray  # (r, c, npan, b_nnz_pad)
    m_ti: np.ndarray  # (r, c, tmax)
    m_tj: np.ndarray  # (r, c, tmax)
    m_cnt: np.ndarray  # (r, c)
    # (r, c, c) bool: True = device (x, y) counts at broadcast round z
    step_keep: "np.ndarray | None" = None
    # per-round probe work (repro.core.plan.StepStats) when planned
    # with_stats — consumed by the skip-aware rebalancer
    stats: "object | None" = None
    # globally-live broadcast rounds (repro.core.plan.CompactSchedule);
    # dead rounds' broadcasts are elided entirely
    compact: "object | None" = None
    # deterministic kernel-shape autotune report (pipeline stage)
    autotune: "dict | None" = None
    # long/short task split set by the autotune stage (first ``n_long``
    # tasks per device need dmax probes, the rest fit in ``d_small``)
    n_long: "int | None" = None
    d_small: "int | None" = None
    # broadcast strategy the plan was staged for ("auto" | "onehot" |
    # "chain") — a planner cache-key component, resolved by the engine
    # via repro.core.plan.resolve_broadcast
    broadcast: str = "auto"
    # hub-split side (repro.pipeline.hubsplit.HubSide, DESIGN.md §4.8)
    hub: "object | None" = None

    def device_arrays(self) -> Dict[str, np.ndarray]:
        out = dict(
            a_indptr=self.a_indptr,
            a_indices=self.a_indices,
            b_indptr=self.b_indptr,
            b_indices=self.b_indices,
            m_ti=self.m_ti,
            m_tj=self.m_tj,
            m_cnt=self.m_cnt,
        )
        if self.step_keep is not None:
            out["step_keep"] = self.step_keep
        if self.hub is not None:
            out.update(self.hub.device_arrays())
        return out

    def shape_structs(self):
        import jax

        return {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in self.device_arrays().items()
        }


def build_summa_plan(graph: Graph, r: int, c: int, *, chunk: int = 512) -> SummaPlan:
    """SUMMA planner — delegates to the pipeline's vectorized packer
    (:func:`repro.pipeline.stages.pack_summa_plan`): A/mask blocks from
    one ``(r, c)`` lexsort pass, B panels gathered from one ``(c, c)``
    pass, no per-block loops."""
    from ..pipeline.stages import pack_summa_plan

    return pack_summa_plan(graph, r, c, chunk=chunk)


def build_summa_fn(
    plan: SummaPlan,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    method: str = "search",
    probe_shorter: bool = True,
    count_dtype=jnp.int32,
    reduce_global: bool = True,
    batched: bool = False,
    use_step_mask: "bool | None" = None,
    compact: "bool | None" = None,
    broadcast: "str | None" = None,
    elide_broadcast: bool = False,
    fused_impl: str = "auto",
    fused_tile: "int | None" = None,
):
    """Thin engine configuration: SummaSchedule × SummaCSRStore × kernel.

    ``use_step_mask=None`` auto-enables sparsity-aware step skipping
    when the plan carries ``step_keep`` masks; ``compact=None``
    auto-enables broadcast-round elision when the plan staged a
    compacted schedule that drops at least one round (dead rounds lose
    their broadcasts entirely — DESIGN.md §4.4).

    ``broadcast`` selects the panel-broadcast strategy (DESIGN.md §4.5):
    ``"onehot"`` (masked psum — an all-reduce per panel), ``"chain"``
    (masked ppermute doubling chains — half the bytes), ``"auto"``/
    ``None`` resolves via :func:`~repro.core.plan.resolve_broadcast`
    (chain for plain engines, one-hot for batched).  Chain rounds need
    static round indices, so the schedule then runs its unrolled body
    even when nothing is elided — dead rounds still elide their
    collectives entirely.  ``elide_broadcast`` is the count-only timing
    probe (counts are wrong for grids > 1x1), mirroring Cannon's
    ``elide_shifts``.
    """
    from . import engine
    from .engine import (
        GridAxes,
        Reduction,
        SummaCSRStore,
        SummaSchedule,
        make_csr_kernel,
    )
    from .plan import (
        as_plan,
        resolve_broadcast,
        resolve_compact_steps,
        resolve_step_mask,
    )

    plan = as_plan(plan)
    use_step_mask = resolve_step_mask(plan, use_step_mask)
    live = resolve_compact_steps(plan, compact, batched=batched)
    broadcast = resolve_broadcast(plan, broadcast, batched=batched)
    if broadcast == "chain" and live is None:
        # chain rounds need static indices: unroll the full round list
        # (elision still applies whenever the plan staged a live subset)
        live = tuple(range(plan.c))
    axes = GridAxes(row_axis, col_axis)
    if method == "fused":
        engine.check_fused_split(plan)
    kernel = make_csr_kernel(
        method,
        dpad=plan.dmax,
        chunk=plan.chunk,
        probe_shorter=probe_shorter,
        count_dtype=count_dtype,
        sentinel=plan.nb_c + 1,
        n_long=getattr(plan, "n_long", None),
        d_small=getattr(plan, "d_small", None),
        fused_impl=fused_impl,
        fused_tile=fused_tile,
    )
    store = SummaCSRStore(
        kernel, r=plan.r, c=plan.c, broadcast=broadcast,
        elide_broadcast=elide_broadcast,
    )
    schedule = SummaSchedule(r=plan.r, c=plan.c, axes=axes, live_steps=live)
    return engine.build_engine_fn(
        mesh, axes, store, schedule,
        count_dtype=count_dtype,
        reduction=Reduction(global_sum=reduce_global),
        batched=batched,
        use_step_mask=use_step_mask,
        hub=engine.HubCount.from_plan(plan, probe_shorter=probe_shorter),
    )
