"""Host-side bit-packed tile stores + active-triple joins for the kernel path.

The doubly-compressed sparsity structure of the paper, promoted to tile
granularity: each block of U keeps only its *nonempty* 128x128-bit tiles
(``packed`` store + ``(tile_row, tile_col)`` ids), and for every Cannon
pairing the planner precomputes the join

    {(a_slot, b_slot, m_slot) : A-tile (ti,tk), B-tile (tj,tk), M-tile (ti,tj)}

which drives the kernel's scalar-prefetch grid — empty tiles are never
touched, the tile-level analogue of "skip vertices with empty adjacency
fragments".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from ..kernels.tc_tile.tc_tile import TILE, WORDS
from .decomp import BlockCSR
from .plan import TCPlan

INT = np.int32

__all__ = ["TilePlan", "build_tile_plan", "pack_block_tiles"]


def pack_block_tiles(blk: BlockCSR):
    """Pack one block's entries into bit tiles.

    Returns (packed (nt, TILE, WORDS) uint32, ids (nt, 2) int32) where
    ``ids[t] = (tile_row, tile_col)`` sorted lexicographically.
    """
    rows = np.repeat(np.arange(blk.n_rows, dtype=np.int64), np.diff(blk.indptr))
    cols = blk.indices
    if rows.size == 0:
        return (
            np.zeros((0, TILE, WORDS), dtype=np.uint32),
            np.zeros((0, 2), dtype=INT),
        )
    tr, tc = rows // TILE, cols // TILE
    key = tr * (blk.n_cols // TILE + 2) + tc
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, start = np.unique(key_s, return_index=True)
    nt = uniq.shape[0]
    packed = np.zeros((nt, TILE, WORDS), dtype=np.uint32)
    ids = np.zeros((nt, 2), dtype=INT)
    slot_of = {int(k): s for s, k in enumerate(uniq)}
    slots = np.array([slot_of[int(k)] for k in key], dtype=np.int64)
    r_in = (rows % TILE).astype(np.int64)
    c_in = (cols % TILE).astype(np.int64)
    word = c_in // 32
    bit = (c_in % 32).astype(np.uint32)
    np.bitwise_or.at(
        packed, (slots, r_in, word), (np.uint32(1) << bit)
    )
    ids[:, 0] = (uniq // (blk.n_cols // TILE + 2)).astype(INT)
    ids[:, 1] = (uniq % (blk.n_cols // TILE + 2)).astype(INT)
    return packed, ids


@dataclasses.dataclass
class TilePlan:
    """Stacked tile stores + per-shift triple joins for a TCPlan."""

    q: int
    nt_pad: int  # padded tiles per block store
    trip_pad: int  # padded triples per (device, shift)

    # pre-skewed stores matching the Cannon placement of the parent plan
    a_tiles: np.ndarray  # (q, q, nt_pad, TILE, WORDS) uint32
    b_tiles: np.ndarray  # (q, q, nt_pad, TILE, WORDS) uint32
    m_tiles: np.ndarray  # (q, q, nt_pad, TILE, WORDS) uint32
    triples: np.ndarray  # (q, q, q, trip_pad, 4) int32  [x, y, shift]

    stats: Dict[str, float] = dataclasses.field(default_factory=dict)
    # propagated from the parent TCPlan so the tile path stages the same
    # (q, q, q) skip mask as the CSR paths
    step_keep: "np.ndarray | None" = None

    def device_arrays(self) -> Dict[str, np.ndarray]:
        out = dict(
            a_tiles=self.a_tiles,
            b_tiles=self.b_tiles,
            m_tiles=self.m_tiles,
            triples=self.triples,
        )
        if self.step_keep is not None:
            out["step_keep"] = self.step_keep
        return out


def build_tile_plan(plan: TCPlan) -> TilePlan:
    """Build tile stores + joins from a planned graph (needs plan.blocks).

    Accepts a raw :class:`TCPlan` or a pipeline ``PlanArtifact``."""
    from .plan import as_plan

    plan = as_plan(plan)
    assert plan.blocks is not None, "build_plan(..., keep_blocks=True) required"
    q = plan.q
    blocks = plan.blocks
    # σ visit order of the parent plan's Cannon alignment (DESIGN.md
    # §4.4): tile stores and per-shift joins must see the same panel
    # z = σ[(x+y+s) % q] as the CSR placement
    sp = (
        list(plan.skew_perm)
        if getattr(plan, "skew_perm", None) is not None
        else list(range(q))
    )

    packed: List[List[np.ndarray]] = [[None] * q for _ in range(q)]
    ids: List[List[np.ndarray]] = [[None] * q for _ in range(q)]
    for x in range(q):
        for y in range(q):
            packed[x][y], ids[x][y] = pack_block_tiles(blocks[x][y])
    nt_pad = max(1, max(ids[x][y].shape[0] for x in range(q) for y in range(q)))

    def store(x, y):
        out = np.zeros((nt_pad, TILE, WORDS), dtype=np.uint32)
        out[: packed[x][y].shape[0]] = packed[x][y]
        return out

    # mask lookup: map (tile_row, tile_col) -> slot per block
    id_maps = [
        [
            {(int(r), int(c)): s for s, (r, c) in enumerate(ids[x][y])}
            for y in range(q)
        ]
        for x in range(q)
    ]

    all_triples: List[List[List[np.ndarray]]] = [
        [[None] * q for _ in range(q)] for _ in range(q)
    ]
    trip_pad = 1
    for x in range(q):
        for y in range(q):
            mmap = id_maps[x][y]
            for s in range(q):
                z = sp[(x + y + s) % q]
                a_ids = ids[x][z]  # (na, 2) tiles of U_{x,z}
                b_ids = ids[y][z]  # (nb, 2) tiles of U_{y,z}
                # join on tk (column tile), filter on mask membership
                trips = []
                from collections import defaultdict

                b_by_tk = defaultdict(list)
                for bs, (tj, tk) in enumerate(b_ids):
                    b_by_tk[int(tk)].append((bs, int(tj)))
                for as_, (ti, tk) in enumerate(a_ids):
                    for bs, tj in b_by_tk.get(int(tk), ()):
                        ms = mmap.get((int(ti), tj))
                        if ms is not None:
                            trips.append((as_, bs, ms, 1))
                arr = np.array(trips, dtype=INT).reshape(-1, 4)
                all_triples[x][y][s] = arr
                trip_pad = max(trip_pad, arr.shape[0])

    triples = np.zeros((q, q, q, trip_pad, 4), dtype=INT)
    ntrips = 0
    for x in range(q):
        for y in range(q):
            for s in range(q):
                arr = all_triples[x][y][s]
                triples[x, y, s, : arr.shape[0]] = arr
                ntrips += arr.shape[0]

    a_tiles = np.stack(
        [np.stack([store(x, sp[(x + y) % q]) for y in range(q)]) for x in range(q)]
    )
    b_tiles = np.stack(
        [np.stack([store(y, sp[(x + y) % q]) for y in range(q)]) for x in range(q)]
    )
    m_tiles = np.stack(
        [np.stack([store(x, y) for y in range(q)]) for x in range(q)]
    )

    total_tiles = sum(
        ids[x][y].shape[0] for x in range(q) for y in range(q)
    )
    return TilePlan(
        q=q,
        nt_pad=nt_pad,
        trip_pad=trip_pad,
        a_tiles=a_tiles,
        b_tiles=b_tiles,
        m_tiles=m_tiles,
        triples=triples,
        step_keep=plan.step_keep,
        stats=dict(
            total_active_tiles=float(total_tiles),
            triples_total=float(ntrips),
            tile_fill=float(plan.m / max(1, total_tiles * TILE * TILE)),
            trip_padding_fraction=float(
                1.0 - ntrips / max(1, q * q * q * trip_pad)
            ),
        ),
    )
