"""Deterministic synthetic data pipelines with background prefetch.

Every family gets a seeded generator (same seed -> same stream, so a
restarted job replays its data cursor from the checkpoint) and a
double-buffered prefetch thread so host batch synthesis overlaps device
steps — the data-side analogue of the collective/compute overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

__all__ = ["TokenPipeline", "RecsysPipeline", "GraphPipeline", "Prefetcher"]


class Prefetcher:
    """Background-thread double buffering around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        for item in self._it:
            self._q.put(item)
        self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


class TokenPipeline:
    """Synthetic LM tokens with a restartable cursor.

    Samples Zipf-ish token ids (matching real vocab skew) with labels =
    tokens shifted by one; ``state_dict``/``load_state`` give exact replay
    after restart.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.cursor = 0

    def state_dict(self):
        return {"cursor": self.cursor, "seed": self.seed}

    def load_state(self, st):
        self.cursor = int(st["cursor"])
        self.seed = int(st["seed"])

    def next_batch(self):
        rng = np.random.default_rng((self.seed, self.cursor))
        self.cursor += 1
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(z, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        while True:
            yield self.next_batch()


class RecsysPipeline:
    """Criteo-like synthetic batches (dense log-normals + Zipf ids)."""

    def __init__(self, cfg, batch: int, seed: int = 0):
        self.cfg, self.batch, self.seed = cfg, batch, seed
        self.cursor = 0

    def next_batch(self):
        rng = np.random.default_rng((self.seed, self.cursor))
        self.cursor += 1
        cfg = self.cfg
        dense = rng.lognormal(0, 1, size=(self.batch, cfg.n_dense)).astype(
            np.float32
        )
        ids = np.stack(
            [
                np.minimum(
                    rng.zipf(1.2, size=(self.batch, cfg.multi_hot)), size - 1
                )
                for size in cfg.table_sizes
            ],
            axis=1,
        ).astype(np.int32)
        ctr = (dense[:, 0] > np.median(dense[:, 0])).astype(np.float32)
        return {"dense": dense, "sparse_ids": ids, "labels": ctr}


class GraphPipeline:
    """Minibatch GNN sampling pipeline over a host CSR graph."""

    def __init__(self, graph, batch_nodes: int, fanouts, seed: int = 0):
        from ..core.graph import Graph

        self.graph = graph
        adj = graph.adjacency_csr()
        self.indptr, self.indices = adj.indptr, adj.indices
        self.batch_nodes = batch_nodes
        self.fanouts = tuple(fanouts)
        self.seed = seed
        self.cursor = 0

    def next_batch(self):
        from ..sparse.sampler import sample_neighbors

        rng = np.random.default_rng((self.seed, self.cursor))
        self.cursor += 1
        seeds = rng.choice(self.graph.n, size=self.batch_nodes, replace=False)
        return sample_neighbors(
            self.indptr, self.indices, seeds, self.fanouts, rng
        )
