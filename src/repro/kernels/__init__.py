"""Pallas TPU kernels for the compute hot spots.

``tc_tile`` — the paper's set-intersection inner loop as a bit-packed
128x128 tile kernel (popcount/VPU and MXU modes), driven by a
scalar-prefetched active-tile-triple list (the doubly-compressed-sparsity
adaptation; see DESIGN.md §2).
"""
from .tc_tile.ops import tile_pair_count  # noqa: F401
