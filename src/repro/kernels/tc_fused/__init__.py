"""Fused device-step intersection kernel (DESIGN.md §5.1).

``tc_fused`` — probe-gather + sorted-intersection + count-accumulate for
an entire device-step in one Pallas kernel, tiled over the autotuner's
``d_small``/``n_long`` maxfrag split: short tasks run through a dense
equality panel held in VMEM, long rows fall back to the chunked
two-level global-search path.  A pure-lax reference with identical
masking semantics backs CPU CI (and is the fast path on CPU backends).

``autotune`` — the measured-roofline table (DESIGN.md §4.6): time
candidate (tile, chunk, d_small) shapes once per (backend, dtype,
shape-bucket), check them against ``launch/roofline.py`` bandwidth
ceilings, and persist the verdict so ``method="auto"`` can resolve to
the fused kernel only where measurement says it wins.
"""
from .ops import (  # noqa: F401
    VMEM_BUDGET_BYTES,
    count_pair_fused,
    fused_gate,
    fused_panel_bytes,
    fused_tile_for,
    fused_vmem_bytes,
    resolve_fused_impl,
)
from .ref import fused_short_ref  # noqa: F401
from .tc_fused import fused_short_counts  # noqa: F401
from .autotune import (  # noqa: F401
    default_table_dir,
    measured_entry,
    measured_table_key,
    predict_fused_wins,
)
