"""Measured-roofline autotune table for the fused kernel (DESIGN.md §4.6).

The PR 5 percentile autotune is purely *analytic* — it derives shapes
from the probe-length distribution without ever running a kernel.  This
module adds the *measured* mode: time a handful of candidate
``(tile, chunk, d_small)`` shapes of the fused panel against the
incumbent two-level search on the plan's busiest device block, sanity-
check the verdict against the :mod:`repro.launch.roofline` HBM
bandwidth ceiling, and persist the result so every later run with the
same (backend, dtype, shape-bucket) resolves ``method="auto"`` straight
from the table.

Keying mirrors the plan cache's content-addressed style
(:func:`repro.pipeline.cache.graph_digest`): a blake2b over the table
version, backend, index dtype, power-of-two buckets of the block
shapes, and the split parameters.  Bucketing (rather than exact shapes)
is what makes the table reusable across graphs of the same size class —
and what makes a warm table possible at all under batched serving.

Entries are single JSON files under :func:`default_table_dir`
(``$REPRO_TC_MEASURED_DIR`` overrides; tests point it at a tmpdir).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.count import (
    build_aug_keys,
    count_pair_search,
    count_pair_search_two_level,
)
from ...launch.roofline import HW
from .ops import count_pair_fused, fused_tile_for, resolve_fused_impl

__all__ = [
    "TABLE_VERSION",
    "default_table_dir",
    "measured_entry",
    "measured_table_key",
    "predict_fused_wins",
    "roofline_predict",
]

TABLE_VERSION = 1
_REPS = 3  # min-of-k timing


def default_table_dir() -> str:
    env = os.environ.get("REPRO_TC_MEASURED_DIR")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "tc_measured"
    )


def _bucket(x: int) -> int:
    """Next power of two — the shape-bucket that makes entries reusable
    across graphs of the same size class."""
    return 1 << max(0, int(math.ceil(math.log2(max(1, int(x))))))


def measured_table_key(
    *,
    kind: str,
    backend: str,
    dtype: str,
    nb: int,
    nnz_pad: int,
    tmax: int,
    dmax: int,
    d_small: int,
    tail_heavy: bool,
) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(
        repr(
            (
                TABLE_VERSION,
                kind,
                backend,
                dtype,
                _bucket(nb),
                _bucket(nnz_pad),
                _bucket(tmax),
                _bucket(dmax),
                int(d_small),
                bool(tail_heavy),
            )
        ).encode()
    )
    return h.hexdigest()


def roofline_predict(
    *, tshort: int, d_small: int, dpad: int, nnz: int
) -> dict:
    """Roofline time model for the short-task bucket (the long bucket
    runs the same fallback on both paths and cancels out).

    search2's short bucket gathers the probe panel (``dpad`` ids per
    task, where ``dpad`` = the baseline's short padding) and then runs a
    binary search whose ~log2(nnz) dependent levels each touch HBM,
    plus the key encode — all charged to ``HW['hbm_bw']``.  The fused
    kernel's HBM traffic is the two fragment gathers ONLY: the (d, d)
    equality panel lives in VMEM/registers and never reaches HBM (the
    point of the fusion), so it is charged to the *compute* ceiling
    instead and the fused time is the max of the two terms.  The model
    ranks the paths; the measured table is the ground truth it is
    sanity-checked against.
    """
    lg = max(1.0, math.log2(max(2, nnz)))
    bytes_search = tshort * dpad * 4.0 * (2.0 + lg)
    bytes_fused = tshort * 2.0 * d_small * 4.0
    ops_fused = tshort * float(d_small) ** 2
    t_fused = max(
        bytes_fused / HW["hbm_bw"], ops_fused / HW["peak_flops"]
    )
    t_search = bytes_search / HW["hbm_bw"]
    return dict(
        t_search=t_search,
        t_fused=t_fused,
        hbm_bw=HW["hbm_bw"],
        peak_flops=HW["peak_flops"],
        predicted_winner="fused" if t_fused < t_search else "search2",
    )


def predict_fused_wins(entry: dict) -> bool:
    """The table's verdict: does the measured fused best beat the
    measured baseline on this shape bucket?"""
    return bool(entry.get("winner") == "fused")


def _time_once(fn, *args) -> float:
    """Min-of-k warm wall time of a jitted ``fn(*args)``.

    The operands MUST be passed as jit arguments, not closures: a
    zero-argument jitted callable is all-constant, so XLA would fold the
    entire count at compile time and the "measurement" would time a
    buffer fetch.  The first call compiles + warms; production pays
    exactly this warm-dispatch cost inside the engine.
    """
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _busiest_arrays(plan) -> Tuple:
    """(a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt, sentinel, kind) of the
    device with the most tasks — the block the measurement represents."""
    if hasattr(plan, "t_cnt"):  # OneDPlan
        p = plan.p
        flat = int(np.argmax(np.asarray(plan.t_cnt)))
        d0, o = flat // p, flat % p
        return (
            plan.indptr[d0], plan.indices[d0],
            plan.indptr[o], plan.indices[o],
            plan.t_i[d0, o], plan.t_j[d0, o],
            int(plan.t_cnt[d0, o]), plan.n + 1, "oned",
        )
    cnts = np.asarray(plan.m_cnt)
    flat = int(np.argmax(cnts))
    x, y = flat // cnts.shape[1], flat % cnts.shape[1]
    if plan.b_indptr.ndim == 4:  # SummaPlan: measure against panel 0
        return (
            plan.a_indptr[x, y], plan.a_indices[x, y],
            plan.b_indptr[x, y, 0], plan.b_indices[x, y, 0],
            plan.m_ti[x, y], plan.m_tj[x, y],
            int(cnts[x, y]), plan.nb_c + 1, "summa",
        )
    return (
        plan.a_indptr[x, y], plan.a_indices[x, y],
        plan.b_indptr[x, y], plan.b_indices[x, y],
        plan.m_ti[x, y], plan.m_tj[x, y],
        int(cnts[x, y]), plan.nb + 1, "cannon",
    )


def _candidates(d_small: int, chunk: int, dmax: int):
    """Candidate (tile, chunk, d_small) shapes: the analytic pick, a
    half-size tile (less VMEM pressure), and a widened panel that pulls
    borderline-long tasks out of the fallback."""
    t0 = fused_tile_for(d_small)
    cands = [(t0, chunk, d_small)]
    if t0 > 8:
        cands.append((t0 // 2, chunk, d_small))
    d2 = min(-(-d_small * 2 // 8) * 8, dmax)
    if d2 > d_small:
        cands.append((fused_tile_for(d2), chunk, d2))
    return cands


def measured_entry(
    plan,
    *,
    backend: Optional[str] = None,
    table_dir: Optional[str] = None,
    force: bool = False,
) -> Tuple[dict, bool]:
    """Measured verdict for ``plan``'s shape bucket: ``(entry, hit)``.

    ``hit`` is True when the entry came off disk.  Requires a
    maxfrag-split plan (``n_long``/``d_small`` set by the two-sided
    autotune stage) — measuring the fused kernel under a probe-only
    split would time a kernel that miscounts.
    """
    n_long = getattr(plan, "n_long", None)
    d_small = getattr(plan, "d_small", None)
    if n_long is None or d_small is None:
        raise ValueError(
            "measured autotune needs a maxfrag-split plan: re-plan with "
            "autotune='fused' (two-sided split) first"
        )
    report = getattr(plan, "autotune", None) or {}
    backend = backend or jax.default_backend()
    a_ptr, a_idx, b_ptr, b_idx, ti, tj, cnt, sentinel, kind = (
        _busiest_arrays(plan)
    )
    key = measured_table_key(
        kind=kind,
        backend=backend,
        dtype=str(np.asarray(a_idx).dtype),
        nb=a_ptr.shape[0] - 1,
        nnz_pad=a_idx.shape[0],
        tmax=ti.shape[0],
        dmax=plan.dmax,
        d_small=d_small,
        tail_heavy=bool(report.get("tail_heavy", False)),
    )
    table_dir = table_dir or default_table_dir()
    path = os.path.join(table_dir, key + ".json")
    if not force and os.path.exists(path):
        with open(path) as fh:
            return json.load(fh), True

    a_ptr = jnp.asarray(a_ptr)
    a_idx = jnp.asarray(a_idx)
    b_ptr = jnp.asarray(b_ptr)
    b_idx = jnp.asarray(b_idx)
    ti = jnp.asarray(ti)
    tj = jnp.asarray(tj)
    chunk = int(plan.chunk)
    impl = resolve_fused_impl("auto")
    long_fallback = "search" if kind == "oned" else "global"

    arrs = (a_ptr, a_idx, b_ptr, b_idx, ti, tj)
    if kind == "oned":
        baseline_name = "search"
        base_jit = jax.jit(
            lambda ap, ai, bp, bi, t1, t2: count_pair_search(
                ap, ai, bp, bi, t1, t2, cnt,
                dpad=plan.dmax, chunk=chunk, sentinel=sentinel,
            )
        )
    else:
        baseline_name = "search2"
        aug = build_aug_keys(b_ptr, b_idx)
        base_jit = jax.jit(
            lambda ap, ai, bp, bi, t1, t2, aug=aug:
            count_pair_search_two_level(
                ap, ai, bp, bi, t1, t2, cnt, n_long,
                dpad_long=plan.dmax, dpad_short=d_small, chunk=chunk,
                aug_b=aug,
            )
        )

    t_base = _time_once(base_jit, *arrs)
    cands = []
    for tile, ch, d in _candidates(d_small, chunk, plan.dmax):
        fused_jit = jax.jit(
            lambda ap, ai, bp, bi, t1, t2, tile=tile, ch=ch, d=d:
            count_pair_fused(
                ap, ai, bp, bi, t1, t2, cnt,
                n_long=n_long, d_small=d, dpad_long=plan.dmax,
                chunk=ch, tile=tile, impl=impl,
                long_fallback=long_fallback, sentinel=sentinel,
            )
        )
        t = _time_once(fused_jit, *arrs)
        cands.append(dict(tile=tile, chunk=ch, d_small=d, seconds=t))
    best = min(cands, key=lambda c: c["seconds"])

    tshort = max(0, cnt - n_long)
    # the baseline's short bucket runs at d_small padding too (search2's
    # dpad_short) — the paths differ in traffic pattern, not padding
    predict = roofline_predict(
        tshort=max(1, tshort), d_small=d_small, dpad=d_small,
        nnz=int(b_idx.shape[0]),
    )
    entry = dict(
        version=TABLE_VERSION,
        key=key,
        kind=kind,
        backend=backend,
        impl=impl,
        baseline=baseline_name,
        t_baseline=t_base,
        t_fused=best["seconds"],
        best=dict(tile=best["tile"], chunk=best["chunk"],
                  d_small=best["d_small"]),
        candidates=cands,
        winner="fused" if best["seconds"] < t_base else baseline_name,
        roofline=predict,
        created=time.time(),
    )
    os.makedirs(table_dir, exist_ok=True)
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as fh:
        json.dump(entry, fh, indent=1)
    os.replace(tmp, path)
    return entry, False
