"""Fused device-step dispatcher: short panel + long-row fallback.

``count_pair_fused`` implements the :mod:`repro.core.engine` CSR-kernel
contract on top of the planner's two-sided maxfrag split: the first
``n_long`` tasks (either fragment > ``d_small``) run the chunked
two-level global-search path at ``dpad_long``; everything after runs
the fused equality panel at ``d_small``.  Unlike ``search2`` the long
bucket is *skipped entirely* when ``n_long == 0`` — no always-on long
chunk, no aug-key traffic on panel-only steps.

VMEM budget (DESIGN.md §5.1): the Pallas kernel stages both CSR index
arrays whole plus two ``(tile, d)`` panels and a ``(tile, d, d)``
equality intermediate.  ``fused_vmem_bytes`` accounts for all of it;
``fused_gate`` is the one decision point: when the total exceeds
``VMEM_BUDGET_BYTES`` an ``impl="auto"`` call falls back to the lax
reference **with a warning** while an explicit ``impl="pallas"`` fails
loudly — and both diagnose a *hub-driven* overflow (``dmax`` dwarfing
``d_small``, the heavy-tail signature that ``hub_split=True`` planning
removes) so the report no longer blames the panel for a handful of hub
rows.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.count import (
    build_aug_keys,
    count_pair_search,
    count_pair_search_global,
)
from .ref import fused_short_ref
from .tc_fused import fused_short_counts

__all__ = [
    "VMEM_BUDGET_BYTES",
    "count_pair_fused",
    "fused_gate",
    "fused_panel_bytes",
    "fused_tile_for",
    "fused_vmem_bytes",
    "resolve_fused_impl",
]

# leave ~4 MiB of a v5e core's ~16 MiB VMEM for double-buffering slack
VMEM_BUDGET_BYTES = 12 * (1 << 20)
# equality-panel working set cap: tile * d * d int32 elements
_PANEL_BUDGET_ELEMS = 1 << 20
_TILE_MIN, _TILE_MAX = 8, 256


def fused_tile_for(d: int, budget_elems: int = _PANEL_BUDGET_ELEMS) -> int:
    """Largest power-of-two tile keeping the (tile, d, d) panel in
    budget, clamped to [8, 256]."""
    cap = budget_elems // max(1, d * d)
    t = _TILE_MIN
    while t * 2 <= min(cap, _TILE_MAX):
        t <<= 1
    return t


def fused_panel_bytes(tile: int, d: int) -> int:
    """int32 bytes of the two gather panels + the equality intermediate."""
    return 4 * (2 * tile * d + tile * d * d)


def fused_vmem_bytes(npad_a: int, npad_b: int, tile: int, d: int) -> int:
    """Whole-kernel VMEM estimate: staged CSR index arrays + panels."""
    return 4 * (npad_a + npad_b) + fused_panel_bytes(tile, d)


# a long-bucket dmax this far past the panel depth is the heavy-tail
# signature: a handful of hub rows, not a uniformly deep plan
_HUB_DMAX_RATIO = 4


def fused_gate(
    npad_a: int,
    npad_b: int,
    tile: int,
    d: int,
    *,
    dmax: Optional[int] = None,
    d_small: Optional[int] = None,
) -> dict:
    """The fused kernel's VMEM admission decision, as data.

    Returns ``need_bytes`` / ``budget_bytes`` / ``fits`` plus
    ``hub_driven``: True when the plan's long-bucket ``dmax`` exceeds
    ``d_small`` by the heavy-tail ratio, i.e. the padded shapes (and any
    overflow) are driven by a few hub rows that hub-split planning
    (``hub_split=True``, DESIGN.md §4.8) would take off the panel's
    plate — rather than by a uniformly deep graph where only a smaller
    ``d_small``/``tile`` helps.
    """
    need = fused_vmem_bytes(npad_a, npad_b, tile, d)
    hub_driven = (
        dmax is not None
        and d_small is not None
        and int(dmax) > _HUB_DMAX_RATIO * max(1, int(d_small))
    )
    return dict(
        need_bytes=int(need),
        budget_bytes=int(VMEM_BUDGET_BYTES),
        fits=bool(need <= VMEM_BUDGET_BYTES),
        hub_driven=bool(hub_driven),
    )


def resolve_fused_impl(impl: str) -> str:
    """``auto`` → Pallas on TPU, the lax reference elsewhere (the panel
    math is identical; on CPU the reference IS the fast path)."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "lax"
    if impl not in ("pallas", "pallas-interpret", "lax"):
        raise ValueError(
            f"unknown fused impl {impl!r}: expected auto | pallas | "
            "pallas-interpret | lax"
        )
    return impl


def count_pair_fused(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    *,
    n_long: int,
    d_small: int,
    dpad_long: int,
    chunk: int,
    tile: Optional[int] = None,
    count_dtype=jnp.int32,
    impl: str = "auto",
    long_fallback: str = "global",
    probe_shorter: bool = True,
    sentinel: Optional[int] = None,
    aug_b=None,
):
    """Device-step count under the maxfrag split (DESIGN.md §5.1).

    ``long_fallback`` picks the long-bucket path: ``"global"`` (the
    two-level row-encoded key search; Cannon/SUMMA block-local ids) or
    ``"search"`` (padded binary search; the 1D ring's global ids, where
    row-encoded keys don't apply).  The short bucket always runs the
    equality panel — raw column ids, valid on every schedule.
    """
    tmax = ti.shape[0]
    n_long = int(n_long)
    n_long_c = 0
    chunk_l = int(chunk)
    if n_long > 0:
        # round the long bucket at fine granularity, NOT at the search
        # path's autotuned chunk: with e.g. chunk=4096 and n_long=522,
        # chunk-rounding would shove 4096 tasks through the fallback and
        # starve the panel of the very tasks it exists for.  The
        # fallback's internal chunk shrinks to match so its padding
        # stays aligned.
        chunk_l = min(chunk_l, max(64, -(-n_long // 64) * 64))
        n_long_c = min(-(-n_long // chunk_l) * chunk_l, tmax)

    d = int(max(1, min(d_small, a_indices.shape[0], b_indices.shape[0])))
    tile = int(tile) if tile else fused_tile_for(d)

    resolved = resolve_fused_impl(impl)
    if resolved == "pallas":
        gate = fused_gate(
            a_indices.shape[0], b_indices.shape[0], tile, d,
            dmax=dpad_long, d_small=d_small,
        )
        if not gate["fits"]:
            hint = (
                "the overflow is hub-driven (dmax "
                f"{dpad_long} >> d_small {d_small}): plan with "
                "hub_split=True to count the hub rows off-panel"
                if gate["hub_driven"]
                else "shrink the plan's d_small/tile"
            )
            if impl == "auto":
                # the old gate demoted silently and the report then
                # blamed the panel for a handful of hub rows — say what
                # happened and why; supervised runs additionally audit
                # the demotion on TCResult.supervision (DESIGN.md §8)
                reason = (
                    "fused panel kernel demoted to the lax reference: "
                    f"needs ~{gate['need_bytes'] / 2**20:.1f} MiB VMEM > "
                    f"budget {gate['budget_bytes'] / 2**20:.0f} MiB; "
                    + hint
                )
                warnings.warn(reason, RuntimeWarning, stacklevel=2)
                from ...runtime.supervisor import note_demotion

                note_demotion("fused_impl", "pallas", "lax", reason=reason)
                resolved = "lax"
            else:
                raise ValueError(
                    "fused panel kernel needs "
                    f"~{gate['need_bytes'] / 2**20:.1f} MiB VMEM "
                    f"(npad_a={a_indices.shape[0]}, "
                    f"npad_b={b_indices.shape[0]}, tile={tile}, d={d}) "
                    f"> budget {gate['budget_bytes'] / 2**20:.0f} MiB; "
                    "use impl='lax' or " + hint
                )

    acc = jnp.zeros((), dtype=count_dtype)
    if n_long_c:
        long_count = jnp.minimum(tcount, n_long_c)
        if long_fallback == "global":
            if aug_b is None:
                aug_b = build_aug_keys(b_indptr, b_indices)
            acc = acc + count_pair_search_global(
                a_indptr, a_indices, b_indptr, b_indices,
                ti[:n_long_c], tj[:n_long_c], long_count,
                dpad=dpad_long, chunk=chunk_l, count_dtype=count_dtype,
                aug_b=aug_b,
            )
        elif long_fallback == "search":
            acc = acc + count_pair_search(
                a_indptr, a_indices, b_indptr, b_indices,
                ti[:n_long_c], tj[:n_long_c], long_count,
                dpad=dpad_long, chunk=chunk_l, probe_shorter=probe_shorter,
                count_dtype=count_dtype, sentinel=sentinel,
            )
        else:
            raise ValueError(
                f"unknown long_fallback {long_fallback!r}: "
                "expected global | search"
            )

    if n_long_c >= tmax:
        return acc

    short_count = jnp.maximum(tcount - n_long_c, 0)
    ti_s = ti[n_long_c:]
    tj_s = tj[n_long_c:]
    if resolved == "lax":
        acc_short = fused_short_ref(
            a_indptr, a_indices, b_indptr, b_indices,
            ti_s, tj_s, short_count,
            d=d, tile=tile, count_dtype=count_dtype,
        )
    else:
        per_tile = fused_short_counts(
            a_indptr, a_indices, b_indptr, b_indices,
            ti_s, tj_s, short_count,
            tile=tile, d=d, interpret=(resolved == "pallas-interpret"),
        )
        acc_short = jnp.sum(per_tile, dtype=count_dtype)
    return acc + acc_short
