"""Pure-lax reference for the fused short-task panel (DESIGN.md §5.1).

Same gather-and-mask semantics as the Pallas kernel in ``tc_fused.py``:
both fragments of every short task are gathered padded to ``d`` with
sentinels that can never collide with a real column id (−1 on the A
side, ``int32.max`` on the B side).  The *intersection* step differs by
backend on purpose:

* the Pallas kernel counts equal pairs through a ``(tile, d, d)``
  outer-equality panel — a VPU-shaped broadcast compare whose ``d²``
  lanes are nearly free on TPU;
* this reference runs sorted membership instead — CSR rows hold
  strictly increasing column ids (and the high B-side sentinel keeps
  the padded row sorted), so a vmapped ``searchsorted`` of the A panel
  into the B panel costs ``O(d log d)`` per task, which is what makes
  ``impl="lax"`` the *fast* path on CPU backends rather than a ``d²``
  scalar grind.

Rows are duplicate-free, so both formulations count exactly
|row_A ∩ row_B| — raw column ids, valid on Cannon/SUMMA block-local ids
and on the 1D ring's global ids alike.  Interpreter-mode CI checks the
Pallas kernel against this independently-formulated reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SENTINEL_A = -1
# high sentinel keeps the padded B row sorted (CSR rows are strictly
# increasing), which the reference's searchsorted needs; the Pallas
# equality panel only needs it distinct from ids and from SENTINEL_A
SENTINEL_B = jnp.iinfo(jnp.int32).max

__all__ = ["fused_short_ref", "SENTINEL_A", "SENTINEL_B"]


def _gather_panel(indptr, indices, rows, d: int, sentinel: int):
    """(T, d) padded fragments with ``sentinel`` in the padding slots."""
    start = indptr[rows]
    length = indptr[rows + 1] - start
    offs = jnp.arange(d, dtype=indptr.dtype)
    idx = start[:, None] + offs[None, :]
    valid = offs[None, :] < length[:, None]
    vals = indices[jnp.clip(idx, 0, indices.shape[0] - 1)]
    return jnp.where(valid, vals.astype(jnp.int32), jnp.int32(sentinel))


def fused_short_ref(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    *,
    d: int,
    tile: int,
    count_dtype=jnp.int32,
):
    """Sum of |row_A(ti) ∩ row_B(tj)| over the first ``tcount`` tasks.

    Every task's fragments must fit in ``d`` (the maxfrag-split
    contract); longer rows are silently truncated, which is why the
    fused dispatcher refuses plans without a two-sided split.
    """
    tmax = ti.shape[0]
    ntile = -(-tmax // tile)
    pad = ntile * tile - tmax
    if pad:
        ti = jnp.concatenate([ti, jnp.zeros((pad,), ti.dtype)])
        tj = jnp.concatenate([tj, jnp.zeros((pad,), tj.dtype)])
    ti_t = ti.reshape(ntile, tile)
    tj_t = tj.reshape(ntile, tile)
    base = jnp.arange(ntile)[:, None] * tile + jnp.arange(tile)[None, :]
    tvalid = base < tcount

    def one_tile(acc, args):
        rows_i, rows_j, valid = args
        pa = _gather_panel(a_indptr, a_indices, rows_i, d, SENTINEL_A)
        pb = _gather_panel(b_indptr, b_indices, rows_j, d, SENTINEL_B)
        # sorted membership: pos is the first slot with pb >= query, so
        # a hit can only sit exactly there; A-side sentinels (-1) search
        # to slot 0 and never equal a real id or the high B pad
        pos = jax.vmap(jnp.searchsorted)(pb, pa)
        hit = jnp.take_along_axis(pb, jnp.minimum(pos, d - 1), axis=1) == pa
        per_task = jnp.sum(hit, axis=1, dtype=count_dtype)
        per_task = jnp.where(valid, per_task, 0)
        return acc + jnp.sum(per_task, dtype=count_dtype), None

    acc0 = jnp.zeros((), dtype=count_dtype)
    acc, _ = jax.lax.scan(one_tile, acc0, (ti_t, tj_t, tvalid))
    return acc
