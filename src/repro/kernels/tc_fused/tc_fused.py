"""Pallas TPU kernel: fused device-step intersection for short tasks.

One grid step processes a *tile* of ``TS`` short tasks end to end —
probe-gather, sorted-intersection, and count-accumulate fused in VMEM
(DESIGN.md §5.1) — instead of the lax path's gather → searchsorted →
segment-sum chain that round-trips every intermediate through HBM:

1. scalar-prefetched task lists + CSR row pointers sit in SMEM; the two
   CSR index arrays are staged whole into VMEM (the dispatcher's VMEM
   budget gate keeps them + the panels under ~12 MiB);
2. a ``fori_loop`` gathers each task's A and B fragments into two
   ``(TS, d)`` VMEM panels via clamped dynamic-slice windows — reads
   near the array end shift the window back and a shift-aware mask
   keeps exactly the fragment's elements, padding with distinct
   sentinels (−1 A-side / ``int32.max`` B-side, shared with ``ref.py``);
3. one ``(TS, d, d)`` outer equality reduces to the tile's triangle
   contribution (CSR fragments are duplicate-free, so equal pairs =
   intersection size; no searchsorted, no key encoding — also valid on
   the 1D ring's global column ids).

Only *short* tasks (both fragments ≤ ``d`` under the planner's maxfrag
split) come here; long rows take the chunked two-level fallback in
``ops.count_pair_fused``.  ``interpret=True`` runs the same body under
the Pallas interpreter for CPU CI parity against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .ref import SENTINEL_A, SENTINEL_B

__all__ = ["fused_short_counts"]


def _fused_panel_kernel(
    # scalar prefetch (SMEM)
    ti_ref,
    tj_ref,
    cnt_ref,
    a_ptr_ref,
    b_ptr_ref,
    # VMEM inputs
    a_idx_ref,
    b_idx_ref,
    # output + scratch
    out_ref,
    pa_ref,
    pb_ref,
    *,
    ts: int,
    d: int,
):
    g = pl.program_id(0)
    base = g * ts
    cnt = cnt_ref[0]
    npad_a = a_idx_ref.shape[0]
    npad_b = b_idx_ref.shape[0]
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)

    def gather_one(ptr_ref, idx_ref, npad, row, ok, sentinel):
        """(1, d) masked fragment window; clamped so the dynamic slice
        never reads past the array end (the shift mask re-aligns)."""
        start = ptr_ref[row]
        length = ptr_ref[row + 1] - start
        start_c = jnp.maximum(jnp.minimum(start, npad - d), 0)
        shift = start - start_c
        frag = idx_ref[pl.ds(start_c, d)].reshape(1, d).astype(jnp.int32)
        keep = ok & (offs >= shift) & (offs < shift + length)
        return jnp.where(keep, frag, jnp.int32(sentinel))

    def fill(t, carry):
        ok = (base + t) < cnt
        i = jnp.where(ok, ti_ref[base + t], 0)
        j = jnp.where(ok, tj_ref[base + t], 0)
        pa_ref[pl.ds(t, 1), :] = gather_one(
            a_ptr_ref, a_idx_ref, npad_a, i, ok, SENTINEL_A
        )
        pb_ref[pl.ds(t, 1), :] = gather_one(
            b_ptr_ref, b_idx_ref, npad_b, j, ok, SENTINEL_B
        )
        return carry

    jax.lax.fori_loop(0, ts, fill, 0)

    pa = pa_ref[:, :]
    pb = pb_ref[:, :]
    eq = (pa[:, :, None] == pb[:, None, :]).astype(jnp.int32)
    out_ref[0] = jnp.sum(eq, dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("tile", "d", "interpret")
)
def fused_short_counts(
    a_indptr,
    a_indices,
    b_indptr,
    b_indices,
    ti,
    tj,
    tcount,
    *,
    tile: int,
    d: int,
    interpret: bool = True,
):
    """Per-tile fused intersection counts for the short-task list.

    Args:
      a_indptr/b_indptr: (nb+1,) CSR row pointers (scalar-prefetched).
      a_indices/b_indices: (npad,) CSR column ids (whole-array VMEM).
      ti, tj: (tmax,) short-task row ids; first ``tcount`` are real.
      tile: tasks per grid step (``ops.fused_tile_for`` sizes this).
      d: panel width — every real fragment must fit (maxfrag contract).
      interpret: Pallas interpreter mode (CPU CI); ``False`` on TPU.

    Returns: (ntile,) int32 per-tile counts (sum for the step total).
    """
    tmax = ti.shape[0]
    ntile = max(1, -(-tmax // tile))
    pad = ntile * tile - tmax
    if pad:
        ti = jnp.concatenate([ti, jnp.zeros((pad,), ti.dtype)])
        tj = jnp.concatenate([tj, jnp.zeros((pad,), tj.dtype)])
    cnt_arr = jnp.asarray(tcount, jnp.int32).reshape(1)

    kern = functools.partial(_fused_panel_kernel, ts=tile, d=d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(ntile,),
        in_specs=[
            pl.BlockSpec(a_indices.shape, lambda g, *pref: (0,)),
            pl.BlockSpec(b_indices.shape, lambda g, *pref: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda g, *pref: (g,)),
        scratch_shapes=[
            pltpu.VMEM((tile, d), jnp.int32),
            pltpu.VMEM((tile, d), jnp.int32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ntile,), jnp.int32),
        interpret=interpret,
    )(
        ti.astype(jnp.int32),
        tj.astype(jnp.int32),
        cnt_arr,
        a_indptr.astype(jnp.int32),
        b_indptr.astype(jnp.int32),
        a_indices,
        b_indices,
    )
