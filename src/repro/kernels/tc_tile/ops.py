"""Jitted public wrapper for the tc_tile kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .tc_tile import tile_triple_counts

__all__ = ["tile_pair_count"]


def tile_pair_count(
    triples, a_tiles, b_tiles, m_tiles, *, mode="popcount", interpret=True
):
    """Total masked-intersection count for one block pair.

    Sums the per-triple partial counts produced by the kernel.  ``mode``
    selects the VPU popcount path or the MXU unpack-matmul path (identical
    results; the roofline decides which wins on hardware).
    """
    per = tile_triple_counts(
        triples, a_tiles, b_tiles, m_tiles, mode=mode, interpret=interpret
    )
    return jnp.sum(per, dtype=jnp.int32)
