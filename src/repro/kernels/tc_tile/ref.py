"""Pure-jnp oracle for the tc_tile kernel (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tc_tile import unpack_bits_tile

__all__ = ["tile_triple_counts_ref"]


def tile_triple_counts_ref(triples, a_tiles, b_tiles, m_tiles):
    """Reference: identical math to the kernel, gathered with jnp.take."""

    def one(trip):
        a = a_tiles[trip[0]]
        b = b_tiles[trip[1]]
        m = m_tiles[trip[2]]
        inter = jax.lax.population_count(a[:, None, :] & b[None, :, :])
        counts = jnp.sum(inter.astype(jnp.int32), axis=-1)
        mask = unpack_bits_tile(m, jnp.int32)
        return jnp.where(trip[3] > 0, jnp.sum(counts * mask), 0)

    return jax.vmap(one)(triples)
