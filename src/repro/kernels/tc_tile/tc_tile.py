"""Pallas TPU kernel: bit-packed tile set-intersection for triangle counting.

The paper's inner loop — "hash Adj(v_j), probe Adj(v_i), count hits with
k > j" — becomes, on TPU, a *bitmap tile* operation (DESIGN.md §2): the
adjacency fragments of 128 consecutive local rows are packed into a
128x128-bit tile (4 uint32 words per row).  For an active triple
(A-tile (ti,tk), B-tile (tj,tk), mask-tile (ti,tj)) the contribution is::

    sum_{i, j} M[i, j] * popcount(A_bits[i, :] & B_bits[j, :])

Two compute modes, selected statically:

* ``mode="popcount"`` — VPU integer path: broadcast AND + population count.
  A bitmap is a collision-free hash table, so this is the paper's "direct
  bitwise AND without probing" optimization promoted to the only mode.
* ``mode="mxu"``      — unpack both tiles to ``bf16`` 0/1 matrices and use
  the MXU: ``counts = A ⋅ Bᵀ`` (exact: partial sums ≤ 128 < 2^8, fp32
  accumulation).  Preferable when tiles are dense enough that the matmul
  beats 4-word popcounting.

The grid runs over a *scalar-prefetched* list of active tile triples
(the doubly-compressed sparsity structure computed by the planner):
``triples[g] = (a_slot, b_slot, m_slot, valid)``.  ``BlockSpec`` index maps
read the prefetched slots so only live tiles are ever staged into VMEM.

VMEM working set per grid step: 3 x 128x4 uint32 tiles (6 KiB) + one
128x128 int32/fp32 intermediate (64 KiB) — comfortably within v5e's
~16 MiB VMEM with full double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

TILE = 128
WORDS = TILE // 32

__all__ = ["tile_triple_counts", "TILE", "WORDS", "unpack_bits_tile"]


def unpack_bits_tile(words, dtype=jnp.bfloat16):
    """(T, W) uint32 -> (T, T) 0/1 matrix; column c = bit c%32 of word c//32."""
    t, w = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(t, w * 32).astype(dtype)


def _kernel_popcount(triples_ref, a_ref, b_ref, m_ref, out_ref):
    g = pl.program_id(0)
    valid = triples_ref[g, 3] > 0
    a = a_ref[0]  # (T, W) uint32 — rows i, k-bits
    b = b_ref[0]  # (T, W) uint32 — rows j, k-bits
    m = m_ref[0]  # (T, W) uint32 — mask bits (i, j)
    # per (i, j): popcount over the 4 k-words of (A_i & B_j)
    inter = jax.lax.population_count(a[:, None, :] & b[None, :, :])
    counts = jnp.sum(inter.astype(jnp.int32), axis=-1)  # (T, T)
    mask = unpack_bits_tile(m, jnp.int32)  # (T, T) over (i, j)
    # dtype pinned: under x64, sum() would promote to int64 and the swap
    # into the int32 out_ref would fail
    total = jnp.sum(counts * mask, dtype=jnp.int32)
    out_ref[0] = jnp.where(valid, total, jnp.int32(0))


def _kernel_mxu(triples_ref, a_ref, b_ref, m_ref, out_ref):
    g = pl.program_id(0)
    valid = triples_ref[g, 3] > 0
    a = unpack_bits_tile(a_ref[0], jnp.bfloat16)  # (T, T) rows i x k
    b = unpack_bits_tile(b_ref[0], jnp.bfloat16)  # (T, T) rows j x k
    counts = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (T, T) exact integers (<= 128 per entry)
    mask = unpack_bits_tile(m_ref[0], jnp.float32)
    total = jnp.sum(counts * mask).astype(jnp.int32)
    out_ref[0] = jnp.where(valid, total, jnp.int32(0))


@functools.partial(
    jax.jit, static_argnames=("mode", "interpret")
)
def tile_triple_counts(
    triples, a_tiles, b_tiles, m_tiles, *, mode="popcount", interpret=True
):
    """Per-triple masked intersection counts.

    Args:
      triples: (G, 4) int32 — (a_slot, b_slot, m_slot, valid).
      a_tiles/b_tiles/m_tiles: (N*, T, W) uint32 packed tile stores.
      mode: "popcount" (VPU) or "mxu".
      interpret: run the kernel body in interpret mode (CPU validation);
        on TPU pass ``interpret=False``.

    Returns: (G,) int32 per-triple counts (sum for the block-pair total).
    """
    g = triples.shape[0]
    kern = _kernel_popcount if mode == "popcount" else _kernel_mxu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, TILE, WORDS), lambda i, trip: (trip[i, 0], 0, 0)),
            pl.BlockSpec((1, TILE, WORDS), lambda i, trip: (trip[i, 1], 0, 0)),
            pl.BlockSpec((1, TILE, WORDS), lambda i, trip: (trip[i, 2], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, trip: (i,)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.int32),
        interpret=interpret,
    )(triples, a_tiles, b_tiles, m_tiles)
