import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the XLA flag above is consumed at first
jax initialization; the first two lines run before any jax import).

For each cell this prints/records:
  * ``compiled.memory_analysis()``  — proves the sharded program fits;
  * ``compiled.cost_analysis()``    — FLOPs/bytes for §Roofline;
  * parsed per-device collective bytes (roofline third term).

Usage:
  python -m repro.launch.dryrun --cell <arch>:<shape>:<mesh>   # one cell
  python -m repro.launch.dryrun --list                         # all cells
  (the sweep driver benchmarks/dryrun_sweep.py runs cells in subprocesses)

Mesh names: "pod" = 16x16 (256 chips), "multipod" = 2x16x16 (512 chips).
"""
import argparse
import json
import sys
import traceback


def all_cells():
    """Every (arch, shape, mesh) cell of the assignment matrix."""
    from ..configs import ASSIGNED_ARCHS, TC_GRAPHS, get_config

    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in cfg.shapes.items():
            if cfg.family == "lm" and shape.get("skip_full_attention"):
                continue  # long_500k skipped: all LM archs are full-attn
            for mesh_name in ("pod", "multipod"):
                cells.append((arch, shape_name, mesh_name))
    for g in TC_GRAPHS:
        for sched in ("cannon", "cannon25d", "oned"):
            mesh_name = "multipod" if sched == "cannon25d" else "pod"
            cells.append((g, sched, mesh_name))
    return cells


def run_cell(arch: str, shape_name: str, mesh_name: str) -> dict:
    import jax

    from ..configs import get_config
    from .mesh import make_production_mesh
    from .roofline import model_flops_lm, roofline_from_compiled

    cfg = get_config(arch)
    multi = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    label = f"{arch}:{shape_name}:{mesh_name}"

    if cfg.family == "tc":
        return _run_tc_cell(cfg, shape_name, mesh, chips, label)

    if cfg.family == "lm":
        from ..models.steps import (
            build_lm_decode_step,
            build_lm_prefill_step,
            build_lm_train_step,
            lm_input_specs,
        )

        shape = cfg.shapes[shape_name]
        kind = shape["kind"]
        dummy_params = jax.eval_shape(
            lambda k: __import__(
                "repro.models.transformer", fromlist=["lm_init"]
            ).lm_init(k, cfg),
            jax.random.key(0),
        )
        if kind == "train":
            fn, info = build_lm_train_step(cfg, mesh)
            specs = lm_input_specs(cfg, shape, step="train")
            opt_shape = info["opt_shape"]
            lowered = fn.lower(
                info["dummy"], opt_shape, specs["batch"], 0
            )
            mf = model_flops_lm(cfg, shape)
        elif kind == "prefill":
            fn, info = build_lm_prefill_step(cfg, mesh)
            specs = lm_input_specs(cfg, shape, step="prefill")
            lowered = fn.lower(info["dummy"], specs["tokens"])
            mf = model_flops_lm(cfg, shape)
        else:  # decode
            fn, info = build_lm_decode_step(cfg, mesh)
            specs = lm_input_specs(cfg, shape, step="decode")
            lowered = fn.lower(
                info["dummy"], specs["cache"], specs["token"], specs["cache_len"]
            )
            mf = model_flops_lm(cfg, shape)
        compiled = lowered.compile()
        rep = roofline_from_compiled(
            label, compiled, mesh_name=mesh_name, chips=chips, model_flops=mf
        )
        return rep.row()

    if cfg.family == "gnn":
        from ..models.gnn_steps import (
            build_gnn_train_step,
            gnn_feat_dim,
            gnn_input_specs,
        )

        shape = cfg.shapes[shape_name]
        d_feat = gnn_feat_dim(cfg, shape)
        batch = gnn_input_specs(cfg, shape)
        build, info = build_gnn_train_step(cfg, mesh, d_feat)
        fn = build(batch)
        opt_shape = jax.eval_shape(info["opt_init"], info["dummy"])
        lowered = fn.lower(info["dummy"], opt_shape, batch, 0)
        compiled = lowered.compile()
        rep = roofline_from_compiled(
            label, compiled, mesh_name=mesh_name, chips=chips,
            model_flops=_gnn_model_flops(cfg, shape),
        )
        return rep.row()

    if cfg.family == "recsys":
        from ..models.gnn_steps import (
            build_dlrm_retrieval_step,
            build_dlrm_serve_step,
            build_dlrm_train_step,
            recsys_input_specs,
        )

        shape = cfg.shapes[shape_name]
        specs = recsys_input_specs(cfg, shape)
        if shape["kind"] == "train":
            fn, info = build_dlrm_train_step(cfg, mesh)
            opt_shape = jax.eval_shape(info["opt_init"], info["dummy"])
            lowered = fn.lower(info["dummy"], opt_shape, specs, 0)
        elif shape["kind"] == "retrieval":
            fn, info = build_dlrm_retrieval_step(cfg, mesh)
            lowered = fn.lower(info["dummy"], specs["dense"], specs["cand_ids"])
        else:
            fn, info = build_dlrm_serve_step(cfg, mesh)
            lowered = fn.lower(
                info["dummy"], specs["dense"], specs["sparse_ids"]
            )
        compiled = lowered.compile()
        rep = roofline_from_compiled(
            label, compiled, mesh_name=mesh_name, chips=chips,
            model_flops=_recsys_model_flops(cfg, shape),
        )
        return rep.row()

    raise ValueError(cfg.family)


def _run_tc_cell(cfg, sched: str, mesh, chips: int, label: str) -> dict:
    """TC dry-run from the analytic plan (shape-only, no 1B-edge alloc)."""
    import jax
    import jax.numpy as jnp

    from ..core.api import get_schedule
    from ..core.plan import analytic_plan
    from .roofline import roofline_from_compiled

    build_cannon_fn = get_schedule("cannon").build_fn

    q = 16
    plan = analytic_plan(
        cfg.n_vertices,
        cfg.n_edges,
        q,
        dmax_block=cfg.dmax_block_est,
        chunk=512,
    )
    structs = plan.shape_structs()
    if sched == "cannon":
        fn = build_cannon_fn(plan, mesh, method="search")
        lowered = fn.lower(**structs)
        nshifts = q
    elif sched == "cannonopt":
        # beyond-paper variant: uint16-length blob compression (§Perf H1b)
        fn = build_cannon_fn(plan, mesh, method="search", compress_lengths=True)
        lowered = fn.lower(**structs)
        nshifts = q
    elif sched == "cannon2l":
        # §Perf H1a projection: two-level bucketed probes + gather-free
        # keys + H1b blobs.  Analytic plans carry no blocks, so the long
        # fraction is assumed 20% at d_small=64 (measured 0.9% at s16,
        # 15% at s18, q=4 — 20% is conservative for s26 at q=16).
        plan.n_long = max(1, int(0.20 * plan.tmax))  # type: ignore
        plan.d_small = 64  # type: ignore
        fn = build_cannon_fn(
            plan, mesh, method="search2", compress_lengths=True
        )
        lowered = fn.lower(**structs)
        nshifts = q
    elif sched == "cannon25d":
        # pod-stacked operands: add the leading pod dim to A/B structs
        npods = 2
        st = dict(structs)
        for k in ("a_indptr", "a_indices", "b_indptr", "b_indices"):
            s = structs[k]
            st[k] = jax.ShapeDtypeStruct((npods,) + s.shape, s.dtype)
        fn = build_cannon_fn(plan, mesh, pod_axis="pod", method="search")
        lowered = fn.lower(**st)
        nshifts = q // npods
    elif sched == "oned":
        from ..core.onedim import OneDPlan
        import numpy as np

        build_oned_fn = get_schedule("oned").build_fn

        p = chips
        nb = -(-cfg.n_vertices // p)
        nnz_pad = int(cfg.n_edges / p * 1.25)
        gmax = max(1, int(cfg.n_edges / (p * p) * 2.0))
        oplan = OneDPlan(
            n=cfg.n_vertices,
            m=cfg.n_edges,
            p=p,
            nb=nb,
            nnz_pad=nnz_pad,
            gmax=gmax,
            dmax=cfg.dmax_block_est * q,  # full rows: no /√p shrink
            chunk=512,
            indptr=np.zeros((1,), np.int32),
            indices=np.zeros((1,), np.int32),
            t_i=np.zeros((1,), np.int32),
            t_j=np.zeros((1,), np.int32),
            t_cnt=np.zeros((1,), np.int32),
        )
        from .. import compat

        flat_mesh = compat.make_mesh((p,), ("flat",))
        fn = build_oned_fn(oplan, flat_mesh)
        structs = {
            "indptr": jax.ShapeDtypeStruct((p, nb + 1), jnp.int32),
            "indices": jax.ShapeDtypeStruct((p, nnz_pad), jnp.int32),
            "t_i": jax.ShapeDtypeStruct((p, p, gmax), jnp.int32),
            "t_j": jax.ShapeDtypeStruct((p, p, gmax), jnp.int32),
            "t_cnt": jax.ShapeDtypeStruct((p, p), jnp.int32),
        }
        lowered = fn.lower(**structs)
        nshifts = p
    else:
        raise ValueError(sched)

    compiled = lowered.compile()
    # useful ops ~ paper's probe count: m * (d_avg/2) log2(d) per full pass
    import math

    d_avg = 2.0 * cfg.n_edges / cfg.n_vertices
    useful = cfg.n_edges * (d_avg / 2.0) * max(1.0, math.log2(max(2, d_avg)))
    rep = roofline_from_compiled(
        label,
        compiled,
        mesh_name="multipod" if sched == "cannon25d" else "pod",
        chips=chips,
        model_flops=useful,
    )
    row = rep.row()
    row["nshifts"] = nshifts
    row["nnz_pad_per_device"] = plan.nnz_pad
    return row


def _gnn_model_flops(cfg, shape) -> float:
    if shape["kind"] == "sampled":
        b = shape["batch_nodes"]
        f1, f2 = shape["fanout"]
        e = b * f1 + b * f1 * f2
        n = b * (1 + f1 + f1 * f2)
    elif shape["kind"] == "batched":
        n = shape["n_nodes"] * shape["batch"]
        e = shape["n_edges"] * shape["batch"]
    else:
        n, e = shape["n_nodes"], shape["n_edges"]
    d = cfg.d_hidden
    if cfg.arch == "gat":
        per_layer = 2 * n * d * d * cfg.n_heads + 6 * e * d * cfg.n_heads
    elif cfg.arch == "graphcast":
        per_layer = 2 * e * (2 * d) * d * 2 + 2 * n * (2 * d) * d * 2
    else:  # equivariant: TP/eSCN dominated
        s = (cfg.l_max + 1) ** 2
        per_layer = 6 * e * d * d * s
    return 3.0 * cfg.n_layers * per_layer  # fwd + bwd ~ 3x fwd


def _recsys_model_flops(cfg, shape) -> float:
    if shape["kind"] == "retrieval":
        return 2.0 * shape["n_candidates"] * cfg.embed_dim
    b = shape["batch"]
    mlp = 0
    dims = cfg.bot_mlp
    for i in range(len(dims) - 1):
        mlp += 2 * dims[i] * dims[i + 1]
    dims = cfg.top_mlp
    for i in range(len(dims) - 1):
        mlp += 2 * dims[i] * dims[i + 1]
    inter = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    mult = 3.0 if shape["kind"] == "train" else 1.0
    return mult * b * (mlp + inter)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:mesh")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print(":".join(c))
        return

    arch, shape_name, mesh_name = args.cell.split(":")
    try:
        row = run_cell(arch, shape_name, mesh_name)
        row["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        row = {
            "name": args.cell,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }
    line = json.dumps(row)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    sys.exit(0 if row["status"] == "ok" else 1)


if __name__ == "__main__":
    main()
