"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod);
multi-pod: 2x16x16 = 512 chips with a leading "pod" axis.  Mesh creation
goes through :mod:`repro.compat` so it works on jax 0.4.x and >= 0.5.
"""
from __future__ import annotations

from .. import compat

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh_for(shape, axes):
    return compat.make_mesh(tuple(shape), tuple(axes))
