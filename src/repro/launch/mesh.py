"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e pod);
multi-pod: 2x16x16 = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(shape)
    )


def make_mesh_for(shape, axes):
    return jax.make_mesh(
        tuple(shape),
        tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(shape),
    )
