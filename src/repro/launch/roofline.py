"""Roofline analysis from compiled artifacts (no hardware required).

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = FLOPs_per_device / peak_FLOPs            (197e12 bf16, v5e)
    memory     = bytes_per_device / HBM_bw                (819e9 B/s)
    collective = collective_bytes_per_device / link_bw    (50e9 B/s ICI)

``compiled.cost_analysis()`` reports per-device FLOPs / bytes for the SPMD
module.  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO (``compiled.as_text()``) and sum bytes moved per device for every
collective op, using ring-algorithm costs:

    all-reduce       2 * size * (n-1)/n     (reduce-scatter + all-gather)
    all-gather       size * (n-1)/n         (size = result bytes)
    reduce-scatter   size * (n-1)           (size = result = operand/n)
    all-to-all       size * (n-1)/n
    collective-permute  size * npairs/N     (pairs-aware, one hop)

where n = replica-group size parsed from the op.  The permute cost is
*pairs-aware*: only the ``npairs`` source devices of its
``source_target_pairs`` send, so the per-device average over the
``N``-device module is ``size * npairs / N`` — a full rotation
(npairs = N) costs ``size``, exactly the old flat estimate, while the
masked tree/chain rounds of DESIGN.md §4.5 cost only their
participating fraction.  ``N`` comes from the module's
``num_partitions`` header (falling back to the largest device id named
by any group or pair); when undeterminable the flat ``size`` estimate
is kept.  These are lower-bound byte counts for bidirectional-ring
collectives on the ICI torus.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = [
    "HW",
    "collective_bytes",
    "collective_phases",
    "infer_num_devices",
    "roofline_from_compiled",
    "RooflineReport",
    "model_flops_lm",
]

# TPU v5e per-chip constants (assignment-specified)
HW = dict(
    peak_flops=197e12,  # bf16
    hbm_bw=819e9,  # B/s
    link_bw=50e9,  # B/s per ICI link
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(?P<dtype>[a-z0-9]+)\[(?P<shape>[0-9,]*)\][^=]*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{(?P<first>[0-9,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(?P<pairs>\{[^=]*?\})\}")
_GROUPS_ALL_RE = re.compile(r"replica_groups=\{(?P<groups>\{[^=]*?\})\}")
_NPART_RE = re.compile(r"num_partitions=(\d+)")


def infer_num_devices(hlo_text: str) -> Optional[int]:
    """Total devices of the SPMD module: the ``num_partitions`` header
    when present, else the largest device id named by any replica group
    or permute pair (+ 1); ``None`` when neither determines it."""
    m = _NPART_RE.search(hlo_text)
    if m and int(m.group(1)) > 1:
        return int(m.group(1))
    best = 0
    for pm in _PAIRS_RE.finditer(hlo_text):
        ids = re.findall(r"\d+", pm.group("pairs"))
        if ids:
            best = max(best, max(int(x) for x in ids) + 1)
    for gm in _GROUPS_ALL_RE.finditer(hlo_text):
        ids = re.findall(r"\d+", gm.group("groups"))
        if ids:
            best = max(best, max(int(x) for x in ids) + 1)
    return best or None


def _tuple_bytes(line: str) -> Optional[float]:
    """Parse '(f32[..], u32[..]) all-reduce' style tuple results."""
    m = re.search(r"= \(([^)]*)\) (all-reduce|all-gather|all-to-all)", line)
    if not m:
        return None
    total = 0.0
    for part in m.group(1).split(", "):
        pm = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", part.strip())
        if pm:
            total += _shape_bytes(pm.group(1), pm.group(2))
    return total


def _shape_bytes(dtype: str, shape: str) -> float:
    n = 1
    if shape:
        for d in shape.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_collective(line: str, ndev: Optional[int] = None):
    """(op, moved_bytes) for a collective op line, else None.

    ``ndev`` (total devices) enables the pairs-aware permute cost
    ``size * npairs / ndev``; without it a permute costs the flat
    ``size`` (every-device-participates) estimate.
    """
    if "-done" in line:
        return None
    m = _COLL_RE.search(line)
    if m:
        op = m.group("op")
        size = _shape_bytes(m.group("dtype"), m.group("shape"))
    else:
        tb = _tuple_bytes(line)
        if tb is None:
            return None
        op = re.search(r"(all-reduce|all-gather|all-to-all)", line).group(1)
        size = tb
    gm = _GROUPS_RE.search(line)
    n = len(gm.group("first").split(",")) if gm else 2
    if op == "all-reduce":
        moved = 2 * size * (n - 1) / max(n, 1)
    elif op == "all-gather":
        moved = size * (n - 1) / max(n, 1)
    elif op == "reduce-scatter":
        moved = size * (n - 1)
    elif op == "all-to-all":
        moved = size * (n - 1) / max(n, 1)
    else:  # collective-permute
        moved = size
        if ndev:
            pm = _PAIRS_RE.search(line)
            if pm:
                npairs = len(re.findall(r"\{\d+,\d+\}", pm.group("pairs")))
                if npairs:
                    moved = size * npairs / ndev
    return op, moved


_COMP_START = re.compile(r"^(ENTRY )?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*condition=%?([\w.\-]+), body=%?([\w.\-]+)"
)
_WHILE_RE2 = re.compile(
    r"while\(.*body=%?([\w.\-]+), condition=%?([\w.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DEF_RE = re.compile(r"%([\w.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(
    r"= (?P<dtype>[a-z0-9]+)\[(?P<shape>[0-9,]*)\][^=]* dot\("
    r"%(?P<lhs>[\w.\-]+), %(?P<rhs>[\w.\-]+)\)"
    r".*lhs_contracting_dims=\{(?P<lcd>[0-9,]*)\}"
)
# Ops whose results we count as HBM traffic (~fusion roots on TPU).  The
# CPU backend leaves elementwise chains unfused; counting every op would
# model each add/exp/select as an HBM round-trip, which TPU fusion
# eliminates — so bytes are counted only at materialization boundaries.
_COUNT_BYTES = (
    " fusion(", " dot(", " gather(", " scatter(", " reduce(",
    " reduce-window(", " concatenate(", " dynamic-slice(",
    " dynamic-update-slice(", " sort(", " custom-call(", " convolution(",
    " pad(", " slice(", " select-and-scatter(", " cholesky(",
    " triangular-solve(", " rng(",
)


def hlo_cost(
    hlo_text: str, num_devices: Optional[int] = None
) -> Dict[str, float]:
    """Loop-aware FLOPs / bytes / collective-bytes from optimized HLO.

    XLA's ``cost_analysis()`` counts while-loop bodies exactly once
    (verified empirically — a length-8 scan of a matmul reports 1 matmul
    of FLOPs), so all three roofline terms here are derived from our own
    walk of the module with while trip counts propagated from ENTRY:

    * flops — 2·K·prod(result) per ``dot`` (K from the lhs symbol table);
      matmuls dominate every assigned arch's flops;
    * bytes — 2 × result bytes per materializing op (one write + ~one
      read by its consumer), a documented estimator within ~30% of true
      traffic for fusion-heavy modules;
    * collectives — ring-cost bytes per op kind (see module docstring).
    """
    ndev = num_devices or infer_num_devices(hlo_text)
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = m.group(2)
                comps[cur] = {
                    "coll": [], "whiles": [], "consts": [],
                    "flops": 0.0, "bytes": 0.0, "syms": {},
                }
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        c = comps[cur]
        dm = _DEF_RE.search(line)
        if dm:
            c["syms"][dm.group(1)] = (dm.group(2), dm.group(3))
        cm2 = re.search(r"calls=%?([\w.\-]+)", line)
        if cm2:
            c.setdefault("calls", []).append(cm2.group(1))
        wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
        if wm:
            c["whiles"].append((wm.group(1), wm.group(2)))
        for x in _CONST_RE.findall(line):
            c["consts"].append(int(x))
        lc = _line_collective(line, ndev)
        if lc:
            c["coll"].append(lc)
        dd = _DOT_RE.search(line)
        if dd:
            out_elems = 1
            if dd.group("shape"):
                for d in dd.group("shape").split(","):
                    out_elems *= int(d)
            k = 1
            lhs = c["syms"].get(dd.group("lhs"))
            if lhs and lhs[1]:
                dims = [int(x) for x in lhs[1].split(",")]
                for ci in dd.group("lcd").split(","):
                    if ci:
                        k *= dims[int(ci)]
            c["flops"] += 2.0 * k * out_elems
        if dm and any(s in line for s in _COUNT_BYTES):
            c["bytes"] += 2.0 * _shape_bytes(dm.group(2), dm.group(3))

    def trip_count(cond_name: str) -> int:
        cc = comps.get(cond_name)
        if not cc or not cc["consts"]:
            return 1
        return max(1, max(cc["consts"]))

    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
    fusion_called = set()
    for c in comps.values():
        fusion_called.update(c.get("calls", ()))
    mult[entry] = 1.0
    frontier = [entry]
    while frontier:
        name = frontier.pop()
        for a, b in comps[name]["whiles"]:
            cond, body = (a, b) if comps.get(a, {}).get("consts") else (b, a)
            t = trip_count(cond)
            if body in mult:
                mult[body] += mult[name] * t
                frontier.append(body)
        for callee in comps[name].get("calls", ()):
            if callee in mult and mult[callee] < mult[name]:
                mult[callee] = mult[name]
                frontier.append(callee)

    flops = 0.0
    byts = 0.0
    coll: Dict[str, float] = {}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0 and (c["coll"] or c["flops"]):
            m = 1.0  # reached some other way; count once
        flops += c["flops"] * m
        # fusion-internal ops don't materialize to HBM — the fusion result
        # bytes are counted at the caller's fusion line
        if name not in fusion_called:
            byts += c["bytes"] * m
        for op, moved in c["coll"]:
            coll[op] = coll.get(op, 0.0) + moved * m
    return {"flops": flops, "bytes": byts, "collectives": coll}


def collective_bytes(
    hlo_text: str, num_devices: Optional[int] = None
) -> Dict[str, float]:
    """Per-device collective bytes, **loop-aware**.

    Collectives inside ``while`` bodies (lax.scan / fori_loop) execute
    trip-count times; a static parse would undercount by that factor.  We
    split the module into computations, read each while's trip count from
    the integer constant in its condition computation, and propagate
    multipliers ENTRY -> body along the (possibly nested) while call graph.
    """
    ndev = num_devices or infer_num_devices(hlo_text)
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = m.group(2)
                comps[cur] = {"coll": [], "whiles": [], "consts": []}
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        c = comps[cur]
        wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
        if wm:
            a, b = wm.group(1), wm.group(2)
            # figure out which is the condition (it will contain ROOT compare)
            c["whiles"].append((a, b))
        cm = _CONST_RE.findall(line)
        if cm:
            c["consts"].extend(int(x) for x in cm)
        lc = _line_collective(line, ndev)
        if lc:
            c["coll"].append(lc)

    def trip_count(cond_name: str) -> int:
        cc = comps.get(cond_name)
        if not cc or not cc["consts"]:
            return 1
        return max(1, max(cc["consts"]))

    # propagate multipliers breadth-first from ENTRY
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {}
    mult[entry] = 1.0
    frontier = [entry]
    seen = set()
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for a, b in comps[name]["whiles"]:
            # one of (a, b) is the condition; the condition has no
            # collectives and holds the trip-count constant
            cond, body = (a, b) if comps.get(a, {}).get("consts") else (b, a)
            t = trip_count(cond)
            if body in mult:
                mult[body] += mult[name] * t
                frontier.append(body)

    # computations never reached via a while (fusions etc. are inlined in
    # the entry; called computations like sort comparators hold no
    # collectives) — anything unreached but holding collectives gets x1
    out: Dict[str, float] = {}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0 and c["coll"]:
            m = 1.0
        for op, moved in c["coll"]:
            out[op] = out.get(op, 0.0) + moved * m
    return out


def collective_by_source(
    hlo_text: str, top: int = 12, num_devices: Optional[int] = None
):
    """Loop-aware collective bytes bucketed by jax op_name metadata —
    the §Perf diagnosis tool: 'which line of model code moves the bytes'."""
    ndev = num_devices or infer_num_devices(hlo_text)
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = m.group(2)
                comps[cur] = {"coll": [], "whiles": [], "consts": []}
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        c = comps[cur]
        wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
        if wm:
            c["whiles"].append((wm.group(1), wm.group(2)))
        for x in _CONST_RE.findall(line):
            c["consts"].append(int(x))
        lc = _line_collective(line, ndev)
        if lc:
            src = re.search(r'op_name="([^"]+)"', line)
            c["coll"].append((lc[0], lc[1], src.group(1) if src else "?"))

    def trip_count(cond_name):
        cc = comps.get(cond_name)
        return max(1, max(cc["consts"])) if cc and cc["consts"] else 1

    mult = {name: 0.0 for name in comps}
    if entry:
        mult[entry] = 1.0
        frontier = [entry]
        while frontier:
            name = frontier.pop()
            for a, b in comps[name]["whiles"]:
                cond, body = (
                    (a, b) if comps.get(a, {}).get("consts") else (b, a)
                )
                if body in mult:
                    mult[body] += mult[name] * trip_count(cond)
                    frontier.append(body)
    buckets: Dict[str, float] = {}
    for name, c in comps.items():
        m = mult.get(name, 0.0) or (1.0 if c["coll"] else 0.0)
        for op, moved, src in c["coll"]:
            key = f"{op} @ {src[-90:]}"
            buckets[key] = buckets.get(key, 0.0) + moved * m
    return sorted(buckets.items(), key=lambda kv: -kv[1])[:top]


_PHASES = ("shift", "broadcast", "reduce")


def collective_phases(
    hlo_text: str, num_devices: Optional[int] = None
) -> Dict[str, float]:
    """Loop-aware collective bytes bucketed by engine phase.

    The engine wraps each collective in a named scope — ``tc_shift``
    (schedule rotations), ``tc_broadcast`` (SUMMA panel broadcasts),
    ``tc_reduce`` (final reduction, flat or tree) — which XLA carries
    into the op_name metadata of the lowered collectives.  Returns
    ``{"shift": B, "broadcast": B, "reduce": B, "other": B}`` (always
    all four keys); untagged collectives land in ``"other"``.
    """
    ndev = num_devices or infer_num_devices(hlo_text)
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_START.match(line)
            if m and "{" in line:
                cur = m.group(2)
                comps[cur] = {"coll": [], "whiles": [], "consts": []}
                if m.group(1):
                    entry = cur
            continue
        if cur is None:
            continue
        c = comps[cur]
        wm = _WHILE_RE.search(line) or _WHILE_RE2.search(line)
        if wm:
            c["whiles"].append((wm.group(1), wm.group(2)))
        for x in _CONST_RE.findall(line):
            c["consts"].append(int(x))
        lc = _line_collective(line, ndev)
        if lc:
            src = re.search(r'op_name="([^"]+)"', line)
            name = src.group(1) if src else ""
            phase = next(
                (p for p in _PHASES if f"tc_{p}" in name), "other"
            )
            c["coll"].append((phase, lc[1]))

    def trip_count(cond_name):
        cc = comps.get(cond_name)
        return max(1, max(cc["consts"])) if cc and cc["consts"] else 1

    mult = {name: 0.0 for name in comps}
    if entry:
        mult[entry] = 1.0
        frontier = [entry]
        while frontier:
            name = frontier.pop()
            for a, b in comps[name]["whiles"]:
                cond, body = (
                    (a, b) if comps.get(a, {}).get("consts") else (b, a)
                )
                if body in mult:
                    mult[body] += mult[name] * trip_count(cond)
                    frontier.append(body)
    out = {p: 0.0 for p in _PHASES + ("other",)}
    for name, c in comps.items():
        m = mult.get(name, 0.0) or (1.0 if c["coll"] else 0.0)
        for phase, moved in c["coll"]:
            out[phase] += moved * m
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0
    useful_fraction: float = 0.0
    memory_per_device: Optional[dict] = None

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(
    name: str,
    compiled,
    *,
    mesh_name: str,
    chips: int,
    model_flops: float = 0.0,
    loop_multiplier: float = 1.0,
) -> RooflineReport:
    """Build the 3-term report from a compiled executable.

    All three terms come from the loop-aware ``hlo_cost`` walk (XLA's own
    cost_analysis counts while bodies once — see hlo_cost docstring); the
    single-iteration XLA numbers are kept in the report for cross-checks.
    """
    from .. import compat

    ca = compat.cost_analysis(compiled)
    cost = hlo_cost(compiled.as_text())
    flops = max(cost["flops"], float(ca.get("flops", 0.0))) * loop_multiplier
    byts = max(
        cost["bytes"], float(ca.get("bytes accessed", 0.0))
    ) * loop_multiplier
    coll = cost["collectives"]
    cbytes = sum(coll.values()) * loop_multiplier
    t_c = flops / HW["peak_flops"]
    t_m = byts / HW["hbm_bw"]
    t_l = cbytes / HW["link_bw"]
    bottleneck = max(
        [("compute", t_c), ("memory", t_m), ("collective", t_l)],
        key=lambda kv: kv[1],
    )[0]
    ma = compiled.memory_analysis()
    mem = dict(
        args=int(ma.argument_size_in_bytes),
        outputs=int(ma.output_size_in_bytes),
        temps=int(ma.temp_size_in_bytes),
        aliased=int(ma.alias_size_in_bytes),
    )
    useful = (
        model_flops / (flops * chips) if flops > 0 and model_flops else 0.0
    )
    return RooflineReport(
        name=name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=cbytes,
        coll_breakdown=coll,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_l,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_fraction=useful,
        memory_per_device=mem,
    )


def model_flops_lm(cfg, shape: dict) -> float:
    """Useful model FLOPs: 6·N·D (dense) / 6·N_active·D (MoE) per step."""
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if shape["kind"] == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n * tokens
    if shape["kind"] == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape["global_batch"]
