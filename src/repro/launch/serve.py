"""Serving drivers.

LM mode — batched KV-cached greedy decode for LM archs:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --batch 4 --gen 16

Triangle-count mode — repeated batched counts over a working set of
graphs, the heavy-traffic shape the planning pipeline is built for
(content-addressed plan cache + one compiled engine call per batch;
round 0 is the cold plan+compile, later rounds are pure dispatch):

    PYTHONPATH=src python -m repro.launch.serve \
        "--tc-graphs" "rmat:10;rmat:10,8,1;karate" --grid 1 --rounds 5
"""
import argparse
import time


def _serve_tc(args):
    from ..core.generators import graphs_from_specs
    from ..pipeline import count_triangles_many, default_cache

    graphs = graphs_from_specs(args.tc_graphs)
    expected = None
    res = None
    for rnd in range(args.rounds):
        t0 = time.perf_counter()
        res = count_triangles_many(
            graphs,
            q=args.grid,
            schedule=args.schedule,
            method=args.method,
        )
        dt = time.perf_counter() - t0
        print(
            f"round {rnd}: triangles={res.triangles} in {dt*1e3:.1f}ms "
            f"({len(graphs)/dt:.1f} graphs/s, "
            f"{'warm' if res.cache_hit else 'cold'})"
        )
        if args.verify:
            # exact host oracle — O(m·d) sequential, small graphs only
            if expected is None:
                from ..core import triangle_count_oracle

                expected = [triangle_count_oracle(g) for g in graphs]
            if res.triangles != expected:
                raise SystemExit(
                    f"count mismatch: {res.triangles} != {expected}"
                )
    stats = default_cache().stats
    print(
        f"plan cache: {stats['hits']} hits / {stats['misses']} misses"
        + (
            f", batched padding overhead {res.padding_overhead:.2f}"
            if res is not None
            else ""
        )
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--tc-graphs", default=None,
                    help="';'-separated graph specs: serve repeated "
                         "batched triangle counts instead of an LM")
    ap.add_argument("--grid", type=int, default=1)
    ap.add_argument("--schedule", default="cannon")
    ap.add_argument("--method", default="search")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--verify", action="store_true",
                    help="check every round against the exact host "
                         "oracle (small graphs only)")
    args = ap.parse_args()

    if args.tc_graphs:
        return _serve_tc(args)
    if not args.arch:
        raise SystemExit("pass --arch (LM serving) or --tc-graphs")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..models.steps import build_lm_decode_step
    from ..models.transformer import init_kv_cache, lm_init

    cfg = get_config(args.arch)
    assert cfg.family == "lm"
    from .. import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm_init(jax.random.key(0), cfg)
    decode, _ = build_lm_decode_step(cfg, mesh)
    cache = init_kv_cache(cfg, args.batch, args.max_len)
    tok = jnp.ones((args.batch,), jnp.int32)
    cache_len = jnp.zeros((args.batch,), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.gen):
        tok, cache = decode(params, cache, tok, cache_len)
        cache_len = cache_len + 1
        outs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(
        f"decoded {args.batch}x{args.gen} tokens in {dt:.2f}s "
        f"({args.batch*args.gen/dt:.1f} tok/s)"
    )
    print("first sequence:", np.stack(outs, 1)[0])


if __name__ == "__main__":
    main()
