"""Serving drivers.

LM mode — batched KV-cached greedy decode for LM archs:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --batch 4 --gen 16

Triangle-count mode — repeated batched counts over a working set of
graphs, the heavy-traffic shape the planning pipeline is built for
(content-addressed plan cache + one compiled engine call per batch;
round 0 is the cold plan+compile, later rounds are pure dispatch):

    PYTHONPATH=src python -m repro.launch.serve \
        "--tc-graphs" "rmat:10;rmat:10,8,1;karate" --grid 1 --rounds 5

Triangle-count *streaming* mode — one live graph mutated by a random
edge delta per round, served through the incremental re-plan path
(DESIGN.md §4.7; round 0 is the cold plan, later rounds splice dirty
blocks and reuse the compiled engine):

    PYTHONPATH=src python -m repro.launch.serve \
        --tc-stream er:500,8,3 --grid 1 --rounds 5 --delta-edges 4
"""
import argparse
import time


def _serve_fault_plan(args):
    if not getattr(args, "inject_faults", None):
        return None
    from ..runtime import FaultPlan

    return FaultPlan.parse(args.inject_faults)


def _new_request_stats():
    return {"ok": 0, "failed": 0, "restarts": 0}


def _serve_request(args, stats, label, fn):
    """One serving request under per-request supervision: bounded
    retries with backoff and an optional cooperative deadline.

    Returns the result, or ``None`` when the request exhausted its retry
    budget — the failure is recorded and the serving loop moves on,
    until the session-wide ``--failure-budget`` trips (``SystemExit``).
    A ``--verify`` mismatch is a ``SystemExit``, never retried: a wrong
    count is a correctness bug, not a transient fault.
    """
    from ..runtime import BackoffPolicy, Supervisor

    sup = Supervisor(
        max_restarts=args.request_retries,
        attempt_deadline=args.request_deadline,
        backoff=BackoffPolicy(base=0.05, max_delay=0.5),
        retry_on=(Exception,),
    )

    def attempt(i, guard):
        guard()
        out = fn()
        guard()  # cooperative: a slow dispatch is recorded post hoc
        return out

    try:
        res = sup.run(attempt)
    except Exception as e:
        stats["failed"] += 1
        stats["restarts"] += sup.report.restarts
        print(
            f"{label} FAILED after {sup.report.restarts - 1} retries: "
            f"{type(e).__name__}: {e}"
        )
        if stats["failed"] > args.failure_budget:
            raise SystemExit(
                f"failure budget exhausted: {stats['failed']} failed "
                f"requests > budget {args.failure_budget}"
            ) from e
        return None
    stats["ok"] += 1
    stats["restarts"] += sup.report.restarts
    return res


def _print_request_stats(args, stats):
    print(
        f"supervision: {stats['ok']} ok, {stats['failed']} failed, "
        f"{stats['restarts']} restarts "
        f"(retries/request {args.request_retries}, "
        f"failure budget {args.failure_budget})"
    )


def _serve_tc(args):
    from ..pipeline import count_triangles_many, default_cache
    from ..core.generators import graphs_from_specs
    from ..runtime import faultinject

    graphs = graphs_from_specs(args.tc_graphs)
    expected = None
    res = None
    req = _new_request_stats()
    with faultinject.armed(_serve_fault_plan(args)):
        for rnd in range(args.rounds):
            t0 = time.perf_counter()
            got = _serve_request(
                args, req, f"round {rnd}",
                lambda: count_triangles_many(
                    graphs,
                    q=args.grid,
                    schedule=args.schedule,
                    method=args.method,
                ),
            )
            if got is None:
                continue
            res = got
            dt = time.perf_counter() - t0
            print(
                f"round {rnd}: triangles={res.triangles} in {dt*1e3:.1f}ms "
                f"({len(graphs)/dt:.1f} graphs/s, "
                f"{'warm' if res.cache_hit else 'cold'})"
            )
            if args.verify:
                # exact host oracle — O(m·d) sequential, small graphs only
                if expected is None:
                    from ..core import triangle_count_oracle

                    expected = [triangle_count_oracle(g) for g in graphs]
                if res.triangles != expected:
                    raise SystemExit(
                        f"count mismatch: {res.triangles} != {expected}"
                    )
    stats = default_cache().stats()
    print(
        f"plan cache: {stats['hits']} hits / {stats['misses']} misses"
        + (
            f", batched padding overhead {res.padding_overhead:.2f}"
            if res is not None
            else ""
        )
    )
    _print_request_stats(args, req)


def _serve_tc_stream(args):
    """Streaming TC serving: a live graph takes one edge delta per round.

    Round 0 plans cold; every later round draws a deterministic random
    flip delta, applies it through :func:`repro.pipeline.apply_delta`
    (splice / repack / rebase ladder) and re-counts from the derived
    artifact — the serving analogue of ``tc_run --stream``.

    Each round runs as a supervised request: a failed round (retry
    budget exhausted) does **not** advance the live graph or the derived
    artifact — completed rounds are the only portable boundary for the
    delta lineage (DESIGN.md §8), so the next round re-derives its delta
    from the last good state."""
    from ..core import count_triangles, count_triangles_delta
    from ..pipeline import EdgeDelta, default_cache
    from ..runtime import faultinject

    g = _spec_graph(args.tc_stream)
    kwargs = dict(q=args.grid, schedule=args.schedule, method=args.method)
    req = _new_request_stats()
    with faultinject.armed(_serve_fault_plan(args)):
        t0 = time.perf_counter()
        res = _serve_request(
            args, req, "round 0", lambda: count_triangles(g, **kwargs)
        )
        if res is None:
            raise SystemExit(
                "round 0 (the cold base count) failed: no artifact to "
                "stream deltas against"
            )
        print(
            f"round 0: triangles={res.triangles} in "
            f"{(time.perf_counter() - t0) * 1e3:.1f}ms (cold plan)"
        )
        _maybe_verify(args, g, res.triangles)
        art = res.artifact
        for rnd in range(1, args.rounds):
            delta = EdgeDelta.random_flips(g, args.delta_edges, seed=rnd)
            t0 = time.perf_counter()
            res = _serve_request(
                args, req, f"round {rnd}",
                lambda: count_triangles_delta(
                    g, delta, artifact=art, **kwargs
                ),
            )
            if res is None:
                continue  # failed round: g/art unchanged (last good state)
            dt = time.perf_counter() - t0
            art, rep = res.artifact, res.delta
            g = delta.apply_to(g)
            print(
                f"round {rnd}: triangles={res.triangles} in {dt*1e3:.1f}ms "
                f"({rep['level']}, {rep['dirty_blocks']} dirty blocks, "
                f"+{rep['edges_added']}/-{rep['edges_removed']} edges"
                f"{', rebased' if rep['rebased'] else ''})"
            )
            _maybe_verify(args, g, res.triangles)
    stats = default_cache().stats()
    print(f"plan cache: {stats['hits']} hits / {stats['misses']} misses")
    _print_request_stats(args, req)


def _spec_graph(spec):
    from ..core.generators import graph_from_spec

    return graph_from_spec(spec)


def _maybe_verify(args, g, got):
    if not args.verify:
        return
    from ..core import triangle_count_oracle

    exp = triangle_count_oracle(g)
    if got != exp:
        raise SystemExit(f"count mismatch: {got} != {exp}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--tc-graphs", default=None,
                    help="';'-separated graph specs: serve repeated "
                         "batched triangle counts instead of an LM")
    ap.add_argument("--tc-stream", default=None,
                    help="single graph spec: serve streaming counts — "
                         "one random edge delta per round through the "
                         "incremental re-plan path")
    ap.add_argument("--delta-edges", type=int, default=4,
                    help="streaming: edge flips per round")
    ap.add_argument("--grid", type=int, default=1)
    ap.add_argument("--schedule", default="cannon")
    ap.add_argument("--method", default="search")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--verify", action="store_true",
                    help="check every round against the exact host "
                         "oracle (small graphs only)")
    ap.add_argument("--request-retries", type=int, default=2,
                    help="TC serving: max retries per round before the "
                         "round is recorded as failed")
    ap.add_argument("--request-deadline", type=float, default=None,
                    help="TC serving: cooperative per-round deadline in "
                         "seconds (a round past it is retried, then "
                         "failed)")
    ap.add_argument("--failure-budget", type=int, default=3,
                    help="TC serving: failed rounds tolerated per "
                         "session before the server exits")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic typed fault injection across "
                         "the serving session (same grammar as tc_run; "
                         "DESIGN.md §8) — exercises the per-request "
                         "retry/failure-budget path")
    args = ap.parse_args()

    if args.tc_graphs:
        return _serve_tc(args)
    if args.tc_stream:
        return _serve_tc_stream(args)
    if not args.arch:
        raise SystemExit(
            "pass --arch (LM serving), --tc-graphs, or --tc-stream"
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..models.steps import build_lm_decode_step
    from ..models.transformer import init_kv_cache, lm_init

    cfg = get_config(args.arch)
    assert cfg.family == "lm"
    from .. import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm_init(jax.random.key(0), cfg)
    decode, _ = build_lm_decode_step(cfg, mesh)
    cache = init_kv_cache(cfg, args.batch, args.max_len)
    tok = jnp.ones((args.batch,), jnp.int32)
    cache_len = jnp.zeros((args.batch,), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.gen):
        tok, cache = decode(params, cache, tok, cache_len)
        cache_len = cache_len + 1
        outs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(
        f"decoded {args.batch}x{args.gen} tokens in {dt:.2f}s "
        f"({args.batch*args.gen/dt:.1f} tok/s)"
    )
    print("first sequence:", np.stack(outs, 1)[0])


if __name__ == "__main__":
    main()
