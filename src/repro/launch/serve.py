"""Serving driver: batched KV-cached greedy decode for LM archs.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
        --batch 4 --gen 16
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..models.steps import build_lm_decode_step
    from ..models.transformer import init_kv_cache, lm_init

    cfg = get_config(args.arch)
    assert cfg.family == "lm"
    from .. import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    params = lm_init(jax.random.key(0), cfg)
    decode, _ = build_lm_decode_step(cfg, mesh)
    cache = init_kv_cache(cfg, args.batch, args.max_len)
    tok = jnp.ones((args.batch,), jnp.int32)
    cache_len = jnp.zeros((args.batch,), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.gen):
        tok, cache = decode(params, cache, tok, cache_len)
        cache_len = cache_len + 1
        outs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(
        f"decoded {args.batch}x{args.gen} tokens in {dt:.2f}s "
        f"({args.batch*args.gen/dt:.1f} tok/s)"
    )
    print("first sequence:", np.stack(outs, 1)[0])


if __name__ == "__main__":
    main()
