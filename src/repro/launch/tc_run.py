"""End-to-end distributed triangle-counting driver (the paper's app).

    PYTHONPATH=src python -m repro.launch.tc_run --graph rmat:18 --grid 2 \
        [--schedule cannon|summa|oned] \
        [--method auto|search|search2|global|dense|tile|fused] \
        [--autotune percentile|measured] [--no-compact] [--time-split] \
        [--ckpt-dir /tmp/tc_ckpt] [--resume] [--rebalance]

Generates (or loads) the graph, plans through the cached pipeline
(degree ordering + 2D-cyclic decomposition + schedule compaction), runs
the selected schedule on a device grid, and verifies against the host
oracle for small graphs.  Reports carry the engine's sparsity
accounting (``skipped_steps``, ``live_steps``/``elided_steps``) and —
under ``--method auto`` — the autotuned kernel shapes.  With
``--ckpt-dir`` it runs shift-at-a-time with checkpoints, resumable
mid-Cannon-loop (compacted schedules iterate live steps only).
``--graphs a,b,c`` counts a whole *batch* of graphs in one compiled
engine call (``count_triangles_many``).
"""
import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:14", help="rmat:<scale>[,<ef>[,<seed>]] | er:<n>,<deg> | named:<id>")
    ap.add_argument("--graphs", default=None,
                    help="';'-separated specs: batched count via "
                         "count_triangles_many (one compiled call)")
    ap.add_argument("--grid", type=int, default=1, help="sqrt(p): grid is q x q")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--schedule", default="cannon")
    ap.add_argument("--method", default="search",
                    choices=["auto", "search", "search2", "global",
                             "dense", "tile", "fused"],
                    help="count kernel; 'auto' runs the deterministic "
                         "autotune stage and picks search2 on "
                         "heavy-tailed graphs; 'fused' is the Pallas "
                         "probe-gather+intersection mega-kernel "
                         "(two-sided maxfrag split)")
    ap.add_argument("--autotune", default="percentile",
                    choices=["percentile", "measured"],
                    help="'percentile' derives kernel shapes "
                         "analytically from the probe-length "
                         "distribution; 'measured' times fused vs "
                         "search2 candidates once per shape bucket, "
                         "persists the verdict to the measured table, "
                         "and lets --method auto resolve to 'fused' "
                         "when the table predicts it wins")
    ap.add_argument("--measured-dir", default=None,
                    help="measured-autotune table directory (default "
                         "$REPRO_TC_MEASURED_DIR or "
                         "~/.cache/repro/tc_measured)")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--opt", action="store_true",
                    help="enable §Perf H1a+H1b (bucketed probes + "
                         "uint16-length blobs)")
    ap.add_argument("--no-probe-shorter", action="store_true")
    ap.add_argument("--no-skip-mask", action="store_true",
                    help="disable sparsity-aware step skipping")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="disable the communication-overlapped Cannon body")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable the compacted kept-step schedule "
                         "(dead-shift elision + fused multi-hop "
                         "ppermutes); mirrors --no-skip-mask")
    ap.add_argument("--time-split", action="store_true",
                    help="also time a comm-only run (all-False mask, "
                         "collectives + conds intact) and a count-only "
                         "run (shifts/broadcasts elided) so the overlap "
                         "column is attributable, and report "
                         "per-collective-phase HLO bytes "
                         "(coll_{shift,broadcast,reduce,other}_bytes); "
                         "any schedule")
    ap.add_argument("--reduce-strategy", default="auto",
                    choices=["auto", "flat", "tree"],
                    help="final-reduction collective: 'flat' psums over "
                         "every mesh axis; 'tree' is the 2.5D staged "
                         "reduce (joint grid psum then log2(pods) "
                         "masked ppermute rounds); 'auto' picks tree "
                         "when --pods > 1")
    ap.add_argument("--broadcast", default=None,
                    choices=["auto", "onehot", "chain"],
                    help="summa panel-broadcast collective: 'onehot' "
                         "psums owner-masked panels; 'chain' is the "
                         "masked ppermute doubling chain (half the "
                         "bytes); 'auto' picks chain for unrolled "
                         "bodies")
    ap.add_argument("--repeat", type=int, default=1,
                    help="count this many times (plan-cache warm after the "
                         "first); tct_seconds reports the MINIMUM over the "
                         "warm runs (2..N), i.e. warm dispatch without "
                         "trace/compile and robust to host timer noise")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-shift", type=int, default=None,
                    help="inject one failure at this shift (FT demo)")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic typed fault injection (DESIGN.md "
                         "§8): ';'-separated sites "
                         "point[@STEP][=FAULT[:LOST]][*TIMES] over points "
                         "plan_stage|device_stage|step|fused|delta_splice|"
                         "ckpt_save, e.g. 'step@1' or "
                         "'step@0=devicelost:5;ckpt_save=ckptcorrupt'; "
                         "implies supervised execution — the run must "
                         "still produce the exact count")
    ap.add_argument("--supervise", action="store_true",
                    help="run under the restart supervisor (backoff + "
                         "jitter, restart budget, degradation ladder, "
                         "DeviceLost regrid) even without injected "
                         "faults; the report gains supervision_* fields")
    ap.add_argument("--restart-budget", type=int, default=5,
                    help="supervised runs: max restarts before giving up")
    ap.add_argument("--attempt-deadline", type=float, default=None,
                    help="supervised runs: cooperative per-attempt "
                         "deadline in seconds (checked at step/attempt "
                         "boundaries)")
    ap.add_argument("--rebalance", type=int, default=0,
                    help="skip-aware rebalance trials: search this many "
                         "relabeling seeds for the lowest masked critical "
                         "path (straggler mitigation, any schedule)")
    ap.add_argument("--hub-split", nargs="?", const=True, default=None,
                    type=float, metavar="C", dest="hub_split",
                    help="hub-split planning (DESIGN.md §4.8): count rows "
                         "with degree > C x the average degree (bare flag "
                         "= the default C) as replicated column-strided "
                         "fragments outside the 2D schedule; the residual "
                         "takes the normal path with a far smaller "
                         "critical path on heavy-tailed graphs")
    ap.add_argument("--stream", default=None, metavar="DELTA_FILE",
                    help="streaming mode: count --graph once, then apply "
                         "each JSONL line ({\"add\": [[u,v],...], "
                         "\"remove\": [...]}, original vertex ids) as an "
                         "edge delta via the incremental re-plan path "
                         "(DESIGN.md §4.7) and re-count; the report "
                         "carries per-round dirty-block / replanned-stage "
                         "accounting")
    ap.add_argument("--rebase-every", type=int, default=8,
                    help="streaming: cold re-plan (rebase the delta "
                         "lineage) after this many chained deltas")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from ..core import (
        available_schedules,
        count_triangles,
        get_schedule,
        graph_from_spec,
        triangle_count_oracle,
    )

    if args.schedule not in available_schedules():
        raise SystemExit(
            f"unknown --schedule {args.schedule!r}; "
            f"registered: {available_schedules()}"
        )

    if args.rebalance and (args.graphs or args.ckpt_dir):
        raise SystemExit(
            "--rebalance is not supported with --graphs or --ckpt-dir; "
            "rebalance single full-engine runs"
        )

    if args.stream and (args.graphs or args.ckpt_dir or args.opt
                        or args.time_split or args.autotune == "measured"):
        raise SystemExit(
            "--stream composes with single-graph pipeline runs only: "
            "drop --graphs/--ckpt-dir/--opt/--time-split/"
            "--autotune measured"
        )

    if args.hub_split is not None:
        if args.graphs:
            raise SystemExit(
                "--hub-split is a single-graph pipeline stage; the "
                "batched engine shares one set of statics across graphs "
                "and takes no hub side — drop --graphs"
            )
        if args.ckpt_dir:
            raise SystemExit(
                "--hub-split is not supported with --ckpt-dir: the "
                "checkpointed stepper counts one shift at a time and "
                "has no slot for the hub-split partial"
            )
        if args.opt:
            raise SystemExit(
                "--hub-split is not wired through the --opt bucketized "
                "path; use the default path (the hub side composes with "
                "--rebalance, --no-compact and every schedule there)"
            )
        if args.method in ("dense", "tile"):
            raise SystemExit(
                f"--hub-split is not supported with --method "
                f"{args.method}: the {args.method} operand store stages "
                "its own blocks and would drop the hub-split partial"
            )

    supervised = bool(args.inject_faults or args.supervise)
    if supervised and (args.graphs or args.opt or args.time_split
                       or args.stream):
        raise SystemExit(
            "--inject-faults/--supervise cover single-graph engine runs "
            "and --ckpt-dir stepper runs; drop --graphs/--opt/"
            "--time-split/--stream (the serve front-end has its own "
            "per-request supervision)"
        )
    fault_plan = None
    if args.inject_faults:
        from ..runtime import FaultPlan

        fault_plan = FaultPlan.parse(args.inject_faults)

    if args.graphs:
        return _run_batched(args)

    g = graph_from_spec(args.graph)

    if args.stream:
        return _run_stream(g, args)

    report = {"graph": args.graph, "n": g.n, "m": g.m}

    if args.ckpt_dir:
        total, timings = _run_checkpointed(g, args, fault_plan=fault_plan)
        report.update(timings)
    else:
        t0 = time.perf_counter()
        if args.opt and args.schedule == "cannon":
            # §Perf H1a+H1b: bucketed probes + compressed shift blobs
            import jax.numpy as jnp

            from .. import compat
            from ..core.api import make_grid_mesh
            from ..core.plan import bucketize_plan

            build_cannon_fn = get_schedule("cannon").build_fn
            # plan through the pipeline (with or without rebalance) so
            # the compaction stage runs and --no-compact has a lever
            from ..pipeline import plan_cannon

            art = plan_cannon(
                g, args.grid, chunk=args.chunk, keep_blocks=True,
                rebalance_trials=args.rebalance, aug_keys=True,
                compact=not args.no_compact,
            )
            if args.rebalance:
                report.update(_rebalance_fields(art.rebalance))
            bplan = bucketize_plan(art.plan)
            # host planning done: ppt = t1o - t0; engine build+trace stay
            # inside tct for repeat==1, as before
            t1o = time.perf_counter()
            mesh = make_grid_mesh(args.grid, npods=args.pods)
            fn = build_cannon_fn(
                bplan, mesh, method="search2", compress_lengths=True,
                count_dtype=compat.default_count_dtype(),
                use_step_mask=False if args.no_skip_mask else None,
                double_buffer=not args.no_double_buffer,
                compact=False if args.no_compact else None,
            )
            staged = {
                k: jnp.asarray(v) for k, v in bplan.device_arrays().items()
            }
            times = []
            for i in range(max(1, args.repeat)):
                t_run = time.perf_counter()
                total = int(fn(**staged))
                times.append(time.perf_counter() - t_run)
            report.update(
                triangles=total,
                ppt_seconds=round(t1o - t0, 4),
                tct_seconds=round(
                    min(times[1:]) if len(times) > 1 else times[0], 4
                ),
                optimized=True,
                bucket_reduction=round(bplan.bucket_stats["reduction"], 3),
            )
            report.update(_skip_fields(bplan, args.no_skip_mask))
            report.update(_compact_fields(bplan))
            if args.verify:
                from ..core import triangle_count_oracle

                exp = triangle_count_oracle(g)
                report["expected"] = exp
                report["correct"] = bool(total == exp)
                assert total == exp
            import json as _json

            print(_json.dumps(report) if args.json else
                  "\n".join(f"{k}: {v}" for k, v in report.items()))
            return
        count_kwargs = dict(
            q=args.grid,
            npods=args.pods,
            schedule=args.schedule,
            method=args.method,
            chunk=args.chunk,
            probe_shorter=not args.no_probe_shorter,
            use_step_mask=False if args.no_skip_mask else None,
            double_buffer=not args.no_double_buffer,
            compact=False if args.no_compact else None,
            rebalance_trials=args.rebalance,
            hub_split=(
                args.hub_split if args.hub_split is not None else False
            ),
            reduce_strategy=args.reduce_strategy,
            broadcast=args.broadcast,
            autotune=args.autotune,
            measured_dir=args.measured_dir,
        )
        times = []
        if supervised:
            from ..runtime import BackoffPolicy, Supervisor, supervised_count

            sup = Supervisor(
                max_restarts=args.restart_budget,
                attempt_deadline=args.attempt_deadline,
                backoff=BackoffPolicy(base=0.02, max_delay=0.5),
            )
            res = supervised_count(
                g, supervisor=sup, fault_plan=fault_plan, **count_kwargs
            )
            times.append(res.count_seconds)
            report.update(_supervision_fields(res.supervision))
        else:
            for _ in range(max(1, args.repeat)):
                res = count_triangles(g, **count_kwargs)
                times.append(res.count_seconds)
        if res.rebalance is not None:
            report.update(_rebalance_fields(res.rebalance))
        if args.hub_split is not None:
            report.update(_hub_fields(res.hub))
        report.update(
            triangles=res.triangles,
            ppt_seconds=round(res.preprocess_seconds, 4),
            tct_seconds=round(min(times[1:]) if len(times) > 1 else times[0], 4),
            total_seconds=round(time.perf_counter() - t0, 4),
            grid=res.grid,
            method=res.method,
        )
        report.update(_skip_fields(res.plan, args.no_skip_mask))
        report.update(_compact_fields(res.plan))
        report.update(_autotune_fields(res.plan))
        if res.autotune_mode is not None:
            report["autotune_mode"] = res.autotune_mode
        if res.measured_table_hit is not None:
            report["measured_table_hit"] = res.measured_table_hit
        if args.time_split:
            report.update(_time_split(g, args))
        total = res.triangles

    from ..pipeline import default_cache

    report["plan_cache"] = default_cache().stats()

    if args.verify:
        expected = triangle_count_oracle(g)
        report["expected"] = expected
        report["correct"] = bool(total == expected)
        assert total == expected, (total, expected)

    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _skip_fields(plan, no_skip_mask: bool) -> dict:
    """Per-(device, step) skip-mask accounting shared by the --opt and
    default report paths."""
    sk = getattr(plan, "step_keep", None)
    if sk is None:
        return {}
    return dict(
        schedule_steps=int(sk.size),
        skipped_steps=0 if no_skip_mask else int(sk.size - sk.sum()),
    )


def _compact_fields(plan) -> dict:
    """Schedule-compaction accounting: live schedule steps and the
    device-step scan slots the compacted engine no longer executes
    (``(n_total - n_live) * ndev``, commensurable with
    ``schedule_steps``/``skipped_steps``).  Plans made under
    ``--no-compact`` carry no ``CompactSchedule``, so such runs simply
    omit the fields."""
    cs = getattr(plan, "compact", None)
    sk = getattr(plan, "step_keep", None)
    if cs is None or sk is None:
        return {}
    ndev = sk.size // max(1, cs.n_total)
    return dict(
        live_steps=cs.n_live,
        elided_steps=cs.n_elided * ndev,
    )


def _autotune_fields(plan) -> dict:
    at = getattr(plan, "autotune", None)
    if not at:
        return {}
    return dict(
        autotuned_chunk=at["chunk"],
        autotuned_d_small=at["d_small"],
        autotuned_tail_heavy=at["tail_heavy"],
    )


def _time_split(g, args) -> dict:
    """Comm/count attribution probes (any schedule):

    * comm-only — the masked engine fed an all-False mask: every
      collective (shift rotation or panel broadcast) and cond executes,
      every count kernel is skipped;
    * count-only — the same engine with its data collectives elided
      (``elide_shifts`` / ``elide_broadcast``): every count kernel
      executes against the locally-held panels (a timing proxy —
      counts are wrong for p > 1, so the result is discarded).

    Both run the *uncompacted* body with the caller's flags, warm
    (timed call preceded by a compile call), so
    ``tct − comm_only − count_only`` exposes what the overlap buys.
    The per-phase byte columns come from
    :func:`repro.launch.roofline.collective_phases` over the compiled
    HLO of the *production* configuration: the engine tags its
    collectives with named scopes (tc_shift / tc_broadcast /
    tc_reduce), and permutes are charged pairs-aware — this is what
    makes tree-vs-flat and chain-vs-onehot A/Bs comparable in bytes,
    not just seconds (DESIGN.md §4.5).
    """
    import jax.numpy as jnp

    from ..core.api import make_grid_mesh
    from .roofline import collective_phases

    out = {}

    def timed_min(fn, arrays, warm=1, iters=3):
        for _ in range(warm):
            fn(**arrays)  # compile + warm
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn(**arrays)
            best = min(best, time.perf_counter() - t0)
        return round(best, 4)

    if args.schedule == "cannon":
        from ..core.cannon import build_cannon_fn, pod_stack_arrays
        from ..pipeline import plan_cannon

        art = plan_cannon(g, args.grid, chunk=args.chunk)
        plan = art.plan
        if plan.step_keep is None:
            return {}
        mesh = make_grid_mesh(args.grid, npods=args.pods)
        if args.pods > 1:
            staged = {
                k: jnp.asarray(v)
                for k, v in pod_stack_arrays(
                    plan.device_arrays(), args.pods, plan.q
                ).items()
            }
        else:
            staged = dict(art.staged())
        common = dict(
            pod_axis="pod" if args.pods > 1 else None,
            double_buffer=not args.no_double_buffer,
            reduce_strategy=args.reduce_strategy,
        )
        fcomm = build_cannon_fn(
            plan, mesh, use_step_mask=True, compact=False, **common
        )
        zeros = dict(staged, step_keep=jnp.zeros_like(staged["step_keep"]))
        out["tct_shift_only"] = timed_min(fcomm, zeros)
        fcount = build_cannon_fn(
            plan, mesh, use_step_mask=False, compact=False,
            elide_shifts=True, **common
        )
        no_mask = {k: v for k, v in staged.items() if k != "step_keep"}
        out["tct_count_only"] = timed_min(fcount, no_mask)
        fprod = build_cannon_fn(
            plan, mesh,
            use_step_mask=False if args.no_skip_mask else None,
            compact=False if args.no_compact else None, **common
        )
    elif args.schedule == "summa":
        from ..core.summa import build_summa_fn
        from ..pipeline import plan_summa

        art = plan_summa(
            g, args.grid, args.grid, chunk=args.chunk,
            broadcast=args.broadcast or "auto",
        )
        plan = art.plan
        if plan.step_keep is None:
            return {}
        mesh = make_grid_mesh(args.grid)
        staged = dict(art.staged())
        fcomm = build_summa_fn(
            plan, mesh, broadcast=args.broadcast, use_step_mask=True,
            compact=False,
        )
        zeros = dict(staged, step_keep=jnp.zeros_like(staged["step_keep"]))
        out["tct_broadcast_only"] = timed_min(fcomm, zeros)
        fcount = build_summa_fn(
            plan, mesh, broadcast=args.broadcast, use_step_mask=False,
            compact=False, elide_broadcast=True,
        )
        no_mask = {k: v for k, v in staged.items() if k != "step_keep"}
        out["tct_count_only"] = timed_min(fcount, no_mask)
        fprod = build_summa_fn(
            plan, mesh, broadcast=args.broadcast,
            use_step_mask=False if args.no_skip_mask else None,
            compact=False if args.no_compact else None,
        )
    elif args.schedule == "oned":
        from .. import compat
        from ..core.onedim import build_oned_fn
        from ..pipeline import plan_oned

        p = args.grid * args.grid * args.pods
        art = plan_oned(g, p, chunk=args.chunk)
        plan = art.plan
        if plan.step_keep is None:
            return {}
        mesh = compat.make_mesh((p,), ("flat",))
        staged = dict(art.staged())
        fcomm = build_oned_fn(
            plan, mesh, use_step_mask=True, compact=False,
        )
        zeros = dict(staged, step_keep=jnp.zeros_like(staged["step_keep"]))
        out["tct_shift_only"] = timed_min(fcomm, zeros)
        fcount = build_oned_fn(
            plan, mesh, use_step_mask=False, compact=False,
            elide_shifts=True,
        )
        no_mask = {k: v for k, v in staged.items() if k != "step_keep"}
        out["tct_count_only"] = timed_min(fcount, no_mask)
        fprod = build_oned_fn(
            plan, mesh,
            use_step_mask=False if args.no_skip_mask else None,
            compact=False if args.no_compact else None,
            reduce_strategy=args.reduce_strategy,
        )
    else:  # a registered schedule this probe doesn't know how to split
        return {}

    hlo = fprod.lower(**staged).compile().as_text()
    phases = collective_phases(hlo)
    out.update(
        coll_shift_bytes=round(phases["shift"]),
        coll_broadcast_bytes=round(phases["broadcast"]),
        coll_reduce_bytes=round(phases["reduce"]),
        coll_other_bytes=round(phases["other"]),
    )
    return out


def _hub_fields(hub: "dict | None") -> dict:
    """Flatten a TCResult.hub report into tc_run report fields.

    ``hub is None`` with the flag on means no row crossed the threshold
    (the stage no-opped) — reported as ``hub_rows=0`` rather than
    omitted, so scripted consumers can tell "off" from "found nothing".
    """
    if hub is None:
        return dict(hub_rows=0, hub_nnz_frac=0.0)
    out = dict(
        hub_rows=int(hub["hub_rows"]),
        hub_nnz_frac=round(float(hub["hub_nnz_frac"]), 4),
    )
    if hub.get("residual_mcp") is not None:
        out["residual_mcp"] = hub["residual_mcp"]
    return out


def _rebalance_fields(rb: dict) -> dict:
    """Flatten a pipeline rebalance report into tc_run report fields:
    masked-critical-path improvement and the skipped-step delta vs the
    seed-0 baseline."""
    import math

    impr = rb["improvement"]
    return dict(
        rebalance_trials=len(rb["trials"]),
        rebalance_best_seed=rb["best_seed"],
        rebalance_baseline_critical_path=rb["baseline_masked_critical_path"],
        rebalance_masked_critical_path=rb["best_masked_critical_path"],
        # inf (best path hit literal zero) is not valid JSON: emit null
        rebalance_improvement=round(impr, 4) if math.isfinite(impr) else None,
        rebalance_skipped_delta=(
            rb["skipped_steps"] - rb["baseline_skipped_steps"]
        ),
    )


def _supervision_fields(sup: "dict | None") -> dict:
    """Flatten a TCResult.supervision record (or a SupervisionReport
    dict) into tc_run report fields.  Attempt-by-attempt detail stays
    nested under ``supervision_attempts``; demotions/regrids are emitted
    only when non-empty so fault-free supervised runs stay compact."""
    if not sup:
        return {}
    out = dict(
        supervision_attempts=sup.get("attempts", []),
        supervision_restarts=sup.get("restarts", 0),
        supervision_backoff_seconds=sup.get("total_backoff_seconds", 0.0),
    )
    if sup.get("demotions"):
        out["supervision_demotions"] = sup["demotions"]
    if sup.get("regrids"):
        out["supervision_regrids"] = sup["regrids"]
    if sup.get("fault_log"):
        out["supervision_fault_log"] = sup["fault_log"]
    if sup.get("gave_up"):
        out["supervision_gave_up"] = True
    return out


def _run_batched(args):
    """Batched mode: count every spec in --graphs with one engine call."""
    from ..core import count_triangles_many, triangle_count_oracle
    from ..core.generators import graph_from_spec, split_specs

    if args.no_skip_mask or args.no_double_buffer:
        raise SystemExit(
            "--no-skip-mask/--no-double-buffer are not supported with "
            "--graphs (the batched engine always follows the plans' "
            "staged masks); use single-graph runs to A/B the levers"
        )
    if args.time_split:
        raise SystemExit(
            "--time-split is not supported with --graphs (one compiled "
            "call spans every plan, so there is no per-graph comm/count "
            "attribution); use single-graph runs"
        )
    if args.autotune == "measured":
        raise SystemExit(
            "--autotune measured is not supported with --graphs: the "
            "measured table is keyed per shape bucket, so a mixed batch "
            "would hit a cold table (and pay a timing run) per graph "
            "inside the one compiled call; warm the table with "
            "single-graph runs first, then batch with --autotune "
            "percentile"
        )
    if args.method == "fused":
        raise SystemExit(
            "--method fused is not supported with --graphs (the batched "
            "engine plans without the two-sided maxfrag split the fused "
            "kernel needs); use single-graph runs"
        )
    if args.broadcast == "chain" or args.reduce_strategy != "auto":
        raise SystemExit(
            "--broadcast chain/--reduce-strategy are not supported with "
            "--graphs (the batched engine keeps the uniform scan body, "
            "which needs traced round indices — chain broadcasts and "
            "staged reductions need the unrolled body); use "
            "single-graph runs to A/B the collectives"
        )
    specs = split_specs(args.graphs)
    graphs = [graph_from_spec(s) for s in specs]
    # the batched engine keeps the uniform scan body (per-graph masks
    # differ, so there is no shared live-step list to compact) and takes
    # only CSR kernels: resolve 'auto' to the flat search path
    method = "search" if args.method == "auto" else args.method
    t0 = time.perf_counter()
    for _ in range(max(1, args.repeat)):  # later rounds hit the program cache
        res = count_triangles_many(
            graphs,
            q=args.grid,
            schedule=args.schedule,
            method=method,
            chunk=args.chunk,
        )
    report = {
        "graphs": specs,
        "batch": res.batch,
        "triangles": res.triangles,
        "ppt_seconds": round(res.plan_seconds, 4),
        "tct_seconds": round(res.count_seconds, 4),
        "total_seconds": round(time.perf_counter() - t0, 4),
        "padding_overhead": round(res.padding_overhead, 4),
        "grid": res.grid,
    }
    if args.verify:
        expected = [triangle_count_oracle(g) for g in graphs]
        report["expected"] = expected
        report["correct"] = bool(res.triangles == expected)
        assert res.triangles == expected, (res.triangles, expected)
    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _run_stream(g, args):
    """Streaming mode: one base count, then one incremental re-count per
    delta line.

    Each JSONL line of ``--stream`` is an :class:`repro.pipeline.EdgeDelta`
    in **original** vertex ids (the lineage's composed relabeling is
    applied internally).  The derived artifact is threaded round to
    round, so unchanged device buffers and compiled engines carry over;
    after ``--rebase-every`` chained deltas the lineage rebases onto a
    cold re-plan.  ``--verify`` checks every round against the host
    oracle of the mutated graph.
    """
    from ..core import count_triangles, count_triangles_delta
    from ..core.graph import triangle_count_oracle
    from ..pipeline import EdgeDelta, default_cache

    kwargs = dict(
        q=args.grid,
        npods=args.pods,
        schedule=args.schedule,
        method=args.method,
        chunk=args.chunk,
        probe_shorter=not args.no_probe_shorter,
        use_step_mask=False if args.no_skip_mask else None,
        double_buffer=not args.no_double_buffer,
        compact=False if args.no_compact else None,
        reduce_strategy=args.reduce_strategy,
        broadcast=args.broadcast,
    )
    t0 = time.perf_counter()
    base = count_triangles(
        g, rebalance_trials=args.rebalance,
        hub_split=args.hub_split if args.hub_split is not None else False,
        **kwargs,
    )
    report = {
        "graph": args.graph, "n": g.n, "m": g.m, "stream": args.stream,
        "triangles_base": base.triangles,
        "base_seconds": round(time.perf_counter() - t0, 4),
        "grid": base.grid, "method": base.method,
    }
    if args.hub_split is not None:
        report.update(_hub_fields(base.hub))
    if args.verify:
        exp = triangle_count_oracle(g)
        assert base.triangles == exp, (base.triangles, exp)

    art, g_cur, rounds = base.artifact, g, []
    with open(args.stream) as fh:
        lines = [ln for ln in (s.strip() for s in fh) if ln]
    for i, line in enumerate(lines):
        spec = json.loads(line)
        delta = EdgeDelta(
            add=spec.get("add") or None, remove=spec.get("remove") or None
        )
        t1 = time.perf_counter()
        res = count_triangles_delta(
            g_cur, delta, artifact=art,
            rebase_every=args.rebase_every, **kwargs,
        )
        dt = time.perf_counter() - t1
        art, rep = res.artifact, res.delta
        g_cur = delta.apply_to(g_cur)
        entry = dict(
            round=i,
            triangles=res.triangles,
            edges_added=rep["edges_added"],
            edges_removed=rep["edges_removed"],
            level=rep["level"],
            dirty_blocks=rep["dirty_blocks"],
            replanned_stages=rep["replanned_stages"],
            rebased=rep["rebased"],
            round_seconds=round(dt, 4),
        )
        if args.verify:
            exp = triangle_count_oracle(g_cur)
            entry["correct"] = bool(res.triangles == exp)
            assert res.triangles == exp, (i, res.triangles, exp)
        rounds.append(entry)

    last = rounds[-1] if rounds else {}
    report.update(
        rounds=rounds,
        deltas_applied=len(rounds),
        triangles=last.get("triangles", base.triangles),
        dirty_blocks=last.get("dirty_blocks", 0),
        replanned_stages=last.get("replanned_stages", []),
        rebased=last.get("rebased", False),
        plan_cache=default_cache().stats(),
    )
    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _run_checkpointed(g, args, fault_plan=None):
    """Shift-at-a-time execution with mid-loop checkpoint/restart.

    The checkpointed state is the engine's *scan carry* (with the
    double-buffered Cannon body: two payload generations, built once by
    ``stepper.prime``) plus the per-device partial counts; the host loop
    owns the shift index and passes it to each step so the sparsity skip
    mask stays aligned after a resume.

    Under a compacted plan the loop iterates ``stepper.live_steps``
    only (single-generation carry, one fused hop per call).  Checkpoints
    store the *original* next-shift index plus the step-list signature:
    same-mode resumes filter the step list to ``>= saved`` (the fused
    hop left the carry exactly at the next live step), while a
    *cross-mode* restore (compacted checkpoint under ``--no-compact`` or
    vice versa) is refused loudly — the carry's position and arity
    (one generation vs two) do not transfer between step sequences, so
    a silent resume would count misaligned panels.

    Supervised runs (``--inject-faults``/``--supervise``) drive the same
    loop under :class:`repro.runtime.Supervisor`: each restart restores
    the latest intact checkpoint (the manager quarantines corrupt steps)
    and a ``DeviceLost`` re-factorizes the surviving devices via
    :func:`repro.runtime.best_grid`, re-plans through the pipeline, and
    restarts the count on the smaller grid — mid-schedule per-device
    partials are **refused** across grids (DESIGN.md §8), so the regrid
    counts from shift 0 into a fresh ``regrid_{q}x{q}`` subdirectory.
    """
    import os

    import jax
    import jax.numpy as jnp
    import numpy as np

    from .. import compat
    from ..ckpt import CheckpointManager
    from ..core.api import make_grid_mesh
    from ..core.cannon import build_cannon_stepper
    from ..pipeline import plan_cannon
    from ..runtime import faultinject

    t0 = time.perf_counter()
    cross_mode = (
        "checkpoint in {d} was written by a run with a different "
        "schedule shape ({why}) — the saved carry's position and arity "
        "do not transfer across step sequences (compacted vs "
        "--no-compact, double- vs single-buffered), and partial counts "
        "accumulated under one collective strategy must not be summed "
        "under another: resume with the original flags or start from a "
        "fresh --ckpt-dir"
    )
    coll_sig = (
        f"reduce={args.reduce_strategy},broadcast={args.broadcast or 'auto'}"
    )

    def setup(q, ckpt_dir):
        """Plan + stepper + checkpoint manager for one grid size.  Runs
        once up front and again per DeviceLost regrid."""
        art = plan_cannon(
            g, q, chunk=args.chunk, compact=not args.no_compact,
        )
        plan = art.plan
        mesh = make_grid_mesh(q)
        stepper = build_cannon_stepper(
            plan, mesh,
            use_step_mask=False if args.no_skip_mask else None,
            double_buffer=not args.no_double_buffer,
            compact=False if args.no_compact else None,
        )
        arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
        statics = {
            k: arrays[k]
            for k in ("m_ti", "m_tj", "m_cnt", "step_keep")
            if k in arrays
        }
        steps = (
            list(stepper.live_steps)
            if stepper.live_steps is not None
            else list(range(q))
        )
        mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)
        n_carry = stepper.n_carry
        # shape/dtype template for restore: carry leaves are
        # operand-shaped (two payload generations when double-buffered)
        # — no need to run the prime dispatch just to describe the
        # checkpoint structure
        ops = [arrays[k] for k in ("a_indptr", "a_indices", "b_indptr",
                                   "b_indices")]
        state_like = {f"carry{i}": ops[i % len(ops)] for i in range(n_carry)}
        state_like["acc"] = jnp.zeros((q, q), compat.default_count_dtype())
        return dict(
            q=q, ckpt_dir=ckpt_dir, stepper=stepper, arrays=arrays,
            statics=statics, steps=steps, mgr=mgr, n_carry=n_carry,
            state_like=state_like, step_sig=",".join(map(str, steps)),
            grid_sig=f"{q}x{q}",
        )

    env = setup(args.grid, args.ckpt_dir)
    t1 = time.perf_counter()

    def restore_or_prime(env):
        from ..runtime.supervisor import check_partials_portable

        try:
            _, restored, extra = env["mgr"].restore_latest(env["state_like"])
        except KeyError as e:  # carry arity mismatch: fewer/more leaves
            raise SystemExit(
                cross_mode.format(d=env["ckpt_dir"], why=f"missing {e}")
            ) from e
        if restored is None:
            carry0 = env["stepper"].prime(env["arrays"])
            st = {f"carry{i}": c for i, c in enumerate(carry0)}
            st["acc"] = env["state_like"]["acc"]
            return st, 0
        check_partials_portable(extra, env["grid_sig"])
        if extra.get("steps", env["step_sig"]) != env["step_sig"]:
            raise SystemExit(
                cross_mode.format(
                    d=env["ckpt_dir"],
                    why=f"steps [{extra['steps']}] vs [{env['step_sig']}]",
                )
            )
        if extra.get("collectives", coll_sig) != coll_sig:
            raise SystemExit(
                cross_mode.format(
                    d=env["ckpt_dir"],
                    why=(
                        f"collectives [{extra['collectives']}] vs "
                        f"[{coll_sig}]"
                    ),
                )
            )
        start = int(extra["shift"])
        print(f"resumed at shift {start}")
        return restored, start

    failed = {"done": False}

    def attempt(attempt_index, guard):
        st, start = restore_or_prime(env)
        stepper, statics = env["stepper"], env["statics"]
        n_carry, mgr, steps = env["n_carry"], env["mgr"], env["steps"]
        todo = [s for s in steps if s >= start]
        while todo:
            guard()
            s = todo.pop(0)
            if (
                args.fail_at_shift is not None
                and s == args.fail_at_shift
                and not failed["done"]
            ):
                failed["done"] = True
                print(
                    f"(injected failure at shift {s}; restarting from ckpt)"
                )
                _, restored, extra = mgr.restore_latest(env["state_like"])
                if restored is not None:
                    st = restored  # noqa: PLW2901
                    saved = int(extra["shift"])  # next shift to execute
                    todo = [t for t in steps if t >= saved]
                    s = todo.pop(0)  # noqa: PLW2901
            faultinject.fire("step", step=s)
            out = stepper(
                tuple(st[f"carry{i}"] for i in range(n_carry))
                + (st["acc"],),
                statics,
                step=s,
            )
            st = {f"carry{i}": out[i] for i in range(n_carry)}
            st["acc"] = out[n_carry]
            mgr.save(
                s + 1, st,
                extra={"shift": s + 1, "steps": env["step_sig"],
                       "collectives": coll_sig,
                       "grid": env["grid_sig"]},
            )
        return st

    if fault_plan is not None or args.supervise:
        from ..runtime import (
            BackoffPolicy,
            DeviceLost,
            Supervisor,
            best_grid,
        )
        from ..runtime.supervisor import (
            GridTransferRefused,
            check_partials_portable,
        )

        sup = Supervisor(
            max_restarts=args.restart_budget,
            attempt_deadline=args.attempt_deadline,
            backoff=BackoffPolicy(base=0.02, max_delay=0.5),
        )

        def on_fault(e, rec):
            if fault_plan is not None and fault_plan.log:
                last = fault_plan.log[-1]
                rec.point, rec.step = last.get("point"), last.get("step")
            if not isinstance(e, DeviceLost):
                return None
            remaining = len(jax.devices()) - e.lost
            # the stepper substrate is Cannon-only: square survivors
            r, _ = best_grid(remaining, require_square=True)
            if r < 1:
                raise RuntimeError(
                    f"cannot regrid: {e.lost} devices lost, "
                    f"{remaining} remaining"
                )
            # surface the refusal loudly: probe the old grid's latest
            # checkpoint against the new signature, then drop it
            try:
                _, restored, extra = env["mgr"].restore_latest(
                    env["state_like"]
                )
                if restored is not None:
                    check_partials_portable(extra, f"{r}x{r}")
            except GridTransferRefused as refuse:
                print(f"(device lost: {refuse})")
            except Exception:  # old-grid dir unreadable: nothing to move
                pass
            env["mgr"].close()
            new_dir = os.path.join(args.ckpt_dir, f"regrid_{r}x{r}")
            env.clear()
            env.update(setup(r, new_dir))
            sup.report.regrids.append(
                dict(lost=e.lost, grid=[r, r], ckpt_dir=new_dir)
            )
            return f"regrid to {r}x{r}"

        with faultinject.armed(fault_plan):
            st = sup.run(attempt, on_fault=on_fault)
        sup_dict = sup.report.to_dict()
        if fault_plan is not None:
            sup_dict["fault_log"] = list(fault_plan.log)
    else:
        st = attempt(0, lambda: None)
        sup_dict = None

    total = int(np.asarray(st["acc"]).sum())
    t2 = time.perf_counter()
    env["mgr"].close()
    out = dict(
        triangles=total,
        ppt_seconds=round(t1 - t0, 4),
        tct_seconds=round(t2 - t1, 4),
        checkpointed=True,
        live_steps=len(env["steps"]),
        schedule_shifts=env["q"],
    )
    if sup_dict is not None:
        if sup_dict.get("regrids"):
            out["final_grid"] = [env["q"], env["q"]]
        out.update(_supervision_fields(sup_dict))
    return total, out


if __name__ == "__main__":
    main()
