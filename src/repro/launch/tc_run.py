"""End-to-end distributed triangle-counting driver (the paper's app).

    PYTHONPATH=src python -m repro.launch.tc_run --graph rmat:18 --grid 2 \
        [--schedule cannon|summa|oned] [--method search|dense|tile] \
        [--ckpt-dir /tmp/tc_ckpt] [--resume] [--rebalance]

Generates (or loads) the graph, plans through the cached pipeline
(degree ordering + 2D-cyclic decomposition), runs the selected schedule
on a device grid, and verifies against the host oracle for small graphs.
With ``--ckpt-dir`` it runs shift-at-a-time with checkpoints, resumable
mid-Cannon-loop.  ``--graphs a,b,c`` counts a whole *batch* of graphs in
one compiled engine call (``count_triangles_many``).
"""
import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:14", help="rmat:<scale>[,<ef>[,<seed>]] | er:<n>,<deg> | named:<id>")
    ap.add_argument("--graphs", default=None,
                    help="';'-separated specs: batched count via "
                         "count_triangles_many (one compiled call)")
    ap.add_argument("--grid", type=int, default=1, help="sqrt(p): grid is q x q")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--schedule", default="cannon")
    ap.add_argument("--method", default="search")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--opt", action="store_true",
                    help="enable §Perf H1a+H1b (bucketed probes + "
                         "uint16-length blobs)")
    ap.add_argument("--no-probe-shorter", action="store_true")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-shift", type=int, default=None,
                    help="inject one failure at this shift (FT demo)")
    ap.add_argument("--rebalance", type=int, default=0,
                    help="planner rebalance trials (straggler mitigation)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from ..core import (
        available_schedules,
        count_triangles,
        get_schedule,
        graph_from_spec,
        preprocess,
        triangle_count_oracle,
    )

    if args.schedule not in available_schedules():
        raise SystemExit(
            f"unknown --schedule {args.schedule!r}; "
            f"registered: {available_schedules()}"
        )

    if args.graphs:
        return _run_batched(args)

    g = graph_from_spec(args.graph)

    report = {"graph": args.graph, "n": g.n, "m": g.m}

    if args.ckpt_dir:
        total, timings = _run_checkpointed(g, args)
        report.update(timings)
    else:
        t0 = time.perf_counter()
        plan = None
        if args.rebalance:
            from ..runtime.rebalance import rebalance_plan

            g2, _ = preprocess(g)
            plan, rb = rebalance_plan(g2, args.grid, trials=args.rebalance)
            report["rebalance"] = rb["improvement"]
        if args.opt and args.schedule == "cannon":
            # §Perf H1a+H1b: bucketed probes + compressed shift blobs
            import jax.numpy as jnp

            from .. import compat
            from ..core import build_plan
            from ..core.api import make_grid_mesh
            from ..core.plan import bucketize_plan

            build_cannon_fn = get_schedule("cannon").build_fn
            g2, _ = preprocess(g)
            t1o = time.perf_counter()
            bplan = bucketize_plan(
                plan or build_plan(g2, args.grid, chunk=args.chunk)
            )
            mesh = make_grid_mesh(args.grid, npods=args.pods) \
                if args.pods == 1 else make_grid_mesh(args.grid, npods=args.pods)
            fn = build_cannon_fn(
                bplan, mesh, method="search2", compress_lengths=True,
                count_dtype=compat.default_count_dtype(),
            )
            total = int(
                fn(**{k: jnp.asarray(v) for k, v in bplan.device_arrays().items()})
            )
            report.update(
                triangles=total,
                ppt_seconds=round(t1o - t0, 4),
                tct_seconds=round(time.perf_counter() - t1o, 4),
                optimized=True,
                bucket_reduction=round(bplan.bucket_stats["reduction"], 3),
            )
            if args.verify:
                from ..core import triangle_count_oracle

                exp = triangle_count_oracle(g)
                report["expected"] = exp
                report["correct"] = bool(total == exp)
                assert total == exp
            import json as _json

            print(_json.dumps(report) if args.json else
                  "\n".join(f"{k}: {v}" for k, v in report.items()))
            return
        res = count_triangles(
            g,
            q=args.grid,
            npods=args.pods,
            schedule=args.schedule,
            method=args.method,
            chunk=args.chunk,
            probe_shorter=not args.no_probe_shorter,
            plan=plan,
            reorder=plan is None,
        )
        report.update(
            triangles=res.triangles,
            ppt_seconds=round(res.preprocess_seconds, 4),
            tct_seconds=round(res.count_seconds, 4),
            total_seconds=round(time.perf_counter() - t0, 4),
            grid=res.grid,
        )
        total = res.triangles

    if args.verify:
        expected = triangle_count_oracle(g)
        report["expected"] = expected
        report["correct"] = bool(total == expected)
        assert total == expected, (total, expected)

    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _run_batched(args):
    """Batched mode: count every spec in --graphs with one engine call."""
    from ..core import count_triangles_many, triangle_count_oracle
    from ..core.generators import graph_from_spec, split_specs

    specs = split_specs(args.graphs)
    graphs = [graph_from_spec(s) for s in specs]
    t0 = time.perf_counter()
    res = count_triangles_many(
        graphs,
        q=args.grid,
        schedule=args.schedule,
        method=args.method,
        chunk=args.chunk,
    )
    report = {
        "graphs": specs,
        "batch": res.batch,
        "triangles": res.triangles,
        "ppt_seconds": round(res.plan_seconds, 4),
        "tct_seconds": round(res.count_seconds, 4),
        "total_seconds": round(time.perf_counter() - t0, 4),
        "padding_overhead": round(res.padding_overhead, 4),
        "grid": res.grid,
    }
    if args.verify:
        expected = [triangle_count_oracle(g) for g in graphs]
        report["expected"] = expected
        report["correct"] = bool(res.triangles == expected)
        assert res.triangles == expected, (res.triangles, expected)
    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _run_checkpointed(g, args):
    """Shift-at-a-time execution with mid-loop checkpoint/restart."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..ckpt import CheckpointManager
    from ..core import build_plan, preprocess
    from ..core.api import make_grid_mesh
    from ..core.cannon import build_cannon_stepper

    t0 = time.perf_counter()
    g2, _ = preprocess(g)
    q = args.grid
    plan = build_plan(g2, q, chunk=args.chunk)
    mesh = make_grid_mesh(q)
    stepper = build_cannon_stepper(plan, mesh)
    arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
    masks = {k: arrays[k] for k in ("m_ti", "m_tj", "m_cnt")}
    t1 = time.perf_counter()

    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=False)
    state_like = dict(
        a_ptr=arrays["a_indptr"],
        a_idx=arrays["a_indices"],
        b_ptr=arrays["b_indptr"],
        b_idx=arrays["b_indices"],
        acc=jnp.zeros((q, q), jnp.int64),
    )
    step0, restored, extra = mgr.restore_latest(state_like)
    if restored is not None:
        st = restored
        start = int(extra["shift"])
        print(f"resumed at shift {start}")
    else:
        st = state_like
        start = 0

    failed = {"done": False}
    for s in range(start, q):
        if (
            args.fail_at_shift is not None
            and s == args.fail_at_shift
            and not failed["done"]
        ):
            failed["done"] = True
            print(f"(injected failure at shift {s}; restarting from ckpt)")
            step0, restored, extra = mgr.restore_latest(state_like)
            if restored is not None:
                st = restored
                s = int(extra["shift"])  # noqa: PLW2901
        out = stepper(
            (st["a_ptr"], st["a_idx"], st["b_ptr"], st["b_idx"], st["acc"]),
            masks,
        )
        st = dict(
            a_ptr=out[0], a_idx=out[1], b_ptr=out[2], b_idx=out[3], acc=out[4]
        )
        mgr.save(s + 1, st, extra={"shift": s + 1})
    total = int(np.asarray(st["acc"]).sum())
    t2 = time.perf_counter()
    mgr.close()
    return total, dict(
        triangles=total,
        ppt_seconds=round(t1 - t0, 4),
        tct_seconds=round(t2 - t1, 4),
        checkpointed=True,
    )


if __name__ == "__main__":
    main()
