"""End-to-end distributed triangle-counting driver (the paper's app).

    PYTHONPATH=src python -m repro.launch.tc_run --graph rmat:18 --grid 2 \
        [--schedule cannon|summa|oned] [--method search|dense|tile] \
        [--ckpt-dir /tmp/tc_ckpt] [--resume] [--rebalance]

Generates (or loads) the graph, plans through the cached pipeline
(degree ordering + 2D-cyclic decomposition), runs the selected schedule
on a device grid, and verifies against the host oracle for small graphs.
With ``--ckpt-dir`` it runs shift-at-a-time with checkpoints, resumable
mid-Cannon-loop.  ``--graphs a,b,c`` counts a whole *batch* of graphs in
one compiled engine call (``count_triangles_many``).
"""
import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:14", help="rmat:<scale>[,<ef>[,<seed>]] | er:<n>,<deg> | named:<id>")
    ap.add_argument("--graphs", default=None,
                    help="';'-separated specs: batched count via "
                         "count_triangles_many (one compiled call)")
    ap.add_argument("--grid", type=int, default=1, help="sqrt(p): grid is q x q")
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--schedule", default="cannon")
    ap.add_argument("--method", default="search")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--opt", action="store_true",
                    help="enable §Perf H1a+H1b (bucketed probes + "
                         "uint16-length blobs)")
    ap.add_argument("--no-probe-shorter", action="store_true")
    ap.add_argument("--no-skip-mask", action="store_true",
                    help="disable sparsity-aware step skipping")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="disable the communication-overlapped Cannon body")
    ap.add_argument("--repeat", type=int, default=1,
                    help="count this many times (plan-cache warm after the "
                         "first); tct_seconds reports the LAST run, i.e. "
                         "warm dispatch without trace/compile")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at-shift", type=int, default=None,
                    help="inject one failure at this shift (FT demo)")
    ap.add_argument("--rebalance", type=int, default=0,
                    help="skip-aware rebalance trials: search this many "
                         "relabeling seeds for the lowest masked critical "
                         "path (straggler mitigation, any schedule)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from ..core import (
        available_schedules,
        count_triangles,
        get_schedule,
        graph_from_spec,
        preprocess,
        triangle_count_oracle,
    )

    if args.schedule not in available_schedules():
        raise SystemExit(
            f"unknown --schedule {args.schedule!r}; "
            f"registered: {available_schedules()}"
        )

    if args.rebalance and (args.graphs or args.ckpt_dir):
        raise SystemExit(
            "--rebalance is not supported with --graphs or --ckpt-dir; "
            "rebalance single full-engine runs"
        )

    if args.graphs:
        return _run_batched(args)

    g = graph_from_spec(args.graph)

    report = {"graph": args.graph, "n": g.n, "m": g.m}

    if args.ckpt_dir:
        total, timings = _run_checkpointed(g, args)
        report.update(timings)
    else:
        t0 = time.perf_counter()
        if args.opt and args.schedule == "cannon":
            # §Perf H1a+H1b: bucketed probes + compressed shift blobs
            import jax.numpy as jnp

            from .. import compat
            from ..core import build_plan
            from ..core.api import make_grid_mesh
            from ..core.plan import bucketize_plan

            build_cannon_fn = get_schedule("cannon").build_fn
            if args.rebalance:
                from ..pipeline import plan_cannon

                art = plan_cannon(
                    g, args.grid, chunk=args.chunk, keep_blocks=True,
                    rebalance_trials=args.rebalance,
                )
                report.update(_rebalance_fields(art.rebalance))
                base_plan = art.plan
            else:
                g2, _ = preprocess(g)
                base_plan = build_plan(g2, args.grid, chunk=args.chunk)
            bplan = bucketize_plan(base_plan)
            # host planning done: ppt = t1o - t0; engine build+trace stay
            # inside tct for repeat==1, as before
            t1o = time.perf_counter()
            mesh = make_grid_mesh(args.grid, npods=args.pods)
            fn = build_cannon_fn(
                bplan, mesh, method="search2", compress_lengths=True,
                count_dtype=compat.default_count_dtype(),
                use_step_mask=False if args.no_skip_mask else None,
                double_buffer=not args.no_double_buffer,
            )
            staged = {
                k: jnp.asarray(v) for k, v in bplan.device_arrays().items()
            }
            t_run = t1o
            for i in range(max(1, args.repeat)):
                if i:
                    t_run = time.perf_counter()
                total = int(fn(**staged))
            report.update(
                triangles=total,
                ppt_seconds=round(t1o - t0, 4),
                tct_seconds=round(time.perf_counter() - t_run, 4),
                optimized=True,
                bucket_reduction=round(bplan.bucket_stats["reduction"], 3),
            )
            report.update(_skip_fields(bplan, args.no_skip_mask))
            if args.verify:
                from ..core import triangle_count_oracle

                exp = triangle_count_oracle(g)
                report["expected"] = exp
                report["correct"] = bool(total == exp)
                assert total == exp
            import json as _json

            print(_json.dumps(report) if args.json else
                  "\n".join(f"{k}: {v}" for k, v in report.items()))
            return
        for _ in range(max(1, args.repeat)):
            res = count_triangles(
                g,
                q=args.grid,
                npods=args.pods,
                schedule=args.schedule,
                method=args.method,
                chunk=args.chunk,
                probe_shorter=not args.no_probe_shorter,
                use_step_mask=False if args.no_skip_mask else None,
                double_buffer=not args.no_double_buffer,
                rebalance_trials=args.rebalance,
            )
        if res.rebalance is not None:
            report.update(_rebalance_fields(res.rebalance))
        report.update(
            triangles=res.triangles,
            ppt_seconds=round(res.preprocess_seconds, 4),
            tct_seconds=round(res.count_seconds, 4),
            total_seconds=round(time.perf_counter() - t0, 4),
            grid=res.grid,
        )
        report.update(_skip_fields(res.plan, args.no_skip_mask))
        total = res.triangles

    if args.verify:
        expected = triangle_count_oracle(g)
        report["expected"] = expected
        report["correct"] = bool(total == expected)
        assert total == expected, (total, expected)

    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _skip_fields(plan, no_skip_mask: bool) -> dict:
    """Per-(device, step) skip-mask accounting shared by the --opt and
    default report paths."""
    sk = getattr(plan, "step_keep", None)
    if sk is None:
        return {}
    return dict(
        schedule_steps=int(sk.size),
        skipped_steps=0 if no_skip_mask else int(sk.size - sk.sum()),
    )


def _rebalance_fields(rb: dict) -> dict:
    """Flatten a pipeline rebalance report into tc_run report fields:
    masked-critical-path improvement and the skipped-step delta vs the
    seed-0 baseline."""
    import math

    impr = rb["improvement"]
    return dict(
        rebalance_trials=len(rb["trials"]),
        rebalance_best_seed=rb["best_seed"],
        rebalance_baseline_critical_path=rb["baseline_masked_critical_path"],
        rebalance_masked_critical_path=rb["best_masked_critical_path"],
        # inf (best path hit literal zero) is not valid JSON: emit null
        rebalance_improvement=round(impr, 4) if math.isfinite(impr) else None,
        rebalance_skipped_delta=(
            rb["skipped_steps"] - rb["baseline_skipped_steps"]
        ),
    )


def _run_batched(args):
    """Batched mode: count every spec in --graphs with one engine call."""
    from ..core import count_triangles_many, triangle_count_oracle
    from ..core.generators import graph_from_spec, split_specs

    if args.no_skip_mask or args.no_double_buffer:
        raise SystemExit(
            "--no-skip-mask/--no-double-buffer are not supported with "
            "--graphs (the batched engine always follows the plans' "
            "staged masks); use single-graph runs to A/B the levers"
        )
    specs = split_specs(args.graphs)
    graphs = [graph_from_spec(s) for s in specs]
    t0 = time.perf_counter()
    for _ in range(max(1, args.repeat)):  # later rounds hit the program cache
        res = count_triangles_many(
            graphs,
            q=args.grid,
            schedule=args.schedule,
            method=args.method,
            chunk=args.chunk,
        )
    report = {
        "graphs": specs,
        "batch": res.batch,
        "triangles": res.triangles,
        "ppt_seconds": round(res.plan_seconds, 4),
        "tct_seconds": round(res.count_seconds, 4),
        "total_seconds": round(time.perf_counter() - t0, 4),
        "padding_overhead": round(res.padding_overhead, 4),
        "grid": res.grid,
    }
    if args.verify:
        expected = [triangle_count_oracle(g) for g in graphs]
        report["expected"] = expected
        report["correct"] = bool(res.triangles == expected)
        assert res.triangles == expected, (res.triangles, expected)
    if args.json:
        print(json.dumps(report))
    else:
        for k, v in report.items():
            print(f"{k}: {v}")


def _run_checkpointed(g, args):
    """Shift-at-a-time execution with mid-loop checkpoint/restart.

    The checkpointed state is the engine's *scan carry* (with the
    double-buffered Cannon body: two payload generations, built once by
    ``stepper.prime``) plus the per-device partial counts; the host loop
    owns the shift index and passes it to each step so the sparsity skip
    mask stays aligned after a resume.
    """
    import jax.numpy as jnp
    import numpy as np

    from .. import compat
    from ..ckpt import CheckpointManager
    from ..core import build_plan, preprocess
    from ..core.api import make_grid_mesh
    from ..core.cannon import build_cannon_stepper

    t0 = time.perf_counter()
    g2, _ = preprocess(g)
    q = args.grid
    plan = build_plan(g2, q, chunk=args.chunk)
    mesh = make_grid_mesh(q)
    stepper = build_cannon_stepper(
        plan, mesh,
        use_step_mask=False if args.no_skip_mask else None,
        double_buffer=not args.no_double_buffer,
    )
    arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
    statics = {
        k: arrays[k]
        for k in ("m_ti", "m_tj", "m_cnt", "step_keep")
        if k in arrays
    }
    t1 = time.perf_counter()

    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=False)
    n_carry = stepper.n_carry
    # shape/dtype template for restore: carry leaves are operand-shaped
    # (two payload generations when double-buffered) — no need to run
    # the prime dispatch just to describe the checkpoint structure
    ops = [arrays[k] for k in ("a_indptr", "a_indices", "b_indptr",
                               "b_indices")]
    state_like = {f"carry{i}": ops[i % len(ops)] for i in range(n_carry)}
    state_like["acc"] = jnp.zeros((q, q), compat.default_count_dtype())
    step0, restored, extra = mgr.restore_latest(state_like)
    if restored is not None:
        st = restored
        start = int(extra["shift"])
        print(f"resumed at shift {start}")
    else:
        carry0 = stepper.prime(arrays)
        st = {f"carry{i}": c for i, c in enumerate(carry0)}
        st["acc"] = state_like["acc"]
        start = 0
    failed = {"done": False}
    for s in range(start, q):
        if (
            args.fail_at_shift is not None
            and s == args.fail_at_shift
            and not failed["done"]
        ):
            failed["done"] = True
            print(f"(injected failure at shift {s}; restarting from ckpt)")
            step0, restored, extra = mgr.restore_latest(state_like)
            if restored is not None:
                st = restored
                s = int(extra["shift"])  # noqa: PLW2901
        out = stepper(
            tuple(st[f"carry{i}"] for i in range(n_carry)) + (st["acc"],),
            statics,
            step=s,
        )
        st = {f"carry{i}": out[i] for i in range(n_carry)}
        st["acc"] = out[n_carry]
        mgr.save(s + 1, st, extra={"shift": s + 1})
    total = int(np.asarray(st["acc"]).sum())
    t2 = time.perf_counter()
    mgr.close()
    return total, dict(
        triangles=total,
        ppt_seconds=round(t1 - t0, 4),
        tct_seconds=round(t2 - t1, 4),
        checkpointed=True,
    )


if __name__ == "__main__":
    main()
