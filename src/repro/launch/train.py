"""Generic training driver: ``--arch <id>`` across all families.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \
        --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/lm_ckpt

Uses the family-appropriate step builder, the synthetic deterministic
pipeline, checkpoint rotation + restart (resumes step AND data cursor),
and prints loss curves.  On this CPU box use the ``-smoke`` configs;
the full configs are exercised by the dry-run.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..ckpt import CheckpointManager
    from ..configs import get_config
    from ..data.pipeline import RecsysPipeline, TokenPipeline

    cfg = get_config(args.arch)
    from .. import compat

    mesh = compat.make_mesh(
        (1, 1), ("data", "model")
    ) if len(jax.devices()) == 1 else None
    if mesh is None:
        from .mesh import make_mesh_for

        n = len(jax.devices())
        mesh = make_mesh_for((1, n), ("data", "model"))

    mgr = (
        CheckpointManager(args.ckpt_dir, keep=2, async_save=False)
        if args.ckpt_dir
        else None
    )

    if cfg.family == "lm":
        from ..models.steps import build_lm_train_step
        from ..models.transformer import lm_init

        params = lm_init(jax.random.key(0), cfg)
        fn, info = build_lm_train_step(cfg, mesh)
        opt = info["opt_init"](params)
        pipe = TokenPipeline(cfg.vocab, args.batch, args.seq)
        start = 0
        if mgr:
            st, restored, extra = mgr.restore_latest(
                {"params": params, "opt": opt}
            )
            if restored is not None:
                params, opt = restored["params"], restored["opt"]
                start = int(extra["next_step"])
                pipe.load_state(extra["pipe"])
                print(f"resumed at step {start}")
        for step in range(start, args.steps):
            batch = {
                k: jnp.asarray(v) for k, v in pipe.next_batch().items()
            }
            params, opt, m = fn(params, opt, batch, step)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(
                    step + 1,
                    {"params": params, "opt": opt},
                    extra={"next_step": step + 1, "pipe": pipe.state_dict()},
                )
        return

    if cfg.family == "recsys":
        from ..models.dlrm import dlrm_init
        from ..models.gnn_steps import build_dlrm_train_step

        params = dlrm_init(jax.random.key(0), cfg)
        fn, info = build_dlrm_train_step(cfg, mesh)
        opt = info["opt_init"](params)
        pipe = RecsysPipeline(cfg, args.batch)
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            params, opt, m = fn(params, opt, batch, step)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(m['loss']):.4f}")
        return

    raise SystemExit(
        f"family {cfg.family}: use examples/train_gnn.py or tc_run"
    )


if __name__ == "__main__":
    main()
