"""Attention substrate: RoPE (incl. partial/"2d"), GQA flash-style causal
attention for training/prefill, and KV-cached decode attention whose cache
may be *sequence-sharded* (GSPMD inserts the flash-decoding style
psum-combined softmax when the cache's seq dim is sharded over `model`).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "apply_rope",
    "rope_angles",
    "causal_attention",
    "decode_attention",
    "quantize_kv",
    "dequantize_kv",
]


def rope_angles(positions, dim: int, theta: float = 10000.0):
    """(..., dim/2) angles for rotary embedding."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 10000.0):
    """Rotary embedding on the first ``fraction`` of head dims.

    ``fraction=0.5`` reproduces ChatGLM's 2D/partial RoPE: only half the
    head dimension rotates, the rest passes through.
    x: (B, S, H, dh); positions: (B, S).
    """
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = rope_angles(positions, rot, theta)  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1)


def causal_attention(
    q,
    k,
    v,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softmax_scale: Optional[float] = None,
    q6_spec=None,
    nq_multiple: int = 1,
):
    """Memory-bounded causal GQA attention (flash-style online softmax).

    q: (B, S, H, dh); k, v: (B, S, KV, dh) with H = KV * G.
    The q-chunk axis is *vmapped* (parallel — shardable over the mesh via
    ``q6_spec``, giving 1/tp q-row context parallelism for any head count);
    the kv-chunk axis is an online-softmax ``lax.scan`` (sequential).
    ``nq_multiple`` forces enough q chunks that the chunk axis divides the
    sharding axis.  A Pallas flash kernel is the hardware next step; this
    jnp schedule is what XLA:TPU fuses today (EXPERIMENTS.md §Perf).
    """
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]  # may differ from dh (e.g. MLA)
    g = h // kvh
    scale = softmax_scale or (dh ** -0.5)

    qc = min(q_chunk, max(1, s // max(nq_multiple, 1)))
    kc = min(kv_chunk, s)
    nq, nk = s // qc, s // kc
    assert s % qc == 0 and s % kc == 0, (s, qc, kc)

    q = q.reshape(b, nq, qc, kvh, g, dh)
    if q6_spec is not None:
        q = jax.lax.with_sharding_constraint(q, q6_spec)
    k = k.reshape(b, nk, kc, kvh, dh)
    v = v.reshape(b, nk, kc, kvh, dv)
    pos_q = jnp.arange(s).reshape(nq, qc)
    pos_k = jnp.arange(s).reshape(nk, kc)

    def q_block(qb, pq):
        # qb: (b, qc, kvh, g, dh); pq: (qc,)
        qb = qb * scale

        def kv_step(carry, ki):
            m, l, o = carry
            kb, vb, pk = k[:, ki], v[:, ki], pos_k[ki]
            sc = jnp.einsum(
                "bqkgd,bckd->bqkgc", qb, kb, preferred_element_type=jnp.float32
            )
            mask = pq[:, None] >= pk[None, :]  # (qc, kc)
            sc = jnp.where(mask[None, :, None, None, :], sc, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None])
            p = jnp.where(mask[None, :, None, None, :], p, 0.0)
            corr = jnp.where(
                jnp.isfinite(m), jnp.exp(m - m_safe), 0.0
            )
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vb, preferred_element_type=jnp.float32
            )
            o_new = o * corr[..., None] + pv
            return (m_safe, l_new, o_new), None

        m0 = jnp.full((b, qc, kvh, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, qc, kvh, g), jnp.float32)
        o0 = jnp.zeros((b, qc, kvh, g, dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0), jnp.arange(nk))
        return (o / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)

    # vmap over the (sharded) q-chunk axis — parallel across the mesh
    out = jax.vmap(q_block, in_axes=(1, 0), out_axes=1)(q, pos_q)
    return out.reshape(b, s, h, dv)


# ----------------------------------------------------------------------
# decode path (KV cache, optionally int8-quantized / seq-sharded)
# ----------------------------------------------------------------------
def quantize_kv(x):
    """Per-(token, head) symmetric int8 quantization of a cache tensor."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.round(x / scale).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, softmax_scale=None):
    """One-token GQA attention against a (possibly seq-sharded) cache.

    q: (B, H, dh); k_cache, v_cache: (B, S, KV, dh); cache_len: (B,).
    Written so every reduction over S is a plain jnp reduction — when the
    cache is sharded over S (P(data, model, ...)), GSPMD turns the max/sum
    into psum-combined partial softmax (flash-decoding on the mesh).
    """
    b, h, dh = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = softmax_scale or (dh ** -0.5)
    qg = q.reshape(b, kvh, g, dh) * scale
    sc = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    mask = jnp.arange(s)[None, :] < cache_len[:, None]  # (B, S)
    sc = jnp.where(mask[:, None, None, :], sc, -jnp.inf)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, dh).astype(q.dtype)
