"""DLRM (arXiv:1906.00091), MLPerf Criteo-TB config.

Bottom MLP on 13 dense features; 26 embedding bags out of ONE concatenated
row-sharded table (the EmbeddingBag substrate); dot-product feature
interaction (pairwise dots of the 27 feature vectors, lower triangle);
top MLP -> CTR logit.  ``retrieval_step`` scores one query against N
candidate item embeddings as a single batched matmul + top-k (no loop).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import RecsysConfig
from ..sparse.embedding_bag import embedding_bag, flatten_ids, table_offsets
from . import nn

__all__ = ["dlrm_init", "dlrm_forward", "dlrm_loss", "dlrm_retrieval"]


def dlrm_init(key, cfg: RecsysConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    # pad the concatenated table to a 512 multiple so row-sharding divides
    # evenly on both production meshes (padding rows are never addressed)
    total_rows = ((cfg.total_rows + 511) // 512) * 512
    params = {
        "emb": {
            "table": jax.random.normal(
                k1, (total_rows, cfg.embed_dim), dtype
            )
            * 0.01
        },
        "bot": nn.mlp_init(k2, cfg.bot_mlp, dtype=dtype),
        "top": nn.mlp_init(k3, cfg.top_mlp, dtype=dtype),
    }
    return params


def _interact_dot(dense_v, sparse_v):
    """dense_v (B, d); sparse_v (B, F, d) -> (B, F+1 choose 2 + d)."""
    b, f, d = sparse_v.shape
    all_v = jnp.concatenate([dense_v[:, None, :], sparse_v], axis=1)  # (B, F+1, d)
    z = jnp.einsum("bfd,bgd->bfg", all_v, all_v)
    iu = jnp.triu_indices(f + 1, k=1)
    pairs = z[:, iu[0], iu[1]]  # (B, (F+1)F/2)
    return jnp.concatenate([dense_v, pairs], axis=1)


def dlrm_forward(params, cfg: RecsysConfig, dense, sparse_ids):
    """dense (B, 13); sparse_ids (B, F, H) local per-table ids -> (B,) logit."""
    offs = table_offsets(cfg.table_sizes)
    flat = flatten_ids(sparse_ids, offs)
    emb = embedding_bag(params["emb"]["table"], flat)  # (B, F, d)
    dv = nn.mlp(params["bot"], dense, final_act=True)  # (B, d)
    feats = _interact_dot(dv, emb)
    # pad/crop interaction features to the top MLP's input width
    want = params["top"]["l0"]["w"].shape[0]
    have = feats.shape[1]
    if have < want:
        feats = jnp.pad(feats, ((0, 0), (0, want - have)))
    elif have > want:
        feats = feats[:, :want]
    return nn.mlp(params["top"], feats)[:, 0]


def dlrm_loss(params, cfg: RecsysConfig, dense, sparse_ids, labels):
    logits = dlrm_forward(params, cfg, dense, sparse_ids)
    # BCE with logits
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return loss, {"loss": loss}


def dlrm_retrieval(params, cfg: RecsysConfig, dense, cand_ids, k: int = 100):
    """Score 1 query against N candidates: batched dot, then top-k.

    dense (1, 13) query features; cand_ids (N,) candidate rows of table 0.
    """
    q = nn.mlp(params["bot"], dense, final_act=True)  # (1, d)
    cand = jnp.take(params["emb"]["table"], cand_ids, axis=0)  # (N, d)
    scores = (cand @ q[0]).astype(jnp.float32)  # (N,)
    return jax.lax.top_k(scores, min(k, scores.shape[0]))
