"""GNN model family: GAT, GraphCast-style mesh GNN, NequIP, Equiformer-v2."""
