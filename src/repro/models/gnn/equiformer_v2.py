"""Equiformer-v2 (arXiv:2306.12059): equivariant graph attention with
eSCN SO(2) convolutions.

The eSCN trick (arXiv:2302.03655, adopted by Equiformer-v2): rotate each
edge's irrep features into a frame where the edge points along +z; in that
frame an SO(3)-equivariant convolution becomes *block-diagonal in m* and
truncating to m <= m_max reduces the tensor-product cost from O(L^6) to
O(L^3).  Our runtime rotation ``D(R_edge)`` comes from
:class:`..irreps.RotationBasis` (analytic Z-rotations + constant J
matrices; verified to 1e-7 against a least-squares Wigner oracle).

Per block: eSCN message (SO(2) linear over m <= m_max, radially modulated)
-> graph attention (scalar-channel logits, segment softmax) -> aggregation
-> equivariant LayerNorm + per-l linear + gated nonlinearity + scalar FFN.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ...sparse.segment import segment_softmax, segment_sum
from .. import nn
from .irreps import RotationBasis, sph_dim, sph_harm, _z_pairing
from .nequip import bessel_rbf

__all__ = ["equiformer_init", "equiformer_energy"]

N_SPECIES = 16


def _sl(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


def equiformer_init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    c, lm = cfg.d_hidden, cfg.l_max
    keys = jax.random.split(key, 3 + cfg.n_layers)
    params = {
        "embed": nn.embed_init(keys[0], N_SPECIES, c, dtype),
        "readout": nn.mlp_init(keys[1], (c, c, 1), dtype=dtype),
    }
    n_m0 = lm + 1  # one m=0 component per l
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 8)
        layer: Dict = {
            "radial": nn.mlp_init(ks[0], (cfg.n_rbf, 32, c), dtype=dtype),
            "w_m0": nn.dense_init(ks[1], c * n_m0, c * n_m0, dtype=dtype),
            "attn": nn.dense_init(ks[2], c, cfg.n_heads, dtype=dtype),
            "ffn": nn.mlp_init(ks[3], (c, 2 * c, c), dtype=dtype),
            "gate": nn.dense_init(ks[4], c, lm * c, dtype=dtype),
            "post": {
                f"l{l}": nn.dense_init(ks[5], c, c, dtype=dtype)
                for l in range(lm + 1)
            },
        }
        for m in range(1, cfg.m_max + 1):
            n_lm = lm + 1 - m  # number of l's carrying this |m|
            if n_lm <= 0:
                continue
            layer[f"w_m{m}_r"] = nn.dense_init(
                ks[6], c * n_lm, c * n_lm, dtype=dtype
            )
            layer[f"w_m{m}_i"] = nn.dense_init(
                ks[7], c * n_lm, c * n_lm, dtype=dtype
            )
        params[f"layer{i}"] = layer
    return params


def _m_indexing(lm: int):
    """Per-l paired-basis metadata: (Q, pairs) from the Schur pairing."""
    qs, pairs = [], []
    for l in range(lm + 1):
        q, p = _z_pairing(l)
        qs.append(np.asarray(q, np.float32))
        pairs.append(p)
    return qs, pairs


def _escn_message(layer_p, cfg, x_rot):
    """SO(2) linear conv on edge-frame features, m truncated to m_max.

    x_rot: (E, C, S) edge-aligned features.  Components are mapped into the
    per-l paired basis (Qᵀ f) where the z-rotation acts as per-|m| 2x2
    blocks; m=0 lines get a real linear over (C * n_l0), |m|>=1 pairs get
    a complex-structured linear; m > m_max is dropped (the eSCN cut).
    """
    c, lm = cfg.d_hidden, cfg.l_max
    qs, pairs = _m_indexing(lm)
    e = x_rot.shape[0]

    # project into paired basis per l
    u = []
    for l in range(lm + 1):
        q = jnp.asarray(qs[l])
        u.append(jnp.einsum("ecs,st->ect", x_rot[..., _sl(l)], q))
    # collect m=0 components (per l, the lines not in any pair).  Pairs
    # with negative Schur m rotate with the OPPOSITE orientation under the
    # residual z-rotation gauge; flipping the second component's sign maps
    # them to +|m| so one complex-linear map per |m| stays equivariant.
    m0_feats, m0_loc = [], []
    m_feats = {m: [] for m in range(1, cfg.m_max + 1)}
    m_loc = {m: [] for m in range(1, cfg.m_max + 1)}
    for l in range(lm + 1):
        d = 2 * l + 1
        in_pair = set()
        for (i, j, m) in pairs[l]:
            in_pair.add(i)
            in_pair.add(j)
            mm = int(round(abs(m)))
            sgn = 1.0 if m > 0 else -1.0
            if mm <= cfg.m_max:
                m_feats[mm].append(
                    jnp.stack([u[l][..., i], sgn * u[l][..., j]], axis=-1)
                )  # (E, C, 2)
                m_loc[mm].append((l, i, j, sgn))
        for i in range(d):
            if i not in in_pair:
                m0_feats.append(u[l][..., i])  # (E, C)
                m0_loc.append((l, i))

    out_u = [jnp.zeros_like(ul) for ul in u]
    # m = 0: real linear across (l, channel)
    f0 = jnp.concatenate(m0_feats, axis=-1).reshape(e, -1)  # (E, C*n_l0)
    y0 = nn.dense(layer_p["w_m0"], f0).reshape(e, c, len(m0_loc))
    for idx, (l, i) in enumerate(m0_loc):
        out_u[l] = out_u[l].at[..., i].set(y0[..., idx])
    # |m| >= 1: complex-structured linear shared over the 2 components
    for m in range(1, cfg.m_max + 1):
        if not m_feats[m]:
            continue
        fm = jnp.stack(m_feats[m], axis=2)  # (E, C, n_lm, 2)
        n_lm = fm.shape[2]
        re = fm[..., 0].reshape(e, -1)
        im = fm[..., 1].reshape(e, -1)
        wr, wi = layer_p[f"w_m{m}_r"], layer_p[f"w_m{m}_i"]
        yr = nn.dense(wr, re) - nn.dense(wi, im)
        yi = nn.dense(wi, re) + nn.dense(wr, im)
        yr = yr.reshape(e, c, n_lm)
        yi = yi.reshape(e, c, n_lm)
        for idx, (l, i, j, sgn) in enumerate(m_loc[m]):
            out_u[l] = out_u[l].at[..., i].set(yr[..., idx])
            out_u[l] = out_u[l].at[..., j].set(sgn * yi[..., idx])

    # back from paired basis
    out = []
    for l in range(lm + 1):
        q = jnp.asarray(qs[l])
        out.append(jnp.einsum("ect,st->ecs", out_u[l], q))
    return jnp.concatenate(out, axis=-1)


def _equiv_layernorm(x, eps=1e-6):
    """RMS over (channel, component) per l-subspace — rotation invariant."""
    lm = int(np.sqrt(x.shape[-1])) - 1
    outs = []
    for l in range(lm + 1):
        blk = x[..., _sl(l)]
        norm = jnp.sqrt(jnp.mean(jnp.sum(blk ** 2, axis=-1), axis=-1) + eps)
        outs.append(blk / norm[..., None, None])
    return jnp.concatenate(outs, axis=-1)


def equiformer_energy(params, cfg, species, positions, edge_src, edge_dst, graph_id, n_graphs):
    n = species.shape[0]
    c, lm = cfg.d_hidden, cfg.l_max
    rb = RotationBasis(lm)
    x = jnp.zeros((n, c, sph_dim(lm)), positions.dtype)
    x = x.at[..., 0].set(params["embed"]["table"][species])

    vec = positions[edge_dst] - positions[edge_src]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / (r[:, None] + 1e-12)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    # zero-length edges (self loops / padding) have no defined alignment
    # frame — their messages are masked out (required for equivariance)
    edge_ok = (r > 1e-6).astype(positions.dtype)[:, None, None]
    # per-l alignment rotations (E, d, d), plus transposes for the way back
    d_align = [rb.align_z(l, unit) for l in range(lm + 1)]

    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        xs = _equiv_layernorm(x)[edge_src]  # (E, C, S)
        # rotate into the edge frame
        x_rot = jnp.concatenate(
            [
                jnp.einsum("eij,ecj->eci", d_align[l], xs[..., _sl(l)])
                for l in range(lm + 1)
            ],
            axis=-1,
        )
        msg = _escn_message(p, cfg, x_rot)
        msg = msg * nn.mlp(p["radial"], rbf)[:, :, None]  # radial modulation
        msg = msg * edge_ok  # degenerate-edge mask
        # rotate back
        msg = jnp.concatenate(
            [
                jnp.einsum("eji,ecj->eci", d_align[l], msg[..., _sl(l)])
                for l in range(lm + 1)
            ],
            axis=-1,
        )
        # graph attention on scalar channel
        logits = nn.dense(p["attn"], msg[..., 0])  # (E, heads)
        alpha = segment_softmax(logits, edge_dst, n)  # (E, heads)
        msg = msg * jnp.mean(alpha, axis=-1)[:, None, None]
        agg = segment_sum(msg, edge_dst, n)
        agg = jnp.concatenate(
            [
                jnp.einsum("ncs,cd->nds", agg[..., _sl(l)], p["post"][f"l{l}"]["w"])
                for l in range(lm + 1)
            ],
            axis=-1,
        )
        # gated nonlinearity + scalar FFN
        scal = agg[..., 0]
        gates = jax.nn.sigmoid(nn.dense(p["gate"], scal).reshape(n, lm, c))
        parts = [jax.nn.silu(scal)[..., None]]
        for l in range(1, lm + 1):
            parts.append(agg[..., _sl(l)] * gates[:, l - 1, :, None])
        upd = jnp.concatenate(parts, axis=-1)
        upd = upd.at[..., 0].add(nn.mlp(p["ffn"], scal))
        x = x + upd

    e_atom = nn.mlp(params["readout"], x[..., 0])[:, 0]
    return segment_sum(e_atom, graph_id, n_graphs)
