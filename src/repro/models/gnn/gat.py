"""Graph Attention Network (GAT, arXiv:1710.10903), Cora config.

SDDMM edge scores -> segment softmax -> SpMM, all on edge lists through
the :mod:`repro.sparse` substrate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sparse.segment import segment_softmax, segment_sum
from .. import nn

__all__ = ["gat_init", "gat_apply"]


def _layer_init(key, d_in, d_out, heads, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": nn.dense_init(k1, d_in, heads * d_out, dtype=dtype),
        "a_src": jax.random.normal(k2, (heads, d_out), dtype) * 0.1,
        "a_dst": jax.random.normal(k3, (heads, d_out), dtype) * 0.1,
    }


def gat_init(key, cfg, d_feat: int):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers)
    layers = {}
    d_in = d_feat
    for i, k in enumerate(keys):
        last = i == cfg.n_layers - 1
        d_out = cfg.d_out if last else cfg.d_hidden
        layers[f"layer{i}"] = _layer_init(k, d_in, d_out, cfg.n_heads, dtype)
        d_in = d_out * (1 if last else cfg.n_heads)
    return layers


def _gat_layer(p, x, edge_src, edge_dst, n_nodes, heads, *, concat, act):
    h = nn.dense(p["w"], x)
    d_out = p["a_src"].shape[1]
    h = h.reshape(-1, heads, d_out)  # (N, H, d)
    s_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
    logits = jax.nn.leaky_relu(
        s_src[edge_src] + s_dst[edge_dst], negative_slope=0.2
    )  # (E, H)
    alpha = segment_softmax(logits, edge_dst, n_nodes)  # (E, H)
    msg = h[edge_src] * alpha[..., None]  # (E, H, d)
    out = segment_sum(msg, edge_dst, n_nodes)  # (N, H, d)
    if concat:
        out = out.reshape(-1, heads * d_out)
    else:
        out = jnp.mean(out, axis=1)
    return act(out) if act is not None else out


def gat_apply(params, cfg, feats, edge_src, edge_dst):
    """feats (N, d_feat) -> logits (N, d_out).  Self-loops are the caller's
    responsibility (Cora preprocessing adds them)."""
    n = feats.shape[0]
    x = feats
    nl = cfg.n_layers
    for i in range(nl):
        last = i == nl - 1
        x = _gat_layer(
            params[f"layer{i}"],
            x,
            edge_src,
            edge_dst,
            n,
            cfg.n_heads,
            concat=not last,
            act=None if last else jax.nn.elu,
        )
    return x
