"""GraphCast-style encode-process-decode mesh GNN (arXiv:2212.12794).

Homogeneous formulation per the assignment: the lat/lon<->mesh frontends
are stubbed (``input_specs`` provides features already on the mesh);
the processor is the published 16-layer, 512-wide interaction network
with sum aggregation, residual updates, and LayerNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...sparse.segment import segment_sum
from .. import nn

__all__ = ["graphcast_init", "graphcast_apply"]


def graphcast_init(key, cfg, d_feat: int):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    params = {
        "encoder": nn.mlp_init(keys[0], (d_feat, d, d), dtype=dtype),
        "decoder": nn.mlp_init(keys[1], (d, d, cfg.d_out), dtype=dtype),
    }
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(keys[i + 2])
        params[f"proc{i}"] = {
            "edge_mlp": nn.mlp_init(k1, (2 * d, d, d), dtype=dtype),
            "node_mlp": nn.mlp_init(k2, (2 * d, d, d), dtype=dtype),
            "ln_e": nn.layernorm_init(d, dtype),
            "ln_n": nn.layernorm_init(d, dtype),
        }
    return params


def graphcast_apply(params, cfg, feats, edge_src, edge_dst):
    """feats (N, n_vars) -> next-state prediction (N, n_vars)."""
    n = feats.shape[0]
    h = nn.mlp(params["encoder"], feats)
    for i in range(cfg.n_layers):
        p = params[f"proc{i}"]
        e_in = jnp.concatenate([h[edge_src], h[edge_dst]], axis=-1)
        m = nn.layernorm(p["ln_e"], nn.mlp(p["edge_mlp"], e_in))
        agg = segment_sum(m, edge_dst, n)
        upd = nn.mlp(p["node_mlp"], jnp.concatenate([h, agg], axis=-1))
        h = h + nn.layernorm(p["ln_n"], upd)  # residual processor step
    return nn.mlp(params["decoder"], h)
