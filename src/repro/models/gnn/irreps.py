"""Irreducible-representation machinery for E(3)-equivariant GNNs.

Built from first principles (no e3nn on this box):

* complex Clebsch-Gordan via the Racah formula (exact, float64);
* real-basis CG through the complex->real change-of-basis matrices;
* real spherical harmonics generated *recursively* through the CG
  coupling itself (``Y_l ∝ CG(l-1,1,l) : Y_{l-1} ⊗ Y_1``) — this makes
  SH/CG mutually consistent *by construction*, so tensor-product
  equivariance holds exactly in whatever orthogonal real basis emerges;
* Wigner rotations assembled as ``D(R) = exp(angle * G)`` from numerically
  extracted so(3) generators, block-diagonalized once on the host so the
  runtime cost per edge is a pair of small dense matmuls (used by the
  eSCN SO(2) convolution in Equiformer-v2).

Everything host-side is cached float64 numpy; runtime pieces are jnp.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "clebsch_gordan",
    "sph_harm",
    "sph_dim",
    "RotationBasis",
    "tp_paths",
]


def sph_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


# ----------------------------------------------------------------------
# complex CG (Racah) + real basis change
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return float(math.factorial(n))


def _cg_complex_coeff(j1, m1, j2, m2, j3, m3) -> float:
    """<j1 m1 j2 m2 | j3 m3> via the Racah formula (exact float64)."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    pref = math.sqrt(
        (2 * j3 + 1)
        * _fact(j3 + j1 - j2)
        * _fact(j3 - j1 + j2)
        * _fact(j1 + j2 - j3)
        / _fact(j1 + j2 + j3 + 1)
    )
    pref *= math.sqrt(
        _fact(j3 + m3)
        * _fact(j3 - m3)
        * _fact(j1 - m1)
        * _fact(j1 + m1)
        * _fact(j2 - m2)
        * _fact(j2 + m2)
    )
    s = 0.0
    kmin = max(0, j2 - j3 - m1, j1 - j3 + m2)
    kmax = min(j1 + j2 - j3, j1 - m1, j2 + m2)
    for k in range(kmin, kmax + 1):
        s += (-1.0) ** k / (
            _fact(k)
            * _fact(j1 + j2 - j3 - k)
            * _fact(j1 - m1 - k)
            * _fact(j2 + m2 - k)
            * _fact(j3 - j2 + m1 + k)
            * _fact(j3 - j1 - m2 + k)
        )
    return pref * s


@functools.lru_cache(maxsize=None)
def _real_to_complex(l: int) -> np.ndarray:
    """Q[l]: complex SH = Q @ real SH (rows m=-l..l complex, cols real)."""
    q = np.zeros((2 * l + 1, 2 * l + 1), dtype=np.complex128)
    # real basis ordered m = -l..l  (sin|m| terms for m<0, cos for m>0)
    for m in range(-l, l + 1):
        row = m + l
        if m < 0:
            q[row, m + l] = 1j / math.sqrt(2)
            q[row, -m + l] = 1 / math.sqrt(2)
        elif m == 0:
            q[row, l] = 1.0
        else:
            q[row, m + l] = (-1) ** m / math.sqrt(2)
            q[row, -m + l] = -1j * (-1) ** m / math.sqrt(2)
    return q


@functools.lru_cache(maxsize=None)
def clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C[(2l1+1), (2l2+1), (2l3+1)] (float64).

    Satisfies (up to the basis' orthogonal freedom):
    ``(x ⊗ y)_l3 = einsum('ijk,i,j->k', C, x_l1, y_l2)`` transforms as l3.
    """
    c = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                c[m1 + l1, m2 + l2, m3 + l3] = _cg_complex_coeff(
                    l1, m1, l2, m2, l3, m3
                )
    q1 = _real_to_complex(l1)
    q2 = _real_to_complex(l2)
    q3 = _real_to_complex(l3)
    real = np.einsum("abc,ai,bj,ck->ijk", c, q1, q2, np.conj(q3))
    # the result must be real or purely imaginary; fold phase in
    if np.abs(real.imag).max() > np.abs(real.real).max():
        real = real.imag
    else:
        real = real.real
    assert np.isfinite(real).all()
    return np.ascontiguousarray(real)


# ----------------------------------------------------------------------
# recursive real spherical harmonics (consistent with the CG above)
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _sh_norms(l_max: int) -> Tuple[float, ...]:
    """Normalization so that |Y_l(u)| = 1 for unit u (e3nn 'norm')."""
    rng = np.random.default_rng(0)
    u = rng.normal(size=3)
    u /= np.linalg.norm(u)
    y = {1: u / np.linalg.norm(u)}
    norms = [1.0, 1.0]
    for l in range(2, l_max + 1):
        cg = clebsch_gordan(l - 1, 1, l)
        raw = np.einsum("ijk,i,j->k", cg, y[l - 1], y[1])
        n = np.linalg.norm(raw)
        norms.append(1.0 / n)
        y[l] = raw / n
    return tuple(norms)


def sph_harm(l_max: int, vecs):
    """Real SH of unit vectors, concatenated l=0..l_max: (..., (l_max+1)^2).

    Built by recursive CG coupling; |Y_l| = 1 for every l on unit input.
    Y_0 = 1; Y_1 = the vector itself (basis order [x, y, z]).
    """
    vecs = jnp.asarray(vecs)
    out = [jnp.ones(vecs.shape[:-1] + (1,), vecs.dtype), vecs]
    norms = _sh_norms(l_max) if l_max >= 2 else (1.0, 1.0)
    prev = vecs
    for l in range(2, l_max + 1):
        cg = jnp.asarray(clebsch_gordan(l - 1, 1, l), vecs.dtype)
        nxt = jnp.einsum("...i,...j,ijk->...k", prev, vecs, cg) * norms[l]
        out.append(nxt)
        prev = nxt
    return jnp.concatenate(out, axis=-1)


def tp_paths(l_in: List[int], l_edge: int, l_out_max: int):
    """All (l1, l2, l3) tensor-product paths for a NequIP-style layer."""
    paths = []
    for l1 in l_in:
        for l2 in range(l_edge + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_out_max) + 1):
                paths.append((l1, l2, l3))
    return paths


# ----------------------------------------------------------------------
# Wigner rotations via numerically-extracted so(3) generators
# ----------------------------------------------------------------------
def _sh_numpy(l_max, vecs):
    """Pure-numpy float64 SH (host precomputation must not depend on the
    process's jax_enable_x64 setting — float32 generators are too noisy
    for the Schur pairing)."""
    vecs = np.asarray(vecs, np.float64)
    out = [np.ones(vecs.shape[:-1] + (1,)), vecs]
    norms = _sh_norms(l_max) if l_max >= 2 else (1.0, 1.0)
    prev = vecs
    for l in range(2, l_max + 1):
        cg = clebsch_gordan(l - 1, 1, l)
        nxt = np.einsum("...i,...j,ijk->...k", prev, vecs, cg) * norms[l]
        out.append(nxt)
        prev = nxt
    return np.concatenate(out, axis=-1)


@functools.lru_cache(maxsize=None)
def _generator(l: int, axis: int) -> np.ndarray:
    """G_axis for irrep l: d/dθ D(R_axis(θ)) at 0, via least squares."""
    rng = np.random.default_rng(l * 13 + axis)
    pts = rng.normal(size=(8 * (2 * l + 1), 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    eps = 1e-5

    def rot(theta):
        from scipy.spatial.transform import Rotation

        return Rotation.from_euler("xyz"[axis], theta).as_matrix()

    def d_of(R):
        y0 = _block(l, pts)
        y1 = _block(l, pts @ R.T)
        return np.linalg.lstsq(y0, y1, rcond=None)[0].T

    dp = d_of(rot(eps))
    dm = d_of(rot(-eps))
    g = (dp - dm) / (2 * eps)
    return g


def _block(l: int, pts: np.ndarray) -> np.ndarray:
    full = _sh_numpy(l, pts)
    return full[:, l * l : (l + 1) * (l + 1)]


@functools.lru_cache(maxsize=None)
def _z_pairing(l: int):
    """Block-diagonalize G_z for irrep l via the real Schur decomposition.

    G_z is real antisymmetric; Schur gives an orthogonal Q with
    Qᵀ G Q block-diagonal: 2x2 blocks [[0, m], [-m, 0]] (plus an m=0 line),
    so ``D(R_z(a)) = Q · blockrot(m·a) · Qᵀ`` analytically.
    Returns (Q (d,d), pairs [(i, j, m_signed)]).
    """
    import scipy.linalg

    g = _generator(l, 2)
    tmat, q = scipy.linalg.schur(g, output="real")
    d = 2 * l + 1
    pairs = []
    i = 0
    while i < d:
        if i + 1 < d and abs(tmat[i, i + 1]) > 0.5:
            m = round(float(tmat[i, i + 1]), 6)
            assert abs(m - round(m)) < 1e-3, (l, m)
            pairs.append((i, i + 1, float(round(m))))
            i += 2
        else:
            i += 1
    assert len(pairs) == l, (l, pairs)  # irrep l has exactly l (m, -m) pairs
    return q, tuple(pairs)


@functools.lru_cache(maxsize=None)
def _j_matrix(l: int) -> np.ndarray:
    """Constant Wigner matrix J_l = D_l(S) with S·ẑ = ŷ (S = R_x(-π/2)),
    so that D(R_y(β)) = J · Z(β) · Jᵀ, computed once by least squares."""
    from scipy.spatial.transform import Rotation

    s = Rotation.from_euler("x", -np.pi / 2).as_matrix()
    rng = np.random.default_rng(l * 7 + 3)
    pts = rng.normal(size=(8 * (2 * l + 1), 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    y0 = _block(l, pts)
    y1 = _block(l, pts @ s.T)
    j = np.linalg.lstsq(y0, y1, rcond=None)[0].T
    return j


class RotationBasis:
    """Host-precomputed constants for runtime Wigner rotations up to l_max.

    ``D(R_z(a))`` is analytic through the pairing basis; ``D(R_y(b)) =
    J^(-1) Z(b) J`` ... assembled here as the alignment rotation used by
    eSCN: ``align(edge)`` returns D mapping the edge direction onto the
    z-axis (per l), plus its transpose for rotating back.
    """

    def __init__(self, l_max: int):
        self.l_max = l_max
        self.T = [jnp.asarray(_z_pairing(l)[0], jnp.float32) for l in range(l_max + 1)]
        self.pairs = [_z_pairing(l)[1] for l in range(l_max + 1)]
        self.J = [jnp.asarray(_j_matrix(l), jnp.float32) for l in range(l_max + 1)]

    def z_rot(self, l: int, angle):
        """D_l(R_z(angle)) for batched angles: (..., d, d).

        exp(a·G) = Q · blockrot(m·a) · Qᵀ from the Schur pairing.
        """
        d = 2 * l + 1
        t = self.T[l]
        blocks = jnp.zeros(angle.shape + (d, d), angle.dtype) + jnp.eye(d)
        for (i, j, m) in self.pairs[l]:
            c, s = jnp.cos(m * angle), jnp.sin(m * angle)
            blocks = blocks.at[..., i, i].set(c)
            blocks = blocks.at[..., i, j].set(s)
            blocks = blocks.at[..., j, i].set(-s)
            blocks = blocks.at[..., j, j].set(c)
        return jnp.einsum("pi,...ij,qj->...pq", t, blocks, t)

    def y_rot(self, l: int, angle):
        """D_l(R_y(angle)) = J · Z(angle) · Jᵀ."""
        j = self.J[l]
        z = self.z_rot(l, angle)
        return jnp.einsum("pi,...ij,qj->...pq", j, z, j)

    def align_z(self, l: int, vecs):
        """D_l(R) with R·v = |v| ẑ for unit-ish edge vectors v (..., 3)."""
        x, y, z = vecs[..., 0], vecs[..., 1], vecs[..., 2]
        phi = jnp.arctan2(y, x)
        theta = jnp.arccos(jnp.clip(z, -1.0, 1.0))
        # R = Ry(-theta) Rz(-phi)
        return jnp.einsum(
            "...ij,...jk->...ik",
            self.y_rot(l, -theta),
            self.z_rot(l, -phi),
        )
