"""NequIP (arXiv:2101.03164): E(3)-equivariant interatomic potential.

Faithful structure: species embedding into l=0 channels; per layer a
Clebsch-Gordan tensor-product interaction ``h_j ⊗ Y(r̂_ij)`` with radial
weights from a Bessel-RBF MLP; sum aggregation; per-l self-interaction
linears; gated nonlinearity (scalars SiLU, higher-l gated by scalar
channels); scalar MLP readout summed into total energy; forces by
``-∂E/∂positions`` (exact autodiff, tested for rotation equivariance).
Irreps layout: features as (N, C, (l_max+1)^2) concatenated real irreps.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...sparse.segment import segment_sum
from .. import nn
from .irreps import clebsch_gordan, sph_dim, sph_harm, tp_paths

__all__ = ["nequip_init", "nequip_energy", "nequip_energy_forces", "bessel_rbf"]

N_SPECIES = 16


def _sl(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


def bessel_rbf(r, n_rbf: int, cutoff: float):
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    rc = cutoff
    x = jnp.clip(r / rc, 1e-6, 1.0)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sin(n[None, :] * jnp.pi * x[:, None]) / x[:, None]
    # polynomial cutoff (p=6)
    p = 6.0
    env = (
        1.0
        - (p + 1) * (p + 2) / 2 * x ** p
        + p * (p + 2) * x ** (p + 1)
        - p * (p + 1) / 2 * x ** (p + 2)
    )
    return basis * env[:, None]


def nequip_init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    c, lm = cfg.d_hidden, cfg.l_max
    paths = tp_paths(list(range(lm + 1)), lm, lm)
    keys = jax.random.split(key, 3 + cfg.n_layers)
    params = {
        "embed": nn.embed_init(keys[0], N_SPECIES, c, dtype),
        "readout": nn.mlp_init(keys[1], (c, c, 1), dtype=dtype),
    }
    for i in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(keys[2 + i], 4)
        params[f"layer{i}"] = {
            "radial": nn.mlp_init(
                k1, (cfg.n_rbf, 32, len(paths) * c), dtype=dtype
            ),
            # per-l self interaction (channel mixing)
            "self": {
                f"l{l}": nn.dense_init(k2, c, c, dtype=dtype)
                for l in range(lm + 1)
            },
            "post": {
                f"l{l}": nn.dense_init(k3, c, c, dtype=dtype)
                for l in range(lm + 1)
            },
            "gate": nn.dense_init(k4, c, lm * c, dtype=dtype),  # scalars->gates
        }
    return params


def _tensor_product_messages(layer_p, cfg, x, edge_src, y_edge, rbf):
    """Per-edge CG tensor product with radial weights, summed into l_out."""
    c, lm = cfg.d_hidden, cfg.l_max
    paths = tp_paths(list(range(lm + 1)), lm, lm)
    w = nn.mlp(layer_p["radial"], rbf)  # (E, n_paths * C)
    w = w.reshape(-1, len(paths), c)
    xs = x[edge_src]  # (E, C, S)
    out = jnp.zeros((xs.shape[0], c, sph_dim(lm)), xs.dtype)
    for pi, (l1, l2, l3) in enumerate(paths):
        cg = jnp.asarray(clebsch_gordan(l1, l2, l3), xs.dtype)
        t = jnp.einsum(
            "eci,ej,ijk->eck", xs[..., _sl(l1)], y_edge[..., _sl(l2)], cg
        )
        out = out.at[..., _sl(l3)].add(w[:, pi, :, None] * t)
    return out


def _gate(layer_p, cfg, x):
    """Equivariant gated nonlinearity."""
    c, lm = cfg.d_hidden, cfg.l_max
    scalars = x[..., 0]  # (N, C)
    gated = [jax.nn.silu(scalars)[..., None]]
    if lm > 0:
        gates = jax.nn.sigmoid(
            nn.dense(layer_p["gate"], scalars).reshape(-1, lm, c)
        )
        for l in range(1, lm + 1):
            gated.append(x[..., _sl(l)] * gates[:, l - 1, :, None])
    return jnp.concatenate(gated, axis=-1)


def nequip_energy(params, cfg, species, positions, edge_src, edge_dst, graph_id, n_graphs):
    """Total energy per graph: (n_graphs,)."""
    n = species.shape[0]
    c, lm = cfg.d_hidden, cfg.l_max
    x = jnp.zeros((n, c, sph_dim(lm)), positions.dtype)
    x = x.at[..., 0].set(params["embed"]["table"][species])

    vec = positions[edge_dst] - positions[edge_src]
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / (r[:, None] + 1e-12)
    y_edge = sph_harm(lm, unit)  # (E, S)
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)

    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        # self-interaction pre-mix
        xm = jnp.concatenate(
            [
                jnp.einsum("ncs,cd->nds", x[..., _sl(l)], p["self"][f"l{l}"]["w"])
                for l in range(lm + 1)
            ],
            axis=-1,
        )
        msg = _tensor_product_messages(p, cfg, xm, edge_src, y_edge, rbf)
        agg = segment_sum(msg, edge_dst, n)
        agg = jnp.concatenate(
            [
                jnp.einsum("ncs,cd->nds", agg[..., _sl(l)], p["post"][f"l{l}"]["w"])
                for l in range(lm + 1)
            ],
            axis=-1,
        )
        x = x + _gate(p, cfg, agg)

    e_atom = nn.mlp(params["readout"], x[..., 0])[:, 0]  # (N,)
    return segment_sum(e_atom, graph_id, n_graphs)


def nequip_energy_forces(params, cfg, species, positions, edge_src, edge_dst, graph_id, n_graphs):
    def e_total(pos):
        e = nequip_energy(
            params, cfg, species, pos, edge_src, edge_dst, graph_id, n_graphs
        )
        return jnp.sum(e), e

    (_, energies), grad = jax.value_and_grad(e_total, has_aux=True)(positions)
    return energies, -grad
