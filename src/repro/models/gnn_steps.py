"""Sharded step builders + input specs for the GNN and recsys families.

GNN sharding: edge arrays over the flattened (pod, data, model) axes (edge
parallelism — the same decomposition argument as the paper's task
distribution); node arrays sharded on the node dim; ``segment_sum``
scatters become psum-combines under GSPMD.

Recsys sharding: batch over (pod, data); the concatenated embedding table
row-sharded over `model` (all-to-all exchange emerges from the gather).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import GNNConfig, RecsysConfig
from ..optim import make_optimizer
from . import nn
from .dlrm import dlrm_init, dlrm_loss, dlrm_retrieval
from .gnn.gat import gat_apply, gat_init
from .gnn.graphcast import graphcast_apply, graphcast_init
from .gnn.nequip import nequip_energy_forces, nequip_init
from .gnn.equiformer_v2 import equiformer_energy, equiformer_init

__all__ = [
    "gnn_init",
    "build_gnn_train_step",
    "gnn_input_specs",
    "build_dlrm_train_step",
    "build_dlrm_serve_step",
    "build_dlrm_retrieval_step",
    "recsys_input_specs",
]

EQUIVARIANT = ("nequip", "equiformer_v2")


def _all_axes(mesh) -> Tuple:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)


def gnn_init(key, cfg: GNNConfig, d_feat: int):
    if cfg.arch == "gat":
        return gat_init(key, cfg, d_feat)
    if cfg.arch == "graphcast":
        return graphcast_init(key, cfg, d_feat)
    if cfg.arch == "nequip":
        return nequip_init(key, cfg)
    if cfg.arch == "equiformer_v2":
        return equiformer_init(key, cfg)
    raise ValueError(cfg.arch)


def gnn_loss(params, cfg: GNNConfig, batch):
    if cfg.arch in EQUIVARIANT:
        fwd = (
            nequip_energy_forces
            if cfg.arch == "nequip"
            else lambda *a: (equiformer_energy(*a), None)
        )
        if cfg.arch == "nequip":
            energy, forces = nequip_energy_forces(
                params,
                cfg,
                batch["species"],
                batch["positions"],
                batch["edge_src"],
                batch["edge_dst"],
                batch["graph_id"],
                batch["energy"].shape[0],
            )
            loss = jnp.mean((energy - batch["energy"]) ** 2)
            loss = loss + jnp.mean((forces - batch["forces"]) ** 2)
        else:
            energy = equiformer_energy(
                params,
                cfg,
                batch["species"],
                batch["positions"],
                batch["edge_src"],
                batch["edge_dst"],
                batch["graph_id"],
                batch["energy"].shape[0],
            )
            loss = jnp.mean((energy - batch["energy"]) ** 2)
        return loss, {"loss": loss}
    if cfg.arch == "gat":
        logits = gat_apply(
            params, cfg, batch["feats"], batch["edge_src"], batch["edge_dst"]
        )
        labels = batch["labels"]
        mask = batch["label_mask"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce, {"loss": ce}
    if cfg.arch == "graphcast":
        pred = graphcast_apply(
            params, cfg, batch["feats"], batch["edge_src"], batch["edge_dst"]
        )
        mse = jnp.mean((pred - batch["target"]) ** 2)
        return mse, {"loss": mse}
    raise ValueError(cfg.arch)


def build_gnn_train_step(cfg: GNNConfig, mesh, d_feat: int):
    axes = _all_axes(mesh)
    # GAT-paper style settings (lr 5e-3, no decoupled weight decay)
    opt_init, opt_update = make_optimizer(
        "adamw", lambda s: 5e-3, weight_decay=0.0
    )

    def step(params, opt_state, batch, step_i):
        (loss, metrics), grads = jax.value_and_grad(
            gnn_loss, has_aux=True
        )(params, cfg, batch)
        new_p, new_o, stats = opt_update(grads, opt_state, params, step_i)
        return new_p, new_o, {**metrics, **stats}

    dummy = jax.eval_shape(lambda k: gnn_init(k, cfg, d_feat), jax.random.key(0))
    pspec = jax.tree.map(lambda x: P(*(None,) * x.ndim), dummy)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    edge_spec = NamedSharding(mesh, P(axes))
    node_spec = NamedSharding(mesh, P(axes))

    def batch_shardings(batch_struct):
        out = {}
        for k, v in batch_struct.items():
            if k.startswith("edge"):
                out[k] = edge_spec
            elif v.ndim >= 1 and k not in ("energy",):
                out[k] = NamedSharding(
                    mesh, P(axes, *([None] * (v.ndim - 1)))
                )
            else:
                out[k] = NamedSharding(mesh, P())
        return out

    opt_shape = jax.eval_shape(opt_init, dummy)
    ospec = jax.tree.map(lambda x: P(*(None,) * x.ndim), opt_shape)

    def build(batch_struct):
        fn = jax.jit(
            step,
            in_shardings=(
                shard(pspec),
                shard(ospec),
                batch_shardings(batch_struct),
                None,
            ),
            donate_argnums=(0, 1),
        )
        return fn

    return build, dict(params=pspec, opt_init=opt_init, dummy=dummy)


def _pad_to(x: int, mult: int = 512) -> int:
    return ((x + mult - 1) // mult) * mult


def gnn_input_specs(cfg: GNNConfig, shape: dict) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch ShapeDtypeStructs per GNN shape cell.

    Node/edge counts are padded to a multiple of 512 so the arrays shard
    evenly on both production meshes (pjit input shardings require exact
    divisibility; the pipeline pads with masked no-op edges on real runs —
    <0.5% overhead at these sizes)."""
    f32, i32 = jnp.float32, jnp.int32
    if shape["kind"] == "sampled":
        b = shape["batch_nodes"]
        f1, f2 = shape["fanout"]
        n = b * (1 + f1 + f1 * f2) + 1
        e = b * f1 + b * f1 * f2 + 1
    elif shape["kind"] == "batched":
        n = shape["n_nodes"] * shape["batch"]
        e = shape["n_edges"] * shape["batch"]
    else:
        n, e = shape["n_nodes"], shape["n_edges"]
    n, e = _pad_to(n), _pad_to(e)
    d_feat = shape.get("d_feat", 128)
    batch = {
        "edge_src": jax.ShapeDtypeStruct((e,), i32),
        "edge_dst": jax.ShapeDtypeStruct((e,), i32),
    }
    if cfg.arch in EQUIVARIANT:
        n_graphs = shape.get("batch", 1)
        batch.update(
            species=jax.ShapeDtypeStruct((n,), i32),
            positions=jax.ShapeDtypeStruct((n, 3), f32),
            graph_id=jax.ShapeDtypeStruct((n,), i32),
            energy=jax.ShapeDtypeStruct((n_graphs,), f32),
        )
        if cfg.arch == "nequip":
            batch["forces"] = jax.ShapeDtypeStruct((n, 3), f32)
    elif cfg.arch == "gat":
        batch.update(
            feats=jax.ShapeDtypeStruct((n, d_feat), f32),
            labels=jax.ShapeDtypeStruct((n,), i32),
            label_mask=jax.ShapeDtypeStruct((n,), f32),
        )
    else:  # graphcast
        nv = cfg.n_vars or d_feat
        batch.update(
            feats=jax.ShapeDtypeStruct((n, nv), f32),
            target=jax.ShapeDtypeStruct((n, nv), f32),
        )
    return batch


def gnn_feat_dim(cfg: GNNConfig, shape: dict) -> int:
    if cfg.arch in EQUIVARIANT:
        return 0
    if cfg.arch == "graphcast":
        return cfg.n_vars
    return shape.get("d_feat", 128)


# ----------------------------------------------------------------------
# recsys (DLRM)
# ----------------------------------------------------------------------
def dlrm_param_specs(params, *, tp="model"):
    def spec(path, x):
        if x.ndim == 2 and x.shape[0] > 4096:  # the big concatenated table
            return P(tp, None)
        return P(*(None,) * x.ndim)

    return jax.tree_util.tree_map_with_path(spec, params)


def build_dlrm_train_step(cfg: RecsysConfig, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    opt_init, opt_update = make_optimizer("adamw", lambda s: 1e-3)

    def step(params, opt_state, batch, step_i):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p, b: dlrm_loss(p, cfg, b["dense"], b["sparse_ids"], b["labels"]),
            has_aux=True,
        )(params, batch)
        new_p, new_o, stats = opt_update(grads, opt_state, params, step_i)
        return new_p, new_o, {**metrics, **stats}

    dummy = jax.eval_shape(lambda k: dlrm_init(k, cfg), jax.random.key(0))
    pspec = dlrm_param_specs(dummy)
    opt_shape = jax.eval_shape(opt_init, dummy)

    def ospec_fn(path, x):
        if x.ndim == 2 and x.shape[0] > 4096:
            return P("model", None)
        if x.ndim >= 1 and x.shape[0] > 4096:  # adafactor factored rows
            return P("model")
        return P(*(None,) * x.ndim)

    ospec = jax.tree_util.tree_map_with_path(ospec_fn, opt_shape)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    bspec = {
        "dense": NamedSharding(mesh, P(dp, None)),
        "sparse_ids": NamedSharding(mesh, P(dp, None, None)),
        "labels": NamedSharding(mesh, P(dp)),
    }
    fn = jax.jit(
        step,
        in_shardings=(shard(pspec), shard(ospec), bspec, None),
        donate_argnums=(0, 1),
    )
    return fn, dict(params=pspec, opt_init=opt_init, dummy=dummy)


def build_dlrm_serve_step(cfg: RecsysConfig, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def serve(params, dense, sparse_ids):
        from .dlrm import dlrm_forward

        return jax.nn.sigmoid(dlrm_forward(params, cfg, dense, sparse_ids))

    dummy = jax.eval_shape(lambda k: dlrm_init(k, cfg), jax.random.key(0))
    pspec = dlrm_param_specs(dummy)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    fn = jax.jit(
        serve,
        in_shardings=(
            shard(pspec),
            NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp, None, None)),
        ),
    )
    return fn, dict(params=pspec, dummy=dummy)


def build_dlrm_retrieval_step(cfg: RecsysConfig, mesh):
    def retrieve(params, dense, cand_ids):
        return dlrm_retrieval(params, cfg, dense, cand_ids)

    dummy = jax.eval_shape(lambda k: dlrm_init(k, cfg), jax.random.key(0))
    pspec = dlrm_param_specs(dummy)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    fn = jax.jit(
        retrieve,
        in_shardings=(
            shard(pspec),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P(axes)),  # candidates sharded over all

        ),
    )
    return fn, dict(params=pspec, dummy=dummy)


def recsys_input_specs(cfg: RecsysConfig, shape: dict):
    f32, i32 = jnp.float32, jnp.int32
    if shape["kind"] == "retrieval":
        return dict(
            dense=jax.ShapeDtypeStruct((1, cfg.n_dense), f32),
            cand_ids=jax.ShapeDtypeStruct(
                (_pad_to(shape["n_candidates"]),), i32
            ),
        )
    b = shape["batch"]
    batch = dict(
        dense=jax.ShapeDtypeStruct((b, cfg.n_dense), f32),
        sparse_ids=jax.ShapeDtypeStruct((b, cfg.n_sparse, cfg.multi_hot), i32),
    )
    if shape["kind"] == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b,), f32)
    return batch
