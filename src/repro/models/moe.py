"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Sort-based (GShard-style) dispatch: the (token, k) assignments are sorted
by expert id, each expert keeps at most ``capacity`` tokens (overflow is
dropped, standard for capacity-factor training), tokens are gathered into
an ``(E, C, d)`` batch, the expert SwiGLU runs as one grouped einsum, and
results scatter-add back weighted by router probabilities.

Expert placement note (DESIGN.md §5): experts are *cyclically* sharded over
the `model` axis — the paper's cyclic-balance argument applied to hot
experts (consecutive experts land on different devices, so correlated-hot
expert pairs spread out).  With E % ep_size == 0 cyclic == blocked in cost
but better under skewed routing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import nn

__all__ = ["moe_init", "moe_apply", "swiglu_init", "swiglu_apply"]


def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32, n_experts: int = 0):
    ks = jax.random.split(key, 3)
    shape_in = (n_experts, d, d_ff) if n_experts else (d, d_ff)
    shape_out = (n_experts, d_ff, d) if n_experts else (d_ff, d)
    import math

    s = 1.0 / math.sqrt(d)
    s2 = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": jax.random.uniform(ks[0], shape_in, dtype, -s, s),
        "w_in": jax.random.uniform(ks[1], shape_in, dtype, -s, s),
        "w_out": jax.random.uniform(ks[2], shape_out, dtype, -s2, s2),
    }


def swiglu_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]


def moe_init(
    key,
    d: int,
    n_experts: int,
    moe_d_ff: int,
    n_shared: int = 0,
    dtype=jnp.float32,
):
    ks = jax.random.split(key, 3)
    p = {
        "router": nn.dense_init(ks[0], d, n_experts, dtype=dtype),
        "experts": swiglu_init(ks[1], d, moe_d_ff, dtype, n_experts=n_experts),
    }
    if n_shared:
        p["shared"] = swiglu_init(ks[2], d, n_shared * moe_d_ff, dtype)
    return p


def moe_apply(
    p,
    x,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    router_noise: bool = False,
):
    """x: (T, d) -> (T, d); returns (y, aux) with the load-balancing loss."""
    t, d = x.shape
    e = p["router"]["w"].shape[1]
    logits = nn.dense(p["router"], x.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    cap = max(1, int(capacity_factor * t * top_k / e))
    flat_e = top_i.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert group (se is sorted)
    pos = jnp.arange(t * top_k) - jnp.searchsorted(se, se, side="left")
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)  # overflow -> pad row

    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(x[st])
    xe = xe[:-1].reshape(e, cap, d)
    # grouped expert SwiGLU
    gate = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, p["experts"]["w_in"])
    ye = jnp.einsum(
        "ecf,efd->ecd", jax.nn.silu(gate) * up, p["experts"]["w_out"]
    )
    ye_flat = ye.reshape(e * cap, d)
    contrib = jnp.where(keep, sw, 0.0)[:, None].astype(x.dtype) * ye_flat[
        jnp.minimum(slot, e * cap - 1)
    ]
    y = jnp.zeros((t, d), x.dtype).at[st].add(contrib)

    if "shared" in p:
        y = y + swiglu_apply(p["shared"], x)

    # Switch-style load-balancing aux loss
    me = jnp.mean(probs, axis=0)  # (E,)
    fe = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux = e * jnp.sum(me * fe)
    return y, aux
