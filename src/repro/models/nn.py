"""Minimal pure-JAX module substrate (no flax on this box).

Convention: a layer is a pair of plain functions —
``init(key, ...) -> params`` (a pytree of jnp arrays) and
``apply(params, x, ...) -> y``.  Models compose these; parameters are
nested dicts so sharding rules can match on path names.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "mlp_init",
    "mlp",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "embed_init",
]

Dtype = jnp.dtype


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    w = jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def mlp_init(key, dims: Sequence[int], *, bias: bool = True, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"l{i}": dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
        for i, k in enumerate(keys)
    }


def mlp(p, x, *, act=jax.nn.silu, final_act=False):
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}
