"""Sharded train/serve step builders for the LM family.

Sharding recipe (GSPMD, DESIGN.md §5):

* parameters — 2D sharded: FSDP dim over ``data``, TP dim over ``model``;
  MoE experts over ``model`` (cyclic EP); scanned layers keep a leading
  un-sharded L dim.  The ``pod`` axis is pure DP (params replicated across
  pods; gradient psum spans pods).
* activations — batch over (``pod``,) ``data``; head/ff dims follow the
  weights; decode KV caches are **sequence-sharded** over ``model`` so
  one-token attention becomes a psum-combined partial softmax
  (flash-decoding on the mesh).
* training — gradient-accumulation microbatching (``cfg.microbatch_size``)
  under ``lax.scan``; AdamW or Adafactor; optional int8-compressed DP
  gradient psum.
"""
from __future__ import annotations

import functools
import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import LMConfig
from ..optim import make_optimizer, cosine_schedule
from . import nn
from .transformer import init_kv_cache, lm_decode_step, lm_forward, lm_init, lm_loss

__all__ = [
    "lm_param_specs",
    "build_lm_train_step",
    "build_lm_prefill_step",
    "build_lm_decode_step",
    "lm_input_specs",
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def lm_param_specs(params, cfg: LMConfig, *, fsdp="data", tp="model", mesh=None):
    """PartitionSpec pytree matched on parameter path names.

    Expert tensors shard E over `model` (EP) when the expert count divides
    the axis; otherwise (e.g. grok's 8 experts on a 16-wide axis) they fall
    back to TP within each expert (d/ff over the mesh axes).
    """
    tp_size = mesh.shape[tp] if mesh is not None else 1
    ep_ok = cfg.n_experts == 0 or (
        tp_size <= 1 or cfg.n_experts % tp_size == 0
    )

    def spec_for(path: str, ndim: int) -> P:
        stacked = path.startswith(("layers/", "dense_layers/"))
        lead = (None,) if stacked else ()
        base_ndim = ndim - (1 if stacked else 0)

        def mk(*dims):
            assert len(dims) == base_ndim, (path, dims, ndim)
            return P(*(lead + dims))

        if "embed/table" in path:
            return P(tp, None)
        if path == "lm_head/w":
            return P(fsdp, tp)
        if base_ndim <= 1:
            return P(*(lead + (None,) * base_ndim))
        if "experts/" in path:  # (E, d, ff) / (E, ff, d)
            if ep_ok:
                if path.endswith("w_out"):
                    return mk(tp, None, fsdp)
                return mk(tp, fsdp, None)
            if path.endswith("w_out"):
                return mk(None, tp, fsdp)
            return mk(None, fsdp, tp)
        if re.search(r"attn/(wq|wk|wv)/w$", path):
            return mk(fsdp, tp)
        if path.endswith("attn/wo/w"):
            return mk(tp, fsdp)
        if re.search(r"(q_up|k_up|v_up)/w$", path):
            return mk(None, tp)
        if re.search(r"(q_down|kv_down)/w$", path):
            return mk(fsdp, None)
        if path.endswith("router/w"):
            return mk(fsdp, None)
        if re.search(r"(w_gate|w_in)$", path):
            return mk(fsdp, tp)
        if path.endswith("w_out"):
            return mk(tp, fsdp)
        if path.endswith("proj/w"):  # mtp projection
            return mk(fsdp, None)
        if path.endswith("/w"):
            return mk(fsdp, None) if base_ndim == 2 else P(*(lead + (None,) * base_ndim))
        return P(*(lead + (None,) * base_ndim))

    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(_path_str(path), x.ndim), params
    )


def _opt_specs(opt_state, param_specs):
    """Derive optimizer-state specs from param specs (factored states drop
    the factored dim)."""

    def leaf_spec(path, x):
        ps = _path_str(path)
        # path looks like m/<param path> or v/<param path>/vr etc.
        parts = ps.split("/")
        tail = parts[-1]
        param_path = "/".join(parts[1:])
        spec = _lookup(param_specs, param_path)
        if spec is None:
            # factored adafactor leaves: strip trailing vr/vc/v
            spec = _lookup(param_specs, "/".join(parts[1:-1]))
            if spec is None:
                return P()
            if tail == "vr":
                return P(*spec[:-1])
            if tail == "vc":
                return P(*(spec[:-2] + spec[-1:]))
            if tail == "v":
                return spec
            return P()
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, opt_state)


def _lookup(spec_tree, path: str):
    node = spec_tree
    for part in path.split("/"):
        if isinstance(node, dict) and part in node:
            node = node[part]
        else:
            return None
    return node if isinstance(node, P) else None


def _dp_spec(mesh) -> Tuple:
    names = list(mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in names)


def _inject_attn_specs(cfg: LMConfig, mesh, *, tp="model"):
    """§Perf H2: q-sequence-parallel attention layout (see _attn_train)."""
    import copy

    cfg = copy.copy(cfg)
    tp_size = mesh.shape[tp] if tp in mesh.axis_names else 1
    if tp_size <= 1:
        cfg._attn_specs = None
        return cfg
    dp = _dp_spec(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    cfg._attn_specs = {
        # reshaped q (b, nq, qc, kvh, g, dh): chunks over the TP axis
        "q6": ns(P(dp, tp, None, None, None, None)),
        # k/v replicated over TP (small: kv_heads * dh per token)
        "kv": ns(P(dp, None, None, None)),
        # attention output back to seq-sharded for the FFN
        "out": ns(P(dp, tp, None, None)),
        "nq_mult": tp_size,
    }
    return cfg


def build_lm_train_step(cfg: LMConfig, mesh, *, compress_grads: bool = False):
    """Returns (step_fn, shardings) — step_fn(params, opt, batch, step)."""
    dp = _dp_spec(mesh)
    cfg = _inject_attn_specs(cfg, mesh)
    opt_init, opt_update = make_optimizer(
        cfg.optimizer, cosine_schedule(3e-4, 2000, 100_000)
    )

    def loss_fn(params, tokens, labels):
        loss, metrics = lm_loss(params, cfg, tokens, labels)
        return loss, metrics

    def step_fn(params, opt_state, batch, step):
        tokens, labels = batch["tokens"], batch["labels"]
        b = tokens.shape[0]
        mb = min(cfg.microbatch_size, b)
        nm = b // mb
        tok_m = tokens.reshape(nm, mb, tokens.shape[1])
        lab_m = labels.reshape(nm, mb, labels.shape[1])

        def micro(carry, xs):
            g_acc, l_acc = carry
            t, l = xs
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, t, l
            )
            g_acc = jax.tree.map(jnp.add, g_acc, grads)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(micro, (g0, 0.0), (tok_m, lab_m))
        grads = jax.tree.map(lambda g: g / nm, grads)
        new_params, new_opt, stats = opt_update(grads, opt_state, params, step)
        metrics = {"loss": loss_sum / nm, **stats}
        return new_params, new_opt, metrics

    # shardings
    dummy = jax.eval_shape(lambda k: lm_init(k, cfg), jax.random.key(0))
    pspecs = lm_param_specs(dummy, cfg, mesh=mesh)
    ospecs_tree = None  # inferred lazily below

    def shard(tree_specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)

    opt_shape = jax.eval_shape(opt_init, dummy)
    ospecs = _opt_specs(opt_shape, pspecs)
    batch_spec = {
        "tokens": NamedSharding(mesh, P(dp, None)),
        "labels": NamedSharding(mesh, P(dp, None)),
    }
    fn = jax.jit(
        step_fn,
        in_shardings=(shard(pspecs), shard(ospecs), batch_spec, None),
        out_shardings=(shard(pspecs), shard(ospecs), None),
        donate_argnums=(0, 1),
    )
    return fn, dict(params=pspecs, opt=ospecs, opt_init=opt_init, dummy=dummy,
                    opt_shape=opt_shape)


def build_lm_prefill_step(cfg: LMConfig, mesh):
    """Prefill: full forward over (B, S) + last-position logits."""
    dp = _dp_spec(mesh)
    cfg = _inject_attn_specs(cfg, mesh)

    def prefill(params, tokens):
        h, _ = lm_forward(params, cfg, tokens)
        logits = nn.dense(params["lm_head"], h[:, -1])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    dummy = jax.eval_shape(lambda k: lm_init(k, cfg), jax.random.key(0))
    pspecs = lm_param_specs(dummy, cfg, mesh=mesh)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    fn = jax.jit(
        prefill,
        in_shardings=(shard(pspecs), NamedSharding(mesh, P(dp, None))),
    )
    return fn, dict(params=pspecs, dummy=dummy)


def cache_specs(cfg: LMConfig, *, dp, tp="model"):
    """KV cache PartitionSpecs: batch over dp, seq over model (SP)."""
    if cfg.mla:
        return {
            "ckv": P(None, dp, tp, None),
            "k_rope": P(None, dp, tp, None),
        }
    return {
        "k": P(None, dp, tp, None, None),
        "v": P(None, dp, tp, None, None),
    }


def build_lm_decode_step(cfg: LMConfig, mesh):
    """One-token decode with sequence-sharded KV cache."""
    dp = _dp_spec(mesh)

    def decode(params, cache, token, cache_len):
        nt, logits, new_cache = lm_decode_step(params, cfg, token, cache, cache_len)
        return nt, new_cache

    dummy = jax.eval_shape(lambda k: lm_init(k, cfg), jax.random.key(0))
    pspecs = lm_param_specs(dummy, cfg, mesh=mesh)
    cspecs = cache_specs(cfg, dp=dp)
    shard = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    fn = jax.jit(
        decode,
        in_shardings=(
            shard(pspecs),
            shard(cspecs),
            NamedSharding(mesh, P(dp)),
            NamedSharding(mesh, P(dp)),
        ),
        out_shardings=(NamedSharding(mesh, P(dp)), shard(cspecs)),
        donate_argnums=(1,),
    )
    return fn, dict(params=pspecs, cache=cspecs, dummy=dummy)


def lm_input_specs(cfg: LMConfig, shape: dict, *, step: str):
    """ShapeDtypeStructs for the dry-run, per shape-set entry."""
    b = shape["global_batch"]
    s = shape["seq_len"]
    if step == "train":
        return dict(
            batch={
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        )
    if step == "prefill":
        return dict(tokens=jax.ShapeDtypeStruct((b, s), jnp.int32))
    if step == "decode":
        cache = jax.eval_shape(
            lambda: init_kv_cache(cfg, b, s)
        )
        return dict(
            cache=cache,
            token=jax.ShapeDtypeStruct((b,), jnp.int32),
            cache_len=jax.ShapeDtypeStruct((b,), jnp.int32),
        )
    raise ValueError(step)
