"""Transformer LM covering all five assigned architectures.

Features: GQA with optional QKV bias, full/partial RoPE, SwiGLU FFN,
MoE (top-k, shared experts, capacity dispatch), MLA (DeepSeek low-rank
attention, absorbed-matmul decode), MTP auxiliary head, scan-over-layers
with remat (compact HLO at 80 layers), bf16 params option.

Parameter tree layout (scanned layers carry a leading L dim):

    {"embed": .., "layers": {...}, ["dense_layers": {...}],
     "final_norm": .., "lm_head": .., ["mtp": {...}]}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from . import nn
from .attention import apply_rope, causal_attention, decode_attention
from .moe import moe_apply, moe_init, swiglu_apply, swiglu_init

__all__ = ["lm_init", "lm_loss", "lm_forward", "lm_decode_step", "init_kv_cache"]


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _attn_init(key, cfg: LMConfig, dtype):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 8)
    if cfg.mla:
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return {
            "q_down": nn.dense_init(ks[0], d, qr, dtype=dtype),
            "q_up": nn.dense_init(ks[1], qr, h * (nope + rope), dtype=dtype),
            "kv_down": nn.dense_init(ks[2], d, kvr + rope, dtype=dtype),
            "k_up": nn.dense_init(ks[3], kvr, h * nope, dtype=dtype),
            "v_up": nn.dense_init(ks[4], kvr, h * vd, dtype=dtype),
            "wo": nn.dense_init(ks[5], h * vd, d, dtype=dtype),
            "ln_q": nn.rmsnorm_init(qr, dtype),
            "ln_kv": nn.rmsnorm_init(kvr, dtype),
        }
    return {
        "wq": nn.dense_init(ks[0], d, h * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": nn.dense_init(ks[1], d, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": nn.dense_init(ks[2], d, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": nn.dense_init(ks[3], h * dh, d, dtype=dtype),
    }


def _layer_init(key, cfg: LMConfig, *, moe_layer: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, dtype),
        "ln2": nn.rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
    }
    if moe_layer:
        p["moe"] = moe_init(
            k2,
            cfg.d_model,
            cfg.n_experts,
            cfg.moe_d_ff,
            n_shared=cfg.n_shared_experts,
            dtype=dtype,
        )
    else:
        p["ffn"] = swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def lm_init(key, cfg: LMConfig):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 6)
    n_dense = cfg.first_dense_layers if cfg.moe else cfg.n_layers
    n_scan = cfg.n_layers - cfg.first_dense_layers if cfg.moe else cfg.n_layers
    params: Dict[str, Any] = {
        "embed": nn.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": nn.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": nn.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype=dtype),
    }
    if cfg.moe:
        if cfg.first_dense_layers:
            dkeys = jax.random.split(keys[2], cfg.first_dense_layers)
            params["dense_layers"] = jax.vmap(
                lambda k: _layer_init(k, cfg, moe_layer=False, dtype=dtype)
            )(dkeys)
        lkeys = jax.random.split(keys[3], n_scan)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=True, dtype=dtype)
        )(lkeys)
    else:
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=False, dtype=dtype)
        )(lkeys)
    if cfg.mtp:
        k_mtp1, k_mtp2 = jax.random.split(keys[4])
        params["mtp"] = {
            "proj": nn.dense_init(k_mtp1, 2 * cfg.d_model, cfg.d_model, dtype=dtype),
            "layer": _layer_init(k_mtp2, cfg, moe_layer=False, dtype=dtype),
            "norm_h": nn.rmsnorm_init(cfg.d_model, dtype),
            "norm_e": nn.rmsnorm_init(cfg.d_model, dtype),
        }
    return params


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------
def _constrain(x, spec):
    return jax.lax.with_sharding_constraint(x, spec) if spec is not None else x


def _attn_train(p, cfg: LMConfig, x, positions):
    """GQA / MLA attention with explicit q-sequence-parallel layout.

    §Perf H2: without constraints GSPMD shards the kv-seq *contraction*
    dim of the flash inner products over `model`, inserting an all-reduce
    per (layer × microbatch × q-chunk × kv-chunk) — 2.9 TB/device/step on
    qwen2 train_4k.  Pinning q (and the attention output) to seq-sharded
    P(dp, model, ...) and k/v to replicated-over-model makes every score/PV
    contraction local: perfect 1/tp q-row parallelism for ANY head count
    (14 heads on a 16-wide axis included), with only a per-layer k/v
    broadcast.  Specs are injected by the step builders via
    ``cfg._attn_specs`` (None on 1x1 meshes).
    """
    specs = getattr(cfg, "_attn_specs", None) or {}
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if cfg.mla:
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        cq = nn.rmsnorm(p["ln_q"], nn.dense(p["q_down"], x))
        q = nn.dense(p["q_up"], cq).reshape(b, s, h, nope + rope)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
        ckv_full = nn.dense(p["kv_down"], x)
        ckv = nn.rmsnorm(p["ln_kv"], ckv_full[..., : cfg.kv_lora_rank])
        k_rope = ckv_full[..., cfg.kv_lora_rank :].reshape(b, s, 1, rope)
        k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
        k_nope = nn.dense(p["k_up"], ckv).reshape(b, s, h, nope)
        v = nn.dense(p["v_up"], ckv).reshape(b, s, h, vd)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1
        )
        k_full = _constrain(k_full, specs.get("kv"))
        v = _constrain(v, specs.get("kv"))
        out = causal_attention(
            q_full, k_full, v,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            q6_spec=specs.get("q6"), nq_multiple=specs.get("nq_mult", 1),
        )
        out = _constrain(out, specs.get("out"))
        return nn.dense(p["wo"], out.reshape(b, s, h * vd))
    q = nn.dense(p["wq"], x).reshape(b, s, h, dh)
    k = nn.dense(p["wk"], x).reshape(b, s, kv, dh)
    v = nn.dense(p["wv"], x).reshape(b, s, kv, dh)
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = _constrain(k, specs.get("kv"))
    v = _constrain(v, specs.get("kv"))
    out = causal_attention(
        q, k, v, q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        q6_spec=specs.get("q6"), nq_multiple=specs.get("nq_mult", 1),
    )
    out = _constrain(out, specs.get("out"))
    return nn.dense(p["wo"], out.reshape(b, s, h * dh))


def _layer_apply(p, cfg: LMConfig, x, positions, *, moe_layer: bool):
    h = x + _attn_train(p["attn"], cfg, nn.rmsnorm(p["ln1"], x), positions)
    z = nn.rmsnorm(p["ln2"], h)
    if moe_layer:
        b, s, d = z.shape
        y, aux = moe_apply(p["moe"], z.reshape(b * s, d), top_k=cfg.top_k)
        return h + y.reshape(b, s, d), aux
    return h + swiglu_apply(p["ffn"], z), jnp.zeros((), jnp.float32)


def lm_forward(params, cfg: LMConfig, tokens):
    """tokens (B, S) -> hidden states (B, S, d) + moe aux loss."""
    b, s = tokens.shape
    x = params["embed"]["table"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def run_stack(stack_params, x, moe_layer):
        def body(carry, layer_p):
            h, aux = carry
            h2, a = _layer_apply(
                layer_p, cfg, h, positions, moe_layer=moe_layer
            )
            return (h2, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), stack_params)
        return x, aux

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.moe and cfg.first_dense_layers:
        x, a = run_stack(params["dense_layers"], x, False)
        aux_total += a
    x, a = run_stack(params["layers"], x, cfg.moe)
    aux_total += a
    return nn.rmsnorm(params["final_norm"], x), aux_total


def lm_loss(params, cfg: LMConfig, tokens, labels):
    """Next-token CE (+ MoE aux + MTP aux).  tokens/labels: (B, S)."""
    h, aux = lm_forward(params, cfg, tokens)
    logits = nn.dense(params["lm_head"], h).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    total = ce + 0.01 * aux
    metrics = {"ce": ce, "moe_aux": aux}
    if cfg.mtp:
        # MTP: predict token t+2 from (h_t, emb(label_t)) through one extra
        # layer (DeepSeek-V3 §2.2); applied on a shifted slice.
        p = params["mtp"]
        emb_next = params["embed"]["table"][labels]
        cat = jnp.concatenate(
            [nn.rmsnorm(p["norm_h"], h), nn.rmsnorm(p["norm_e"], emb_next)],
            axis=-1,
        )
        h2 = nn.dense(p["proj"], cat)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        h2, _ = _layer_apply(p["layer"], cfg, h2, positions, moe_layer=False)
        logits2 = nn.dense(params["lm_head"], h2[:, :-1]).astype(jnp.float32)
        mtp_labels = labels[:, 1:]
        logz2 = jax.nn.logsumexp(logits2, axis=-1)
        gold2 = jnp.take_along_axis(
            logits2, mtp_labels[..., None], axis=-1
        )[..., 0]
        mtp_ce = jnp.mean(logz2 - gold2)
        total = total + 0.3 * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return total, metrics


# ----------------------------------------------------------------------
# decode (serving)
# ----------------------------------------------------------------------
def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Per-layer stacked KV cache pytree (see steps.serve_step for specs)."""
    dtype = dtype or _dtype(cfg)
    l = cfg.n_layers
    if cfg.mla:
        return {
            "ckv": jnp.zeros((l, batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((l, batch, max_len, cfg.qk_rope_dim), dtype),
        }
    kv, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((l, batch, max_len, kv, dh), dtype),
        "v": jnp.zeros((l, batch, max_len, kv, dh), dtype),
    }


def _attn_decode(p, cfg: LMConfig, x, cache_layer, cache_len):
    """x: (B, d) single token; returns (out (B, d), updated cache_layer)."""
    b, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = cache_len  # (B,) current position
    if cfg.mla:
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        kvr = cfg.kv_lora_rank
        cq = nn.rmsnorm(p["ln_q"], nn.dense(p["q_down"], x))
        q = nn.dense(p["q_up"], cq).reshape(b, h, nope + rope)
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        q_rope = apply_rope(
            q_rope[:, None], pos[:, None], theta=cfg.rope_theta
        )[:, 0]
        ckv_full = nn.dense(p["kv_down"], x)
        ckv_new = nn.rmsnorm(p["ln_kv"], ckv_full[..., :kvr])
        kr_new = apply_rope(
            ckv_full[..., kvr:][:, None, None], pos[:, None], theta=cfg.rope_theta
        )[:, 0, 0]
        ckv_c = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u[None], i, 0)
        )(cache_layer["ckv"], ckv_new.astype(cache_layer["ckv"].dtype), pos)
        kr_c = jax.vmap(
            lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u[None], i, 0)
        )(cache_layer["k_rope"], kr_new.astype(cache_layer["k_rope"].dtype), pos)
        # absorbed decode: q_eff[b,h,r] = sum_n q_nope[b,h,n] * k_up[r, h, n]
        k_up = p["k_up"]["w"].reshape(kvr, h, nope)
        q_eff = jnp.einsum("bhn,rhn->bhr", q_nope, k_up)
        s_len = ckv_c.shape[1]
        sc = jnp.einsum("bhr,bsr->bhs", q_eff, ckv_c.astype(jnp.float32))
        sc += jnp.einsum("bhr,bsr->bhs", q_rope, kr_c.astype(jnp.float32))
        sc = sc * ((nope + rope) ** -0.5)
        mask = jnp.arange(s_len)[None, :] <= pos[:, None]
        sc = jnp.where(mask[:, None, :], sc, -jnp.inf)
        w = jax.nn.softmax(sc, axis=-1)
        ctx = jnp.einsum("bhs,bsr->bhr", w, ckv_c.astype(jnp.float32))
        v_up = p["v_up"]["w"].reshape(kvr, h, vd)
        out = jnp.einsum("bhr,rhv->bhv", ctx, v_up).astype(x.dtype)
        out = nn.dense(p["wo"], out.reshape(b, h * vd))
        return out, {"ckv": ckv_c, "k_rope": kr_c}
    q = nn.dense(p["wq"], x).reshape(b, h, dh)
    k_new = nn.dense(p["wk"], x).reshape(b, kv, dh)
    v_new = nn.dense(p["wv"], x).reshape(b, kv, dh)
    q = apply_rope(
        q[:, None], pos[:, None], fraction=cfg.rope_fraction,
        theta=cfg.rope_theta,
    )[:, 0]
    k_new = apply_rope(
        k_new[:, None], pos[:, None], fraction=cfg.rope_fraction,
        theta=cfg.rope_theta,
    )[:, 0]
    upd = lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(c, u[None], i, 0)
    k_c = jax.vmap(upd)(cache_layer["k"], k_new.astype(cache_layer["k"].dtype), pos)
    v_c = jax.vmap(upd)(cache_layer["v"], v_new.astype(cache_layer["v"].dtype), pos)
    out = decode_attention(q, k_c, v_c, pos + 1)
    out = nn.dense(p["wo"], out.reshape(b, h * dh))
    return out, {"k": k_c, "v": v_c}


def lm_decode_step(params, cfg: LMConfig, token, cache, cache_len):
    """One greedy decode step.

    token: (B,) int32; cache: stacked per-layer pytree; cache_len: (B,).
    Returns (next_token (B,), logits (B, V), new cache).
    """
    x = params["embed"]["table"][token]

    n_dense = cfg.first_dense_layers if cfg.moe else 0

    # scan over layers carrying x, emitting updated caches
    def scan_stack(x, stack_params, stack_cache, moe_layer):
        def body(x, sl):
            layer_p = sl[0]
            cache_layer = sl[1]
            z = nn.rmsnorm(layer_p["ln1"], x)
            attn_out, new_cache = _attn_decode(
                layer_p["attn"], cfg, z, cache_layer, cache_len
            )
            h = x + attn_out
            z2 = nn.rmsnorm(layer_p["ln2"], h)
            if moe_layer:
                y, _ = moe_apply(layer_p["moe"], z2, top_k=cfg.top_k)
                h = h + y
            else:
                h = h + swiglu_apply(layer_p["ffn"], z2)
            return h, new_cache

        return jax.lax.scan(body, x, (stack_params, stack_cache))

    new_cache = {}
    if cfg.moe and n_dense:
        dense_cache = jax.tree.map(lambda c: c[:n_dense], cache)
        moe_cache = jax.tree.map(lambda c: c[n_dense:], cache)
        x, dc = scan_stack(x, params["dense_layers"], dense_cache, False)
        x, mc = scan_stack(x, params["layers"], moe_cache, True)
        new_cache = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), dc, mc
        )
    else:
        x, new_cache = scan_stack(x, params["layers"], cache, cfg.moe)

    x = nn.rmsnorm(params["final_norm"], x)
    logits = nn.dense(params["lm_head"], x).astype(jnp.float32)
    next_token = jnp.argmax(logits, axis=-1).astype(token.dtype)
    return next_token, logits, new_cache
