"""Optimizers + schedules + gradient compression (pure JAX, sharding-aware).

AdamW keeps fp32 moments (sharded like the params by GSPMD); Adafactor
keeps factored second moments (~4 bytes/param total) for the 100B+ configs
that cannot afford AdamW states on v5e.  ``compressed_psum`` implements
int8 chunk-quantized gradient all-reduce for the DP axes (beyond-paper
distributed-optimization feature).
"""
from .adamw import adamw_init, adamw_update  # noqa: F401
from .adafactor import adafactor_init, adafactor_update  # noqa: F401
from .schedule import cosine_schedule, linear_warmup  # noqa: F401
from .compress import compressed_psum, quantize_grads, dequantize_grads  # noqa: F401


def make_optimizer(name: str, lr, **kw):
    if name == "adamw":
        return (
            lambda params: adamw_init(params),
            lambda g, s, p, step: adamw_update(g, s, p, step, lr=lr, **kw),
        )
    if name == "adafactor":
        return (
            lambda params: adafactor_init(params),
            lambda g, s, p, step: adafactor_update(g, s, p, step, lr=lr, **kw),
        )
    raise ValueError(name)
