"""Adafactor (factored second moments) for memory-constrained giants.

For a (r, c) matrix the second moment is stored as row/col means
(r + c floats instead of r*c); vectors fall back to full moments.
~4 bytes/param optimizer state vs AdamW's 8 — the difference between
deepseek-v3-671b fitting on a v5e-256 pod or not (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adafactor_init", "adafactor_update"]


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init, params, is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(
    grads,
    state,
    params,
    step,
    *,
    lr,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    lr_t = lr(step) if callable(lr) else lr
    beta = 1.0 - (step + 1.0) ** -decay

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p.shape):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (
                vr[..., None]
                * vc[..., None, :]
                / jnp.maximum(
                    jnp.mean(vr, axis=-1)[..., None, None], eps
                )
            )
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            nv = {"vr": vr, "vc": vc}
        else:
            vf = beta * v["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(vf, eps))
            nv = {"v": vf}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        newp = p.astype(jnp.float32) - lr_t * (
            u + weight_decay * p.astype(jnp.float32)
        )
        return newp.astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    return (
        treedef.unflatten([o[0] for o in out]),
        {"v": treedef.unflatten([o[1] for o in out])},
        {},
    )
