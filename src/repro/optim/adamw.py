"""AdamW with fp32 moments and decoupled weight decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update"]


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(
    grads,
    state,
    params,
    step,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    lr_t = lr(step) if callable(lr) else lr
    # global grad-norm clip
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** (step + 1))
        vhat = v2 / (1 - b2 ** (step + 1))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm}
