"""int8 chunk-quantized gradient all-reduce (beyond-paper optimization).

DP gradient psum traffic dominates the collective term of LM training at
small per-device batch; quantizing gradients to int8 with per-chunk scales
cuts those bytes 4x at <0.5% relative error (verified in tests).  Used via
``shard_map`` around the DP axes: quantize -> psum(int32) -> dequantize.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_grads", "dequantize_grads", "compressed_psum"]

CHUNK = 1024


def _quantize(x):
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % CHUNK
    flat = jnp.pad(flat, (0, pad))
    ch = flat.reshape(-1, CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(ch), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(ch / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def quantize_grads(grads):
    return jax.tree.map(lambda g: _quantize(g), grads)


def dequantize_grads(qgrads, grads_like):
    return jax.tree.map(
        lambda qs, g: _dequantize(qs[0], qs[1], g.shape, g.size),
        qgrads,
        grads_like,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def compressed_psum(grads, axis_name):
    """psum a gradient pytree in int8 (int32 accumulation) over axis_name.

    Every shard quantizes against the *group-max* per-chunk scale (one tiny
    fp32 pmax first) so the int payloads are commensurable; the int8 sum is
    then exact up to one quantization step per shard.
    """

    def one(g):
        flat = g.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % CHUNK
        flat = jnp.pad(flat, (0, pad))
        ch = flat.reshape(-1, CHUNK)
        local = jnp.max(jnp.abs(ch), axis=1, keepdims=True)
        scale = jnp.maximum(jax.lax.pmax(local, axis_name), 1e-12) / 127.0
        q = jnp.clip(jnp.round(ch / scale), -127, 127).astype(jnp.int8)
        qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return _dequantize(qs, scale, g.shape, g.size)

    return jax.tree.map(one, grads)
