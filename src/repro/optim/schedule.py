"""Learning-rate schedules."""
import jax.numpy as jnp


def linear_warmup(peak, warmup_steps):
    def fn(step):
        return peak * jnp.minimum(1.0, (step + 1) / warmup_steps)

    return fn


def cosine_schedule(peak, warmup_steps, total_steps, floor=0.1):
    def fn(step):
        warm = jnp.minimum(1.0, (step + 1) / warmup_steps)
        t = jnp.clip(
            (step - warmup_steps) / jnp.maximum(1, total_steps - warmup_steps),
            0.0,
            1.0,
        )
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak * warm * cos

    return fn
