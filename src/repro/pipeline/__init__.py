"""Staged host-planning pipeline (DESIGN.md §3).

Public surface:

* :func:`plan_cannon` / :func:`plan_summa` / :func:`plan_oned` — cached
  pipeline drivers (ingest → relabel → decompose → pack → stage)
  returning a :class:`PlanArtifact`.
* :class:`PlanCache` / :func:`graph_digest` / :func:`default_cache` —
  the content-addressed plan cache (§10.5).
* :func:`count_triangles_many` — batched front-end: many graphs, one
  compiled engine call.
* :mod:`.stages` — the individual stage functions (vectorized packers,
  relabel composition) for callers assembling their own pipelines.
"""
from .artifact import PlanArtifact  # noqa: F401
from .batch import ManyResult, count_triangles_many  # noqa: F401
from .cache import (  # noqa: F401
    PlanCache,
    default_cache,
    graph_digest,
    set_default_cache,
)
from .delta import EdgeDelta, apply_delta  # noqa: F401
from .planner import plan_cannon, plan_oned, plan_summa  # noqa: F401
from .rebalance import (  # noqa: F401
    masked_critical_path,
    rebalance_stage,
    rebalance_trial_perm,
)
from .stages import relabel_stage  # noqa: F401

__all__ = [
    "EdgeDelta",
    "apply_delta",
    "relabel_stage",
    "rebalance_stage",
    "rebalance_trial_perm",
    "masked_critical_path",
    "PlanArtifact",
    "PlanCache",
    "ManyResult",
    "count_triangles_many",
    "default_cache",
    "set_default_cache",
    "graph_digest",
    "plan_cannon",
    "plan_summa",
    "plan_oned",
]
