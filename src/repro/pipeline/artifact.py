"""The single product of the host planning pipeline (DESIGN.md §3).

A :class:`PlanArtifact` bundles everything one planned graph needs to be
counted repeatedly: the relabeled host graph, the composed relabeling
permutation, the device-ready plan (``TCPlan`` / ``SummaPlan`` /
``OneDPlan``), per-stage wall times, and a memo space where the runners
park derived state (staged ``jnp`` arrays, compiled engine fns, tile
plans) so a cache hit skips *all* per-call host work — planning, host→
device staging, and retracing.

Artifacts are what the schedule runners and engine builders consume;
``repro.core.plan.as_plan`` coerces an artifact (or a raw plan) to its
plan object, so every ``build_*_fn`` accepts either.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from ..core.graph import Graph

__all__ = ["PlanArtifact"]


@dataclasses.dataclass
class PlanArtifact:
    """One planned graph, ready for repeated counting.

    ``kind`` names the plan family ("cannon" | "summa" | "oned");
    ``digest`` is the content digest of the *input* graph (pre-relabel),
    ``key`` the full cache key this artifact is stored under.
    """

    kind: str
    digest: str
    key: Tuple
    graph: Graph  # relabeled graph actually planned
    perm: Optional[np.ndarray]  # composed relabeling, old id -> new id
    plan: Any  # TCPlan | SummaPlan | OneDPlan
    stage_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)
    cache_hit: bool = False
    # skip-aware rebalance search report (DESIGN.md §4.3): trial history,
    # winning seed, baseline/best masked critical path, skipped steps;
    # None when the plan was not rebalanced.  The trials knob is part of
    # ``key``, so rebalanced and plain artifacts never collide.
    rebalance: Optional[dict] = None
    # planner knobs this artifact was built with, recorded so the delta
    # path (DESIGN.md §4.7) can re-pack stages or rebase with identical
    # flags; None on artifacts from pre-delta code paths.
    config: Optional[dict] = None
    # delta lineage: dict(root_digest, chain, depth) joining the cache
    # key for incrementally-derived artifacts; None for cold plans.
    lineage: Optional[dict] = None
    # per-delta report (dirty blocks/cells, replanned stages, rebased,
    # level) attached by ``apply_delta``; None for cold plans.
    delta_report: Optional[dict] = None
    # re-stage handoff: (prev host arrays, prev staged jnp arrays) from
    # the parent artifact, consumed lazily by ``staged()`` so clean
    # device buffers are reused instead of re-uploaded.
    restage_from: Optional[Tuple[Dict, Dict]] = dataclasses.field(
        default=None, repr=False
    )
    _memo: Dict = dataclasses.field(default_factory=dict, repr=False)
    _memo_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )

    # ------------------------------------------------------------------
    def device_arrays(self) -> Dict[str, np.ndarray]:
        return self.plan.device_arrays()

    @property
    def compact(self):
        """The plan's staged :class:`~repro.core.plan.CompactSchedule`
        (globally-live steps + fused hop vector), or ``None`` when the
        compaction stage was off or had no mask to work from."""
        return getattr(self.plan, "compact", None)

    @property
    def autotune(self) -> Optional[dict]:
        """The deterministic kernel-shape autotune report (chunk,
        ``d_small``/``n_long`` split, ``tail_heavy``), or ``None``."""
        return getattr(self.plan, "autotune", None)

    @property
    def hubsplit(self) -> Optional[dict]:
        """The hub-split stage report (``h0``, ``hub_rows``,
        ``hub_nnz_frac``, … — DESIGN.md §4.8), or ``None`` when the
        stage was off or no row crossed the threshold."""
        hub = getattr(self.plan, "hub", None)
        return None if hub is None else hub.report()

    def memo(self, key, build: Callable):
        """Build-once storage for derived per-artifact state.

        Used by the runners for staged arrays, compiled engine fns (keyed
        by mesh/method/dtype), tile plans, and dense blocks — everything
        that would otherwise be recomputed or retraced on every count of
        an already-planned graph.  Locked, so serving threads sharing a
        cached artifact build (and trace/compile) each entry once.
        """
        with self._memo_lock:
            if key not in self._memo:
                self._memo[key] = build()
            return self._memo[key]

    def staged(self) -> Dict:
        """Device-staged (``jnp``) plan arrays, memoized (the pipeline's
        ``stage`` step); records its first-call wall time.

        Delta-derived artifacts carry ``restage_from`` — the parent's
        host/staged array pairs — and go through the engine re-stage
        path, which keeps the parent's device buffer for every array the
        splice left unchanged (DESIGN.md §4.7)."""
        import time

        import jax.numpy as jnp

        def build():
            t0 = time.perf_counter()
            handoff = self.restage_from
            if handoff is not None:
                from ..core.engine import restage_device_arrays

                out, reused = restage_device_arrays(
                    handoff[0], handoff[1], self.device_arrays()
                )
                self.stage_seconds["stage_reused_buffers"] = float(reused)
            else:
                out = {
                    k: jnp.asarray(v) for k, v in self.device_arrays().items()
                }
            self.stage_seconds["stage"] = time.perf_counter() - t0
            return out

        return self.memo("staged_arrays", build)

    def release(self) -> None:
        """Drop memoized device state (staged buffers, compiled fns, tile
        plans) and the re-stage handoff.  Called by ``PlanCache`` on LRU
        eviction so pinned device memory does not outlive the cache entry
        while serving threads still hold the artifact; the next use
        simply rebuilds the memo entries."""
        with self._memo_lock:
            self._memo.clear()
            self.restage_from = None
