"""Batched front-end: count many graphs in one compiled engine call.

``count_triangles_many`` pads a list of graphs onto shared shapes —
vertex counts lifted to the batch maximum (isolated vertices are free),
index/task arrays padded to the batch-wide maxima — stacks every device
array on an unsharded leading batch axis, and runs the whole batch
through the engine's batched builder: **one** compile and **one**
dispatch for the batch, versus one of each per graph in a Python loop.

The assembled program (stacked staged arrays + compiled fn) is itself
cached under the tuple of graph digests, so a serving process that sees
the same batch again skips planning, padding, staging, *and* retracing.
The padding overhead of batching is measured and reported
(``ManyResult.padding_overhead``, DESIGN.md §10.5), never hidden.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from .. import compat
from ..core.graph import Graph
from .cache import PlanCache, default_cache, graph_digest
from .planner import relabel_cached
from .stages import pack_oned_plan, pack_summa_plan, pack_tc_plan

__all__ = ["ManyResult", "count_triangles_many"]

_CSR_METHODS = ("search", "search2", "global")


@dataclasses.dataclass
class ManyResult:
    """Per-graph triangle counts plus batch accounting."""

    triangles: List[int]
    schedule: str
    method: str
    grid: tuple
    batch: int
    plan_seconds: float  # planning + padding + staging (0-ish on cache hit)
    count_seconds: float
    padding_overhead: float  # stacked cells / sum(per-graph cells) - 1
    cache_hit: bool


@dataclasses.dataclass
class _BatchProgram:
    fn: object
    staged: Dict
    grid: tuple
    padding_overhead: float


def _pad_last(arr: np.ndarray, size: int, fill) -> np.ndarray:
    """Pad the last axis of ``arr`` up to ``size`` with ``fill``."""
    if arr.shape[-1] == size:
        return arr
    out = np.full(arr.shape[:-1] + (size,), fill, dtype=arr.dtype)
    out[..., : arr.shape[-1]] = arr
    return out


def _stack(plans, pads: Dict[str, tuple]) -> Dict[str, np.ndarray]:
    """Stack per-graph device arrays, padding each named array's last
    axis to the batch-wide size with its sentinel/zero fill."""
    out = {}
    for name, (size, fill) in pads.items():
        out[name] = np.stack(
            [_pad_last(p.device_arrays()[name], size, fill) for p in plans]
        )
    return out


def _padding_overhead(stacked: Dict, plans) -> float:
    batched = sum(v.size for v in stacked.values())
    single = sum(
        a.size for p in plans for a in p.device_arrays().values()
    )
    return float(batched / max(1, single) - 1.0)


def _build_batch_program(
    graphs: Sequence[Graph],
    mesh,
    *,
    q: int,
    schedule: str,
    method: str,
    chunk: int,
    reorder: bool,
    cyclic_p: Optional[int],
    probe_shorter: bool,
    count_dtype,
    cache: PlanCache,
) -> _BatchProgram:
    import jax.numpy as jnp

    # relabel each graph on its own vertex set (degree order must not see
    # the padding vertices), then lift all graphs to the shared n.
    relabeled = [
        relabel_cached(
            g, graph_digest(g), reorder=reorder, cyclic_p=cyclic_p,
            cache=cache,
        )[0]
        for g in graphs
    ]
    n_max = max(g.n for g in relabeled)
    lifted = [
        g if g.n == n_max else Graph(n=n_max, edges=g.edges, name=g.name)
        for g in relabeled
    ]

    if schedule == "cannon":
        from ..core.cannon import build_cannon_fn
        from ..core.plan import bucketize_plan

        plans = [
            pack_tc_plan(
                g, q, skew=True, chunk=chunk, with_stats=False,
                keep_blocks=(method == "search2"),
                aug_keys=(method in ("global", "search2")),
            )
            for g in lifted
        ]
        if method == "search2":
            plans = [bucketize_plan(p) for p in plans]
        nnz_pad = max(p.nnz_pad for p in plans)
        tmax = max(p.tmax for p in plans)
        nb = plans[0].nb
        pads = dict(
            a_indptr=(nb + 1, 0),
            a_indices=(nnz_pad, nb),
            b_indptr=(nb + 1, 0),
            b_indices=(nnz_pad, nb),
            m_ti=(tmax, 0),
            m_tj=(tmax, 0),
            m_cnt=(plans[0].m_cnt.shape[-1], 0),
        )
        if plans[0].step_keep is not None:
            pads["step_keep"] = (q, False)  # (q, q, q) per graph, same q
        if plans[0].b_aug is not None:
            # tail-pad with the maximal key (row nb, col nb) so every
            # block's staged key array stays sorted after batch padding
            pads["b_aug"] = (nnz_pad, (nb + 1) * (nb + 1) - 1)
        stacked = _stack(plans, pads)
        rep = dataclasses.replace(
            plans[0],
            nnz_pad=nnz_pad,
            tmax=tmax,
            dmax=max(p.dmax for p in plans),
            chunk=min(chunk, tmax),
            stats=None,
            blocks=None,
        )
        if method == "search2":
            rep.n_long = max(p.n_long for p in plans)
            rep.d_small = plans[0].d_small
        fn = build_cannon_fn(
            rep, mesh, method=method, probe_shorter=probe_shorter,
            count_dtype=count_dtype, batched=True,
        )
        grid = (q, q)
    elif schedule == "summa":
        from ..core.summa import build_summa_fn

        names = list(mesh.axis_names)
        r, c = mesh.shape[names[-2]], mesh.shape[names[-1]]
        plans = [pack_summa_plan(g, r, c, chunk=chunk) for g in lifted]
        a_nnz_pad = max(p.a_nnz_pad for p in plans)
        b_nnz_pad = max(p.b_nnz_pad for p in plans)
        tmax = max(p.tmax for p in plans)
        nb_c = plans[0].nb_c
        pads = dict(
            a_indptr=(plans[0].nb_r + 1, 0),
            a_indices=(a_nnz_pad, nb_c),
            b_indptr=(nb_c + 1, 0),
            b_indices=(b_nnz_pad, nb_c),
            m_ti=(tmax, 0),
            m_tj=(tmax, 0),
            m_cnt=(plans[0].m_cnt.shape[-1], 0),
        )
        if plans[0].step_keep is not None:
            pads["step_keep"] = (c, False)  # (r, c, c) per graph
        stacked = _stack(plans, pads)
        rep = dataclasses.replace(
            plans[0],
            a_nnz_pad=a_nnz_pad,
            b_nnz_pad=b_nnz_pad,
            tmax=tmax,
            dmax=max(p.dmax for p in plans),
            chunk=min(chunk, tmax),
        )
        fn = build_summa_fn(
            rep, mesh, method=method, probe_shorter=probe_shorter,
            count_dtype=count_dtype, batched=True,
        )
        grid = (r, c)
    elif schedule == "oned":
        from ..core.onedim import build_oned_fn

        p_ring = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        flat_mesh = compat.make_mesh((p_ring,), ("flat",))
        plans = [pack_oned_plan(g, p_ring, chunk=chunk) for g in lifted]
        nnz_pad = max(p.nnz_pad for p in plans)
        gmax = max(p.gmax for p in plans)
        pads = dict(
            indptr=(plans[0].nb + 1, 0),
            indices=(nnz_pad, n_max + 1),
            t_i=(gmax, 0),
            t_j=(gmax, 0),
            t_cnt=(plans[0].t_cnt.shape[-1], 0),
        )
        if plans[0].step_keep is not None:
            pads["step_keep"] = (p_ring, False)  # (p, p) per graph
        stacked = _stack(plans, pads)
        rep = dataclasses.replace(
            plans[0],
            nnz_pad=nnz_pad,
            gmax=gmax,
            dmax=max(p.dmax for p in plans),
            chunk=min(chunk, gmax),
        )
        fn = build_oned_fn(
            rep, flat_mesh, method=method, probe_shorter=probe_shorter,
            count_dtype=count_dtype, batched=True,
        )
        grid = (p_ring,)
    else:
        raise ValueError(
            f"count_triangles_many supports schedules cannon/summa/oned, "
            f"got {schedule!r}"
        )

    overhead = _padding_overhead(stacked, plans)
    staged = {k: jnp.asarray(v) for k, v in stacked.items()}
    return _BatchProgram(
        fn=fn, staged=staged, grid=grid, padding_overhead=overhead
    )


def count_triangles_many(
    graphs: Sequence[Graph],
    mesh=None,
    *,
    q: Optional[int] = None,
    schedule: str = "cannon",
    method: str = "search",
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    probe_shorter: bool = True,
    count_dtype=None,
    cache: Optional[PlanCache] = None,
) -> ManyResult:
    """Count triangles of many graphs with one compiled engine call.

    Results are exactly the per-graph ``count_triangles`` totals (padding
    to shared shapes never changes a count, only adds measured overhead).
    ``method`` must be a CSR kernel (``search``/``search2``/``global``);
    the dense and tile operand stores are per-graph paths.
    """
    graphs = list(graphs)
    assert graphs, "count_triangles_many needs at least one graph"
    if method not in _CSR_METHODS:
        raise ValueError(
            f"batched counting supports CSR methods {_CSR_METHODS}, "
            f"got {method!r}"
        )
    if method == "search2" and schedule != "cannon":
        raise ValueError("method 'search2' is a cannon-schedule path")

    from ..runtime import faultinject

    faultinject.fire("plan_stage", kind="many")
    t0 = time.perf_counter()
    if mesh is None:
        from ..core.api import make_grid_mesh

        q = q or 1
        mesh = make_grid_mesh(q)
    else:
        names = list(mesh.axis_names)
        q = mesh.shape[names[-1]]
    if count_dtype is None:
        count_dtype = compat.default_count_dtype()
    cache = cache if cache is not None else default_cache()

    digests = tuple(graph_digest(g) for g in graphs)
    key = (
        "many", schedule, method, mesh, q, chunk, reorder, cyclic_p,
        probe_shorter, str(np.dtype(count_dtype)), digests,
    )
    prog = cache.get(key)
    cache_hit = prog is not None
    if not cache_hit:
        prog = _build_batch_program(
            graphs, mesh,
            q=q, schedule=schedule, method=method, chunk=chunk,
            reorder=reorder, cyclic_p=cyclic_p,
            probe_shorter=probe_shorter, count_dtype=count_dtype,
            cache=cache,
        )
        cache.put(key, prog)
    t1 = time.perf_counter()

    faultinject.fire("device_stage")
    totals = np.asarray(prog.fn(**prog.staged))
    counts = [
        compat.check_count_overflow(int(t), count_dtype) for t in totals
    ]
    t2 = time.perf_counter()

    return ManyResult(
        triangles=counts,
        schedule=schedule,
        method=method,
        grid=prog.grid,
        batch=len(graphs),
        plan_seconds=t1 - t0,
        count_seconds=t2 - t1,
        padding_overhead=prog.padding_overhead,
        cache_hit=cache_hit,
    )
