"""Content-addressed plan cache (DESIGN.md §10.5).

Planning is addressed by *content*, not identity: the cache key starts
with :func:`graph_digest` — a blake2b over the canonicalized edge set —
so two structurally identical graphs hit the same entry no matter how
they were constructed, and any edge edit changes the digest and misses.
The rest of the key is the full planning configuration supplied by the
planner drivers: kind, grid, chunk, relabel options, mask/stat flags,
``rebalance_trials``, and the PR-5 stage knobs ``compact`` /
``autotune`` / ``aug_keys`` — every stage that changes the packed
arrays or the staged schedule is a key component, so (for example) a
compacted σ-re-packed artifact can never be served to a
``compact=False`` caller.  Derived *results* (the chosen σ, the
autotuned shapes) are deliberately **not** keyed: they are pure
functions of the keyed inputs.

One :class:`PlanCache` instance stores every pipeline product —
relabel results, plan artifacts, and batched programs — under
namespaced keys, so ``clear()`` is a single switch and the hit/miss
stats describe the whole planning stack.  The default process-wide
cache (:func:`default_cache`) is what ``count_triangles`` uses when no
cache is passed; serving processes can hold their own instance.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Hashable, Optional

import numpy as np

from ..core.graph import Graph

__all__ = ["PlanCache", "graph_digest", "default_cache", "set_default_cache"]


def graph_digest(graph: Graph) -> str:
    """Content digest of a graph: blake2b over (n, sorted edge keys).

    Canonicalizes via the packed key ``lo * n + hi`` (edges are already
    stored as ``(min, max)``) sorted ascending, so edge *order* never
    affects the digest — only the edge *set* and vertex count do.
    """
    n = np.int64(graph.n)
    key = graph.edges[:, 0] * n + graph.edges[:, 1]
    # Graph.from_edges emits keys already ascending (np.unique); only
    # hand-built edge lists pay the sort
    if key.size and not np.all(key[1:] > key[:-1]):
        key = np.sort(key)
    h = hashlib.blake2b(digest_size=16)
    h.update(int(n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(key).tobytes())
    return h.hexdigest()


class PlanCache:
    """Thread-safe LRU over pipeline products.

    ``maxsize=0`` disables caching (every ``get`` misses, ``put`` is a
    no-op) — useful for benchmarking the cold path.

    Eviction is entry-count-based, not byte-based.  Cached artifacts pin
    whatever they have memoized — staged *device* arrays and compiled
    executables — so LRU eviction calls ``value.release()`` (or the
    supplied ``on_evict`` hook) *outside the lock*: the pinned device
    memory is dropped immediately even while serving threads still hold
    Python references to the artifact; they simply re-stage on next use.
    Size ``maxsize`` to the working set of distinct (graph, config)
    pairs the process actually serves; for one-shot batch jobs prefer
    ``maxsize=0``.
    """

    def __init__(self, maxsize: int = 8, on_evict=None):
        self.maxsize = int(maxsize)
        self._on_evict = on_evict
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _release(self, value: Any) -> None:
        if self._on_evict is not None:
            self._on_evict(value)
            return
        release = getattr(value, "release", None)
        if callable(release):
            release()

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.maxsize <= 0:
            return
        evicted = []
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                _, old = self._entries.popitem(last=False)
                self.evictions += 1
                evicted.append(old)
        for old in evicted:
            if old is not value:  # self-eviction of a fresh put keeps it usable
                self._release(old)

    def memo(self, key: Hashable, build) -> Any:
        """Get-or-build: return the cached value, building (outside the
        lock) and storing it on a miss.  NOTE: concurrent misses may both
        build; the last ``put`` wins — acceptable for pipeline products,
        which are pure functions of their key."""
        hit = self.get(key)
        if hit is not None:
            return hit
        out = build()
        self.put(key, out)
        return out

    def clear(self) -> None:
        with self._lock:
            dropped = list(self._entries.values())
            self._entries.clear()
        for old in dropped:
            self._release(old)

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return dict(
            size=len(self._entries),
            maxsize=self.maxsize,
            hits=self.hits,
            misses=self.misses,
            evictions=self.evictions,
        )


_DEFAULT = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide cache used when callers pass ``cache=None``."""
    return _DEFAULT


def set_default_cache(cache: PlanCache) -> PlanCache:
    """Swap the process-wide cache (returns the previous one)."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, cache
    return prev
