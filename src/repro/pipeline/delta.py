"""Delta-aware planning: incremental re-plans over edge deltas
(DESIGN.md §4.7).

The cold pipeline is content-addressed — *any* edge edit changes the
graph digest and forces ingest → relabel → decompose → pack → stage from
scratch.  Streaming workloads (Tangwongsan, Pavan & Tirthapura,
arXiv:1308.2166) mutate one graph continuously, so this module gives
every pipeline stage an incremental contract:

* :class:`EdgeDelta` — a batched, canonicalized add/remove edge list
  with its own content digest;
* :func:`apply_delta` — ``PlanArtifact × EdgeDelta → PlanArtifact``,
  choosing the cheapest correct level per delta:

  - **splice** (Cannon): diff block membership under the existing
    cyclic decomposition to find the *dirty* canonical blocks, re-sort
    only their edges, splice the re-packed rows into copies of the
    staged CSR/task/key arrays via the inverse σ placement, recompute
    probe stats and ``step_keep`` only for dirty (device, shift) cells,
    and reuse the compacted live-step schedule (plus the parent's
    compiled engine fns) verbatim when the live-step set did not grow;
  - **repack** (fallback): stage-local re-pack of the mutated graph
    with the parent's relabeling permutation and σ kept verbatim —
    taken when a padded dimension would overflow, too many blocks are
    dirty for splicing to pay, or the plan kind has no splice path
    (SUMMA / 1D);
  - **rebase** (periodic): a cold re-plan through the planner drivers
    every ``rebase_every`` deltas, restoring the degree ordering and
    padding tightness that drift under repeated splices; the returned
    artifact composes the relabeling permutations so callers keep
    addressing vertices by their original ids.

Cache lineage: delta-derived artifacts are cached under
``(kind, "delta", root digest, (δ₁, …, δₖ)) + config tail`` — the base
digest plus the chain of delta digests — so replaying the same stream
hits; a rebase starts a fresh chain at the new root digest.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Optional, Tuple

import numpy as np

from ..core.decomp import BlockCSR, blocks_from_coo
from ..core.graph import Graph
from ..core.plan import (
    INT,
    PlanStats,
    bucketize_plan,
    compact_live_steps,
    host_aug_keys,
)
from .artifact import PlanArtifact
from .cache import PlanCache, default_cache
from .hubsplit import hubsplit_stage
from .stages import (
    autotune_oned_plan,
    autotune_summa_plan,
    autotune_tc_plan,
    cannon_step_keep,
    compact_stage,
    pack_oned_plan,
    pack_summa_plan,
    pack_tc_plan,
)

__all__ = ["EdgeDelta", "apply_delta"]


def _canon_pairs(pairs) -> np.ndarray:
    """Canonicalize an edge list to deduplicated, sorted ``(min, max)``
    rows: the same normal form :meth:`Graph.from_edges` uses, so delta
    digests and set arithmetic are order-insensitive."""
    arr = np.asarray(
        pairs if pairs is not None else np.zeros((0, 2)), dtype=np.int64
    ).reshape(-1, 2)
    keep = arr[:, 0] != arr[:, 1]
    arr = arr[keep]
    lo = np.minimum(arr[:, 0], arr[:, 1])
    hi = np.maximum(arr[:, 0], arr[:, 1])
    order = np.lexsort((hi, lo))
    lo, hi = lo[order], hi[order]
    if lo.size:
        first = np.ones(lo.size, dtype=bool)
        first[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
        lo, hi = lo[first], hi[first]
    return np.stack([lo, hi], axis=1)


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """A batched edge delta: edges to add and edges to remove.

    Both lists are canonicalized (``(min, max)``, deduplicated, self
    loops dropped) at construction; an edge appearing in both lists is
    an error — the delta would be order-dependent.  Vertex ids are in
    the *original* (pre-relabel) id space of the graph the stream is
    mutating; :func:`apply_delta` maps them through the artifact's
    composed permutation.
    """

    add: np.ndarray  # (ka, 2) int64, canonical
    remove: np.ndarray  # (kr, 2) int64, canonical

    def __init__(self, add=None, remove=None):
        a = _canon_pairs(add)
        r = _canon_pairs(remove)
        if a.shape[0] and r.shape[0]:
            span = np.int64(max(a.max(initial=0), r.max(initial=0))) + 1
            both = np.intersect1d(
                a[:, 0] * span + a[:, 1], r[:, 0] * span + r[:, 1]
            )
            if both.size:
                raise ValueError(
                    f"{both.size} edge(s) appear in both add and remove"
                )
        object.__setattr__(self, "add", a)
        object.__setattr__(self, "remove", r)

    # ------------------------------------------------------------------
    @property
    def k(self) -> int:
        """Total number of edge edits in the batch."""
        return int(self.add.shape[0] + self.remove.shape[0])

    def digest(self) -> str:
        """Content digest of the delta (joins the cache lineage key)."""
        h = hashlib.blake2b(digest_size=16)
        h.update(np.ascontiguousarray(self.add).tobytes())
        h.update(b"|")
        h.update(np.ascontiguousarray(self.remove).tobytes())
        return h.hexdigest()

    def relabeled(self, perm: Optional[np.ndarray]) -> "EdgeDelta":
        """The same delta with vertices renamed by ``perm`` (old → new)."""
        if perm is None:
            return self
        perm = np.asarray(perm, dtype=np.int64)
        return EdgeDelta(add=perm[self.add], remove=perm[self.remove])

    def apply_to(self, graph: Graph) -> Graph:
        """Host-side reference application: ``G ± Δ`` as a new graph."""
        g2, _, _ = _merge(graph, self)
        return g2

    @staticmethod
    def random_flips(graph: Graph, k: int, seed: int) -> "EdgeDelta":
        """Deterministic delta of ``k`` random edge flips: a sampled pair
        already present becomes a removal, an absent one an addition
        (the ``delta:`` graph-spec's mutation model)."""
        from ..core.generators import random_edge_flips

        add, remove = random_edge_flips(graph, k, seed)
        return EdgeDelta(add=add, remove=remove)


def _edge_keys(edges: np.ndarray, n: int) -> np.ndarray:
    return edges[:, 0] * np.int64(n) + edges[:, 1]


def _merge(graph: Graph, delta: EdgeDelta):
    """Apply ``delta`` to ``graph``: returns the merged graph plus the
    *effective* additions / removals (adds already present and removes
    already absent are dropped — the merge is idempotent)."""
    n = graph.n
    for arr, what in ((delta.add, "add"), (delta.remove, "remove")):
        if arr.size and (arr.min() < 0 or arr.max() >= n):
            raise ValueError(
                f"delta {what} list references vertices outside 0..{n - 1}"
            )
    base = _edge_keys(graph.edges, n)
    if base.size and not np.all(base[1:] > base[:-1]):
        order = np.argsort(base)
        base = base[order]
    add_k = _edge_keys(delta.add, n)
    rem_k = _edge_keys(delta.remove, n)

    def member(keys):
        if base.size == 0:
            return np.zeros(keys.shape, dtype=bool)
        pos = np.searchsorted(base, keys)
        return (pos < base.size) & (
            base[np.minimum(pos, base.size - 1)] == keys
        )

    eff_add = add_k[~member(add_k)]
    eff_rem = rem_k[member(rem_k)]

    kept = base
    if eff_rem.size:
        kept = base[~np.isin(base, eff_rem, assume_unique=True)]
    merged = kept
    if eff_add.size:
        merged = np.insert(kept, np.searchsorted(kept, eff_add), eff_add)
    edges = np.stack([merged // n, merged % n], axis=1)
    g2 = Graph(n=n, edges=edges, name=graph.name + "+d")

    def unkey(k):
        return np.stack([k // n, k % n], axis=1)

    return g2, unkey(eff_add), unkey(eff_rem)


# ======================================================================
# apply_delta: the incremental re-plan ladder
# ======================================================================
def apply_delta(
    artifact: PlanArtifact,
    delta: EdgeDelta,
    *,
    cache: Optional[PlanCache] = None,
    rebase_every: int = 8,
    dirty_limit: float = 0.5,
) -> PlanArtifact:
    """Re-plan ``artifact`` for ``graph ± delta`` incrementally.

    Returns a new :class:`PlanArtifact` whose ``delta_report`` records
    the chosen level (``"splice"`` / ``"repack"`` / ``"rebase"`` /
    ``"noop"``), the dirty block/cell fractions, which stages were
    re-run, and whether the compiled-fn memo could be inherited.  The
    result is cached under the delta lineage key (base digest + delta
    digest chain), so replaying a stream hits the cache.

    ``rebase_every`` bounds the lineage depth: after that many
    incremental applications the next delta triggers a cold re-plan
    (rebase) restoring degree ordering and padding tightness.
    ``dirty_limit`` is the dirty-block fraction above which splicing
    falls back to the stage-local repack.
    """
    if artifact.config is None:
        raise ValueError(
            "artifact carries no planner config (built by a pre-delta "
            "code path); re-plan through plan_cannon/plan_summa/plan_oned"
        )
    cache = cache if cache is not None else default_cache()
    cfg = artifact.config
    lineage = artifact.lineage or dict(
        root_digest=artifact.digest, chain=(), depth=0
    )
    chain = tuple(lineage["chain"]) + (delta.digest(),)
    # config tail of the cache key: cold keys are (kind, digest) + tail,
    # lineage keys (kind, "delta", root, chain) + tail
    tail = tuple(
        artifact.key[4:] if artifact.lineage is not None
        else artifact.key[2:]
    )
    key = (artifact.kind, "delta", lineage["root_digest"], chain) + tail
    hit = cache.get(key)
    if hit is not None:
        hit.cache_hit = True
        return hit

    t0 = time.perf_counter()
    d2 = delta.relabeled(artifact.perm)
    g2, eff_add, eff_rem = _merge(artifact.graph, d2)
    eff = np.concatenate([eff_add, eff_rem], axis=0)

    if eff.shape[0] == 0:
        art = dataclasses.replace(
            artifact,
            key=key,
            cache_hit=False,
            lineage=dict(lineage, chain=chain),
            delta_report=_report(
                "noop", 0, 0.0, None, None, [], False, lineage["depth"],
                eff_add, eff_rem, True,
            ),
        )
        cache.put(key, art)
        return art

    depth = int(lineage["depth"]) + 1
    hub_side = getattr(artifact.plan, "hub", None)
    if depth > int(rebase_every):
        art = _rebase(artifact, g2, cfg, cache, key, eff, eff_add, eff_rem)
    elif hub_side is not None and not getattr(hub_side, "aligned", True):
        # the rebalance stage relabeled the residual *after* the split,
        # so the hub side's internal ids no longer match the artifact's
        # id space and the parent cut cannot be reused positionally —
        # rebase (cold re-plan, fresh cut) and say so in the report
        art = _rebase(artifact, g2, cfg, cache, key, eff, eff_add, eff_rem)
        art.delta_report["reason"] = "hub_split_misaligned"
    else:
        art = None
        splice_refused = None
        if artifact.kind == "cannon" and cfg.get("skew", True):
            if hub_side is not None:
                # the splice edits packed residual blocks in place; a
                # delta edge landing on a split hub row would silently
                # corrupt the residual/hub partition (the hub arrays
                # have no splice path) — refuse loudly, repack instead
                splice_refused = "hub_split"
            else:
                from ..runtime import faultinject

                faultinject.fire("delta_splice")
                art = _splice_cannon(
                    artifact, g2, eff, eff_add, eff_rem, depth, chain,
                    dirty_limit, lineage,
                )
        if art is None:
            art = _repack(
                artifact, g2, cfg, eff, eff_add, eff_rem, depth, chain,
                lineage,
            )
            if splice_refused is not None:
                art.delta_report["reason"] = splice_refused
    art.key = key
    art.stage_seconds["apply_delta"] = time.perf_counter() - t0
    cache.put(key, art)
    return art


def _report(
    level, dirty_blocks, dirty_block_frac, dirty_cells, dirty_cell_frac,
    replanned, rebased, depth, eff_add, eff_rem, fn_inherited,
):
    return dict(
        level=level,
        dirty_blocks=int(dirty_blocks),
        dirty_block_fraction=float(dirty_block_frac),
        dirty_cells=None if dirty_cells is None else int(dirty_cells),
        dirty_cell_fraction=(
            None if dirty_cell_frac is None else float(dirty_cell_frac)
        ),
        replanned_stages=list(replanned),
        rebased=bool(rebased),
        depth=int(depth),
        edges_added=int(eff_add.shape[0]),
        edges_removed=int(eff_rem.shape[0]),
        fn_inherited=bool(fn_inherited),
    )


def _dirty_grid(eff: np.ndarray, r: int, c: int) -> np.ndarray:
    dirty = np.zeros((r, c), dtype=bool)
    dirty[eff[:, 0] % r, eff[:, 1] % c] = True
    return dirty


def _lineage_digest(root: str, chain: Tuple[str, ...]) -> str:
    return f"{root}+{len(chain)}d:{chain[-1][:8]}" if chain else root


def _derived_artifact(artifact, g2, plan2, depth, chain, lineage, report,
                      inherit_fns):
    """Assemble the delta-derived artifact: fresh memo space seeded with
    the parent's compiled fns when the engine statics survived, plus the
    re-stage handoff so clean device buffers skip the re-upload."""
    art = PlanArtifact(
        kind=artifact.kind,
        digest=_lineage_digest(lineage["root_digest"], chain),
        key=artifact.key,  # overwritten by apply_delta with the lineage key
        graph=g2,
        perm=artifact.perm,
        plan=plan2,
        rebalance=artifact.rebalance,
        config=artifact.config,
        lineage=dict(
            root_digest=lineage["root_digest"], chain=chain, depth=depth
        ),
        delta_report=report,
    )
    if inherit_fns:
        with artifact._memo_lock:
            inherited = {
                k: v
                for k, v in artifact._memo.items()
                if isinstance(k, tuple) and k and k[0] == "fn"
            }
        art._memo.update(inherited)
    with artifact._memo_lock:
        staged = artifact._memo.get("staged_arrays")
    if staged is not None:
        art.restage_from = (artifact.plan.device_arrays(), staged)
    return art


# ----------------------------------------------------------------------
# level 0: Cannon block splice
# ----------------------------------------------------------------------
def _splice_cannon(
    artifact, g2, eff, eff_add, eff_rem, depth, chain, dirty_limit, lineage
):
    """Splice re-packed dirty blocks into copies of the staged arrays.

    Placement inversion: under the σ-skewed placement ``a[x, y] =
    c[x, σ[(x+y)%q]]`` / ``b[x, y] = c[y, σ[(x+y)%q]]``, the canonical
    block ``(bx, bz)`` appears exactly once in each operand — at
    ``a[bx, (σ⁻¹[bz]-bx)%q]`` and ``b[(σ⁻¹[bz]-bx)%q, bx]`` — and the
    task/mask arrays sit at ``(bx, bz)`` directly.  Returns ``None``
    when a padded dimension would overflow or too many blocks are dirty
    (caller falls back to the stage-local repack).
    """
    plan = artifact.plan
    q, nb, nnz_pad, tmax = plan.q, plan.nb, plan.nnz_pad, plan.tmax
    sp = (
        np.asarray(plan.skew_perm, dtype=np.int64)
        if plan.skew_perm is not None
        else np.arange(q, dtype=np.int64)
    )
    inv = np.argsort(sp)

    dirty = _dirty_grid(eff, q, q)
    n_dirty = int(dirty.sum())
    if n_dirty > dirty_limit * q * q:
        return None
    dirty_bids = np.flatnonzero(dirty.ravel())
    nd = dirty_bids.size

    # --- re-sort only the dirty blocks' edges (the decompose stage,
    # restricted): one lexsort over the touched fraction of the graph
    i, j = g2.edges[:, 0], g2.edges[:, 1]
    bid = (i % q) * q + (j % q)
    sel = dirty.ravel()[bid]
    pos = np.searchsorted(dirty_bids, bid[sel])  # dense dirty-block index
    li, lj = i[sel] // q, j[sel] // q
    order = np.lexsort((lj, li, pos))
    pos_s, li_s, lj_s = pos[order], li[order], lj[order]

    counts_d = np.bincount(pos_s, minlength=nd)
    rowcnt_d = np.bincount(
        pos_s * nb + li_s, minlength=nd * nb
    ).reshape(nd, nb)

    # exact padded dims of a cold pack of g2: max nnz over *all* blocks
    # (clean blocks keep their counts) — growing deltas widen the staged
    # arrays, shrinking ones narrow them, so splice output stays
    # byte-identical to a cold re-pack under the same σ
    counts2_all = plan.m_cnt.astype(np.int64).copy()
    counts2_all[dirty_bids // q, dirty_bids % q] = counts_d
    nnz_pad2 = max(1, int(counts2_all.max()))
    tmax2 = nnz_pad2

    starts_d = np.zeros(nd + 1, dtype=np.int64)
    np.cumsum(counts_d, out=starts_d[1:])
    offs = np.arange(pos_s.size, dtype=np.int64) - starts_d[pos_s]

    new_ptr = np.zeros((nd, nb + 1), dtype=INT)
    np.cumsum(rowcnt_d, axis=1, out=new_ptr[:, 1:])
    new_idx = np.full((nd, nnz_pad2), nb, dtype=INT)  # cols_loc sentinel
    new_idx[pos_s, offs] = lj_s
    new_ti = np.zeros((nd, tmax2), dtype=INT)
    new_tj = np.zeros((nd, tmax2), dtype=INT)
    new_ti[pos_s, offs] = li_s
    new_tj[pos_s, offs] = lj_s

    # --- splice into copies of the staged arrays (pack stage, dirty rows)
    bx = dirty_bids // q
    bz = dirty_bids % q
    ya = (inv[bz] - bx) % q  # a column / b row holding canonical (bx, bz)

    a_ptr = plan.a_indptr.copy()
    b_ptr = plan.b_indptr.copy()
    if nnz_pad2 == nnz_pad:
        a_idx = plan.a_indices.copy()
        b_idx = plan.b_indices.copy()
        m_ti = plan.m_ti.copy()
        m_tj = plan.m_tj.copy()
    else:
        # resize the padded axis; the copied prefix is exact because
        # every clean block's payload fits in the new max by definition,
        # and the tails are sentinel (indices) / zero (tasks) both ways
        w = min(nnz_pad, nnz_pad2)
        a_idx = np.full((q, q, nnz_pad2), nb, dtype=INT)
        a_idx[:, :, :w] = plan.a_indices[:, :, :w]
        b_idx = np.full((q, q, nnz_pad2), nb, dtype=INT)
        b_idx[:, :, :w] = plan.b_indices[:, :, :w]
        m_ti = np.zeros((q, q, tmax2), dtype=INT)
        m_ti[:, :, :w] = plan.m_ti[:, :, :w]
        m_tj = np.zeros((q, q, tmax2), dtype=INT)
        m_tj[:, :, :w] = plan.m_tj[:, :, :w]
    a_ptr[bx, ya] = new_ptr
    a_idx[bx, ya] = new_idx
    b_ptr[ya, bx] = new_ptr
    b_idx[ya, bx] = new_idx
    m_cnt = plan.m_cnt.copy()
    m_ti[bx, bz] = new_ti
    m_tj[bx, bz] = new_tj
    m_cnt[bx, bz] = counts_d.astype(INT)

    b_aug = plan.b_aug
    if b_aug is not None:
        if nnz_pad2 == nnz_pad:
            aug_rows = host_aug_keys(new_ptr, new_idx)
            if aug_rows is None:  # same nb as the parent: cannot happen
                return None
            b_aug = b_aug.copy()
            b_aug[ya, bx] = aug_rows.astype(b_aug.dtype)
        else:  # padded width changed: rebuild keys over the new layout
            aug_all = host_aug_keys(
                b_ptr.reshape(q * q, nb + 1), b_idx.reshape(q * q, -1)
            )
            if aug_all is None:
                return None
            b_aug = aug_all.reshape(q, q, nnz_pad2).astype(b_aug.dtype)

    blocks2 = plan.blocks
    if blocks2 is not None:
        blocks2 = [list(row) for row in blocks2]
        for t in range(nd):
            x_, z_ = int(bx[t]), int(bz[t])
            indptr64 = np.zeros(nb + 1, dtype=np.int64)
            np.cumsum(rowcnt_d[t], out=indptr64[1:])
            blocks2[x_][z_] = BlockCSR(
                bx=x_,
                by=z_,
                n_rows=nb,
                n_cols=nb,
                indptr=indptr64,
                indices=lj_s[starts_d[t]:starts_d[t + 1]].astype(np.int64),
                active_rows=np.nonzero(rowcnt_d[t])[0].astype(np.int64),
            )

    replanned = ["decompose:dirty", "pack:splice"]

    # --- fragment lengths for every (block row, panel), reconstructed
    # from the spliced placement: a[x, y] holds canonical (x, σ[(x+y)%q])
    lens = np.diff(a_ptr.astype(np.int64), axis=2)  # (q, q, nb)
    xg = np.broadcast_to(np.arange(q)[:, None], (q, q))
    zg = sp[(np.arange(q)[:, None] + np.arange(q)[None, :]) % q]
    rowcnt3 = np.zeros((q, q, nb), dtype=np.int64)
    rowcnt3[xg, zg] = lens
    counts2 = m_cnt.astype(np.int64)  # (q, q) nnz per canonical block
    dmax2 = max(1, int(rowcnt3.max()))  # kernels' dpad, like a cold pack

    # --- stats: recompute probe / itasks only at dirty (device, shift)
    # cells — the dominant cold-planning loop, cut to the dirty fraction
    dirty_cells = None
    dirty_cell_frac = None
    stats2 = plan.stats
    probe2 = None
    if stats2 is not None:
        x3 = np.arange(q)[:, None, None]
        y3 = np.arange(q)[None, :, None]
        s3 = np.arange(q)[None, None, :]
        z3 = sp[(x3 + y3 + s3) % q]
        dirty_cell = dirty[:, :, None] | dirty[x3, z3] | dirty[y3, z3]
        dirty_cells = int(dirty_cell.sum())
        dirty_cell_frac = dirty_cells / float(q * q * q)

        probe2 = stats2.probe_work_per_device_shift.copy()
        it_cell = stats2.itasks_per_cell
        it_cell2 = it_cell.copy() if it_cell is not None else None
        for x, y in zip(*np.nonzero(dirty_cell.any(axis=2))):
            cnt = int(m_cnt[x, y])
            rows = m_ti[x, y, :cnt]
            cols = m_tj[x, y, :cnt]
            for s in np.flatnonzero(dirty_cell[x, y]):
                z = int(sp[(x + y + int(s)) % q])
                la = rowcnt3[x, z][rows]
                lb = rowcnt3[y, z][cols]
                both = (la > 0) & (lb > 0)
                probe2[x, y, s] = int(np.minimum(la, lb)[both].sum())
                if it_cell2 is not None:
                    it_cell2[x, y, s] = int(both.sum())
        tot_idx = q * q * nnz_pad2
        stats2 = PlanStats(
            tasks_per_device=counts2,
            nnz_per_block=counts2.copy(),
            probe_work_per_device_shift=probe2,
            task_imbalance=float(
                counts2.max() / max(1.0, counts2.mean())
            ),
            probe_imbalance=float(
                probe2.sum(axis=2).max()
                / max(1.0, probe2.sum(axis=2).mean())
            ),
            intersection_tasks_total=(
                int(it_cell2.sum())
                if it_cell2 is not None
                else stats2.intersection_tasks_total
            ),
            padding_fraction_indices=float(1.0 - g2.m / max(1, tot_idx)),
            padding_fraction_tasks=float(1.0 - g2.m / max(1, q * q * tmax2)),
            itasks_per_cell=it_cell2,
        )
        replanned.append("stats:dirty-cells")

    # --- step masks: full vectorized recompute (cheap), same inputs a
    # cold pack would use under this σ and stats configuration
    keep2 = plan.step_keep
    if keep2 is not None:
        keep2 = cannon_step_keep(
            counts2, m_cnt, probe2,
            skew_perm=sp if plan.skew_perm is not None else None,
        )
        replanned.append("masks")

    # --- compaction: σ is never re-searched on a delta; the schedule
    # (and the compiled fns baked around its live list) is reused
    # verbatim when the live-step set did not grow
    compact2 = plan.compact
    live_grew = False
    if compact2 is not None and keep2 is not None:
        new_cs = compact_live_steps(keep2)
        if set(new_cs.live_steps) <= set(compact2.live_steps):
            pass  # superset of the true live set stays correct
        else:
            compact2 = new_cs
            live_grew = True
            replanned.append("compact:live-steps")

    cfg = artifact.config
    plan2 = dataclasses.replace(
        plan,
        m=g2.m,
        nnz_pad=nnz_pad2,
        tmax=tmax2,
        dmax=dmax2,
        chunk=min(int(cfg.get("chunk") or plan.chunk), tmax2),
        a_indptr=a_ptr,
        a_indices=a_idx,
        b_indptr=b_ptr,
        b_indices=b_idx,
        m_ti=m_ti,
        m_tj=m_tj,
        m_cnt=m_cnt,
        stats=stats2,
        blocks=blocks2,
        step_keep=keep2,
        b_aug=b_aug,
        compact=compact2,
    )

    if cfg.get("bucketize"):
        plan2 = bucketize_plan(plan2, d_small=cfg.get("d_small") or 32)
        replanned.append("bucketize")
    if cfg.get("autotune"):
        plan2 = autotune_tc_plan(
            plan2, two_sided=(cfg["autotune"] == "fused")
        )
        replanned.append("autotune")

    statics_changed = (
        live_grew
        or plan2.chunk != plan.chunk
        or plan2.dmax != plan.dmax
        or plan2.n_long != plan.n_long
        or plan2.d_small != plan.d_small
    )
    report = _report(
        "splice", n_dirty, n_dirty / float(q * q), dirty_cells,
        dirty_cell_frac, replanned, False, depth, eff_add, eff_rem,
        not statics_changed,
    )
    return _derived_artifact(
        artifact, g2, plan2, depth, chain, lineage, report,
        inherit_fns=not statics_changed,
    )


# ----------------------------------------------------------------------
# level 1: stage-local repack (relabel + σ + lineage kept, pack re-run)
# ----------------------------------------------------------------------
def _repack(artifact, g2, cfg, eff, eff_add, eff_rem, depth, chain, lineage):
    """Re-run decompose+pack (and the downstream stages) on the mutated
    graph, skipping ingest (no digest) and relabel (parent permutation
    kept) and never re-searching σ — the stage-local fallback when the
    splice's shape invariants break."""
    kind = artifact.kind
    plan = artifact.plan
    replanned = ["decompose+pack"]
    hub_side = getattr(plan, "hub", None)
    g_pack = g2
    hub2 = None
    if hub_side is not None:
        # re-split the merged graph at the *parent* cut (positional — a
        # suffix cut is exact for any h0, so no re-detection drift) and
        # pack the new residual; the ladder routed misaligned hub sides
        # to _rebase, so the parent id space is the artifact's own
        grid = (
            (cfg["q"], cfg["q"]) if kind == "cannon"
            else (cfg["r"], cfg["c"]) if kind == "summa"
            else (cfg["p"],)
        )
        g_pack, hub2 = hubsplit_stage(
            g2, grid, chunk=cfg["chunk"], h0=hub_side.h0
        )
        replanned.insert(0, "hubsplit")
    if kind == "cannon":
        dirty = _dirty_grid(eff, cfg["q"], cfg["q"])
        sp = plan.skew_perm
        plan2 = pack_tc_plan(
            g_pack,
            cfg["q"],
            skew=cfg["skew"],
            chunk=cfg["chunk"],
            with_stats=cfg["with_stats"],
            keep_blocks=cfg["keep_blocks"] or cfg["bucketize"],
            step_masks=cfg["step_masks"],
            skew_perm=sp if cfg["skew"] else None,
            aug_keys=cfg["aug_keys"],
        )
        if cfg["compact"] and cfg["skew"]:
            plan2 = compact_stage(plan2)  # live list under the kept σ
            replanned.append("compact")
        if cfg["bucketize"]:
            plan2 = bucketize_plan(plan2, d_small=cfg["d_small"])
            replanned.append("bucketize")
        if cfg["autotune"]:
            plan2 = autotune_tc_plan(
                plan2, two_sided=(cfg["autotune"] == "fused")
            )
            replanned.append("autotune")
    elif kind == "summa":
        dirty = _dirty_grid(eff, cfg["r"], cfg["c"])
        plan2 = pack_summa_plan(
            g_pack, cfg["r"], cfg["c"], chunk=cfg["chunk"],
            step_masks=cfg["step_masks"],
            with_stats=bool(cfg["rebalance_trials"]),
        )
        if cfg["compact"]:
            plan2 = compact_stage(plan2)
            replanned.append("compact")
        if cfg["autotune"]:
            plan2 = autotune_summa_plan(
                plan2, two_sided=(cfg["autotune"] == "fused")
            )
            replanned.append("autotune")
        plan2.broadcast = cfg["broadcast"]
    elif kind == "oned":
        dirty = _dirty_grid(eff, cfg["p"], cfg["p"])
        plan2 = pack_oned_plan(
            g_pack, cfg["p"], chunk=cfg["chunk"],
            step_masks=cfg["step_masks"],
            with_stats=bool(cfg["rebalance_trials"]),
        )
        if cfg["compact"]:
            plan2 = compact_stage(plan2)
            replanned.append("compact")
        if cfg["autotune"]:
            plan2 = autotune_oned_plan(
                plan2, two_sided=(cfg["autotune"] == "fused")
            )
            replanned.append("autotune")
    else:
        raise ValueError(f"unknown plan kind {kind!r}")
    if hub_side is not None:
        plan2.hub = hub2  # may be None: the delta drained the hub side

    report = _report(
        "repack", int(dirty.sum()), float(dirty.mean()), None, None,
        replanned, False, depth, eff_add, eff_rem, False,
    )
    return _derived_artifact(
        artifact, g2, plan2, depth, chain, lineage, report,
        inherit_fns=False,
    )


# ----------------------------------------------------------------------
# level 2: periodic rebase (cold re-plan, composed permutation)
# ----------------------------------------------------------------------
def _rebase(artifact, g2, cfg, cache, key, eff, eff_add, eff_rem):
    """Cold re-plan of the mutated graph through the planner driver —
    restores the degree ordering, σ search, padding tightness, and
    rebalance; starts a fresh lineage chain at the new root digest.  The
    relabeling permutations are composed so the returned artifact still
    maps *original* vertex ids."""
    from .planner import plan_cannon, plan_oned, plan_summa

    kind = artifact.kind
    if kind == "cannon":
        dirty = _dirty_grid(eff, cfg["q"], cfg["q"])
        art2 = plan_cannon(
            g2, cfg["q"], skew=cfg["skew"], chunk=cfg["chunk"],
            reorder=cfg["reorder"], cyclic_p=cfg["cyclic_p"],
            with_stats=cfg["with_stats"], keep_blocks=cfg["keep_blocks"],
            bucketize=cfg["bucketize"], d_small=cfg["d_small"],
            step_masks=cfg["step_masks"],
            rebalance_trials=cfg["rebalance_trials"],
            compact=cfg["compact"], autotune=cfg["autotune"],
            aug_keys=cfg["aug_keys"],
            hub_split=cfg.get("hub_split", False), cache=cache,
        )
    elif kind == "summa":
        dirty = _dirty_grid(eff, cfg["r"], cfg["c"])
        art2 = plan_summa(
            g2, cfg["r"], cfg["c"], chunk=cfg["chunk"],
            reorder=cfg["reorder"], cyclic_p=cfg["cyclic_p"],
            step_masks=cfg["step_masks"],
            rebalance_trials=cfg["rebalance_trials"],
            compact=cfg["compact"], autotune=cfg["autotune"],
            broadcast=cfg["broadcast"],
            hub_split=cfg.get("hub_split", False), cache=cache,
        )
    elif kind == "oned":
        dirty = _dirty_grid(eff, cfg["p"], cfg["p"])
        art2 = plan_oned(
            g2, cfg["p"], chunk=cfg["chunk"], reorder=cfg["reorder"],
            cyclic_p=cfg["cyclic_p"], step_masks=cfg["step_masks"],
            rebalance_trials=cfg["rebalance_trials"],
            compact=cfg["compact"], autotune=cfg["autotune"],
            hub_split=cfg.get("hub_split", False), cache=cache,
        )
    else:
        raise ValueError(f"unknown plan kind {kind!r}")

    if artifact.perm is None:
        perm = art2.perm
    elif art2.perm is None:
        perm = artifact.perm
    else:
        perm = art2.perm[artifact.perm]
    report = _report(
        "rebase", int(dirty.sum()), float(dirty.mean()), None, None,
        ["ingest", "relabel", "decompose+pack", "compact", "autotune"],
        True, 0, eff_add, eff_rem, False,
    )
    return dataclasses.replace(
        art2,
        key=key,
        perm=perm,
        cache_hit=False,
        lineage=dict(root_digest=art2.digest, chain=(), depth=0),
        delta_report=report,
    )
