"""Hub-split planning stage for heavy-tailed graphs (DESIGN.md §4.8).

On power-law graphs a handful of hub vertices dominate the masked
critical path no matter how rows are permuted (Arifuzzaman et al.,
arXiv:1706.05151): the rebalance stage (§4.3) only shuffles *which*
device holds the hub row, it cannot shrink it.  This stage removes the
hubs from the 2D cyclic path entirely.

Under the degree ordering (non-decreasing, so hubs get the *highest*
ids) the degree-threshold hub set is a contiguous id suffix ``[h0, n)``,
which admits an exact suffix-cut decomposition of the standard
edge-apex triangle sum ``T(G) = Σ_{(i,j)∈U} |U(i) ∩ U(j)|``:

* **residual** — the true induced subgraph on ``[0, h0)`` (every U edge
  with column < h0; rows ≥ h0 are empty by ``i < j``).  Its triangle
  count covers exactly the apexes ``k < h0``, and it flows through the
  normal relabel → rebalance → decompose → pack path with strictly
  smaller ``nnz`` / ``dmax`` / probe work.
* **hub side** — for every original U edge ``(i, j)``, the partial
  ``|H(i) ∩ H(j)|`` with ``H(v) = U(v) ∩ [h0, n)`` (v's neighbors at or
  above the cut).  This covers exactly the apexes ``k ≥ h0``.  Tasks
  where either fragment is empty are pruned.

The hub side is **self-contained in post-relabel ids**: fragments are
only ever intersected against each other, so the rebalance stage's
trial relabelings of the residual and the compaction stage's σ-search
never touch it, and it can never revive an elided schedule step — it
runs *outside* the schedule loop as one extra partial sum folded into
the existing :class:`~repro.core.engine.Reduction` (flat and tree).

Replication layout: on an ``(r, c)`` grid the device column ``y`` holds
the column-strided fragment slice ``H_y(v) = {k ∈ H(v) : k % c == y}``
(stored as local ids ``k // c``) and tasks are round-robin over grid
rows, so every device sees ``~tasks/r × nnz_H/c`` work and summing the
per-device partials over the whole grid reconstructs every
``|H(i) ∩ H(j)|`` exactly once.  On a 1D ring the ``p`` devices split
the tasks round-robin and hold full fragments.  Multi-pod grids
replicate the hub arrays (they ride the static — non-pod — partition
specs) and the engine zeroes the partial on every pod but pod 0.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.graph import Graph

__all__ = [
    "HubSide",
    "DEFAULT_HUB_C",
    "normalize_hub_split",
    "detect_hub_cut",
    "hubsplit_stage",
]

INT = np.int32

# Degree threshold multiplier: rows with degree > c · (2m/n) (c × the
# average degree) are hubs.  Grid-independent — the same graph splits
# the same way on every grid — and calibrated on the powerlaw fixtures
# (alpha 2.2 / 1.8: ~14 hub rows, ~9-10× masked-critical-path drop).
DEFAULT_HUB_C = 8.0


def normalize_hub_split(hub_split) -> Optional[float]:
    """Canonicalize the knob: False/None → None, True → DEFAULT_HUB_C,
    a number → that threshold multiplier (cache keys stay canonical)."""
    if hub_split is None or hub_split is False:
        return None
    if hub_split is True:
        return DEFAULT_HUB_C
    c = float(hub_split)
    if c < 0:
        raise ValueError(f"hub_split threshold multiplier must be >= 0, got {c}")
    return c


def detect_hub_cut(graph: Graph, c: float) -> int:
    """The suffix cut ``h0``: vertices ``>= h0`` are hubs.

    ``graph`` must be degree-ordered (non-decreasing degrees — the
    relabel stage's output), so ``degree > threshold`` is a suffix and
    one ``searchsorted`` finds it.  Returns ``n`` when nothing crosses
    the threshold (hub side empty, stage is a no-op).
    """
    n = graph.n
    if n == 0 or graph.m == 0:
        return n
    deg = graph.degrees()
    tau = c * (2.0 * graph.m / n)
    return int(np.searchsorted(deg, tau, side="right"))


@dataclasses.dataclass
class HubSide:
    """Device-ready hub-fragment arrays + the cut metadata.

    Arrays are stacked ``(*grid, ...)`` exactly like the plan statics
    (``grid`` is ``(r, c)`` or ``(p,)``); they join
    ``plan.device_arrays()`` under the ``hub_*`` names and are consumed
    by :class:`repro.core.engine.HubCount`.
    """

    h0: int  # suffix cut: vertices >= h0 are hubs
    n: int  # relabeled graph size
    grid: Tuple[int, ...]  # (r, c) or (p,)
    hub_rows: int  # n - h0
    hub_nnz: int  # U entries with column >= h0
    hub_nnz_frac: float  # hub_nnz / m
    hub_tasks: int  # task pairs with both fragments nonempty
    dpad: int  # max fragment length on any device (padded probe len)
    chunk: int
    sentinel: int  # > any stored local id

    hub_indptr: np.ndarray  # (*grid, nref_pad + 1)
    hub_indices: np.ndarray  # (*grid, hnnz_pad)
    hub_ti: np.ndarray  # (*grid, tmax) local task row i
    hub_tj: np.ndarray  # (*grid, tmax) local task row j
    hub_cnt: np.ndarray  # (*grid,) valid task count

    # True while the hub side's internal id space matches the artifact's
    # final id space (set False by the planner when a non-identity
    # rebalance trial relabeled the residual after the split) — the
    # delta path repacks in place only when aligned, else it rebases.
    aligned: bool = True

    names = ("hub_indptr", "hub_indices", "hub_ti", "hub_tj", "hub_cnt")

    def device_arrays(self) -> Dict[str, np.ndarray]:
        return {k: getattr(self, k) for k in self.names}

    def report(self) -> dict:
        return dict(
            h0=self.h0,
            hub_rows=self.hub_rows,
            hub_nnz=self.hub_nnz,
            hub_nnz_frac=self.hub_nnz_frac,
            hub_tasks=self.hub_tasks,
            hub_dpad=self.dpad,
        )


def _build_hub_side(
    edges: np.ndarray, n: int, m: int, h0: int,
    grid: Tuple[int, ...], chunk: int,
) -> Optional[HubSide]:
    """Pack the hub-side arrays for an (r, c) grid or (p,) ring."""
    if len(grid) == 2:
        r, c = int(grid[0]), int(grid[1])
    else:
        r, c = int(grid[0]), 1  # ring: full fragments, tasks over p
    hi = edges[edges[:, 1] >= h0]
    if hi.shape[0] == 0:
        return None
    # high fragments H(v) as one (v, k)-sorted entry list
    order = np.lexsort((hi[:, 1], hi[:, 0]))
    hv, hk = hi[order, 0], hi[order, 1]
    hdeg = np.bincount(hv, minlength=n)
    has = hdeg > 0
    # tasks: every original U edge whose both endpoints keep a fragment
    te = edges[has[edges[:, 0]] & has[edges[:, 1]]]

    ndev_rows = r
    per_x = []  # (ref, lti, ltj) per grid row
    tmax = 1
    nref = 1
    for x in range(ndev_rows):
        tx = te[x::ndev_rows]
        if tx.shape[0] == 0:
            per_x.append((np.zeros(0, np.int64), np.zeros(0, np.int64),
                          np.zeros(0, np.int64)))
            continue
        ref, inv = np.unique(tx.reshape(-1), return_inverse=True)
        inv = inv.reshape(-1)
        per_x.append((ref, inv[0::2], inv[1::2]))
        tmax = max(tmax, tx.shape[0])
        nref = max(nref, ref.shape[0])

    # per-(x, y) strided CSR of the referenced rows' fragments
    frag = {}
    hnnz_pad = 1
    dpad = 1
    for x in range(ndev_rows):
        ref, _, _ = per_x[x]
        if ref.shape[0] == 0:
            continue
        pos = np.searchsorted(ref, hv)
        pos_c = np.minimum(pos, ref.shape[0] - 1)
        in_ref = (pos < ref.shape[0]) & (ref[pos_c] == hv)
        for y in range(c):
            sel = in_ref & ((hk % c) == y) if c > 1 else in_ref
            rows = pos_c[sel]
            vals = (hk[sel] // c).astype(INT) if c > 1 else hk[sel].astype(INT)
            counts = np.bincount(rows, minlength=ref.shape[0])
            indptr = np.zeros(ref.shape[0] + 1, INT)
            np.cumsum(counts, out=indptr[1:], dtype=np.int64)
            frag[(x, y)] = (indptr, vals)
            hnnz_pad = max(hnnz_pad, vals.shape[0])
            if counts.size:
                dpad = max(dpad, int(counts.max()))

    sentinel = n + 1
    shape = (r, c) if len(grid) == 2 else (r,)
    hub_indptr = np.zeros(shape + (nref + 1,), INT)
    hub_indices = np.full(shape + (hnnz_pad,), sentinel, INT)
    hub_ti = np.zeros(shape + (tmax,), INT)
    hub_tj = np.zeros(shape + (tmax,), INT)
    hub_cnt = np.zeros(shape, INT)
    for x in range(ndev_rows):
        ref, lti, ltj = per_x[x]
        for y in range(c):
            dev = (x, y) if len(grid) == 2 else (x,)
            if ref.shape[0] == 0:
                continue
            indptr, vals = frag[(x, y)]
            hub_indptr[dev][: indptr.shape[0]] = indptr
            hub_indptr[dev][indptr.shape[0]:] = indptr[-1]
            hub_indices[dev][: vals.shape[0]] = vals
            hub_ti[dev][: lti.shape[0]] = lti
            hub_tj[dev][: ltj.shape[0]] = ltj
            hub_cnt[dev] = lti.shape[0]

    return HubSide(
        h0=h0,
        n=n,
        grid=tuple(int(g) for g in grid),
        hub_rows=n - h0,
        hub_nnz=int(hi.shape[0]),
        hub_nnz_frac=float(hi.shape[0]) / max(1, m),
        hub_tasks=int(te.shape[0]),
        dpad=dpad,
        chunk=int(min(chunk, max(64, -(-tmax // 64) * 64))),
        sentinel=sentinel,
        hub_indptr=hub_indptr,
        hub_indices=hub_indices,
        hub_ti=hub_ti,
        hub_tj=hub_tj,
        hub_cnt=hub_cnt,
    )


def hubsplit_stage(
    graph: Graph,
    grid: Tuple[int, ...],
    *,
    c: float = DEFAULT_HUB_C,
    chunk: int = 512,
    h0: Optional[int] = None,
) -> Tuple[Graph, Optional[HubSide]]:
    """Split ``graph`` (degree-ordered) at the hub cut.

    Returns ``(residual, hub_side)``: the residual is the induced
    subgraph on ``[0, h0)`` (handed to rebalance → pack unchanged), the
    hub side carries the replicated fragment arrays (``None`` when no
    row crosses the threshold — the stage is then a no-op).  ``h0``
    overrides detection (the delta repack path reuses the parent cut so
    stage-local repacks stay deterministic).
    """
    if h0 is None:
        h0 = detect_hub_cut(graph, c)
    h0 = int(h0)
    if h0 >= graph.n or graph.m == 0:
        return graph, None
    hub = _build_hub_side(graph.edges, graph.n, graph.m, h0, grid, chunk)
    if hub is None:
        return graph, None
    residual = Graph(
        n=graph.n,
        edges=graph.edges[graph.edges[:, 1] < h0],
        name=graph.name + f"+hub{h0}",
    )
    return residual, hub
