"""Cached pipeline drivers: one call = ingest → relabel → decompose →
pack (→ stage, lazily), all behind the content-addressed cache.

``plan_cannon`` / ``plan_summa`` / ``plan_oned`` are what the schedule
runners in :mod:`repro.core.api` call; each returns a
:class:`~repro.pipeline.artifact.PlanArtifact`.  Repeated counts of the
same (or merely re-labeled / re-ordered-edge) graph hit the cache at the
digest and skip every stage; the relabel result is cached separately so
different schedules planning the same graph share the degree ordering.
"""
from __future__ import annotations

import time
from typing import Optional

from ..core.graph import Graph
from ..core.plan import bucketize_plan
from .artifact import PlanArtifact
from .cache import PlanCache, default_cache, graph_digest
from .hubsplit import hubsplit_stage, normalize_hub_split
from .rebalance import rebalance_stage
from .stages import (
    autotune_oned_plan,
    autotune_summa_plan,
    autotune_tc_plan,
    compact_stage,
    pack_oned_plan,
    pack_summa_plan,
    pack_tc_plan,
    relabel_stage,
)

__all__ = ["plan_cannon", "plan_summa", "plan_oned", "relabel_cached"]


def relabel_cached(
    graph: Graph,
    digest: str,
    *,
    reorder: bool,
    cyclic_p: Optional[int],
    cache: PlanCache,
):
    """Relabel stage behind the cache: shared across plan kinds."""
    return cache.memo(
        ("relabel", digest, reorder, cyclic_p),
        lambda: relabel_stage(graph, reorder=reorder, cyclic_p=cyclic_p),
    )


def _rebalanced(g2, perm, trials, reorder, pack_trial, seconds):
    """Run the rebalance stage between relabel and pack (no-op when off).

    Trials pack lean (stats + masks only); the returned winner plan is
    reused by callers whose flags match the trial flags, and re-packed
    otherwise — so the stage composes with any pack configuration
    (keep_blocks, bucketize, step_masks=False, ...).
    """
    if not trials:
        return g2, perm, None, None
    if not reorder:
        raise ValueError(
            "rebalance_trials requires reorder=True: trial relabelings "
            "shuffle within equal-degree runs of the degree ordering"
        )
    t0 = time.perf_counter()
    g2, perm, best_plan, report = rebalance_stage(g2, perm, trials, pack_trial)
    seconds["rebalance"] = time.perf_counter() - t0
    return g2, perm, best_plan, report


def _hub_knob(hub_split, reorder, cyclic_p):
    """Validate + canonicalize the hub-split knob (None | threshold c).

    The suffix-cut decomposition needs the degree ordering: hub
    detection is a ``searchsorted`` on the sorted degrees, and the cut
    ``[h0, n)`` is only the hub set because hubs get the highest ids.
    """
    hub_c = normalize_hub_split(hub_split)
    if hub_c is None:
        return None
    if not reorder:
        raise ValueError(
            "hub_split requires reorder=True: the hub cut is a suffix "
            "of the degree ordering"
        )
    if cyclic_p is not None:
        raise ValueError(
            "hub_split composes with the degree ordering only; the "
            "cyclic redistribution (cyclic_p) breaks the degree-suffix "
            "property the cut relies on"
        )
    return hub_c


def _hub_stage(g2, grid, hub_c, chunk, seconds):
    """Run the hub-split stage (no-op when off / nothing crosses)."""
    if hub_c is None:
        return g2, None
    t0 = time.perf_counter()
    g2, hub = hubsplit_stage(g2, grid, c=hub_c, chunk=chunk)
    seconds["hubsplit"] = time.perf_counter() - t0
    return g2, hub


def _drive(kind, graph, key_tail, cache, pack):
    """Shared driver: ingest (digest + cache probe) then relabel + pack."""
    from ..runtime import faultinject

    faultinject.fire("plan_stage", kind=kind)
    cache = cache if cache is not None else default_cache()
    seconds = {}
    t0 = time.perf_counter()
    digest = graph_digest(graph)
    seconds["ingest"] = time.perf_counter() - t0

    key = (kind, digest) + key_tail
    art = cache.get(key)
    if art is not None:
        art.cache_hit = True
        return art

    art = pack(digest, key, seconds, cache)
    art.stage_seconds.update(seconds)
    cache.put(key, art)
    return art


def plan_cannon(
    graph: Graph,
    q: int,
    *,
    skew: bool = True,
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    with_stats: bool = True,
    keep_blocks: bool = True,
    bucketize: bool = False,
    d_small: int = 32,
    step_masks: bool = True,
    rebalance_trials: int = 0,
    compact: bool = True,
    autotune: bool = False,
    aug_keys: bool = False,
    hub_split=False,
    cache: Optional[PlanCache] = None,
) -> PlanArtifact:
    """Plan the 2D-cyclic (Cannon family) execution of ``graph`` on a
    ``q x q`` grid, through the cache.

    ``bucketize=True`` stores the §Perf H1a long/short-reordered plan
    (for ``method="search2"``) under its own cache entry;
    ``step_masks`` stages the per-(device, shift) skip mask the engine
    consumes for sparsity-aware step skipping (part of the cache key —
    masked and unmasked artifacts are distinct entries).
    ``rebalance_trials > 0`` runs the skip-aware rebalance stage
    (DESIGN.md §4.3) over that many relabeling seeds; the trials knob is
    part of the cache key, the winning seed lands on the artifact.
    ``compact`` (default on) runs the schedule-compaction stage
    (DESIGN.md §4.4): it searches the σ visit order concentrating live
    work onto the fewest steps, re-packs under the winner, and stages
    the globally-live step list the engine's compacted bodies execute.
    ``autotune`` runs the deterministic kernel-shape stage (chunk +
    two-level split from the probe-length distribution, DESIGN.md §5) —
    pass the string ``"fused"`` for the two-sided maxfrag split the
    fused panel kernel requires (DESIGN.md §5.1); ``aug_keys`` stages
    the row-encoded B intersection keys for the ``global``/``search2``
    kernels.  All three are cache-key components.
    ``hub_split`` (False | True | threshold multiplier c) runs the
    hub-split stage (DESIGN.md §4.8) between relabel and rebalance: the
    heavy-tailed id suffix is cut off into replicated column-strided
    fragments and every later stage — rebalance, σ-search, pack,
    autotune — sees only the residual graph.
    """
    hub_c = _hub_knob(hub_split, reorder, cyclic_p)

    def pack(digest, key, seconds, cache_):
        t0 = time.perf_counter()
        g2, perm = relabel_cached(
            graph, digest, reorder=reorder, cyclic_p=cyclic_p, cache=cache_
        )
        seconds["relabel"] = time.perf_counter() - t0
        g2, hub = _hub_stage(g2, (q, q), hub_c, chunk, seconds)
        g2, perm, best_plan, rb = _rebalanced(
            g2, perm, rebalance_trials, reorder,
            lambda gt: pack_tc_plan(
                gt, q, skew=skew, chunk=chunk, with_stats=True,
                keep_blocks=False, step_masks=True,
            ),
            seconds,
        )
        t1 = time.perf_counter()
        pack_kwargs = dict(
            skew=skew,
            chunk=chunk,
            with_stats=with_stats,
            keep_blocks=keep_blocks or bucketize,
            step_masks=step_masks,
            aug_keys=aug_keys,
        )
        if best_plan is not None and (
            with_stats and not (keep_blocks or bucketize) and step_masks
            and not aug_keys
        ):  # caller flags == trial flags: the winner pack is the plan
            plan = best_plan
        else:
            plan = pack_tc_plan(g2, q, **pack_kwargs)
        if compact and skew:
            plan = compact_stage(
                plan,
                repack=lambda sigma: pack_tc_plan(
                    g2, q, skew_perm=sigma, **pack_kwargs
                ),
            )
        if bucketize:
            plan = bucketize_plan(plan, d_small=d_small)
        if autotune:
            plan = autotune_tc_plan(plan, two_sided=(autotune == "fused"))
        plan.hub = hub
        seconds["decompose+pack"] = time.perf_counter() - t1
        art_graph = g2
        if hub is not None:
            # the plan arrays cover only the residual; the artifact must
            # carry the *full* relabeled graph so the delta path merges
            # edits against reality.  aligned records whether the hub
            # side's id space survived rebalance (trial seed 0 = yes).
            hub.aligned = rb is None or int(rb.get("best_seed", 0)) == 0
            art_graph = graph.relabel(perm)
        return PlanArtifact(
            kind="cannon", digest=digest, key=key, graph=art_graph,
            perm=perm, plan=plan, rebalance=rb, config=config,
        )

    config = dict(
        q=q, skew=skew, chunk=chunk, reorder=reorder, cyclic_p=cyclic_p,
        with_stats=with_stats, keep_blocks=keep_blocks, bucketize=bucketize,
        d_small=d_small, step_masks=step_masks,
        rebalance_trials=rebalance_trials, compact=compact,
        autotune=autotune, aug_keys=aug_keys, hub_split=hub_c,
    )
    tail = (
        q, skew, chunk, reorder, cyclic_p, with_stats, keep_blocks,
        bucketize, d_small if bucketize else None, step_masks,
        rebalance_trials, compact, autotune, aug_keys, hub_c,
    )
    return _drive("cannon", graph, tail, cache, pack)


def plan_summa(
    graph: Graph,
    r: int,
    c: int,
    *,
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    step_masks: bool = True,
    rebalance_trials: int = 0,
    compact: bool = True,
    autotune: bool = False,
    broadcast: str = "auto",
    hub_split=False,
    cache: Optional[PlanCache] = None,
) -> PlanArtifact:
    """Plan the SUMMA execution on an ``r x c`` grid, through the cache.

    ``compact`` stages the globally-live broadcast rounds (dead rounds'
    broadcasts are elided by the engine, DESIGN.md §4.4);
    ``autotune`` runs the deterministic kernel-shape stage;
    ``broadcast`` records the panel-broadcast strategy the plan is
    staged for (``"auto"``/``"onehot"``/``"chain"`` — DESIGN.md §4.5,
    resolved by the engine builder) — like every planner knob it is a
    cache-key component, so strategy A/B runs never share artifacts.
    ``hub_split`` cuts the heavy-tailed suffix off the 2D path
    (DESIGN.md §4.8) before rebalance/pack."""
    hub_c = _hub_knob(hub_split, reorder, cyclic_p)

    def pack(digest, key, seconds, cache_):
        t0 = time.perf_counter()
        g2, perm = relabel_cached(
            graph, digest, reorder=reorder, cyclic_p=cyclic_p, cache=cache_
        )
        seconds["relabel"] = time.perf_counter() - t0
        g2, hub = _hub_stage(g2, (r, c), hub_c, chunk, seconds)
        g2, perm, best_plan, rb = _rebalanced(
            g2, perm, rebalance_trials, reorder,
            lambda gt: pack_summa_plan(
                gt, r, c, chunk=chunk, step_masks=True, with_stats=True
            ),
            seconds,
        )
        t1 = time.perf_counter()
        if best_plan is not None and step_masks:
            plan = best_plan  # caller flags == trial flags
        else:
            plan = pack_summa_plan(
                g2, r, c, chunk=chunk, step_masks=step_masks,
                with_stats=bool(rebalance_trials),
            )
        if compact:
            plan = compact_stage(plan)  # rounds have no free visit order
        if autotune:
            plan = autotune_summa_plan(plan, two_sided=(autotune == "fused"))
        plan.broadcast = broadcast
        plan.hub = hub
        seconds["decompose+pack"] = time.perf_counter() - t1
        art_graph = g2
        if hub is not None:
            hub.aligned = rb is None or int(rb.get("best_seed", 0)) == 0
            art_graph = graph.relabel(perm)
        return PlanArtifact(
            kind="summa", digest=digest, key=key, graph=art_graph,
            perm=perm, plan=plan, rebalance=rb, config=config,
        )

    config = dict(
        r=r, c=c, chunk=chunk, reorder=reorder, cyclic_p=cyclic_p,
        step_masks=step_masks, rebalance_trials=rebalance_trials,
        compact=compact, autotune=autotune, broadcast=broadcast,
        hub_split=hub_c,
    )
    tail = (
        r, c, chunk, reorder, cyclic_p, step_masks, rebalance_trials,
        compact, autotune, broadcast, hub_c,
    )
    return _drive("summa", graph, tail, cache, pack)


def plan_oned(
    graph: Graph,
    p: int,
    *,
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    step_masks: bool = True,
    rebalance_trials: int = 0,
    compact: bool = True,
    autotune: bool = False,
    hub_split=False,
    cache: Optional[PlanCache] = None,
) -> PlanArtifact:
    """Plan the 1D-ring baseline over ``p`` devices, through the cache.

    ``compact`` stages the globally-live ring steps (dead steps become
    fused multi-hop rotations, DESIGN.md §4.4); ``autotune`` tunes the
    chunk (the ring's global-id columns rule out the two-level split);
    ``hub_split`` cuts the heavy-tailed suffix off the ring path
    (DESIGN.md §4.8 — tasks round-robin over the ring, full fragments)."""
    hub_c = _hub_knob(hub_split, reorder, cyclic_p)

    def pack(digest, key, seconds, cache_):
        t0 = time.perf_counter()
        g2, perm = relabel_cached(
            graph, digest, reorder=reorder, cyclic_p=cyclic_p, cache=cache_
        )
        seconds["relabel"] = time.perf_counter() - t0
        g2, hub = _hub_stage(g2, (p,), hub_c, chunk, seconds)
        g2, perm, best_plan, rb = _rebalanced(
            g2, perm, rebalance_trials, reorder,
            lambda gt: pack_oned_plan(
                gt, p, chunk=chunk, step_masks=True, with_stats=True
            ),
            seconds,
        )
        t1 = time.perf_counter()
        if best_plan is not None and step_masks:
            plan = best_plan  # caller flags == trial flags
        else:
            plan = pack_oned_plan(
                g2, p, chunk=chunk, step_masks=step_masks,
                with_stats=bool(rebalance_trials),
            )
        if compact:
            plan = compact_stage(plan)  # ring steps have no free order
        if autotune:
            plan = autotune_oned_plan(plan, two_sided=(autotune == "fused"))
        plan.hub = hub
        seconds["decompose+pack"] = time.perf_counter() - t1
        art_graph = g2
        if hub is not None:
            hub.aligned = rb is None or int(rb.get("best_seed", 0)) == 0
            art_graph = graph.relabel(perm)
        return PlanArtifact(
            kind="oned", digest=digest, key=key, graph=art_graph,
            perm=perm, plan=plan, rebalance=rb, config=config,
        )

    config = dict(
        p=p, chunk=chunk, reorder=reorder, cyclic_p=cyclic_p,
        step_masks=step_masks, rebalance_trials=rebalance_trials,
        compact=compact, autotune=autotune, hub_split=hub_c,
    )
    tail = (
        p, chunk, reorder, cyclic_p, step_masks, rebalance_trials,
        compact, autotune, hub_c,
    )
    return _drive("oned", graph, tail, cache, pack)
