"""Skip-aware load rebalancing — a composable planning stage (DESIGN.md §4.3).

The paper's degree-ordered cyclic distribution bounds *task-count*
imbalance (Table 3), but with sparsity-aware step skipping the SPMD
critical path is the max **kept** probe work per schedule step — what the
engine actually executes.  This stage searches randomized relabelings
that perturb the vertex order only *within equal-degree runs* (preserving
the non-decreasing-degree property the algorithm's correctness and
locality arguments rely on) and keeps the seed minimizing:

1. **masked critical path** — per-step max over devices of probe work on
   kept steps only (``step_keep ⊙ probe_work_per_device_shift``), summed
   over steps;
2. tie-break: the fewest kept (device, step) pairs, i.e. the most
   skippable all-empty steps.

Trial seed 0 is always the *identity* on the degree-ordered graph — the
unrebalanced baseline — so the search can never return a plan worse than
the default pipeline's (pinned by ``tests/test_property.py`` and the
``benchmarks/table3_imbalance.py --smoke`` CI guard).

The stage slots between *relabel* and *decompose*: every trial reuses the
cached ingest digest and degree ordering, re-running only the
decompose+pack mask emission.  All three plan families participate —
Cannon ``(q, q, q)``, SUMMA ``(r, c, c)``, 1D ring ``(p, p)`` — through
their packers' probe-work stats (:class:`repro.core.plan.StepStats` /
``PlanStats``).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from ..core.graph import Graph

__all__ = [
    "rebalance_trial_perm",
    "masked_critical_path",
    "plan_cost",
    "rebalance_stage",
]


def rebalance_trial_perm(degrees: np.ndarray, seed: int) -> np.ndarray:
    """Trial permutation for one rebalance seed (current id → new id).

    ``degrees`` are the degrees of an already degree-ordered graph
    (non-decreasing).  Seed 0 is the identity — the deterministic
    baseline; seeds ≥ 1 shuffle positions uniformly within each
    equal-degree run, so every trial keeps degrees non-decreasing.
    """
    n = int(degrees.shape[0])
    if seed == 0:
        return np.arange(n, dtype=np.int64)
    rng = np.random.default_rng(seed)
    jitter = rng.random(n)
    order = np.lexsort((jitter, degrees))  # degree blocks kept, ties shuffled
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n, dtype=np.int64)
    return perm


def masked_critical_path(
    probe: np.ndarray, step_keep: Optional[np.ndarray] = None
) -> float:
    """Sum over steps of the max per-device probe work on kept steps.

    ``probe`` is any ``(..., nsteps)`` per-(device, step) work array;
    ``step_keep`` (same shape, bool) zeroes skipped steps first.  With no
    mask this degenerates to the unmasked critical path.
    """
    probe = np.asarray(probe, dtype=np.int64)
    kept = probe if step_keep is None else np.where(step_keep, probe, 0)
    flat = kept.reshape(-1, kept.shape[-1]) if kept.ndim else kept
    if flat.size == 0:
        return 0.0
    return float(flat.max(axis=0).sum())


def plan_cost(plan) -> Tuple[float, int]:
    """Rebalance objective of a packed plan: ``(masked critical path,
    kept device-steps)``, minimized lexicographically.

    Requires the plan to carry probe stats (``with_stats``); the skip
    mask may be absent (then nothing is masked and every step counts as
    kept).
    """
    stats = plan.stats
    assert stats is not None, "rebalance needs a plan packed with_stats"
    probe = stats.probe_work_per_device_shift
    keep = getattr(plan, "step_keep", None)
    kept = int(keep.sum()) if keep is not None else int(probe.size)
    return masked_critical_path(probe, keep), kept


def rebalance_stage(
    graph: Graph,
    perm: Optional[np.ndarray],
    trials: int,
    pack_trial: Callable[[Graph], object],
) -> Tuple[Graph, Optional[np.ndarray], object, dict]:
    """Search ``trials`` relabeling seeds; return the winner.

    ``graph`` is the relabel stage's output (degree-ordered) and ``perm``
    the composed permutation so far; ``pack_trial(graph) -> plan`` must
    pack with probe stats and skip masks.  Returns the winning relabeled
    graph, the re-composed total permutation, the winning trial's packed
    plan (reusable by callers whose pack flags match the trial flags),
    and the search report (consumed verbatim by ``tc_run --rebalance``
    and ``benchmarks/table3_imbalance.py``).
    """
    deg = graph.degrees()
    history = []
    best = None  # (cost tuple, seed, trial perm, trial graph, trial plan)
    for seed in range(int(trials)):
        tp = rebalance_trial_perm(deg, seed)
        gt = graph if seed == 0 else graph.relabel(
            tp, name=graph.name + f"+rb{seed}"
        )
        plan = pack_trial(gt)
        mcp, kept = plan_cost(plan)
        keep = getattr(plan, "step_keep", None)
        nsteps = int(keep.size) if keep is not None else kept
        history.append(
            dict(
                seed=seed,
                masked_critical_path=mcp,
                unmasked_critical_path=masked_critical_path(
                    plan.stats.probe_work_per_device_shift
                ),
                skipped_steps=nsteps - kept,
            )
        )
        if best is None or (mcp, kept) < best[0]:
            best = ((mcp, kept), seed, tp, gt, plan)
    (best_mcp, _), best_seed, best_tp, best_graph, best_plan = best
    baseline = history[0]["masked_critical_path"]
    # improvement = baseline / best, guarded only against a literal zero
    # denominator (an all-skippable best plan; inf is JSON-unsafe, so
    # report emitters serialize non-finite values as null)
    if best_mcp > 0:
        improvement = baseline / best_mcp
    else:
        improvement = 1.0 if baseline == 0 else float("inf")
    report = dict(
        trials=history,
        best_seed=best_seed,
        baseline_masked_critical_path=baseline,
        best_masked_critical_path=best_mcp,
        improvement=improvement,
        skipped_steps=history[best_seed]["skipped_steps"],
        baseline_skipped_steps=history[0]["skipped_steps"],
    )
    total = best_tp if perm is None else best_tp[perm]
    return best_graph, total, best_plan, report
