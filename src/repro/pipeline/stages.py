"""Composable host-planning stages (DESIGN.md §3).

The pipeline is  **ingest → relabel → decompose → pack → stage**:

* *ingest*    — content-digest the input graph (:mod:`.cache`);
* *relabel*   — optional cyclic redistribution (paper §5.3 step 1) then
  degree ordering (step 2), composed into one permutation;
* *decompose* — the single lexsort pass over the 2D-cyclic decomposition
  (:func:`repro.core.decomp.cyclic_coo`);
* *pack*      — emit the stacked, padded device arrays **directly** from
  the sorted pass (this module): one cumsum for every indptr, one
  scatter for every index/task array — no per-block Python loops;
* *stage*     — host→device conversion, memoized on the artifact
  (:meth:`repro.pipeline.artifact.PlanArtifact.staged`).

The packers here are the real implementations behind
``repro.core.plan.build_plan``, ``repro.core.summa.build_summa_plan``
and ``repro.core.onedim.build_oned_plan``; the byte-level layout
contract (padding fills, dtypes, orderings) is pinned by
``tests/test_pipeline.py`` against the retained loop reference.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from ..core.decomp import CyclicCOO, blocks_from_coo, cyclic_coo
from ..core.graph import Graph
from ..core.onedim import OneDPlan
from ..core.plan import (
    INT,
    PlanStats,
    StepStats,
    TCPlan,
    compact_live_steps,
    host_aug_keys,
)
from ..core.preprocess import cyclic_relabel, degree_order
from ..core.summa import SummaPlan

__all__ = [
    "relabel_stage",
    "emit_block_arrays",
    "cannon_step_keep",
    "summa_probe_work",
    "oned_probe_work",
    "pack_tc_plan",
    "pack_summa_plan",
    "pack_oned_plan",
    "choose_cannon_skew",
    "compact_stage",
    "autotune_tc_plan",
    "autotune_summa_plan",
    "autotune_oned_plan",
]


# ======================================================================
# relabel
# ======================================================================
def relabel_stage(
    graph: Graph,
    *,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
) -> Tuple[Graph, Optional[np.ndarray]]:
    """Paper §5.3 steps 1-2 as one composed permutation.

    ``cyclic_p`` applies the initial cyclic redistribution over ``p``
    ranks first (optional — a relabeling choice in our SPMD setting);
    ``reorder`` then ranks vertices by non-decreasing degree.  Returns
    the relabeled graph and the composed ``perm`` (old id → new id), or
    ``(graph, None)`` when both steps are off.
    """
    perm: Optional[np.ndarray] = None
    g = graph
    if cyclic_p is not None:
        perm = cyclic_relabel(g.n, cyclic_p)
        g = g.relabel(perm, name=g.name + f"+cyc{cyclic_p}")
    if reorder:
        dperm = degree_order(g)
        g = g.relabel(dperm, name=g.name + "+degord")
        perm = dperm if perm is None else dperm[perm]
    return g, perm


# ======================================================================
# pack: canonical stacked block arrays from one sorted pass
# ======================================================================
def emit_block_arrays(
    coo: CyclicCOO, nnz_pad: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Stacked ``(r, c, nb+1)`` indptr and ``(r, c, nnz_pad)`` indices.

    One cumsum (indptr) and one scatter (indices) over the whole sorted
    pass; padding positions hold the ``cols_loc`` sentinel (beyond any
    valid local column id) so padded rows stay sorted for the
    binary-search probe.
    """
    rc = coo.r * coo.c
    nb = coo.rows_loc
    indptr = np.zeros((rc, nb + 1), dtype=INT)
    np.cumsum(coo.rowcnt, axis=1, out=indptr[:, 1:])
    indices = np.full((rc, nnz_pad), coo.cols_loc, dtype=INT)
    indices[coo.bid_s, coo.offsets()] = coo.lj_s
    return (
        indptr.reshape(coo.r, coo.c, nb + 1),
        indices.reshape(coo.r, coo.c, nnz_pad),
    )


def _emit_tasks(
    coo: CyclicCOO, tmax: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-block task lists ``(m_ti, m_tj, m_cnt)`` by direct scatter."""
    rc = coo.r * coo.c
    m_ti = np.zeros((rc, tmax), dtype=INT)
    m_tj = np.zeros((rc, tmax), dtype=INT)
    offs = coo.offsets()
    m_ti[coo.bid_s, offs] = coo.li_s
    m_tj[coo.bid_s, offs] = coo.lj_s
    return (
        m_ti.reshape(coo.r, coo.c, tmax),
        m_tj.reshape(coo.r, coo.c, tmax),
        coo.counts.reshape(coo.r, coo.c).astype(INT),
    )


def _tc_plan_stats(
    coo: CyclicCOO, q: int, nnz_pad: int, tmax: int, m: int,
    skew_perm: Optional[np.ndarray] = None,
):
    """Balance statistics (paper Tables 3/4 analogues) from the sorted
    pass — fragment lengths come straight from ``rowcnt``.  ``skew_perm``
    indexes the per-shift probe by the σ visit order so stats stay
    aligned with the staged masks."""
    rowcnt3 = coo.rowcnt.reshape(q, q, coo.rows_loc)
    tasks = coo.counts.reshape(q, q).astype(np.int64)
    probe = np.zeros((q, q, q), dtype=np.int64)
    sp = (
        np.asarray(skew_perm, dtype=np.int64)
        if skew_perm is not None
        else np.arange(q, dtype=np.int64)
    )
    it_cell = np.zeros((q, q, q), dtype=np.int64)
    for x in range(q):
        for y in range(q):
            b = x * q + y
            lo, hi = coo.starts[b], coo.starts[b + 1]
            rows = coo.li_s[lo:hi]
            cols = coo.lj_s[lo:hi]
            for s in range(q):
                z = int(sp[(x + y + s) % q])
                la = rowcnt3[x, z][rows]
                lb = rowcnt3[y, z][cols]
                both = (la > 0) & (lb > 0)
                it_cell[x, y, s] = int(both.sum())
                probe[x, y, s] = int(np.minimum(la, lb)[both].sum())
    tot_idx = q * q * nnz_pad
    return PlanStats(
        tasks_per_device=tasks,
        nnz_per_block=tasks.copy(),
        probe_work_per_device_shift=probe,
        task_imbalance=float(tasks.max() / max(1.0, tasks.mean())),
        probe_imbalance=float(
            probe.sum(axis=2).max() / max(1.0, probe.sum(axis=2).mean())
        ),
        intersection_tasks_total=int(it_cell.sum()),
        padding_fraction_indices=float(1.0 - m / max(1, tot_idx)),
        padding_fraction_tasks=float(1.0 - m / max(1, q * q * tmax)),
        itasks_per_cell=it_cell,
    )


def cannon_step_keep(
    nnz_blocks: np.ndarray,
    m_cnt: np.ndarray,
    probe: Optional[np.ndarray],
    skew_perm: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Per-(device, shift) skip mask for the pre-skewed Cannon rotation.

    Device ``(x, y)`` at shift ``s`` holds ``A = U_{x,z}`` and
    ``B = U_{y,z}`` with ``z = σ[(x + y + s) % q]`` (``σ`` the
    visit-order permutation, identity by default), so its count that
    step is provably zero — and safe to skip — unless the device's task
    list and *both* incoming blocks are non-empty.  When the planner
    computed per-shift probe work (``with_stats``), the mask is refined
    to exact zero-work steps (``probe == 0`` ⇒ every task has an empty
    fragment side ⇒ count 0), which also prunes steps whose blocks are
    non-empty but never intersect a task row.
    """
    q = m_cnt.shape[0]
    x = np.arange(q)[:, None, None]
    y = np.arange(q)[None, :, None]
    s = np.arange(q)[None, None, :]
    z = (x + y + s) % q
    if skew_perm is not None:
        z = np.asarray(skew_perm, dtype=np.int64)[z]
    nz = nnz_blocks > 0
    keep = (m_cnt > 0)[:, :, None] & nz[x, z] & nz[y, z]
    if probe is not None:
        keep &= probe > 0
    return keep


def pack_tc_plan(
    graph: Graph,
    q: int,
    *,
    skew: bool = True,
    chunk: int = 512,
    with_stats: bool = True,
    keep_blocks: bool = True,
    step_masks: bool = True,
    skew_perm=None,
    aug_keys: bool = False,
    coo: Optional[CyclicCOO] = None,
) -> TCPlan:
    """Vectorized 2D-cyclic planner: the decompose+pack stages for the
    Cannon/2.5D family (see :func:`repro.core.plan.build_plan` for the
    placement semantics it implements).

    Emits the stacked ``(q, q, ...)`` device arrays directly from one
    lexsorted pass: the canonical block family is packed once and the
    (skewed) A/B placements are fancy-indexed gathers of it.
    ``skew_perm`` gathers through the σ visit order instead of the
    identity (:func:`choose_cannon_skew`); ``aug_keys`` emits the
    host-staged ``b_aug`` intersection keys for the placed B blocks.
    """
    n, m = graph.n, graph.m
    assert skew_perm is None or skew, "skew_perm is a Cannon-placement knob"
    if coo is None:
        coo = cyclic_coo(graph, q, q)
    nb = coo.rows_loc
    nnz_pad = max(1, coo.nnz_max)
    tmax = nnz_pad

    sp = (
        np.asarray(skew_perm, dtype=np.int64)
        if skew_perm is not None
        else None
    )
    c_ptr, c_idx = emit_block_arrays(coo, nnz_pad)
    x = np.arange(q)[:, None]
    y = np.arange(q)[None, :]
    if skew:
        z = (x + y) % q
        if sp is not None:
            z = sp[z]
        a_indptr, a_indices = c_ptr[x, z], c_idx[x, z]
        b_indptr, b_indices = c_ptr[y, z], c_idx[y, z]
    else:
        a_indptr, a_indices = c_ptr.copy(), c_idx.copy()
        b_indptr, b_indices = c_ptr[y, x], c_idx[y, x]

    m_ti, m_tj, m_cnt = _emit_tasks(coo, tmax)
    dmax = max(1, coo.row_len_max)

    stats = (
        _tc_plan_stats(coo, q, nnz_pad, tmax, m, skew_perm=sp)
        if with_stats
        else None
    )
    blocks = blocks_from_coo(coo) if keep_blocks else None

    step_keep = None
    if skew and step_masks:
        step_keep = cannon_step_keep(
            coo.counts.reshape(q, q),
            m_cnt,
            stats.probe_work_per_device_shift if stats is not None else None,
            skew_perm=sp,
        )

    b_aug = host_aug_keys(b_indptr, b_indices) if aug_keys else None

    return TCPlan(
        n=n,
        m=m,
        q=q,
        nb=nb,
        nnz_pad=nnz_pad,
        tmax=tmax,
        dmax=dmax,
        chunk=min(chunk, tmax),
        a_indptr=a_indptr,
        a_indices=a_indices,
        b_indptr=b_indptr,
        b_indices=b_indices,
        m_ti=m_ti,
        m_tj=m_tj,
        m_cnt=m_cnt,
        stats=stats,
        blocks=blocks,
        step_keep=step_keep,
        b_aug=b_aug,
        skew_perm=tuple(int(v) for v in sp) if sp is not None else None,
    )


def summa_probe_work(acoo: CyclicCOO, bcoo: CyclicCOO, r: int, c: int) -> np.ndarray:
    """Per-(device, round) probe work for SUMMA, ``(r, c, c)`` int64.

    Broadcast round ``z`` hands device ``(x, y)`` the A panel ``(x, z)``
    and the B panel ``(y, z)``; each task ``(i, j)`` of its mask block
    then intersects row ``i`` of the A panel with row ``j`` of the B
    panel, so the round's work is ``sum(min(la, lb))`` over tasks with
    both fragments non-empty (the SUMMA analogue of
    :func:`_tc_plan_stats`'s Cannon probe)."""
    rowcnt_a = acoo.rowcnt.reshape(r, c, acoo.rows_loc)
    rowcnt_b = bcoo.rowcnt.reshape(c, c, bcoo.rows_loc)
    probe = np.zeros((r, c, c), dtype=np.int64)
    for x in range(r):
        for y in range(c):
            b = x * c + y
            lo, hi = acoo.starts[b], acoo.starts[b + 1]
            rows = acoo.li_s[lo:hi]
            cols = acoo.lj_s[lo:hi]
            for z in range(c):
                la = rowcnt_a[x, z][rows]
                lb = rowcnt_b[y, z][cols]
                both = (la > 0) & (lb > 0)
                probe[x, y, z] = int(np.minimum(la, lb)[both].sum())
    return probe


def oned_probe_work(
    rowcnt: np.ndarray, t_i: np.ndarray, t_j: np.ndarray,
    gcnt: np.ndarray, p: int,
) -> np.ndarray:
    """Per-(device, ring step) probe work for the 1D baseline, ``(p, p)``.

    At ring step ``t`` device ``d`` holds owner ``o = (d + t) % p``'s row
    block and counts its task group ``(d, o)``: row ``i`` comes from its
    own block, row ``j`` from the arriving one."""
    probe = np.zeros((p, p), dtype=np.int64)
    for d in range(p):
        for o in range(p):
            cnt = int(gcnt[d * p + o])
            if not cnt:
                continue
            la = rowcnt[d][t_i[d * p + o, :cnt]]
            lb = rowcnt[o][t_j[d * p + o, :cnt]]
            both = (la > 0) & (lb > 0)
            probe[d, (o - d) % p] = int(np.minimum(la, lb)[both].sum())
    return probe


def _step_stats(probe: np.ndarray) -> StepStats:
    per_dev = probe.reshape(-1, probe.shape[-1]).sum(axis=1)
    return StepStats(
        probe_work_per_device_shift=probe,
        probe_imbalance=float(per_dev.max() / max(1.0, per_dev.mean()))
        if per_dev.size else 1.0,
    )


def pack_summa_plan(
    graph: Graph, r: int, c: int, *, chunk: int = 512,
    step_masks: bool = True, with_stats: bool = False,
) -> SummaPlan:
    """Vectorized SUMMA planner (semantics of
    :func:`repro.core.summa.build_summa_plan`): A/mask blocks from one
    ``(r, c)`` pass, B panels gathered from one ``(c, c)`` pass.

    ``with_stats`` computes per-round probe work (:class:`StepStats`) —
    the skip-aware rebalancer's cost input — and, like the Cannon
    packer, refines the skip mask to exact zero-work rounds."""
    n, m = graph.n, graph.m
    nb_r = -(-n // r)
    nb_c = -(-n // c)
    npan = -(-c // r)

    acoo = cyclic_coo(graph, r, c)
    bcoo = cyclic_coo(graph, c, c)
    a_nnz_pad = max(1, acoo.nnz_max)
    b_nnz_pad = max(1, bcoo.nnz_max)
    tmax = a_nnz_pad

    a_indptr, a_indices = emit_block_arrays(acoo, a_nnz_pad)
    m_ti, m_tj, m_cnt = _emit_tasks(acoo, tmax)

    cb_ptr, cb_idx = emit_block_arrays(bcoo, b_nnz_pad)
    b_indptr = np.zeros((r, c, npan, nb_c + 1), dtype=INT)
    b_indices = np.full((r, c, npan, b_nnz_pad), nb_c, dtype=INT)
    for kc in range(c):  # panel owner mapping: kc -> (row kc % r, slot kc // r)
        b_indptr[kc % r, :, kc // r] = cb_ptr[:, kc]
        b_indices[kc % r, :, kc // r] = cb_idx[:, kc]

    stats = None
    probe = None
    if with_stats:
        probe = summa_probe_work(acoo, bcoo, r, c)
        stats = _step_stats(probe)

    step_keep = None
    if step_masks:
        # step z broadcasts A panel (x, z) and B panel (y, z): skip the
        # count when the task list or either incoming panel is empty
        a_nz = acoo.counts.reshape(r, c) > 0
        b_nz = bcoo.counts.reshape(c, c) > 0
        step_keep = (
            (m_cnt > 0)[:, :, None] & a_nz[:, None, :] & b_nz[None, :, :]
        )
        if probe is not None:
            # probe == 0 ⇒ every task has an empty fragment side ⇒ the
            # round's count is provably zero even with non-empty panels
            step_keep &= probe > 0

    dmax = max(1, acoo.row_len_max, bcoo.row_len_max)
    return SummaPlan(
        n=n,
        m=m,
        r=r,
        c=c,
        nb_r=nb_r,
        nb_c=nb_c,
        npan=npan,
        a_nnz_pad=a_nnz_pad,
        b_nnz_pad=b_nnz_pad,
        tmax=tmax,
        dmax=dmax,
        chunk=min(chunk, tmax),
        a_indptr=a_indptr,
        a_indices=a_indices,
        b_indptr=b_indptr,
        b_indices=b_indices,
        m_ti=m_ti,
        m_tj=m_tj,
        m_cnt=m_cnt,
        step_keep=step_keep,
        stats=stats,
    )


def pack_oned_plan(
    graph: Graph, p: int, *, chunk: int = 512, step_masks: bool = True,
    with_stats: bool = False,
) -> OneDPlan:
    """Vectorized 1D planner (semantics of
    :func:`repro.core.onedim.build_oned_plan`): the per-device row CSR
    and the owner-grouped task lists are both single-sort scatters —
    the old per-edge Python fill loop is gone.

    ``with_stats`` computes per-step probe work (:class:`StepStats`) for
    the skip-aware rebalancer and refines the skip mask to exact
    zero-work ring steps."""
    n, m = graph.n, graph.m
    nb = -(-n // p)
    i = graph.edges[:, 0]
    j = graph.edges[:, 1]
    own = i % p

    # per-device CSR over local rows, global sorted cols
    order = np.lexsort((j, i, own))
    i_s, j_s, own_s = i[order], j[order], own[order]
    dev_cnt = np.bincount(own_s, minlength=p)
    nnz_pad = max(1, int(dev_cnt.max()) if m else 0)
    dev_starts = np.zeros(p + 1, dtype=np.int64)
    np.cumsum(dev_cnt, out=dev_starts[1:])
    rowcnt = np.bincount(own_s * nb + i_s // p, minlength=p * nb).reshape(p, nb)
    indptr = np.zeros((p, nb + 1), dtype=INT)
    np.cumsum(rowcnt, axis=1, out=indptr[:, 1:])
    indices = np.full((p, nnz_pad), n + 1, dtype=INT)
    indices[own_s, np.arange(m, dtype=np.int64) - dev_starts[own_s]] = j_s

    # task groups: device d = i%p, group o = j%p (stable in edge order)
    gid = own * p + j % p
    gorder = np.argsort(gid, kind="stable")
    gid_s = gid[gorder]
    gcnt = np.bincount(gid_s, minlength=p * p)
    gmax = max(1, int(gcnt.max()) if m else 0)
    gstarts = np.zeros(p * p + 1, dtype=np.int64)
    np.cumsum(gcnt, out=gstarts[1:])
    goffs = np.arange(m, dtype=np.int64) - gstarts[gid_s]
    t_i = np.zeros((p * p, gmax), dtype=INT)
    t_j = np.zeros((p * p, gmax), dtype=INT)
    t_i[gid_s, goffs] = i[gorder] // p
    t_j[gid_s, goffs] = j[gorder] // p

    stats = None
    probe = None
    if with_stats:
        probe = oned_probe_work(rowcnt, t_i, t_j, gcnt, p)
        stats = _step_stats(probe)

    step_keep = None
    if step_masks:
        # device d at ring step t holds owner o = (d + t) % p's rotating
        # row block and counts its task group (d, o): skip when either
        # the group or the incoming block is empty
        d = np.arange(p)[:, None]
        t = np.arange(p)[None, :]
        o = (d + t) % p
        t_cnt_pp = gcnt.reshape(p, p)
        step_keep = (t_cnt_pp[d, o] > 0) & (dev_cnt[o] > 0)
        if probe is not None:
            step_keep &= probe > 0

    dmax = max(1, int(rowcnt.max()) if m else 0)
    return OneDPlan(
        n=n,
        m=m,
        p=p,
        nb=nb,
        nnz_pad=nnz_pad,
        gmax=gmax,
        dmax=dmax,
        chunk=min(chunk, gmax),
        indptr=indptr,
        indices=indices,
        t_i=t_i.reshape(p, p, gmax),
        t_j=t_j.reshape(p, p, gmax),
        t_cnt=gcnt.reshape(p, p).astype(INT),
        step_keep=step_keep,
        stats=stats,
    )


# ======================================================================
# schedule compaction: dead-shift elision + σ visit-order search
# ======================================================================
_SKEW_SEARCH_MAX_Q = 8  # q! permutations; beyond this keep the identity


def choose_cannon_skew(step_keep: np.ndarray):
    """Pick the visit-order permutation σ minimizing globally-live steps.

    Any σ is a valid Cannon alignment (placement ``A0[x,y] =
    U_{x,σ[(x+y)%q]}`` with the same unit shifts), so the planner is
    free to *reorder which k-panel every device sees at which step*.
    Liveness only depends on the per-diagonal-class union of live panels
    ``W[d, z] = ∃(x,y): (x+y)%q == d and panel z live at (x, y)``; step
    ``s`` is dead under σ iff ``σ[(d+s)%q] ∉ W[d]`` for every class
    ``d``.  Exhaustive over ``q!`` permutations (q ≤ 8; lexicographic
    order, identity first, first minimum wins — deterministic), identity
    beyond.

    Returns ``(σ tuple, n_live under σ)``; σ is the identity whenever it
    is already optimal, so dense graphs re-pack to byte-identical plans.
    """
    import itertools

    keep = np.asarray(step_keep, dtype=bool)
    q = keep.shape[-1]
    x = np.arange(q)[:, None, None]
    y = np.arange(q)[None, :, None]
    s = np.arange(q)[None, None, :]
    # live panels per device: keep is indexed by step; panel at step s is
    # z = (x+y+s)%q under the identity placement the mask was packed with
    z = (x + y + s) % q
    d = np.broadcast_to((x + y) % q, z.shape)
    W = np.zeros((q, q), dtype=bool)
    np.logical_or.at(W, (d.ravel(), z.ravel()), keep.ravel())

    dd = np.arange(q)[:, None]
    ss = np.arange(q)[None, :]
    identity = tuple(range(q))
    n_live_id = int(W[dd, (dd + ss) % q].any(axis=0).sum())
    if n_live_id <= 1 or q > _SKEW_SEARCH_MAX_Q:
        return identity, n_live_id
    perms = np.array(list(itertools.permutations(range(q))), dtype=np.int64)
    # visit[p, d, s] = σ_p[(d+s)%q]; live step s under σ_p iff any class
    # d has its visited panel in W[d]
    visit = perms[np.arange(perms.shape[0])[:, None, None], (dd + ss)[None] % q]
    live = W[dd[None], visit]
    n_live = live.any(axis=1).sum(axis=1)
    best = int(np.argmin(n_live))  # first minimum: identity wins ties at σ=id
    return tuple(int(v) for v in perms[best]), int(n_live[best])


def compact_stage(plan, *, repack=None):
    """Attach the compacted executable schedule to a packed plan.

    Computes the globally-live step list from the staged ``step_keep``
    mask (:func:`repro.core.plan.compact_live_steps`).  For Cannon plans
    a ``repack`` callable re-packs the graph under the live-minimizing σ
    visit order first (:func:`choose_cannon_skew`) when that beats the
    identity; SUMMA rounds and ring steps have no free visit order, so
    their dead steps are elided in place.  No-op (returns the plan
    unchanged) when the plan has no skip mask.
    """
    keep = getattr(plan, "step_keep", None)
    if keep is None:
        return plan
    if repack is not None:
        sigma, n_live = choose_cannon_skew(keep)
        if list(sigma) != list(range(len(sigma))):
            plan = repack(sigma)
            keep = plan.step_keep
    plan.compact = compact_live_steps(keep)
    return plan


# ======================================================================
# deterministic kernel-shape autotune (chunk + two-level split)
# ======================================================================
_CHUNK_BUDGET = 1 << 17  # probe-panel elements one chunk may gather
_CHUNK_MIN, _CHUNK_MAX = 64, 4096
_TAIL_PERCENTILE = 90.0


def _pick_chunk(tmax: int, d_eff: int) -> int:
    """Deterministic chunk: the smallest power of two covering the task
    list, capped so one chunk's gathered probe panel (``chunk * d_eff``
    elements) stays within a fixed budget — fewer scan iterations on
    small blocks, bounded working set on large ones."""
    cap = max(_CHUNK_MIN, _CHUNK_BUDGET // max(1, int(d_eff)))
    c = _CHUNK_MIN
    while c < min(max(1, tmax), cap):
        c <<= 1
    return int(max(_CHUNK_MIN, min(c, _CHUNK_MAX)))


def _tail_split(need: np.ndarray, dmax: int):
    """Percentile split of the per-task probe-length distribution:
    ``d_small`` = p90 rounded up to a multiple of 8 (≥ 8), ``tail_heavy``
    when the max exceeds twice that — the regime where flat ``dmax``
    padding wastes ≥ 2x on ≥ 90% of tasks and ``search2`` pays off."""
    if need.size == 0:
        return min(8, max(1, dmax)), False
    p = float(np.percentile(need, _TAIL_PERCENTILE))
    d_small = int(min(max(8, int(-(-p // 8)) * 8), dmax))
    return d_small, bool(dmax > 2 * d_small)


def _autotune_tasks(ti3, tj3, cnt, need_rows_of, dmax, tmax,
                    need_rows_b_of=None):
    """Shared autotune body: per-task probe lengths → percentile
    ``d_small``/``n_long`` split, stable long-first task reorder, and the
    deterministic chunk.  Returns ``(new_ti, new_tj, chunk, report)``.

    With ``need_rows_b_of`` the split is *two-sided* ("maxfrag"): a task
    is short only when BOTH fragments fit in ``d_small`` — required by
    the fused panel kernel, which gathers the A and B fragments at
    ``d_small`` and would silently truncate a long B row under the
    probe-only criterion."""
    ti = ti3.reshape(-1, ti3.shape[-1])
    tj = tj3.reshape(-1, tj3.shape[-1])
    cnt = np.asarray(cnt).reshape(-1)
    new_ti = ti.copy()
    new_tj = tj.copy()

    def _need(b):
        c = int(cnt[b])
        if not c:
            return np.zeros(0, np.int64)
        need = need_rows_of(b)[ti[b, :c]]
        if need_rows_b_of is not None:
            need = np.maximum(need, need_rows_b_of(b)[tj[b, :c]])
        return need

    per_dev = [_need(b) for b in range(ti.shape[0])]
    needs_all = (
        np.concatenate(per_dev) if per_dev else np.zeros(0, np.int64)
    )
    d_small, tail_heavy = _tail_split(needs_all, dmax)
    n_long_max = 0
    for b in range(ti.shape[0]):
        c = int(cnt[b])
        if not c:
            continue
        long_mask = per_dev[b] > d_small
        order = np.argsort(~long_mask, kind="stable")  # long tasks first
        new_ti[b, :c] = ti[b, :c][order]
        new_tj[b, :c] = tj[b, :c][order]
        n_long_max = max(n_long_max, int(long_mask.sum()))
    chunk = max(
        1, min(_pick_chunk(tmax, d_small if tail_heavy else dmax), tmax)
    )
    report = dict(
        chunk=int(chunk),
        d_small=int(d_small),
        n_long=int(n_long_max),
        dmax=int(dmax),
        tail_heavy=tail_heavy,
        split="maxfrag" if need_rows_b_of is not None else "probe",
        probe_p90=float(np.percentile(needs_all, _TAIL_PERCENTILE))
        if needs_all.size
        else 0.0,
    )
    return new_ti.reshape(ti3.shape), new_tj.reshape(tj3.shape), chunk, report


def autotune_tc_plan(plan: TCPlan, two_sided: bool = False) -> TCPlan:
    """Deterministic kernel-shape autotune for Cannon plans (DESIGN.md
    §5): per-task probe lengths (max over every pairing a task can meet)
    come straight from the packed ``a_indptr`` — grid row ``x`` holds
    every panel of block-row ``x`` across its columns, so the row-wise
    max over ``y`` is the max over ``z`` regardless of the σ visit
    order.  No timing, no randomness: same plan in, same shapes out
    (the property the plan cache key relies on).

    ``two_sided=True`` switches to the fused kernel's maxfrag split:
    B-side lengths come from ``b_indptr`` the same way (grid *column*
    ``y`` holds every panel of block-column ``y`` across its rows)."""
    import dataclasses as _dc

    q = plan.q
    lens = np.diff(plan.a_indptr.astype(np.int64), axis=2)  # (q, q, nb)
    need_rows = lens.max(axis=1)  # (q, nb): max over all panels of row x
    need_b_of = None
    if two_sided:
        lens_b = np.diff(plan.b_indptr.astype(np.int64), axis=2)
        need_rows_b = lens_b.max(axis=0)  # (q, nb): max over column y
        need_b_of = lambda b: need_rows_b[b % q]  # noqa: E731

    new_ti, new_tj, chunk, report = _autotune_tasks(
        plan.m_ti, plan.m_tj, plan.m_cnt, lambda b: need_rows[b // q],
        plan.dmax, plan.tmax, need_rows_b_of=need_b_of,
    )
    return _dc.replace(
        plan, m_ti=new_ti, m_tj=new_tj, chunk=chunk,
        n_long=report["n_long"], d_small=report["d_small"],
        autotune=report,
    )


def autotune_summa_plan(plan: SummaPlan, two_sided: bool = False) -> SummaPlan:
    """SUMMA autotune: the probe side is the A panel row, so per-task
    lengths are the max over broadcast rounds of the ``a_indptr`` row
    lengths (panel ``(x, z)`` sits at grid position ``(x, z)``).  With
    ``two_sided=True`` the maxfrag split also folds in the B panel rows:
    device column ``y`` sees exactly the panels stored at
    ``b_indptr[:, y, :]``, so the max over (grid row, panel slot) is the
    max over broadcast rounds."""
    import dataclasses as _dc

    c = plan.c
    lens = np.diff(plan.a_indptr.astype(np.int64), axis=2)  # (r, c, nb_r)
    need_rows = lens.max(axis=1)  # (r, nb_r)
    need_b_of = None
    if two_sided:
        lens_b = np.diff(plan.b_indptr.astype(np.int64), axis=3)
        need_rows_b = lens_b.max(axis=(0, 2))  # (c, nb_c)
        need_b_of = lambda b: need_rows_b[b % c]  # noqa: E731

    new_ti, new_tj, chunk, report = _autotune_tasks(
        plan.m_ti, plan.m_tj, plan.m_cnt, lambda b: need_rows[b // c],
        plan.dmax, plan.tmax, need_rows_b_of=need_b_of,
    )
    return _dc.replace(
        plan, m_ti=new_ti, m_tj=new_tj, chunk=chunk,
        n_long=report["n_long"], d_small=report["d_small"],
        autotune=report,
    )


def autotune_oned_plan(plan: OneDPlan, two_sided: bool = False) -> OneDPlan:
    """1D-ring autotune: chunk only by default.  The ring's B columns are
    *global* ids (they rotate whole adjacency rows), so the block-local
    global-key two-level kernel does not apply — ``tail_heavy`` is
    reported for visibility but ``method='auto'`` resolves to ``search``
    on this schedule, and no two-level split lands on the plan.

    ``two_sided=True`` (fused): the panel path compares raw column ids,
    which IS valid on global ids, so the maxfrag split and long-first
    reorder land on the plan — task (d, o) intersects device ``d``'s row
    ``t_i`` with partner ``o``'s row ``t_j``."""
    import dataclasses as _dc

    lens = np.diff(plan.indptr.astype(np.int64), axis=1)  # (p, nb)
    p = plan.p

    new_ti, new_tj, chunk, report = _autotune_tasks(
        plan.t_i, plan.t_j, plan.t_cnt, lambda b: lens[b // p],
        plan.dmax, plan.gmax,
        need_rows_b_of=(lambda b: lens[b % p]) if two_sided else None,
    )
    if two_sided:
        return _dc.replace(
            plan, t_i=new_ti, t_j=new_tj, chunk=chunk,
            n_long=report["n_long"], d_small=report["d_small"],
            autotune=report,
        )
    # task order stays put (the two-level boundary is unused here)
    return _dc.replace(
        plan, chunk=chunk, autotune=dict(report, n_long=None, d_small=None)
    )


def timed(name: str, seconds: dict, fn, *args, **kwargs):
    """Run one stage, recording its wall time under ``name``."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    seconds[name] = time.perf_counter() - t0
    return out
