"""Distributed runtime: elasticity, plan rebalancing, fault handling,
deterministic fault injection, and the restart supervisor."""
from .elastic import best_grid, replan_elastic  # noqa: F401
from .rebalance import rebalance_plan  # noqa: F401
from .fault import run_with_restarts  # noqa: F401
from .faultinject import (  # noqa: F401
    CkptCorrupt,
    DeviceLost,
    FaultPlan,
    InjectedFault,
    StageFault,
    StepFault,
)
from .supervisor import (  # noqa: F401
    BackoffPolicy,
    GridTransferRefused,
    Supervisor,
    supervised_count,
    supervise_loop,
)
