"""Distributed runtime: elasticity, plan rebalancing, fault handling."""
from .elastic import best_grid, replan_elastic  # noqa: F401
from .rebalance import rebalance_plan  # noqa: F401
from .fault import run_with_restarts  # noqa: F401
