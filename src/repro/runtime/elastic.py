"""Elastic scaling: re-factorize the grid after device count changes.

Cannon needs a square grid; after losing devices the framework falls back
to the best rectangular factorization under the SUMMA schedule (the
paper's own §8 suggestion) and replans.  Since PR 10, ``replan_elastic``
plans through :mod:`repro.pipeline` — the content-addressed plan cache,
skip masks, schedule compaction, rebalance and hub-split all survive an
elastic re-plan, where the legacy path silently dropped every one of
them.  Checkpointed mid-schedule partials do **not** transfer across
grids (see :func:`repro.runtime.supervisor.check_partials_portable`);
only completed-graph / stream-round boundaries are portable.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional, Tuple

__all__ = ["best_grid", "replan_elastic"]


def best_grid(n_devices: int, *, require_square: bool = False) -> Tuple[int, int]:
    """Largest usable (r, c) with r*c <= n_devices.

    Prefers square; falls back to the most-square factorization where the
    larger dim is a multiple of the smaller (SUMMA panel-slot requirement).
    """
    q = int(math.isqrt(n_devices))
    if require_square:
        return q, q
    best = (1, 1)
    for r in range(1, n_devices + 1):
        c = n_devices // r
        if c < r:
            break
        if c % r == 0 and r * c <= n_devices:
            # prefer larger area, then most-square (largest r)
            if (r * c, r) > (best[0] * best[1], best[0]):
                best = (r, c)
    if best == (1, 1):
        best = (q, q)
    return best


def replan_elastic(
    graph,
    n_devices: int,
    *,
    schedule: Optional[str] = None,
    chunk: int = 512,
    reorder: bool = True,
    cyclic_p: Optional[int] = None,
    compact: bool = True,
    rebalance_trials: int = 0,
    hub_split=False,
    cache=None,
    legacy: bool = False,
):
    """Re-plan for a new device count through the pipeline planner.

    Returns ``(schedule_name, artifact, (r, c))`` where ``artifact`` is a
    :class:`repro.pipeline.PlanArtifact` — plan features (skip masks,
    compaction, rebalance seed, hub cut) and cache behavior are
    identical to a cold pipeline plan at the new grid, so nothing is
    lost to elasticity.  ``schedule="cannon"`` forces the square
    factorization; the default picks Cannon when the best factorization
    is square and SUMMA otherwise.

    ``legacy=True`` (deprecated) reproduces the pre-PR-10 raw-plan
    return built by the legacy planners — no cache, no masks, no
    compaction; it exists only for old callers and will be removed.
    """
    if legacy:
        warnings.warn(
            "replan_elastic(legacy=True) bypasses the pipeline (no plan "
            "cache, skip masks, compaction, rebalance or hub-split) and "
            "will be removed; drop legacy= to plan through the pipeline",
            DeprecationWarning,
            stacklevel=2,
        )
        from ..core.plan import build_plan
        from ..core.summa import build_summa_plan

        r, c = best_grid(n_devices)
        if r == c:
            return "cannon", build_plan(graph, r, chunk=chunk), (r, c)
        return "summa", build_summa_plan(graph, r, c, chunk=chunk), (r, c)

    from ..pipeline import plan_cannon, plan_summa

    if schedule == "cannon":
        r, c = best_grid(n_devices, require_square=True)
    elif schedule == "summa":
        r, c = best_grid(n_devices)
    else:
        r, c = best_grid(n_devices)
    common = dict(
        chunk=chunk,
        reorder=reorder,
        cyclic_p=cyclic_p,
        compact=compact,
        rebalance_trials=rebalance_trials,
        hub_split=hub_split,
        cache=cache,
    )
    if r == c and schedule != "summa":
        art = plan_cannon(graph, r, **common)
        return "cannon", art, (r, c)
    art = plan_summa(graph, r, c, **common)
    return "summa", art, (r, c)
