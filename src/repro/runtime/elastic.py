"""Elastic scaling: re-factorize the grid after device count changes.

Cannon needs a square grid; after losing devices the framework falls back
to the best rectangular factorization under the SUMMA schedule (the
paper's own §8 suggestion) and replans.  Checkpointed TC state (shift
index + partial counts) or training state (global arrays) restores onto
the new mesh via :mod:`repro.ckpt`.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

__all__ = ["best_grid", "replan_elastic"]


def best_grid(n_devices: int, *, require_square: bool = False) -> Tuple[int, int]:
    """Largest usable (r, c) with r*c <= n_devices.

    Prefers square; falls back to the most-square factorization where the
    larger dim is a multiple of the smaller (SUMMA panel-slot requirement).
    """
    q = int(math.isqrt(n_devices))
    if require_square:
        return q, q
    best = (1, 1)
    for r in range(1, n_devices + 1):
        c = n_devices // r
        if c < r:
            break
        if c % r == 0 and r * c <= n_devices:
            # prefer larger area, then most-square (largest r)
            if (r * c, r) > (best[0] * best[1], best[0]):
                best = (r, c)
    if best == (1, 1):
        best = (q, q)
    return best


def replan_elastic(graph, n_devices: int, *, chunk: int = 512):
    """Re-plan for a new device count: square -> Cannon, else SUMMA."""
    from ..core.plan import build_plan
    from ..core.summa import build_summa_plan

    r, c = best_grid(n_devices)
    if r == c:
        return "cannon", build_plan(graph, r, chunk=chunk), (r, c)
    return "summa", build_summa_plan(graph, r, c, chunk=chunk), (r, c)
