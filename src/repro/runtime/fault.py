"""Fault-tolerant execution wrapper: checkpoint/restart with retries.

``run_with_restarts`` is the seed-era front door, kept for its callers;
since PR 10 it delegates to :func:`repro.runtime.supervisor.supervise_loop`
— the same supervised driver the TC stepper uses — so restarts get
exponential backoff + jitter, a structured attempt record, and corrupt
checkpoints are quarantined instead of crashing the restore.
"""
from __future__ import annotations

import logging
from typing import Callable, Optional

log = logging.getLogger(__name__)

__all__ = ["run_with_restarts"]


def run_with_restarts(
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    max_restarts: int = 3,
    state_like=None,
    fault_injector: Optional[Callable[[int], None]] = None,
):
    """Run ``step_fn`` n_steps times with checkpoint/restart semantics.

    ``fault_injector(step)`` may raise to simulate failures (used by tests
    and the fault-tolerance example).  Any exception is restartable, as
    before.  Returns the final state dict.
    """
    from .supervisor import BackoffPolicy, Supervisor, supervise_loop

    sup = Supervisor(
        max_restarts=max_restarts,
        backoff=BackoffPolicy(base=0.01, max_delay=0.05),
        retry_on=(Exception,),
    )
    state, _report = supervise_loop(
        init_state,
        step_fn,
        n_steps=n_steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every,
        supervisor=sup,
        state_like=state_like,
        fault_injector=fault_injector,
    )
    return state
