"""Fault-tolerant execution wrapper: checkpoint/restart with retries.

``run_with_restarts`` drives a step function with periodic checkpoints;
on failure (device loss / preemption / injected fault) it restores the
latest checkpoint — optionally onto a smaller elastic grid — and
continues.  The TC driver uses shift-level state (shift index + partial
counts); training uses (step, params, opt, rng).
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

from ..ckpt import CheckpointManager

log = logging.getLogger(__name__)

__all__ = ["run_with_restarts"]


def run_with_restarts(
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    max_restarts: int = 3,
    state_like=None,
    fault_injector: Optional[Callable[[int], None]] = None,
):
    """Run ``step_fn`` n_steps times with checkpoint/restart semantics.

    ``fault_injector(step)`` may raise to simulate failures (used by tests
    and the fault-tolerance example).  Returns the final state dict.
    """
    mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)
    restarts = 0
    state = None
    start = 0

    like = state_like or init_state()
    got_step, restored, extra = mgr.restore_latest(like)
    if restored is not None:
        state, start = restored, int(extra["next_step"])
        log.info("resumed from step %d", start)
    else:
        state = init_state()

    step = start
    while step < n_steps:
        try:
            if fault_injector is not None:
                fault_injector(step)
            state = step_fn(state, step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                mgr.save(step, state, extra={"next_step": step})
        except Exception as e:  # noqa: BLE001
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restarting", step, e)
            got_step, restored, extra = mgr.restore_latest(like)
            if restored is None:
                state, step = init_state(), 0
            else:
                state, step = restored, int(extra["next_step"])
            time.sleep(0.01)
    mgr.close()
    return state
