"""Deterministic fault injection for the supervised execution layer.

A :class:`FaultPlan` is a seeded, declarative list of faults to raise at
named injection points threaded through the hot paths:

========================  ====================================================
point                     where it fires
========================  ====================================================
``plan_stage``            :func:`repro.pipeline.planner._drive` (host planning)
``device_stage``          runner ``mark_counting`` — host→device staging done
``step``                  each stepper shift, by **original** step index (so a
                          fault registered at an elided step composes with
                          schedule compaction and simply never fires)
``fused``                 fused-kernel factory dispatch
``delta_splice``          :func:`repro.pipeline.delta.apply_delta` splice path
``ckpt_save``             :meth:`repro.ckpt.CheckpointManager.save` — raising
                          faults fire *before* the write; ``CkptCorrupt``
                          sites instead flip a byte of the just-written
                          payload (exercising the restore quarantine path)
========================  ====================================================

Faults are *typed* (:class:`DeviceLost`, :class:`StepFault`,
:class:`StageFault`, :class:`CkptCorrupt`) so the supervisor can route
each to its recovery path.  Sites fire a bounded number of ``times``
(default once), which is what makes recovery deterministic: the retry of
a one-shot fault succeeds.

Arming is ambient: ``with plan.armed(): ...`` (or the module-level
:func:`armed`) sets the process-wide active plan consulted by
:func:`fire`; ``count_triangles(fault_plan=)`` and ``tc_run
--inject-faults SPEC`` arm through the same mechanism.  ``fire`` is a
cheap no-op when nothing is armed, so instrumented hot paths cost one
global read in production.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import random
from typing import List, Optional, Tuple

__all__ = [
    "InjectedFault",
    "DeviceLost",
    "StepFault",
    "StageFault",
    "CkptCorrupt",
    "FaultSite",
    "FaultPlan",
    "POINTS",
    "armed",
    "active_plan",
    "is_armed",
    "fire",
    "live_step_indices",
]


class InjectedFault(RuntimeError):
    """Base class of all typed injected faults."""


class DeviceLost(InjectedFault):
    """Simulated loss of ``lost`` devices — the supervisor answers with
    an elastic regrid (re-factorize via ``best_grid``, re-plan, re-count
    from the last globally consistent boundary)."""

    def __init__(self, message: str = "injected device loss", *, lost: int = 1):
        super().__init__(message)
        self.lost = int(lost)


class StepFault(InjectedFault):
    """A schedule step failed mid-count (transient kernel/dispatch
    error) — restartable in place."""


class StageFault(InjectedFault):
    """Host planning or host→device staging failed — restartable in
    place (planning is deterministic and cached)."""


class CkptCorrupt(InjectedFault):
    """Checkpoint payload corruption.  At the ``ckpt_save`` point this
    does not raise: the just-written payload gets a byte flipped so the
    *restore* path exercises digest verification + quarantine."""


_FAULT_TYPES = {
    "devicelost": DeviceLost,
    "device_lost": DeviceLost,
    "stepfault": StepFault,
    "step_fault": StepFault,
    "stagefault": StageFault,
    "stage_fault": StageFault,
    "ckptcorrupt": CkptCorrupt,
    "ckpt_corrupt": CkptCorrupt,
}

POINTS = (
    "plan_stage",
    "device_stage",
    "step",
    "fused",
    "delta_splice",
    "ckpt_save",
)

# default fault type per point when the spec names only the point
_DEFAULT_FAULT = {
    "plan_stage": StageFault,
    "device_stage": StageFault,
    "step": StepFault,
    "fused": StepFault,
    "delta_splice": StageFault,
    "ckpt_save": CkptCorrupt,
}


@dataclasses.dataclass
class FaultSite:
    """One armed fault: fire ``fault`` at ``point`` (optionally only at
    original step index ``step``) up to ``times`` times (-1 = always)."""

    point: str
    fault: type = StepFault
    step: Optional[int] = None
    times: int = 1
    lost: int = 1  # DeviceLost payload
    fired: int = 0

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; known: {POINTS}"
            )
        if not (isinstance(self.fault, type)
                and issubclass(self.fault, InjectedFault)):
            raise ValueError(f"fault must be an InjectedFault subclass, "
                             f"got {self.fault!r}")

    def matches(self, point: str, step: Optional[int]) -> bool:
        if point != self.point:
            return False
        if self.times != -1 and self.fired >= self.times:
            return False
        if self.step is not None and step != self.step:
            return False
        return True

    def describe(self) -> str:
        s = self.point
        if self.step is not None:
            s += f"@{self.step}"
        s += f"={self.fault.__name__}"
        if self.fault is DeviceLost and self.lost != 1:
            s += f":{self.lost}"
        if self.times != 1:
            s += f"*{self.times}"
        return s


def _parse_site(token: str) -> FaultSite:
    """``point[@STEP][=FAULT[:LOST]][*TIMES]`` — e.g. ``step@2``,
    ``step@1=devicelost:5``, ``fused=stepfault*-1``, ``ckpt_save``."""
    times = 1
    if "*" in token:
        token, times_s = token.rsplit("*", 1)
        times = int(times_s)
    fault_s = None
    if "=" in token:
        token, fault_s = token.split("=", 1)
    step = None
    if "@" in token:
        token, step_s = token.split("@", 1)
        step = int(step_s)
    point = token.strip()
    lost = 1
    if fault_s is None:
        fault = _DEFAULT_FAULT.get(point, StepFault)
    else:
        fault_s = fault_s.strip().lower()
        if ":" in fault_s:
            fault_s, lost_s = fault_s.split(":", 1)
            lost = int(lost_s)
        try:
            fault = _FAULT_TYPES[fault_s]
        except KeyError:
            raise ValueError(
                f"unknown fault type {fault_s!r}; known: "
                f"{sorted(set(_FAULT_TYPES))}"
            ) from None
    return FaultSite(point=point, fault=fault, step=step, times=times,
                     lost=lost)


class FaultPlan:
    """A deterministic set of :class:`FaultSite`\\ s plus a firing log.

    ``seed`` drives :meth:`random` site generation and nothing else —
    firing itself is fully determined by the sites and the execution
    order of the instrumented points.
    """

    def __init__(self, sites, *, seed: int = 0):
        self.sites: List[FaultSite] = list(sites)
        self.seed = int(seed)
        self.log: List[dict] = []

    # ------------------------------------------------------------------
    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "FaultPlan":
        """Parse a ``tc_run --inject-faults`` spec: ``';'``-separated
        site tokens (see :func:`_parse_site` for the grammar)."""
        tokens = [t.strip() for t in spec.split(";") if t.strip()]
        if not tokens:
            raise ValueError(f"empty fault spec {spec!r}")
        return cls([_parse_site(t) for t in tokens], seed=seed)

    @classmethod
    def random(cls, *, n_steps: int, k: int = 1, seed: int = 0,
               points: Tuple[str, ...] = ("step",)) -> "FaultPlan":
        """``k`` seeded one-shot faults at random points/steps — the
        property-test front door."""
        rng = random.Random(seed)
        sites = []
        for _ in range(k):
            point = rng.choice(points)
            step = rng.randrange(n_steps) if point == "step" else None
            sites.append(FaultSite(point=point,
                                   fault=_DEFAULT_FAULT[point], step=step))
        return cls(sites, seed=seed)

    # ------------------------------------------------------------------
    def spent(self) -> bool:
        """True when every bounded site has fired its quota."""
        return all(
            s.times != -1 and s.fired >= s.times for s in self.sites
        )

    def fire(self, point: str, *, step: Optional[int] = None,
             path: Optional[str] = None, **info) -> None:
        for site in self.sites:
            if not site.matches(point, step):
                continue
            # CkptCorrupt at ckpt_save corrupts the written payload, so
            # it only fires on the post-write call (which passes `path`);
            # every raising fault fires on the pre-write/point call.
            corrupting = site.fault is CkptCorrupt and point == "ckpt_save"
            if corrupting != (path is not None):
                continue
            site.fired += 1
            entry = dict(point=point, step=step,
                         fault=site.fault.__name__, **info)
            self.log.append(entry)
            if corrupting:
                _flip_byte(path)
                return
            if site.fault is DeviceLost:
                raise DeviceLost(
                    f"injected device loss at {point}"
                    + (f" step {step}" if step is not None else ""),
                    lost=site.lost,
                )
            raise site.fault(
                f"injected {site.fault.__name__} at {point}"
                + (f" step {step}" if step is not None else "")
            )

    @contextlib.contextmanager
    def armed(self):
        """Arm this plan process-wide for the duration of the block."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield self
        finally:
            _ACTIVE = prev

    def describe(self) -> str:
        return ";".join(s.describe() for s in self.sites)


def _flip_byte(path: str) -> None:
    """Flip one payload byte in place (deterministic: mid-file)."""
    size = os.path.getsize(path)
    pos = max(0, size // 2)
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1) or b"\x00"
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
        f.flush()
        os.fsync(f.fileno())


# ----------------------------------------------------------------------
# ambient arming
# ----------------------------------------------------------------------
_ACTIVE: Optional[FaultPlan] = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def is_armed() -> bool:
    return _ACTIVE is not None


def armed(plan: Optional[FaultPlan]):
    """Module-level arming helper; ``armed(None)`` is a no-op block."""
    if plan is None:
        return contextlib.nullcontext()
    return plan.armed()


def fire(point: str, *, step: Optional[int] = None,
         path: Optional[str] = None, **info) -> None:
    """Fire any armed fault matching ``point``/``step``.  No-op (one
    global read) when no plan is armed."""
    plan = _ACTIVE
    if plan is None:
        return
    plan.fire(point, step=step, path=path, **info)


# ----------------------------------------------------------------------
# helpers shared by the instrumented call sites and the test suite
# ----------------------------------------------------------------------
def live_step_indices(plan, compact_enabled: bool = True) -> List[int]:
    """Original step indices the engine will actually execute.

    Under a compacted schedule only the globally-live steps run, so a
    ``step@s`` fault registered at an elided ``s`` never fires — the
    injection point composes with compaction by construction.
    """
    cs = getattr(plan, "compact", None)
    if compact_enabled and cs is not None and cs.n_elided > 0:
        return list(cs.live_steps)
    if cs is not None:
        return list(range(cs.n_total))
    sk = getattr(plan, "step_keep", None)
    if sk is not None:
        return list(range(int(sk.shape[-1])))
    for attr in ("q", "c", "p"):
        v = getattr(plan, attr, None)
        if v:
            return list(range(int(v)))
    return []
