"""Plan rebalancer — straggler mitigation at the planning level.

In lockstep SPMD the slowest device per shift sets the pace, so the lever
against stragglers is *balance*: the paper relies on degree-ordered cyclic
distribution (Table 3 measures <= 6% task imbalance / 1.05-1.14 per-shift
runtime imbalance).  We go further (beyond paper): a randomized-relabeling
search perturbs the vertex order *within equal-degree runs* and keeps the
seed minimizing the **masked critical path** — the max per-device probe
work on *kept* (non-skipped) steps per shift, i.e. what the engine
actually executes with sparsity-aware step skipping on.

This module is the thin front-end; the search itself is the pipeline's
composable rebalance stage (:mod:`repro.pipeline.rebalance`, DESIGN.md
§4.3), so it runs behind the content-addressed plan cache and supports
all three schedules.  Gains are measured in
benchmarks/table3_imbalance.py.
"""
from __future__ import annotations

from typing import Tuple

from ..core.graph import Graph

__all__ = ["rebalance_plan"]


def rebalance_plan(
    graph: Graph,
    q: int,
    *,
    trials: int = 8,
    chunk: int = 512,
    schedule: str = "cannon",
    cache=None,
) -> Tuple[object, dict]:
    """Search relabeling seeds; return the best-balanced plan + report.

    Pipeline-backed: plans the *raw* graph through the cached planning
    pipeline with its skip-aware rebalance stage.  ``schedule`` picks the
    plan family — ``cannon`` (``q x q``), ``summa`` (``q x q``), or
    ``oned`` (``p = q``).  The report carries the trial history, the
    winning seed, ``baseline/best`` masked critical paths, the
    ``improvement`` ratio (baseline / best, guarded only against a
    literal-zero best), and the winner's ``skipped_steps``.
    """
    from ..pipeline import plan_cannon, plan_oned, plan_summa

    trials = max(1, int(trials))
    if schedule == "cannon":
        art = plan_cannon(
            graph, q, chunk=chunk, keep_blocks=False,
            rebalance_trials=trials, cache=cache,
        )
    elif schedule == "summa":
        art = plan_summa(
            graph, q, q, chunk=chunk, rebalance_trials=trials, cache=cache
        )
    elif schedule == "oned":
        art = plan_oned(
            graph, q, chunk=chunk, rebalance_trials=trials, cache=cache
        )
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    return art.plan, art.rebalance
