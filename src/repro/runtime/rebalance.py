"""Plan rebalancer — straggler mitigation at the planning level.

In lockstep SPMD the slowest device per shift sets the pace, so the lever
against stragglers is *balance*: the paper relies on degree-ordered cyclic
distribution (Table 3 measures <= 6% task imbalance / 1.05-1.14 per-shift
runtime imbalance).  We go further (beyond paper): a randomized-relabeling
search perturbs the vertex order *within equal-degree runs* (preserving
the non-decreasing-degree property that the algorithm's correctness and
locality arguments rely on) and keeps the seed minimizing the max
per-device probe work.  Gains are measured in
benchmarks/table3_imbalance.py.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..core.graph import Graph
from ..core.plan import TCPlan, build_plan

__all__ = ["rebalance_plan", "shuffled_degree_order"]


def shuffled_degree_order(graph: Graph, seed: int) -> np.ndarray:
    """Degree-order permutation with within-degree-bucket shuffling."""
    deg = graph.degrees()
    rng = np.random.default_rng(seed)
    jitter = rng.random(graph.n)
    order = np.lexsort((jitter, deg))  # non-decreasing degree, random ties
    perm = np.empty(graph.n, dtype=np.int64)
    perm[order] = np.arange(graph.n)
    return perm


def rebalance_plan(
    graph: Graph, q: int, *, trials: int = 8, chunk: int = 512
) -> Tuple[TCPlan, dict]:
    """Search relabeling seeds; return the best-balanced plan + report."""
    best_plan = None
    best_cost = float("inf")
    history = []
    for seed in range(trials):
        perm = shuffled_degree_order(graph, seed)
        g2 = graph.relabel(perm)
        plan = build_plan(g2, q, chunk=chunk, with_stats=True)
        # cost: max per-device probe work summed over shifts (the SPMD
        # critical path), tie-broken by task imbalance
        probe = plan.stats.probe_work_per_device_shift
        crit = float(probe.max(axis=(0, 1)).sum())
        history.append(
            dict(
                seed=seed,
                critical_path=crit,
                task_imbalance=plan.stats.task_imbalance,
                probe_imbalance=plan.stats.probe_imbalance,
            )
        )
        if crit < best_cost:
            best_cost = crit
            best_plan = plan
    report = dict(
        trials=history,
        best_seed=min(history, key=lambda h: h["critical_path"])["seed"],
        improvement=(
            history[0]["critical_path"] / max(best_cost, 1.0)
        ),
    )
    return best_plan, report
