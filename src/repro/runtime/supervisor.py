"""Supervised fault-tolerant execution (DESIGN.md §8).

:class:`Supervisor` is the one retry loop in the system: exponential
backoff + deterministic jitter, a restart budget, cooperative
per-attempt deadlines, and structured :class:`Attempt` records.  Clock,
sleep and RNG are injectable so the backoff/deadline/budget logic is
unit-testable with a fake clock.

:func:`supervised_count` wraps :func:`repro.core.count_triangles` with
the full recovery policy:

* **transient faults** (``StepFault`` / ``StageFault`` / ``CkptCorrupt``)
  retry in place under backoff;
* **persistent faults** (the same site keeps firing) demote one rung of
  the graceful degradation ladder per repeat —
  fused → search2 → search (the lax path), compacted → cond-only,
  tree → flat reduction, hub-split → off — each demotion recorded with
  its reason before the budget gives up;
* **``DeviceLost``** triggers an elastic regrid: re-factorize the
  remaining devices via :func:`repro.runtime.best_grid`, re-plan on the
  smaller mesh through the pipeline planner (skip masks, compaction,
  rebalance, hub-split and the plan cache all intact — the runners plan
  through :mod:`repro.pipeline`), and re-count from the last *globally
  consistent* boundary.  Mid-schedule per-device partials are
  decomposition-specific and are **refused** across grids
  (:func:`check_partials_portable`); only completed-graph /
  stream-round boundaries transfer.

Every recovered count is byte-identical to the fault-free run: recovery
re-executes the deterministic pipeline, it never patches partial state.

:func:`supervise_loop` is the generic checkpointed step-loop driver that
``run_with_restarts`` (and the ``tc_run --ckpt-dir`` stepper) delegate
to.
"""
from __future__ import annotations

import dataclasses
import logging
import random
import time
from typing import Callable, List, Optional

from .elastic import best_grid
from .faultinject import (
    CkptCorrupt,
    DeviceLost,
    FaultPlan,
    InjectedFault,
    StageFault,
    StepFault,
    armed,
)

log = logging.getLogger(__name__)

__all__ = [
    "AttemptDeadlineExceeded",
    "GridTransferRefused",
    "BackoffPolicy",
    "Attempt",
    "SupervisionReport",
    "Supervisor",
    "next_demotion",
    "note_demotion",
    "collecting_demotions",
    "check_partials_portable",
    "supervised_count",
    "supervise_loop",
]


class AttemptDeadlineExceeded(RuntimeError):
    """Cooperative per-attempt deadline fired (checked at step/attempt
    boundaries — the host loop cannot preempt a running dispatch)."""


class GridTransferRefused(RuntimeError):
    """Mid-schedule per-device partial counts were asked to move across
    grids.  Partials are decomposition-specific (each device's
    accumulator sums a grid-dependent set of block pairs), so the only
    portable boundaries are a completed graph count or a completed
    stream round; the supervisor restarts the count on the new grid
    instead."""


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff with bounded deterministic jitter."""

    base: float = 0.05
    factor: float = 2.0
    max_delay: float = 2.0
    jitter: float = 0.1  # fraction of the delay, uniform [0, jitter)

    def delay(self, restart_index: int, rng: random.Random) -> float:
        """Delay before restart ``restart_index`` (1-based)."""
        d = min(self.max_delay, self.base * self.factor ** (restart_index - 1))
        return d * (1.0 + self.jitter * rng.random())


@dataclasses.dataclass
class Attempt:
    """One attempt record: outcome is ``ok`` | ``fault`` | ``deadline``."""

    index: int
    outcome: str
    seconds: float
    fault: Optional[str] = None  # exception class name
    point: Optional[str] = None  # injection point, when typed
    step: Optional[int] = None
    backoff: float = 0.0  # sleep before the *next* attempt
    note: Optional[str] = None

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


@dataclasses.dataclass
class SupervisionReport:
    attempts: List[Attempt] = dataclasses.field(default_factory=list)
    demotions: List[dict] = dataclasses.field(default_factory=list)
    regrids: List[dict] = dataclasses.field(default_factory=list)
    gave_up: bool = False
    total_backoff_seconds: float = 0.0

    @property
    def restarts(self) -> int:
        return sum(1 for a in self.attempts if a.outcome != "ok")

    def to_dict(self) -> dict:
        return dict(
            attempts=[a.to_dict() for a in self.attempts],
            restarts=self.restarts,
            demotions=list(self.demotions),
            regrids=list(self.regrids),
            gave_up=self.gave_up,
            total_backoff_seconds=round(self.total_backoff_seconds, 4),
        )


class Supervisor:
    """Retry loop with backoff, budget, and cooperative deadlines.

    ``clock``/``sleep``/``seed`` are injectable for fake-clock tests.
    ``retry_on`` bounds which exceptions are restartable (default: the
    typed injected faults plus :class:`AttemptDeadlineExceeded`);
    anything else propagates immediately.
    """

    def __init__(
        self,
        *,
        max_restarts: int = 5,
        backoff: Optional[BackoffPolicy] = None,
        attempt_deadline: Optional[float] = None,
        retry_on: tuple = (InjectedFault, AttemptDeadlineExceeded),
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        seed: int = 0,
    ):
        self.max_restarts = int(max_restarts)
        self.backoff = backoff or BackoffPolicy()
        self.attempt_deadline = attempt_deadline
        self.retry_on = retry_on
        self.clock = clock
        self.sleep = sleep
        self.rng = random.Random(seed)
        self.report = SupervisionReport()

    # ------------------------------------------------------------------
    def deadline_guard(self, t0: float) -> Callable[[], None]:
        """A zero-arg callable the attempt invokes at step boundaries;
        raises :class:`AttemptDeadlineExceeded` past the deadline."""
        deadline = self.attempt_deadline

        def guard():
            if deadline is not None and self.clock() - t0 > deadline:
                raise AttemptDeadlineExceeded(
                    f"attempt exceeded its {deadline}s deadline"
                )

        return guard

    def run(self, attempt_fn: Callable, *, on_fault: Optional[Callable] = None):
        """Run ``attempt_fn(attempt_index, deadline_guard)`` until it
        returns, retrying restartable failures under backoff within the
        budget.  ``on_fault(exc, attempt_record)`` (optional) runs
        before each backoff — it may mutate state for the retry (regrid,
        demote, restore a checkpoint) or raise to abort."""
        attempt = 0
        while True:
            t0 = self.clock()
            try:
                out = attempt_fn(attempt, self.deadline_guard(t0))
            except self.retry_on as e:
                rec = Attempt(
                    index=attempt,
                    outcome=("deadline"
                             if isinstance(e, AttemptDeadlineExceeded)
                             else "fault"),
                    seconds=self.clock() - t0,
                    fault=type(e).__name__,
                )
                self.report.attempts.append(rec)
                attempt += 1
                if attempt > self.max_restarts:
                    self.report.gave_up = True
                    raise
                if on_fault is not None:
                    rec.note = on_fault(e, rec)
                delay = self.backoff.delay(attempt, self.rng)
                rec.backoff = round(delay, 4)
                self.report.total_backoff_seconds += delay
                log.warning(
                    "attempt %d failed (%s: %s); restarting in %.3fs "
                    "(%d/%d restarts used)",
                    attempt - 1, type(e).__name__, e, delay, attempt,
                    self.max_restarts,
                )
                self.sleep(delay)
                continue
            self.report.attempts.append(
                Attempt(index=attempt, outcome="ok",
                        seconds=self.clock() - t0)
            )
            return out


# ----------------------------------------------------------------------
# graceful degradation ladder
# ----------------------------------------------------------------------
def next_demotion(cfg: dict) -> Optional[dict]:
    """Mutate ``cfg`` one rung down the ladder; returns the demotion
    record, or ``None`` when the ladder is exhausted.

    Order (first applicable wins): fused → search2, search2 → search
    (the lax-kernel path; on the 1-D ring fused demotes straight to
    search — its global-id columns rule out the two-level kernel),
    compacted → cond-only, tree → flat reduction, hub-split → off.
    """
    method = cfg.get("method", "search")
    if method == "fused":
        to = "search" if cfg.get("schedule") == "oned" else "search2"
        cfg["method"] = to
        return dict(rung="method", frm="fused", to=to)
    if method == "search2":
        cfg["method"] = "search"
        return dict(rung="method", frm="search2", to="search")
    if cfg.get("compact") is not False:
        cfg["compact"] = False
        return dict(rung="compact", frm="auto", to="off")
    if cfg.get("reduce_strategy", "auto") != "flat":
        frm = cfg.get("reduce_strategy", "auto")
        cfg["reduce_strategy"] = "flat"
        return dict(rung="reduce", frm=frm, to="flat")
    if cfg.get("hub_split"):
        cfg["hub_split"] = False
        return dict(rung="hub_split", frm="on", to="off")
    return None


# Ambient demotion collector: one audited stream for every demotion in
# the system — ladder rungs above AND the engine's own auto-demotions
# (e.g. the fused VMEM gate falling back to the lax reference), which
# previously only warned.
_DEMOTIONS: Optional[List[dict]] = None


def note_demotion(rung: str, frm: str, to: str, *, reason: str) -> None:
    """Record a demotion into the ambient collector (no-op outside a
    supervised run — callers keep their warnings for unsupervised
    use)."""
    if _DEMOTIONS is not None:
        _DEMOTIONS.append(dict(rung=rung, frm=frm, to=to, reason=reason))


class collecting_demotions:
    """Context manager exposing the demotion list collected inside."""

    def __enter__(self) -> List[dict]:
        global _DEMOTIONS
        self._prev = _DEMOTIONS
        _DEMOTIONS = []
        return _DEMOTIONS

    def __exit__(self, *exc):
        global _DEMOTIONS
        _DEMOTIONS = self._prev
        return False


# ----------------------------------------------------------------------
# cross-grid state portability
# ----------------------------------------------------------------------
def check_partials_portable(extra: dict, grid_sig: str) -> None:
    """Refuse (loudly) to resume mid-schedule partial counts written
    under a different grid.  ``extra`` is a checkpoint manifest's extra
    dict; ``grid_sig`` the current ``"{r}x{c}"`` signature."""
    saved = (extra or {}).get("grid")
    if saved is not None and saved != grid_sig:
        raise GridTransferRefused(
            f"refusing to transfer mid-schedule per-device partial "
            f"counts from grid {saved} to {grid_sig}: partials are "
            "decomposition-specific (each accumulator sums a "
            "grid-dependent set of block pairs); only completed-graph / "
            "stream-round boundaries are portable — the count restarts "
            "from step 0 on the new grid"
        )


def _regrid(schedule: str, lost_total: int) -> tuple:
    """Re-factorize the surviving devices: (schedule, mesh, (r, c)).

    Square survivors keep the schedule family; rectangular survivors
    force SUMMA (Cannon needs a square grid — the paper's §8 fallback).
    """
    import jax

    from .. import compat
    from ..core.api import make_grid_mesh

    remaining = len(jax.devices()) - int(lost_total)
    if remaining < 1:
        raise RuntimeError(
            f"cannot regrid: {lost_total} devices lost, none remaining"
        )
    r, c = best_grid(remaining)
    if r == c:
        if schedule == "oned":
            mesh = compat.make_mesh((r * c,), ("flat",))
        else:
            mesh = make_grid_mesh(r)
        return schedule, mesh, (r, c)
    if schedule == "oned":
        return "oned", compat.make_mesh((r * c,), ("flat",)), (r, c)
    mesh = compat.make_mesh((r, c), ("data", "model"))
    return "summa", mesh, (r, c)


# ----------------------------------------------------------------------
# supervised full-engine count
# ----------------------------------------------------------------------
def supervised_count(
    graph,
    mesh=None,
    *,
    supervisor: Optional[Supervisor] = None,
    fault_plan: Optional[FaultPlan] = None,
    ladder: bool = True,
    regrid: bool = True,
    demote_after: int = 2,
    **kwargs,
):
    """``count_triangles`` under supervision; returns a ``TCResult``
    whose ``supervision`` field carries the full attempt/demotion/regrid
    record.  ``demote_after`` is how many consecutive identical faults
    it takes to call a fault persistent and demote a ladder rung."""
    from ..core.api import count_triangles

    sup = supervisor or Supervisor()
    cfg = dict(kwargs)
    state = {"mesh": mesh, "schedule": cfg.get("schedule", "cannon"),
             "last_sig": None, "repeats": 0}

    def on_fault(e, rec):
        if isinstance(e, InjectedFault) and fault_plan is not None:
            last = fault_plan.log[-1] if fault_plan.log else {}
            rec.point, rec.step = last.get("point"), last.get("step")
        if isinstance(e, DeviceLost) and regrid:
            sched, new_mesh, (r, c) = _regrid(state["schedule"], e.lost)
            state["mesh"], state["schedule"] = new_mesh, sched
            cfg["schedule"] = sched
            # grid-shape knobs don't survive re-factorization
            cfg.pop("q", None)
            cfg.pop("npods", None)
            ev = dict(lost=e.lost, grid=[r, c], schedule=sched)
            sup.report.regrids.append(ev)
            state["last_sig"], state["repeats"] = None, 0
            return f"regrid to {r}x{c} ({sched})"
        sig = (type(e).__name__, rec.point, rec.step)
        state["repeats"] = (
            state["repeats"] + 1 if sig == state["last_sig"] else 1
        )
        state["last_sig"] = sig
        if ladder and state["repeats"] >= demote_after:
            demo = next_demotion(cfg)
            state["repeats"] = 0
            if demo is not None:
                demo["reason"] = (
                    f"persistent {sig[0]}"
                    + (f" at {sig[1]}" if sig[1] else "")
                )
                sup.report.demotions.append(demo)
                return f"demoted {demo['rung']}: {demo['frm']}→{demo['to']}"
        return None

    def attempt(i, guard):
        guard()
        with collecting_demotions() as demos:
            res = count_triangles(
                graph, state["mesh"], fault_plan=fault_plan, **cfg
            )
        sup.report.demotions.extend(demos)
        return res

    with armed(fault_plan):
        res = sup.run(attempt, on_fault=on_fault)
    res.supervision = sup.report.to_dict()
    if fault_plan is not None:
        res.supervision["fault_log"] = list(fault_plan.log)
    return res


# ----------------------------------------------------------------------
# generic checkpointed step loop (run_with_restarts / stepper substrate)
# ----------------------------------------------------------------------
def supervise_loop(
    init_state: Callable[[], dict],
    step_fn: Callable[[dict, int], dict],
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    supervisor: Optional[Supervisor] = None,
    state_like=None,
    fault_injector: Optional[Callable[[int], None]] = None,
):
    """Drive ``step_fn`` for ``n_steps`` with periodic checkpoints under
    a :class:`Supervisor`: every failure restores the latest intact
    checkpoint (corrupt steps are quarantined by the manager) and
    resumes under backoff.  Returns ``(final_state, report)``."""
    from ..ckpt import CheckpointManager

    mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)
    sup = supervisor or Supervisor(
        max_restarts=3,
        backoff=BackoffPolicy(base=0.01, max_delay=0.05),
        retry_on=(Exception,),
    )
    like = state_like or init_state()

    def attempt(i, guard):
        got_step, restored, extra = mgr.restore_latest(like)
        if restored is not None:
            state, step = restored, int(extra["next_step"])
            if i == 0:
                log.info("resumed from step %d", step)
        else:
            state, step = init_state(), 0
        while step < n_steps:
            guard()
            if fault_injector is not None:
                fault_injector(step)
            state = step_fn(state, step)
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                mgr.save(step, state, extra={"next_step": step})
        return state

    state = sup.run(attempt)
    mgr.close()
    return state, sup.report
