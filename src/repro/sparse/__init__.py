"""Sparse substrate: segment-op message passing, EmbeddingBag, sampling.

JAX has no native EmbeddingBag or CSR SpMM — these are built here from
``jnp.take`` + ``jax.ops.segment_sum`` as first-class framework pieces
(assignment requirement; see kernel_taxonomy §GNN/§RecSys).
"""
from .segment import segment_softmax, segment_sum, spmm_edges  # noqa: F401
from .embedding_bag import embedding_bag  # noqa: F401
from .sampler import sample_neighbors  # noqa: F401
