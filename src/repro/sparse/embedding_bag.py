"""EmbeddingBag: ragged multi-hot lookup + segment reduce (no torch here).

The DLRM hot path.  Tables are stored as ONE concatenated (total_rows, d)
matrix with per-table row offsets so a batch of 26 sparse fields is a
single gather + segment_sum — and row-sharding the concatenated table over
the `model` axis turns the gather into the standard all-to-all embedding
exchange under GSPMD.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["embedding_bag", "table_offsets", "flatten_ids"]


def table_offsets(table_sizes: Sequence[int]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(table_sizes)[:-1]]).astype(np.int64)


def flatten_ids(ids, offsets):
    """ids: (B, F, H) per-table local ids -> global row ids (B, F, H)."""
    return ids + jnp.asarray(offsets, ids.dtype)[None, :, None]


def embedding_bag(table, flat_ids, *, combiner: str = "sum"):
    """table: (rows, d); flat_ids: (B, F, H) global ids (H = bag size).

    Returns (B, F, d) — one reduced embedding per (sample, field).
    """
    emb = jnp.take(table, flat_ids, axis=0)  # (B, F, H, d)
    if combiner == "sum":
        return jnp.sum(emb, axis=2)
    if combiner == "mean":
        return jnp.mean(emb, axis=2)
    if combiner == "max":
        return jnp.max(emb, axis=2)
    raise ValueError(combiner)
