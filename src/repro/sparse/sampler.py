"""Neighbor sampler (GraphSAGE-style fanout) for minibatch GNN training.

Host-side (numpy) sampling over a CSR graph — part of the data pipeline:
given seed nodes and fanouts (e.g. 15-10), draws a layered subgraph and
returns relabeled edge lists with static (padded) shapes so the device
step compiles once.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["sample_neighbors", "SampledSubgraph"]


class SampledSubgraph:
    def __init__(self, node_ids, edge_src, edge_dst, seed_count):
        self.node_ids = node_ids  # (N_sub,) global ids (padded w/ -1)
        self.edge_src = edge_src  # (E_sub,) local ids into node_ids
        self.edge_dst = edge_dst
        self.seed_count = seed_count

    @property
    def n_nodes(self):
        return self.node_ids.shape[0]

    @property
    def n_edges(self):
        return self.edge_src.shape[0]


def sample_neighbors(
    indptr: np.ndarray,
    indices: np.ndarray,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> SampledSubgraph:
    """Layered uniform neighbor sampling with replacement-free truncation.

    Shapes are padded to the static maxima ``batch * prod(fanouts)`` so the
    training step has a fixed signature.
    """
    seeds = np.asarray(seeds, dtype=np.int64)
    layers: List[np.ndarray] = [seeds]
    e_src: List[np.ndarray] = []
    e_dst: List[np.ndarray] = []
    frontier = seeds
    for f in fanouts:
        srcs = []
        dsts = []
        for v in frontier:
            nbrs = indices[indptr[v] : indptr[v + 1]]
            if nbrs.shape[0] == 0:
                continue
            take = min(f, nbrs.shape[0])
            sel = rng.choice(nbrs, size=take, replace=False)
            srcs.append(sel)
            dsts.append(np.full(take, v, dtype=np.int64))
        if srcs:
            srcs = np.concatenate(srcs)
            dsts = np.concatenate(dsts)
        else:
            srcs = np.zeros(0, np.int64)
            dsts = np.zeros(0, np.int64)
        e_src.append(srcs)
        e_dst.append(dsts)
        frontier = np.unique(srcs)
        layers.append(frontier)

    node_ids, inverse = np.unique(
        np.concatenate([np.concatenate(layers), np.array([0], np.int64)]),
        return_inverse=True,
    )
    remap = {int(g): i for i, g in enumerate(node_ids)}
    src_all = np.concatenate(e_src) if e_src else np.zeros(0, np.int64)
    dst_all = np.concatenate(e_dst) if e_dst else np.zeros(0, np.int64)
    src_l = np.array([remap[int(v)] for v in src_all], dtype=np.int32)
    dst_l = np.array([remap[int(v)] for v in dst_all], dtype=np.int32)

    # pad to static shapes
    max_nodes = int(seeds.shape[0] * np.prod([f + 1 for f in fanouts])) + 1
    max_edges = int(seeds.shape[0] * np.prod(fanouts) * 2) + 1
    nid = np.full(max_nodes, -1, np.int64)
    nid[: node_ids.shape[0]] = node_ids
    es = np.zeros(max_edges, np.int32)
    ed = np.zeros(max_edges, np.int32)
    es[: src_l.shape[0]] = src_l
    ed[: dst_l.shape[0]] = dst_l
    return SampledSubgraph(nid, es, ed, seeds.shape[0])
