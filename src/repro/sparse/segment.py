"""Edge-index message passing via segment reductions.

``spmm_edges`` is the GNN SpMM primitive: gather source-node features along
edges, optionally weight per edge, scatter-add into destination nodes.
All ops are shape-static and GSPMD-shardable (edges sharded over devices;
the scatter becomes a psum-combine when dst nodes are sharded).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["segment_sum", "segment_softmax", "spmm_edges", "degree"]


def segment_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_softmax(logits, segment_ids, num_segments: int):
    """Numerically-stable softmax over variable-size segments (edge->dst)."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(logits - seg_max[segment_ids])
    denom = segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-16)


def degree(segment_ids, num_segments: int, dtype=jnp.float32):
    ones = jnp.ones(segment_ids.shape[0], dtype)
    return segment_sum(ones, segment_ids, num_segments)


def spmm_edges(
    x_src,
    edge_src,
    edge_dst,
    num_dst: int,
    *,
    edge_weight: Optional[jnp.ndarray] = None,
    reduce: str = "sum",
):
    """y[dst] = reduce_{(s,d) in E} w_e * x_src[s].

    x_src: (N_src, ...); edge_src/edge_dst: (E,) int32.
    """
    msg = jnp.take(x_src, edge_src, axis=0)
    if edge_weight is not None:
        msg = msg * edge_weight.reshape((-1,) + (1,) * (msg.ndim - 1))
    if reduce == "sum":
        return jax.ops.segment_sum(msg, edge_dst, num_segments=num_dst)
    if reduce == "mean":
        s = jax.ops.segment_sum(msg, edge_dst, num_segments=num_dst)
        d = degree(edge_dst, num_dst, msg.dtype)
        return s / jnp.maximum(d, 1.0).reshape((-1,) + (1,) * (msg.ndim - 1))
    if reduce == "max":
        return jax.ops.segment_max(msg, edge_dst, num_segments=num_dst)
    raise ValueError(reduce)
