"""Shared fixtures.  NOTE: device count is deliberately left at the
default (1 CPU device) — multi-device tests spawn subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=N so smoke tests and
benchmarks always see a single device (see launch/dryrun.py for the only
512-device entry point)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_distributed(code: str, ndev: int, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with ndev host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def distributed_runner():
    return run_distributed
