"""Per-architecture smoke tests: reduced configs, one forward/train (or
serve) step on CPU, asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config

MESH = None


def mesh11():
    global MESH
    if MESH is None:
        from repro import compat

        MESH = compat.make_mesh((1, 1), ("data", "model"))
    return MESH


LM_ARCHS = ["chatglm3-6b", "qwen2-0.5b", "qwen1.5-110b", "grok-1-314b",
            "deepseek-v3-671b"]
GNN_ARCHS = ["nequip", "graphcast", "gat-cora", "equiformer-v2"]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_train_step(name):
    from repro.models.steps import build_lm_train_step
    from repro.models.transformer import lm_init

    cfg = get_config(name + "-smoke")
    params = lm_init(jax.random.key(0), cfg)
    fn, info = build_lm_train_step(cfg, mesh11())
    opt = info["opt_init"](params)
    batch = {
        "tokens": jnp.ones((4, 32), jnp.int32),
        "labels": jnp.ones((4, 32), jnp.int32),
    }
    p2, o2, m = fn(params, opt, batch, 0)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    w0 = jax.tree.leaves(params)[0]
    w1 = jax.tree.leaves(p2)[0]
    assert w0.shape == w1.shape


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_decode_step(name):
    from repro.models.steps import build_lm_decode_step
    from repro.models.transformer import init_kv_cache, lm_init

    cfg = get_config(name + "-smoke")
    params = lm_init(jax.random.key(1), cfg)
    dec, _ = build_lm_decode_step(cfg, mesh11())
    cache = init_kv_cache(cfg, 2, 16)
    tok = jnp.zeros((2,), jnp.int32)
    for i in range(3):
        tok, cache = dec(params, cache, tok, jnp.full((2,), i, jnp.int32))
    assert tok.shape == (2,)
    assert int(tok.max()) < cfg.vocab


def test_lm_prefill_step():
    from repro.models.steps import build_lm_prefill_step
    from repro.models.transformer import lm_init

    cfg = get_config("qwen2-0.5b-smoke")
    params = lm_init(jax.random.key(0), cfg)
    fn, _ = build_lm_prefill_step(cfg, mesh11())
    out = fn(params, jnp.ones((2, 64), jnp.int32))
    assert out.shape == (2,)


def _rand_graph(rng, n, e):
    return (
        jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
    )


def _gnn_batch(cfg, rng, n=24, e=72):
    src, dst = _rand_graph(rng, n, e)
    if cfg.arch in ("nequip", "equiformer_v2"):
        b = dict(
            species=jnp.asarray(rng.integers(0, 4, n), jnp.int32),
            positions=jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
            edge_src=src,
            edge_dst=dst,
            graph_id=jnp.zeros(n, jnp.int32),
            energy=jnp.zeros(1, jnp.float32),
        )
        if cfg.arch == "nequip":
            b["forces"] = jnp.zeros((n, 3), jnp.float32)
        return b, 0
    if cfg.arch == "gat":
        d = 16
        return (
            dict(
                feats=jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
                edge_src=src,
                edge_dst=dst,
                labels=jnp.asarray(rng.integers(0, cfg.d_out, n), jnp.int32),
                label_mask=jnp.ones(n, jnp.float32),
            ),
            d,
        )
    return (
        dict(
            feats=jnp.asarray(rng.normal(size=(n, cfg.n_vars)), jnp.float32),
            target=jnp.asarray(rng.normal(size=(n, cfg.n_vars)), jnp.float32),
            edge_src=src,
            edge_dst=dst,
        ),
        cfg.n_vars,
    )


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_train_step(name):
    from repro.models.gnn_steps import build_gnn_train_step, gnn_init

    cfg = get_config(name + "-smoke")
    rng = np.random.default_rng(3)
    batch, d_feat = _gnn_batch(cfg, rng)
    params = gnn_init(jax.random.key(0), cfg, d_feat)
    build, info = build_gnn_train_step(cfg, mesh11(), d_feat)
    fn = build(jax.eval_shape(lambda: batch))
    opt = info["opt_init"](params)
    p2, o2, m = fn(params, opt, batch, 0)
    assert np.isfinite(float(m["loss"]))


def test_gnn_training_reduces_loss():
    """GAT actually learns a separable synthetic task in a few steps."""
    from repro.models.gnn_steps import build_gnn_train_step, gnn_init, gnn_loss

    cfg = get_config("gat-cora-smoke")
    rng = np.random.default_rng(0)
    n, e, d = 60, 240, 8
    labels = rng.integers(0, cfg.d_out, n)
    feats = 0.1 * rng.normal(size=(n, d))
    feats[:, : cfg.d_out] += 4.0 * np.eye(cfg.d_out)[labels]
    # self-loops (standard Cora preprocessing) so nodes see their features
    src = np.concatenate([rng.integers(0, n, e), np.arange(n)])
    dst = np.concatenate([rng.integers(0, n, e), np.arange(n)])
    batch = dict(
        feats=jnp.asarray(feats, jnp.float32),
        edge_src=jnp.asarray(src, jnp.int32),
        edge_dst=jnp.asarray(dst, jnp.int32),
        labels=jnp.asarray(labels, jnp.int32),
        label_mask=jnp.ones(n, jnp.float32),
    )
    params = gnn_init(jax.random.key(0), cfg, d)
    build, info = build_gnn_train_step(cfg, mesh11(), d)
    fn = build(jax.eval_shape(lambda: batch))
    opt = info["opt_init"](params)
    loss0 = float(gnn_loss(params, cfg, batch)[0])
    for i in range(120):
        params, opt, m = fn(params, opt, batch, i)
    loss1 = float(m["loss"])
    assert loss1 < loss0 * 0.8, (loss0, loss1)


def test_equivariance_energy_invariance():
    from scipy.spatial.transform import Rotation

    from repro.models.gnn.nequip import nequip_energy
    from repro.models.gnn.equiformer_v2 import equiformer_energy
    from repro.models.gnn_steps import gnn_init

    rng = np.random.default_rng(7)
    n, e = 16, 48
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    species = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    gid = jnp.zeros(n, jnp.int32)
    rot = jnp.asarray(Rotation.random(random_state=5).as_matrix(), jnp.float32)

    cfg = get_config("nequip-smoke")
    p = gnn_init(jax.random.key(0), cfg, 0)
    e1 = float(nequip_energy(p, cfg, species, pos, src, dst, gid, 1)[0])
    e2 = float(nequip_energy(p, cfg, species, pos @ rot.T, src, dst, gid, 1)[0])
    assert abs(e1 - e2) < 1e-4 + 1e-3 * abs(e1)

    cfg = get_config("equiformer-v2-smoke")
    p = gnn_init(jax.random.key(0), cfg, 0)
    e1 = float(equiformer_energy(p, cfg, species, pos, src, dst, gid, 1)[0])
    e2 = float(
        equiformer_energy(p, cfg, species, pos @ rot.T, src, dst, gid, 1)[0]
    )
    assert abs(e1 - e2) < 1e-3 + 5e-3 * abs(e1)


def test_nequip_forces_are_equivariant():
    from scipy.spatial.transform import Rotation

    from repro.models.gnn.nequip import nequip_energy_forces
    from repro.models.gnn_steps import gnn_init

    rng = np.random.default_rng(11)
    n, e = 12, 36
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    species = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    gid = jnp.zeros(n, jnp.int32)
    rot = jnp.asarray(Rotation.random(random_state=2).as_matrix(), jnp.float32)
    cfg = get_config("nequip-smoke")
    p = gnn_init(jax.random.key(0), cfg, 0)
    _, f1 = nequip_energy_forces(p, cfg, species, pos, src, dst, gid, 1)
    _, f2 = nequip_energy_forces(p, cfg, species, pos @ rot.T, src, dst, gid, 1)
    np.testing.assert_allclose(
        np.asarray(f1 @ rot.T), np.asarray(f2), atol=2e-4
    )


def test_dlrm_steps():
    from repro.models.dlrm import dlrm_init
    from repro.models.gnn_steps import (
        build_dlrm_retrieval_step,
        build_dlrm_serve_step,
        build_dlrm_train_step,
    )

    cfg = get_config("dlrm-mlperf-smoke")
    rng = np.random.default_rng(0)
    params = dlrm_init(jax.random.key(0), cfg)
    fn, info = build_dlrm_train_step(cfg, mesh11())
    opt = info["opt_init"](params)
    b = 8
    batch = dict(
        dense=jnp.asarray(rng.normal(size=(b, 13)), jnp.float32),
        sparse_ids=jnp.asarray(
            rng.integers(0, 10, (b, cfg.n_sparse, 1)), jnp.int32
        ),
        labels=jnp.asarray(rng.integers(0, 2, b), jnp.float32),
    )
    p2, o2, m = fn(params, opt, batch, 0)
    assert np.isfinite(float(m["loss"]))
    srv, _ = build_dlrm_serve_step(cfg, mesh11())
    probs = srv(p2, batch["dense"], batch["sparse_ids"])
    assert probs.shape == (b,) and np.all((np.asarray(probs) >= 0))
    ret, _ = build_dlrm_retrieval_step(cfg, mesh11())
    vals, idx = ret(p2, batch["dense"][:1], jnp.arange(40, dtype=jnp.int32))
    assert idx.shape[0] == 40 or idx.shape[0] == 100


def test_param_counts_match_published():
    """Full configs' parameter counts are in the right ballpark."""
    cases = {
        "qwen2-0.5b": (0.35e9, 0.8e9),
        "chatglm3-6b": (5e9, 8e9),
        "qwen1.5-110b": (90e9, 130e9),
        "grok-1-314b": (250e9, 360e9),
        "deepseek-v3-671b": (550e9, 750e9),
    }
    for name, (lo, hi) in cases.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)
    # MoE active params
    ds = get_config("deepseek-v3-671b")
    assert 25e9 < ds.active_param_count() < 55e9
