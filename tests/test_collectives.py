"""Communication-avoiding collectives (DESIGN.md §4.5).

Covers: the masked ppermute primitives (binomial tree all-reduce,
doubling-chain broadcast) against their collective semantics on a flat
mesh, count equivalence across (reduce strategy × schedule × store ×
npods ∈ {1, 2, 4}) including compacted schedules and edgeless graphs,
loud rejection of unsupported strategy combinations, the checkpoint
cross-strategy resume guard, and the roofline's pairs-aware permute
accounting + per-phase byte attribution.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core import count_triangles, triangle_count_oracle
from repro.core.generators import graph_from_spec

ER = "er:300,16,5"
CLIQUES = "cliques:2,40"  # block-diagonal: compaction elides steps


# ======================================================================
# primitive semantics (flat 4-device mesh, subprocess)
# ======================================================================
PRIMITIVES_CODE = """
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.engine import chain_broadcast, pod_tree_allreduce

mesh = compat.make_mesh((4,), ("flat",))
x = jnp.arange(1.0, 5.0)  # device d holds d + 1

tree = compat.shard_map(
    lambda v: pod_tree_allreduce(v, "flat", 4),
    mesh=mesh, in_specs=P("flat"), out_specs=P("flat"),
)(x)
assert tree.tolist() == [10.0] * 4, tree  # every device holds the sum

for owner in range(4):
    got = compat.shard_map(
        lambda v: chain_broadcast(v, "flat", 4, owner),
        mesh=mesh, in_specs=P("flat"), out_specs=P("flat"),
    )(x)
    assert got.tolist() == [owner + 1.0] * 4, (owner, got)
print("PRIMITIVES_OK")
"""


def test_tree_and_chain_primitives(distributed_runner):
    out = distributed_runner(PRIMITIVES_CODE, 4)
    assert "PRIMITIVES_OK" in out


def test_pod_tree_allreduce_rejects_non_pow2():
    from repro.core.engine import pod_tree_allreduce

    with pytest.raises(AssertionError):
        pod_tree_allreduce(0.0, "pod", 3)


# ======================================================================
# count equivalence: strategy × schedule × store × npods
# ======================================================================
CANNON_EQUIV_CODE = """
from repro.core import count_triangles, triangle_count_oracle
from repro.core.generators import graph_from_spec

for spec in ({specs}):
    g = graph_from_spec(spec)
    exp = triangle_count_oracle(g)
    for strat in {strategies}:
        for compact in (None, False):
            r = count_triangles(
                g, q={q}, npods={npods}, method="search",
                reduce_strategy=strat, compact=compact,
            )
            assert r.triangles == exp, (spec, strat, compact, r.triangles, exp)
print("CANNON_OK")
"""


@pytest.mark.parametrize("npods,q", [(1, 2), (2, 2), (4, 4)])
def test_cannon_counts_equal_across_strategies(distributed_runner, npods, q):
    """CSR cannon: every applicable strategy agrees with the oracle on
    dense-ish and block-diagonal (compacted) fixtures, compaction on
    and off, at every pod count (explicit tree needs a pod axis, so the
    single-pod grid runs flat/auto only — see
    test_tree_rejected_without_pods)."""
    specs = (ER, CLIQUES) if npods < 4 else ("karate",)
    strategies = ("flat", "auto") if npods == 1 else ("flat", "tree", "auto")
    code = CANNON_EQUIV_CODE.format(
        specs=repr(specs), strategies=repr(strategies), q=q, npods=npods
    )
    out = distributed_runner(code, q * q * npods)
    assert "CANNON_OK" in out


DENSE_EQUIV_CODE = """
from repro.core import count_triangles, triangle_count_oracle
from repro.core.generators import graph_from_spec

g = graph_from_spec({spec!r})
exp = triangle_count_oracle(g)
for strat in ("flat", "auto"):
    r = count_triangles(g, q=2, npods={npods}, method="dense",
                        reduce_strategy=strat)
    assert r.triangles == exp, (strat, r.triangles, exp)

# the dense store replicates whole rounds per pod — it has no pod
# decomposition to tree over, so an explicit tree is refused loudly
if {npods} > 1:
    try:
        count_triangles(g, q=2, npods={npods}, method="dense",
                        reduce_strategy="tree")
    except ValueError as e:
        assert "pod axis" in str(e), e
    else:
        raise AssertionError("dense + tree should have been rejected")
print("DENSE_OK")
"""


@pytest.mark.parametrize("npods", [1, 2])
def test_dense_store_strategies(distributed_runner, npods):
    code = DENSE_EQUIV_CODE.format(spec=ER, npods=npods)
    out = distributed_runner(code, 4 * npods)
    assert "DENSE_OK" in out


SUMMA_EQUIV_CODE = """
from repro.core import count_triangles, triangle_count_oracle
from repro.core.generators import graph_from_spec

for spec in ({er!r}, {cliques!r}):
    g = graph_from_spec(spec)
    exp = triangle_count_oracle(g)
    for bc in (None, "auto", "onehot", "chain"):
        for compact in (None, False):
            r = count_triangles(
                g, q=3, schedule="summa", broadcast=bc, compact=compact,
            )
            assert r.triangles == exp, (spec, bc, compact, r.triangles, exp)
r = count_triangles(g, q=3, schedule="oned", reduce_strategy="flat")
assert r.triangles == exp
print("SUMMA_OK")
"""


def test_summa_counts_equal_across_broadcasts(distributed_runner):
    """SUMMA: every broadcast strategy × compaction agrees with the
    oracle (the chain forces the unrolled body; compacted chains elide
    dead rounds' collectives entirely); plus the oned flat baseline."""
    code = SUMMA_EQUIV_CODE.format(er=ER, cliques=CLIQUES)
    out = distributed_runner(code, 9)
    assert "SUMMA_OK" in out


EDGELESS_CODE = """
from repro.core import count_triangles
from repro.core.generators import graph_from_spec

g = graph_from_spec("er:20,0")
assert g.m == 0
for strat in ("flat", "tree", "auto"):
    assert count_triangles(g, q=2, npods=2, reduce_strategy=strat).triangles == 0
for bc in ("onehot", "chain"):
    assert count_triangles(g, q=2, schedule="summa", broadcast=bc).triangles == 0
print("EDGELESS_OK")
"""


def test_edgeless_graph_all_strategies(distributed_runner):
    out = distributed_runner(EDGELESS_CODE, 8)
    assert "EDGELESS_OK" in out


# ======================================================================
# validation: unsupported combinations are refused loudly
# ======================================================================
def test_tree_rejected_without_pods():
    g = graph_from_spec("karate")
    with pytest.raises(ValueError, match="pod axis"):
        count_triangles(g, q=1, reduce_strategy="tree")
    with pytest.raises(ValueError, match="pod axis"):
        count_triangles(g, q=1, schedule="oned", reduce_strategy="tree")


def test_unknown_strategy_rejected():
    g = graph_from_spec("karate")
    with pytest.raises(ValueError, match="reduce strategy"):
        count_triangles(g, q=1, reduce_strategy="bogus")
    with pytest.raises(ValueError, match="broadcast"):
        count_triangles(g, q=1, schedule="summa", broadcast="bogus")


def test_chain_rejected_for_batched_bodies():
    from repro.core.plan import resolve_broadcast
    from repro.core.summa import SummaPlan

    plan = SummaPlan.__new__(SummaPlan)
    plan.broadcast = "auto"
    assert resolve_broadcast(plan, None, batched=True) == "onehot"
    assert resolve_broadcast(plan, None, batched=False) == "chain"
    with pytest.raises(ValueError, match="chain"):
        resolve_broadcast(plan, "chain", batched=True)


# ======================================================================
# checkpoint cross-strategy guard
# ======================================================================
def test_ckpt_refuses_cross_strategy_resume(tmp_path):
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        PYTHONPATH=os.path.join(repo, "src"),
    )

    def run(extra):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.tc_run",
             "--graph", ER, "--grid", "2", "--json",
             "--ckpt-dir", str(tmp_path), *extra],
            env=env, capture_output=True, text=True, timeout=600,
        )

    first = run([])
    assert first.returncode == 0, first.stdout[-800:] + first.stderr[-800:]
    r = json.loads(first.stdout.strip().splitlines()[-1])
    assert r["checkpointed"]

    # same flags resume fine (the final checkpoint leaves nothing to do)
    again = run([])
    assert again.returncode == 0, again.stdout[-800:] + again.stderr[-800:]

    # a different reduction strategy must be refused, not silently summed
    crossed = run(["--reduce-strategy", "tree"])
    assert crossed.returncode != 0
    assert "collectives" in crossed.stderr
    assert "reduce=tree" in crossed.stderr


# ======================================================================
# roofline: pairs-aware permutes + per-phase attribution
# ======================================================================
_HLO = """\
HloModule jit_fn, entry_computation_layout={(f32[8]{0})->f32[8]{0}}, num_partitions=4

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %cp = f32[8]{0} collective-permute(%p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}, metadata={op_name="jit(fn)/tc_shift/ppermute"}
  %cp2 = f32[8]{0} collective-permute(%cp), source_target_pairs={{0,1}}, metadata={op_name="jit(fn)/tc_broadcast/ppermute"}
  ROOT %ar = f32[8]{0} all-reduce(%cp2), replica_groups={{0,1,2,3}}, metadata={op_name="jit(fn)/tc_reduce/psum"}
}
"""


def test_roofline_pairs_aware_permutes():
    from repro.launch.roofline import collective_bytes, infer_num_devices

    assert infer_num_devices(_HLO) == 4
    # headerless module: N falls back to max named device id + 1
    assert infer_num_devices(_HLO.replace(", num_partitions=4", "")) == 4

    out = collective_bytes(_HLO)
    # full rotation (4 pairs / 4 devices) costs its payload; the masked
    # single-pair hop costs a quarter; all-reduce keeps the ring cost
    assert out["collective-permute"] == pytest.approx(32.0 + 8.0)
    assert out["all-reduce"] == pytest.approx(2 * 32.0 * 3 / 4)

    # explicit num_devices overrides the header
    out8 = collective_bytes(_HLO, num_devices=8)
    assert out8["collective-permute"] == pytest.approx(16.0 + 4.0)


def test_roofline_collective_phases():
    from repro.launch.roofline import collective_phases

    phases = collective_phases(_HLO)
    assert phases == {
        "shift": pytest.approx(32.0),
        "broadcast": pytest.approx(8.0),
        "reduce": pytest.approx(2 * 32.0 * 3 / 4),
        "other": 0.0,
    }
    # untagged collectives land in "other", not a phase bucket
    untagged = collective_phases(_HLO.replace("tc_reduce", "psum_impl"))
    assert untagged["reduce"] == 0.0
    assert untagged["other"] == pytest.approx(2 * 32.0 * 3 / 4)


def test_roofline_phases_loop_aware():
    from repro.launch.roofline import collective_phases

    hlo = """\
HloModule jit_fn, num_partitions=2

%cond (c: (s32[], f32[4])) -> pred[] {
  %c = (s32[], f32[4]{0}) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (b: (s32[], f32[4])) -> (s32[], f32[4]) {
  %b = (s32[], f32[4]{0}) parameter(0)
  %x = f32[4]{0} get-tuple-element(%b), index=1
  %cp = f32[4]{0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}, metadata={op_name="jit(fn)/tc_shift/ppermute"}
  ROOT %t = (s32[], f32[4]{0}) tuple(%i, %cp)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %w = (s32[], f32[4]{0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    phases = collective_phases(hlo)
    # 5 trips x 16B payload x (2 pairs / 2 devices)
    assert phases["shift"] == pytest.approx(5 * 16.0)
    assert phases["broadcast"] == phases["reduce"] == phases["other"] == 0.0
