"""Compacted kept-step schedules (DESIGN.md §4.4) + staged aug keys +
deterministic autotune (§5).

Covers: live-step derivation and the σ visit-order search, compacted vs
uncompacted count equivalence across (schedule × store × method) on the
empty-block fixtures, host-staged intersection-key parity with the
on-device build (x64 on and off), the compacted stepper's checkpoint
round trip, autotune determinism (plan-cache hits), and the two-level
kernel's ignored-kwarg warning.
"""
import numpy as np
import pytest

from repro.core import (
    Graph,
    build_plan,
    count_triangles,
    count_triangles_many,
    named_graph,
    preprocess,
    residue_cliques,
    rmat,
    star,
    triangle_count_oracle,
)
from repro.core.plan import (
    CompactSchedule,
    compact_live_steps,
    host_aug_keys,
    resolve_compact_steps,
)
from repro.pipeline import plan_cannon, plan_oned, plan_summa
from repro.pipeline.cache import PlanCache
from repro.pipeline.stages import choose_cannon_skew


# ======================================================================
# live-step derivation + σ search
# ======================================================================
def test_compact_live_steps_and_hops():
    keep = np.zeros((2, 2, 5), dtype=bool)
    keep[0, 1, 1] = True
    keep[1, 0, 4] = True
    cs = compact_live_steps(keep)
    assert cs.n_total == 5
    assert cs.live_steps == (1, 4)
    assert cs.n_elided == 3
    # hops: prologue to step 1, then the fused 1 -> 4 jump
    assert cs.hops == (1, 3)

    empty = compact_live_steps(np.zeros((3, 3, 3), dtype=bool))
    assert empty.live_steps == () and empty.n_elided == 3


def test_choose_cannon_skew_concentrates_cliques():
    """Block-diagonal graph: the default alignment leaves every step
    live (device (x,x) lives at shift -x mod q); the σ search must find
    the visit order putting all live work on one step."""
    q = 3
    g, _ = preprocess(residue_cliques(q, 8))
    plan = build_plan(g, q)
    assert compact_live_steps(plan.step_keep).n_live == q  # default: all live
    sigma, n_live = choose_cannon_skew(plan.step_keep)
    assert n_live == 1
    assert sorted(sigma) == list(range(q))  # a true permutation

    # the re-packed σ plan's mask realizes exactly that live count, with
    # the same number of kept (device, step) pairs (σ only re-times them)
    splan = build_plan(g, q, skew_perm=sigma)
    assert compact_live_steps(splan.step_keep).n_live == 1
    assert int(splan.step_keep.sum()) == int(plan.step_keep.sum())


def test_choose_cannon_skew_identity_on_dense():
    g, _ = preprocess(rmat(8, 8, seed=3))
    plan = build_plan(g, 3)
    sigma, n_live = choose_cannon_skew(plan.step_keep)
    assert sigma == (0, 1, 2)  # nothing to gain: identity, byte-stable plans
    assert n_live == 3


def test_pipeline_attaches_sigma_and_compact():
    art = plan_cannon(residue_cliques(3, 12), 3, cache=PlanCache())
    plan = art.plan
    assert plan.skew_perm is not None and sorted(plan.skew_perm) == [0, 1, 2]
    assert plan.compact is not None and plan.compact.n_live == 1
    assert art.compact is plan.compact
    # summa/oned: no free visit order, but live lists are staged
    assert plan_summa(
        residue_cliques(3, 12), 3, 3, cache=PlanCache()
    ).compact is not None
    oned = plan_oned(residue_cliques(3, 12), 9, cache=PlanCache())
    assert oned.compact.live_steps == (0, 3, 6)  # rings hop in clique strides


def test_sigma_pack_matches_loop_reference():
    from repro.core.plan import _build_plan_loops
    from repro.pipeline.stages import pack_tc_plan

    g, _ = preprocess(residue_cliques(3, 8))
    sigma = (0, 2, 1)
    fast = pack_tc_plan(g, 3, skew_perm=sigma, aug_keys=True)
    ref = _build_plan_loops(g, 3, skew_perm=sigma, aug_keys=True)
    for name, arr in fast.device_arrays().items():
        assert arr.tobytes() == ref.device_arrays()[name].tobytes(), name


def test_resolve_compact_steps_contract():
    g, _ = preprocess(named_graph("karate"))
    plan = build_plan(g, 2)  # raw pack: no compaction stage ran
    assert resolve_compact_steps(plan, None) is None
    with pytest.raises(ValueError, match="no compacted schedule"):
        resolve_compact_steps(plan, True)
    plan.compact = CompactSchedule(n_total=2, live_steps=(0,))
    assert resolve_compact_steps(plan, None) == (0,)
    assert resolve_compact_steps(plan, False) is None
    # auto never compacts batched/multi-pod engines; explicit True errors
    assert resolve_compact_steps(plan, None, batched=True) is None
    assert resolve_compact_steps(plan, None, npods=2) is None
    with pytest.raises(ValueError, match="batched or multi-pod"):
        resolve_compact_steps(plan, True, batched=True)
    # nothing elided -> auto keeps the scan body
    plan.compact = CompactSchedule(n_total=2, live_steps=(0, 1))
    assert resolve_compact_steps(plan, None) is None


# ======================================================================
# compacted == uncompacted (q=1 in-process; q=3 subprocess below)
# ======================================================================
SPARSE_FIXTURES = {
    "cliques": lambda: residue_cliques(3, 8),
    "star": lambda: star(37),
    "edgeless": lambda: Graph.from_edges(6, [], [], name="empty"),
}

COMBOS = [
    ("cannon", "search"),
    ("cannon", "search2"),
    ("cannon", "global"),
    ("cannon", "dense"),
    ("cannon", "tile"),
    ("cannon", "auto"),
    ("summa", "search"),
    ("summa", "auto"),
    ("oned", "search"),
    ("oned", "auto"),
]


@pytest.mark.parametrize("graph_name", sorted(SPARSE_FIXTURES))
@pytest.mark.parametrize("schedule,method", COMBOS)
def test_compacted_equals_uncompacted_q1(graph_name, schedule, method):
    g = SPARSE_FIXTURES[graph_name]()
    exp = triangle_count_oracle(g)
    compacted = count_triangles(g, q=1, schedule=schedule, method=method)
    full = count_triangles(
        g, q=1, schedule=schedule, method=method, compact=False
    )
    assert compacted.triangles == full.triangles == exp


def test_superset_live_steps_are_valid():
    """Keeping a globally-dead step live is always correct — the
    contract the stepper's resume path relies on."""
    import jax.numpy as jnp

    from repro.core.api import make_grid_mesh
    from repro.core.cannon import build_cannon_fn

    g = residue_cliques(2, 8)
    exp = triangle_count_oracle(g)
    g2, _ = preprocess(g)
    plan = build_plan(g2, 1)
    plan.compact = CompactSchedule(n_total=1, live_steps=(0,))
    fn = build_cannon_fn(plan, make_grid_mesh(1), compact=True)
    arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
    assert int(fn(**arrays)) == exp


DIST_COMPACT_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import (Graph, count_triangles, residue_cliques, star,
                        triangle_count_oracle)

q = 3
fixtures = [residue_cliques(q, 12), star(10 * q + 1),
            Graph.from_edges(6, [], [], name="empty")]
combos = {combos}
for g in fixtures:
    exp = triangle_count_oracle(g)
    for schedule, method in combos:
        m = count_triangles(g, q=q, schedule=schedule, method=method)
        u = count_triangles(g, q=q, schedule=schedule, method=method,
                            compact=False)
        n = count_triangles(g, q=q, schedule=schedule, method=method,
                            compact=False, use_step_mask=False)
        assert m.triangles == u.triangles == n.triangles == exp, (
            g.name, schedule, method, m.triangles, u.triangles,
            n.triangles, exp)
        cs = getattr(m.plan, "compact", None)
        assert cs is not None, (g.name, schedule)
        if g.name.startswith("cliques") and schedule == "cannon":
            assert cs.n_live == 1, (g.name, schedule, cs)
        if g.name == "empty":
            assert cs.n_live == 0, (g.name, schedule, cs)
        print(f"{{g.name}}/{{schedule}}/{{method}} ok")
print("ALL-OK")
"""


def test_compacted_equivalence_distributed(distributed_runner):
    combos = [
        ("cannon", "search"), ("cannon", "global"), ("cannon", "search2"),
        ("cannon", "dense"), ("cannon", "tile"), ("cannon", "auto"),
        ("summa", "search"), ("oned", "search"),
    ]
    out = distributed_runner(
        DIST_COMPACT_CODE.format(combos=combos), ndev=9, timeout=1800
    )
    assert "ALL-OK" in out


# ======================================================================
# host-staged aug keys: parity with the on-device build (x64 on & off)
# ======================================================================
def _assert_aug_parity(plan):
    import jax.numpy as jnp

    from repro.core.count import build_aug_keys

    q = plan.q
    for x in range(q):
        for y in range(q):
            dev = build_aug_keys(
                jnp.asarray(plan.b_indptr[x, y]),
                jnp.asarray(plan.b_indices[x, y]),
            )
            assert np.array_equal(np.asarray(dev), plan.b_aug[x, y]), (x, y)
    assert np.all(np.diff(plan.b_aug, axis=-1) >= 0)  # sorted per block


def test_staged_aug_keys_parity_x64_off():
    """Default test process runs with x64 off: int32 keys, staged and
    on-device builds must agree bit for bit and count identically."""
    from repro import compat

    assert not compat.x64_enabled()
    g, _ = preprocess(residue_cliques(3, 8))
    plan = build_plan(g, 3, aug_keys=True)
    assert plan.b_aug is not None and plan.b_aug.dtype == np.int32
    _assert_aug_parity(plan)

    exp = triangle_count_oracle(residue_cliques(3, 8))
    for method in ("global", "search2"):
        staged = count_triangles(
            residue_cliques(3, 8), q=1, method=method
        )
        assert staged.triangles == exp


DIST_AUG_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from repro.core import build_plan, preprocess, rmat, triangle_count_oracle
from repro.core.api import make_grid_mesh
from repro.core.cannon import build_cannon_fn
from repro.core.count import build_aug_keys

q = 2
g = rmat(8, 8, seed=21)
exp = triangle_count_oracle(g)
g2, _ = preprocess(g)
plan = build_plan(g2, q, aug_keys=True)
assert plan.b_aug is not None
for x in range(q):
    for y in range(q):
        dev = build_aug_keys(jnp.asarray(plan.b_indptr[x, y]),
                             jnp.asarray(plan.b_indices[x, y]))
        assert np.array_equal(np.asarray(dev), plan.b_aug[x, y]), (x, y)

mesh = make_grid_mesh(q)
arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
staged = build_cannon_fn(plan, mesh, method="global")
plain_plan = build_plan(g2, q, aug_keys=False)
plain = build_cannon_fn(plain_plan, mesh, method="global")
plain_arrays = {k: jnp.asarray(v)
                for k, v in plain_plan.device_arrays().items()}
a = int(staged(**arrays))
b = int(plain(**plain_arrays))
assert a == b == exp, (a, b, exp)
print("AUG-OK", a)
"""


def test_staged_aug_keys_distributed_x64_on(distributed_runner):
    out = distributed_runner(DIST_AUG_CODE, ndev=4, timeout=900)
    assert "AUG-OK" in out


def test_batched_global_uses_staged_keys():
    graphs = [residue_cliques(2, 6), star(13), named_graph("karate")]
    expected = [triangle_count_oracle(g) for g in graphs]
    res = count_triangles_many(graphs, q=1, method="global")
    assert res.triangles == expected


def test_host_aug_keys_refuses_unstageable_width(monkeypatch):
    """Past the int32 key range with x64 off the host build must return
    None (staging would silently truncate on device)."""
    from repro import compat

    assert not compat.x64_enabled()
    nb = 46341
    indptr = np.zeros((1, 1, nb + 1), dtype=np.int32)
    indices = np.zeros((1, 1, 1), dtype=np.int32)
    assert host_aug_keys(indptr, indices) is None


# ======================================================================
# compacted stepper: checkpoint round trip across an elided schedule
# ======================================================================
DIST_STEPPER_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from repro.core import residue_cliques, triangle_count_oracle
from repro.core.api import make_grid_mesh
from repro.core.cannon import build_cannon_fn, build_cannon_stepper
from repro.core.plan import CompactSchedule, compact_live_steps
from repro.pipeline import plan_cannon
from repro.pipeline.cache import PlanCache

q = 3
g = residue_cliques(q, 8)
exp = triangle_count_oracle(g)
art = plan_cannon(g, q, cache=PlanCache())
plan = art.plan
true_live = plan.compact.live_steps
assert plan.compact.n_live == 1, plan.compact
# widen to a 2-step live list (supersets of the true live set are valid
# schedules) so the checkpoint lands *between* live steps
extra = next(s for s in range(q) if s not in true_live)
live = tuple(sorted(set(true_live) | {extra}))
plan.compact = CompactSchedule(n_total=q, live_steps=live)

mesh = make_grid_mesh(q)
stepper = build_cannon_stepper(plan, mesh)
assert stepper.live_steps == live
assert stepper.n_carry == 4  # compacted stepper: single payload generation
arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
statics = {k: arrays[k] for k in ("m_ti", "m_tj", "m_cnt", "step_keep")}

carry = list(stepper.prime(arrays))
acc = jnp.zeros((q, q), jnp.int64)
saved = None
for s in live:
    if s == live[1]:  # checkpoint mid-loop, host numpy round trip
        saved = ([np.asarray(c).copy() for c in carry],
                 np.asarray(acc).copy(), s)
    out = stepper(tuple(carry) + (acc,), statics, step=s)
    carry, acc = list(out[:-1]), out[-1]
total = int(np.asarray(acc).sum())

carry2 = [jnp.asarray(c) for c in saved[0]]
acc2 = jnp.asarray(saved[1])
for s in [t for t in live if t >= saved[2]]:
    out = stepper(tuple(carry2) + (acc2,), statics, step=s)
    carry2, acc2 = list(out[:-1]), out[-1]
total2 = int(np.asarray(acc2).sum())
assert total == total2 == exp, (total, total2, exp)
for a, b in zip(carry, carry2):
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

# and the compacted scan engine agrees
fn = build_cannon_fn(plan, mesh)
assert int(fn(**arrays)) == exp
print("COMPACT-STEPPER-OK")
"""


def test_compacted_stepper_checkpoint_roundtrip(distributed_runner):
    out = distributed_runner(DIST_STEPPER_CODE, ndev=9, timeout=1200)
    assert "COMPACT-STEPPER-OK" in out


# ======================================================================
# autotune: determinism + auto-method resolution
# ======================================================================
def test_autotune_deterministic_and_cached():
    g = rmat(8, 8, seed=5)
    cache = PlanCache()
    a1 = plan_cannon(g, 2, autotune=True, cache=cache)
    a2 = plan_cannon(g, 2, autotune=True, cache=cache)
    assert a2.cache_hit and a1.plan.chunk == a2.plan.chunk
    # same graph through a fresh cache: identical shapes (no timing, no
    # randomness anywhere in the stage)
    b = plan_cannon(g, 2, autotune=True, cache=PlanCache())
    assert not b.cache_hit
    assert b.plan.chunk == a1.plan.chunk
    assert b.autotune == a1.autotune
    assert b.plan.n_long == a1.plan.n_long
    assert b.plan.d_small == a1.plan.d_small
    # the autotune knob is a cache-key component
    c = plan_cannon(g, 2, autotune=False, cache=cache)
    assert not c.cache_hit and c.autotune is None


def test_autotune_counts_stay_exact_after_reorder():
    g = rmat(8, 8, seed=5)
    exp = triangle_count_oracle(g)
    for schedule in ("cannon", "summa", "oned"):
        r = count_triangles(g, q=1, schedule=schedule, method="auto")
        assert r.triangles == exp, (schedule, r.triangles)


def test_auto_resolves_search2_with_staged_keys_on_heavy_tail():
    """A pendant-heavy hub clique keeps p90 probe length at 1 while the
    clique rows reach ~39: auto must resolve to search2 and re-plan with
    staged aug keys (the search resolution never pays for them)."""
    iu, ju = np.triu_indices(30, k=1)
    src = np.concatenate([iu, np.full(8000, 0)])
    dst = np.concatenate([ju, np.arange(30, 8030)])
    g = Graph.from_edges(8030, src, dst, name="hubclique")
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, method="auto")
    assert r.method == "search2"
    assert r.triangles == exp
    assert r.plan.autotune["tail_heavy"]
    assert r.plan.b_aug is not None  # re-planned with staged keys

    flat = count_triangles(g, q=1, method="auto")  # warm cache path
    assert flat.triangles == exp and flat.method == "search2"

    light = count_triangles(rmat(7, 8, seed=2), q=1, method="auto")
    assert light.method == "search"
    assert light.plan.b_aug is None  # search resolution stages no keys


def test_auto_method_resolution():
    from repro.core.api import _resolve_auto_method

    class P:
        pass

    p = P()
    assert _resolve_auto_method(p) == "search"  # no autotune report
    p.autotune = dict(tail_heavy=False)
    assert _resolve_auto_method(p) == "search"
    p.autotune = dict(tail_heavy=True)
    p.n_long = 7
    assert _resolve_auto_method(p) == "search2"
    q = P()
    q.autotune = dict(tail_heavy=True, n_long=None)  # oned: no split
    assert _resolve_auto_method(q) == "search"


def test_pick_chunk_properties():
    from repro.pipeline.stages import _pick_chunk

    assert _pick_chunk(100, 8) == 128  # pow2 cover of the task list
    assert _pick_chunk(100000, 8) == 4096  # hard cap
    assert _pick_chunk(100000, 100000) == 64  # budget-bound floor
    assert _pick_chunk(1, 1) == 64


# ======================================================================
# two-level kernel: ignored-kwarg warning (satellite guard rail)
# ======================================================================
def test_two_level_warns_once_on_ignored_kwargs(monkeypatch):
    import jax.numpy as jnp

    from repro.core import count as count_mod

    monkeypatch.setattr(count_mod, "_TWO_LEVEL_KW_WARNED", False)
    args = (
        jnp.asarray(np.array([0, 1], np.int32)),  # a_indptr (nb=1)
        jnp.asarray(np.array([0], np.int32)),
        jnp.asarray(np.array([0, 1], np.int32)),
        jnp.asarray(np.array([0], np.int32)),
        jnp.zeros(1, jnp.int32),
        jnp.zeros(1, jnp.int32),
        jnp.asarray(1),
        1,
    )
    kw = dict(dpad_long=1, dpad_short=1, chunk=1)
    with pytest.warns(UserWarning, match="ignores probe_shorter"):
        count_mod.count_pair_search_two_level(
            *args, probe_shorter=False, **kw
        )
    # one-time: a second offending call stays silent
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        count_mod.count_pair_search_two_level(
            *args, probe_shorter=False, sentinel=7, **kw
        )

    # defaults (and the engine's search2 factory) never warn
    monkeypatch.setattr(count_mod, "_TWO_LEVEL_KW_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        count_mod.count_pair_search_two_level(*args, **kw)
