"""Delta-aware planning (DESIGN.md §4.7): EdgeDelta semantics, the
splice / repack / rebase ladder, cache lineage, and exact streaming
counts.

The load-bearing invariant everywhere: counting an incrementally
re-planned artifact equals a cold count of the mutated graph — and on
the splice path the plan *arrays* are byte-identical to a cold re-pack
under the same kept σ, so count parity follows structurally.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    count_triangles,
    count_triangles_delta,
    graph_from_spec,
    residue_cliques,
    triangle_count_oracle,
)
from repro.core.generators import flip_edges, random_edge_flips, split_specs
from repro.core.graph import Graph
from repro.pipeline import EdgeDelta, PlanCache, apply_delta, plan_cannon
from repro.pipeline.stages import (
    autotune_tc_plan,
    pack_tc_plan,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------------------
# EdgeDelta semantics
# ----------------------------------------------------------------------
def test_edge_delta_canonicalizes():
    d = EdgeDelta(add=[(5, 2), (2, 5), (3, 3), (1, 4)])
    # dedup + (min, max) orientation + self-loop drop, sorted
    assert d.add.tolist() == [[1, 4], [2, 5]]
    assert d.remove.shape == (0, 2)
    assert d.k == 2


def test_edge_delta_rejects_overlap():
    with pytest.raises(ValueError):
        EdgeDelta(add=[(1, 2)], remove=[(2, 1)])


def test_edge_delta_digest_is_content_addressed():
    a = EdgeDelta(add=[(1, 2)], remove=[(3, 4)])
    b = EdgeDelta(add=[(2, 1)], remove=[(4, 3)])
    c = EdgeDelta(add=[(3, 4)], remove=[(1, 2)])
    assert a.digest() == b.digest()  # canonical form decides
    assert a.digest() != c.digest()  # add/remove sides are distinct


def test_edge_delta_apply_to_matches_manual_merge():
    g = graph_from_spec("er:60,5,1")
    d = EdgeDelta.random_flips(g, 9, seed=3)
    g2 = d.apply_to(g)
    base = {tuple(e) for e in np.sort(g.edges, axis=1).tolist()}
    want = (base - {tuple(e) for e in d.remove.tolist()}) | {
        tuple(e) for e in d.add.tolist()
    }
    got = {tuple(e) for e in np.sort(g2.edges, axis=1).tolist()}
    assert got == want
    assert g2.n == g.n


def test_random_flips_deterministic_and_disjoint():
    g = graph_from_spec("er:80,6,2")
    add1, rem1 = random_edge_flips(g, 11, seed=5)
    add2, rem2 = random_edge_flips(g, 11, seed=5)
    assert np.array_equal(add1, add2) and np.array_equal(rem1, rem2)
    assert len(add1) + len(rem1) == 11
    base = {tuple(e) for e in np.sort(g.edges, axis=1).tolist()}
    assert all(tuple(e) not in base for e in add1.tolist())
    assert all(tuple(e) in base for e in rem1.tolist())
    add3, _ = random_edge_flips(g, 11, seed=6)
    assert not np.array_equal(add1, add3)  # seed matters


def test_delta_graph_spec():
    g = graph_from_spec("delta:7,4,er:100,6,1")
    assert np.array_equal(
        g.edges, flip_edges(graph_from_spec("er:100,6,1"), 7, 4).edges
    )
    # base specs containing commas survive the 2-split
    g2 = graph_from_spec("delta:3,0,rmat:8,4,2")
    assert g2.n == graph_from_spec("rmat:8,4,2").n
    with pytest.raises(ValueError):
        graph_from_spec("delta:5,er:10,3")  # missing a field
    # well-formedness: one spec, not split at its interior commas
    assert split_specs("delta:5,0,karate") == ["delta:5,0,karate"]


# ----------------------------------------------------------------------
# splice byte-parity: the incremental pack equals the cold re-pack
# ----------------------------------------------------------------------
_ARRAYS = (
    "a_indptr", "a_indices", "b_indptr", "b_indices",
    "m_ti", "m_tj", "m_cnt",
)


def _assert_plan_parity(got, ref):
    for name in _ARRAYS:
        a, b = getattr(got, name), getattr(ref, name)
        assert a.shape == b.shape and np.array_equal(a, b), name
    if ref.step_keep is not None:
        assert np.array_equal(got.step_keep, ref.step_keep)
    if ref.b_aug is not None:
        assert np.array_equal(got.b_aug, ref.b_aug)
    if ref.stats is not None and got.stats is not None:
        assert (
            got.stats.intersection_tasks_total
            == ref.stats.intersection_tasks_total
        )
        assert np.array_equal(
            got.stats.probe_work_per_device_shift,
            ref.stats.probe_work_per_device_shift,
        )


@pytest.mark.parametrize("q", [2, 3])
@pytest.mark.parametrize(
    "flags",
    [
        dict(),
        dict(keep_blocks=True, aug_keys=True),
        dict(autotune=True),
    ],
    ids=["plain", "blocks+aug", "autotune"],
)
def test_apply_delta_matches_cold_pack(q, flags):
    g = graph_from_spec("er:300,9,5")
    cache = PlanCache(maxsize=4)
    art = plan_cannon(g, q, reorder=False, cache=cache, **flags)
    spliced = 0
    # dirty-block count must stay under the splice ladder's 50% limit
    # for at least some trials: fewer flips on the smaller grid
    k = 2 if q == 2 else 5
    for trial in range(6):
        d = EdgeDelta.random_flips(g, k, seed=40 + trial)
        art2 = apply_delta(art, d, cache=PlanCache(maxsize=0))
        assert art2.graph.m == d.apply_to(g).m
        ref = pack_tc_plan(
            d.apply_to(g), q, skew_perm=art.plan.skew_perm,
            keep_blocks=flags.get("keep_blocks", False) or False,
            aug_keys=flags.get("aug_keys", False),
        )
        if flags.get("autotune"):
            ref = autotune_tc_plan(ref)
        _assert_plan_parity(art2.plan, ref)
        spliced += art2.delta_report["level"] == "splice"
    assert spliced > 0  # localized flips must exercise the fast path


def test_apply_delta_noop_reuses_everything():
    g = graph_from_spec("er:100,6,1")
    art = plan_cannon(g, 2, cache=PlanCache(maxsize=2))
    art2 = apply_delta(art, EdgeDelta(), cache=PlanCache(maxsize=0))
    assert art2.delta_report["level"] == "noop"
    assert art2.plan is art.plan
    # removing an absent edge is also a no-op after effect-filtering
    art3 = apply_delta(
        art, EdgeDelta(remove=[(0, 1), (0, 2)]), cache=PlanCache(maxsize=0)
    ) if not _has_edge(g, 0, 1) and not _has_edge(g, 0, 2) else None
    if art3 is not None:
        assert art3.delta_report["level"] == "noop"


def _has_edge(g, u, v):
    key = {tuple(e) for e in np.sort(g.edges, axis=1).tolist()}
    return (min(u, v), max(u, v)) in key


# ----------------------------------------------------------------------
# counting equivalence (1 device, in-process)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("schedule", ["cannon", "summa", "oned"])
def test_count_triangles_delta_exact(schedule):
    g = graph_from_spec("er:150,7,2")
    cache = PlanCache(maxsize=8)
    d = EdgeDelta.random_flips(g, 10, seed=1)
    res = count_triangles_delta(g, d, q=1, schedule=schedule, cache=cache)
    assert res.triangles == triangle_count_oracle(d.apply_to(g))
    assert res.delta is not None and res.delta["level"] in (
        "splice", "repack", "rebase"
    )
    assert res.artifact is not None and res.artifact.lineage is not None


def test_count_triangles_delta_chained_with_rebase():
    g = graph_from_spec("er:120,6,4")
    cache = PlanCache(maxsize=8)
    art = None
    for i in range(4):
        d = EdgeDelta.random_flips(g, 4, seed=50 + i)
        res = count_triangles_delta(
            g, d, q=1, artifact=art, cache=cache, rebase_every=2
        )
        g = d.apply_to(g)
        assert res.triangles == triangle_count_oracle(g), i
        art = res.artifact
        if res.delta["rebased"]:
            assert res.delta["depth"] == 0
    # depth 1, 2, rebase (depth>2 would-be 3), depth 1: at least one
    assert art.lineage["depth"] <= 2


def test_delta_count_equals_fresh_plan_count():
    g = graph_from_spec("er:200,8,7")
    d = EdgeDelta.random_flips(g, 8, seed=2)
    cache = PlanCache(maxsize=8)
    inc = count_triangles_delta(g, d, q=1, cache=cache)
    fresh = count_triangles(d.apply_to(g), q=1, cache=PlanCache(maxsize=2))
    assert inc.triangles == fresh.triangles


# ----------------------------------------------------------------------
# edge cases: emptied blocks, revived steps, edgeless base
# ----------------------------------------------------------------------
def test_delta_emptying_a_block_flips_skip_mask():
    # residue cliques mod 3: each clique's triangles live in one
    # diagonal block — deleting clique 0's edges empties block (0, 0)
    # and must flip its live steps back to skipped
    q = 3
    g = residue_cliques(3, 5)
    art = plan_cannon(g, q, reorder=False, cache=PlanCache(maxsize=2))
    live0 = int(art.plan.step_keep.sum())
    assert live0 > 0
    clique0 = [
        tuple(e) for e in np.sort(g.edges, axis=1).tolist()
        if e[0] % 3 == 0
    ]
    d = EdgeDelta(remove=clique0)
    art2 = apply_delta(art, d, cache=PlanCache(maxsize=0))
    g2 = d.apply_to(g)
    ref = pack_tc_plan(g2, q, skew_perm=art2.plan.skew_perm)
    _assert_plan_parity(art2.plan, ref)
    assert int(art2.plan.step_keep.sum()) < live0
    res = count_triangles(g2, q=1, cache=PlanCache(maxsize=2))
    assert res.triangles == triangle_count_oracle(g2)


def test_delta_reviving_elided_step_recomputes_schedule():
    # residue cliques: only diagonal blocks are non-empty, so the
    # compaction stage elides shifts; cross-class edges land work in an
    # off-diagonal block — the splice must grow the live-step set (and
    # drop inherited engines), not silently keep the stale schedule
    g = residue_cliques(3, 5)
    art = plan_cannon(g, 3, reorder=False, compact=True,
                      cache=PlanCache(maxsize=2))
    n_live0 = art.plan.compact.n_live
    assert n_live0 < art.plan.compact.n_total  # fixture elides steps
    add = [(0, 1), (3, 4), (6, 7)]  # residues (0, 1): block (0, 1)
    d = EdgeDelta(add=add)
    art2 = apply_delta(art, d, cache=PlanCache(maxsize=0))
    g2 = d.apply_to(g)
    ref = pack_tc_plan(g2, 3, skew_perm=art2.plan.skew_perm)
    for name in _ARRAYS:
        assert np.array_equal(getattr(art2.plan, name), getattr(ref, name))
    assert np.array_equal(
        art2.plan.step_keep,
        pack_tc_plan(g2, 3, skew_perm=art2.plan.skew_perm).step_keep,
    )
    if art2.delta_report["level"] == "splice":
        live0 = set(art.plan.compact.live_steps)
        live2 = set(art2.plan.compact.live_steps)
        assert live2 >= live0
        if live2 - live0:  # a dead step revived: engines must not carry
            assert not art2.delta_report["fn_inherited"]
    res = count_triangles(g2, q=1, cache=PlanCache(maxsize=2))
    assert res.triangles == triangle_count_oracle(g2)


def test_delta_from_edgeless_graph():
    g = Graph(n=24, edges=np.zeros((0, 2), np.int64), name="empty")
    cache = PlanCache(maxsize=4)
    base = count_triangles(g, q=1, cache=cache)
    assert base.triangles == 0
    tri = [(0, 1), (1, 2), (0, 2), (3, 4)]
    res = count_triangles_delta(
        g, EdgeDelta(add=tri), q=1, artifact=base.artifact, cache=cache
    )
    assert res.triangles == 1


# ----------------------------------------------------------------------
# cache lineage + eviction hooks
# ----------------------------------------------------------------------
def test_delta_lineage_cache_hit():
    g = graph_from_spec("er:90,5,3")
    cache = PlanCache(maxsize=8)
    art = plan_cannon(g, 2, cache=cache)
    d = EdgeDelta.random_flips(g, 3, seed=9)
    a1 = apply_delta(art, d, cache=cache)
    assert not a1.cache_hit
    a2 = apply_delta(art, d, cache=cache)
    assert a2.cache_hit and a2.key == a1.key
    # a different delta is a different lineage entry
    a3 = apply_delta(art, EdgeDelta.random_flips(g, 3, seed=10), cache=cache)
    assert not a3.cache_hit and a3.key != a1.key


def test_eviction_releases_artifact_buffers():
    g1, g2 = graph_from_spec("er:60,4,1"), graph_from_spec("er:70,4,2")
    tiny = PlanCache(maxsize=1)
    a1 = plan_cannon(g1, 2, cache=tiny)
    a1.staged()  # pin device buffers in the artifact memo
    assert a1._memo
    plan_cannon(g2, 2, cache=tiny)  # evicts a1 (and relabel entries)
    assert tiny.stats()["evictions"] >= 1
    assert not a1._memo  # release() dropped staged buffers + engines
    assert a1.restage_from is None


def test_eviction_custom_hook():
    seen = []
    tiny = PlanCache(maxsize=1, on_evict=lambda v: seen.append(v))
    tiny.put(("k", 1), "a")
    tiny.put(("k", 2), "b")
    assert seen == ["a"]
    tiny.clear()
    assert seen == ["a", "b"]


def test_splice_restages_only_dirty_buffers():
    g = graph_from_spec("er:300,9,5")
    cache = PlanCache(maxsize=4)
    art = plan_cannon(g, 3, reorder=False, cache=cache)
    art.staged()
    for trial in range(6):
        d = EdgeDelta.random_flips(g, 4, seed=70 + trial)
        art2 = apply_delta(art, d, cache=PlanCache(maxsize=0))
        if art2.delta_report["level"] != "splice":
            continue
        art2.staged()
        assert art2.stage_seconds.get("stage_reused_buffers", 0) >= 1
        return
    pytest.skip("no trial took the splice path")


# ----------------------------------------------------------------------
# property suite (hypothesis; defined only when available — CI installs
# it, so the full schedule × method × compact cross runs there, while
# the deterministic tests above always run)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal envs
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:

    @st.composite
    def graph_and_delta(draw):
        n = draw(st.integers(min_value=4, max_value=32))
        m = draw(st.integers(min_value=0, max_value=3 * n))
        src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        g = Graph.from_edges(n, src, dst)
        k = draw(
            st.integers(min_value=0, max_value=min(6, n * (n - 1) // 2))
        )
        seed = draw(st.integers(min_value=0, max_value=2**16))
        return g, EdgeDelta.random_flips(g, k, seed=seed)

    @pytest.mark.parametrize("schedule", ["cannon", "summa", "oned"])
    @pytest.mark.parametrize("method", ["search2", "fused"])
    @pytest.mark.parametrize("compact", [True, False])
    @given(gd=graph_and_delta())
    @settings(max_examples=4, deadline=None)
    def test_property_delta_count_equivalence(schedule, method, compact, gd):
        g, d = gd
        # explicit search2 is wired at the api level on Cannon only
        # (the two-level split needs the bucketized plan); the other
        # schedules run their incumbent kernel for that slot
        m = method if schedule == "cannon" or method == "fused" else "search"
        kwargs = dict(q=1, schedule=schedule, method=m, compact=compact)
        inc = count_triangles_delta(g, d, **kwargs)
        g2 = d.apply_to(g)
        fresh = count_triangles(g2, cache=PlanCache(maxsize=2), **kwargs)
        assert (
            inc.triangles == fresh.triangles == triangle_count_oracle(g2)
        )

    @given(gd=graph_and_delta())
    @settings(max_examples=15, deadline=None)
    def test_property_splice_matches_cold_pack(gd):
        g, d = gd
        if g.m == 0 and d.k == 0:
            return
        for q in (2, 3):
            art = plan_cannon(
                g, q, reorder=False, cache=PlanCache(maxsize=2)
            )
            art2 = apply_delta(art, d, cache=PlanCache(maxsize=0))
            ref = pack_tc_plan(
                d.apply_to(g), q, skew_perm=art2.plan.skew_perm
            )
            _assert_plan_parity(art2.plan, ref)


# ----------------------------------------------------------------------
# distributed e2e (subprocess, 4 host devices)
# ----------------------------------------------------------------------
def test_delta_counts_distributed(distributed_runner):
    code = """
    import numpy as np
    from repro.core import (count_triangles, count_triangles_delta,
                            graph_from_spec, triangle_count_oracle)
    from repro.pipeline import EdgeDelta, PlanCache

    g = graph_from_spec("er:160,7,3")
    d = EdgeDelta.random_flips(g, 8, seed=4)
    g2 = d.apply_to(g)
    exp = triangle_count_oracle(g2)
    for schedule, method in (("cannon", "search2"), ("cannon", "fused"),
                             ("summa", "search"), ("summa", "fused"),
                             ("oned", "search")):
        for compact in (True, False):
            cache = PlanCache(maxsize=8)
            res = count_triangles_delta(
                g, d, q=2, schedule=schedule, method=method,
                compact=compact, cache=cache,
            )
            assert res.triangles == exp, (
                schedule, method, compact, res.triangles, exp)
            assert res.delta["level"] in ("splice", "repack", "rebase")
    print("OK", exp)
    """
    out = distributed_runner(code, ndev=4, timeout=1200)
    assert "OK" in out


def test_tc_run_stream_e2e(tmp_path):
    g = graph_from_spec("er:140,6,2")
    deltas, cur = [], g
    rng_seed = 11
    for i in range(3):
        add, rem = random_edge_flips(cur, 5, seed=rng_seed + i)
        deltas.append({"add": add.tolist(), "remove": rem.tolist()})
        cur = EdgeDelta(add=add, remove=rem).apply_to(cur)
    stream = tmp_path / "deltas.jsonl"
    stream.write_text("\n".join(json.dumps(d) for d in deltas) + "\n")

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.tc_run",
         "--graph", "er:140,6,2", "--grid", "2",
         "--stream", str(stream), "--verify", "--json"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert report["deltas_applied"] == 3
    assert {"dirty_blocks", "replanned_stages", "rebased"} <= set(report)
    assert all(r["correct"] for r in report["rounds"])
    assert report["triangles"] == triangle_count_oracle(cur)
    assert report["plan_cache"]["size"] >= 1
