"""Engine equivalence: every (schedule × operand-store × kernel)
combination must produce identical counts on the same graphs.

q=1 combinations run in-process; q in {2, 3} run in subprocesses with
XLA host devices via the ``distributed_runner`` fixture (conftest.py).
"""
import pytest

from repro.core import (
    available_schedules,
    count_triangles,
    get_schedule,
    named_graph,
    rmat,
    triangle_count_oracle,
)

# (schedule, method) -> operand store exercised (see DESIGN.md §2):
#   cannon/search|search2|global -> CSRStore (blob)
#   cannon/dense                 -> DenseStore
#   cannon/tile                  -> TileStore (bit-packed 128x128)
#   summa/search                 -> SummaCSRStore (panel broadcast)
#   oned/search                  -> OneDCSRStore (ring blob)
COMBOS = [
    ("cannon", "search"),
    ("cannon", "search2"),
    ("cannon", "global"),
    ("cannon", "dense"),
    ("cannon", "tile"),
    ("summa", "search"),
    ("oned", "search"),
]

GRAPHS = ["bull", "karate", "rmat"]


def _graph(name):
    if name == "rmat":
        return rmat(9, 8, seed=42)
    return named_graph(name)


def test_registry_contains_bundled_schedules():
    assert {"cannon", "summa", "oned"} <= set(available_schedules())
    for name in ("cannon", "summa", "oned"):
        spec = get_schedule(name)
        assert callable(spec.runner)
        assert callable(spec.build_fn)
    with pytest.raises(ValueError, match="unknown schedule"):
        get_schedule("nope")


def test_csr_kernel_registry():
    from repro.core.engine import CSR_KERNELS, make_csr_kernel

    assert {"search", "search2", "global"} <= set(CSR_KERNELS)
    with pytest.raises(ValueError, match="unknown CSR count method"):
        make_csr_kernel("nope", dpad=1, chunk=1)
    with pytest.raises(ValueError, match="bucketized plan"):
        make_csr_kernel("search2", dpad=1, chunk=1)


@pytest.mark.parametrize("graph_name", GRAPHS)
@pytest.mark.parametrize("schedule,method", COMBOS)
def test_equivalence_q1(graph_name, schedule, method):
    g = _graph(graph_name)
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, schedule=schedule, method=method)
    assert r.triangles == exp, (graph_name, schedule, method)


def test_per_device_counts_sum_to_global():
    """Reduction(global_sum=False) partials must psum to the same total."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build_plan, preprocess
    from repro.core.api import make_grid_mesh
    from repro.core.cannon import build_cannon_fn

    g = _graph("rmat")
    exp = triangle_count_oracle(g)
    g2, _ = preprocess(g)
    plan = build_plan(g2, 1)
    fn = build_cannon_fn(plan, make_grid_mesh(1), reduce_global=False)
    per = fn(**{k: jnp.asarray(v) for k, v in plan.device_arrays().items()})
    assert int(np.asarray(per).sum()) == exp


DIST_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import count_triangles, named_graph, rmat, triangle_count_oracle

COMBOS = {combos}
for gname in {graphs!r}:
    g = rmat(9, 8, seed=42) if gname == "rmat" else named_graph(gname)
    exp = triangle_count_oracle(g)
    for schedule, method in COMBOS:
        r = count_triangles(g, q={q}, schedule=schedule, method=method)
        assert r.triangles == exp, (gname, schedule, method, r.triangles, exp)
        print(f"{{gname}}/{{schedule}}/{{method}}: {{r.triangles}} ok")
print("ALL-OK")
"""


@pytest.mark.parametrize("q", [2, 3])
def test_equivalence_distributed(q, distributed_runner):
    out = distributed_runner(
        DIST_CODE.format(combos=COMBOS, graphs=GRAPHS, q=q),
        ndev=q * q,
        timeout=1200,
    )
    assert "ALL-OK" in out


def test_custom_schedule_registration():
    """A new schedule is one registration away (and unregisterable by
    overwrite) — the extension point future PRs plug into."""
    from repro.core.api import RunContext, register_schedule

    calls = {}

    def runner(graph, mesh, ctx: RunContext):
        calls["ctx"] = ctx
        return 7, None

    register_schedule("seven", runner)
    try:
        g = _graph("bull")
        r = count_triangles(g, q=1, schedule="seven")
        assert r.triangles == 7
        assert calls["ctx"].q == 1
    finally:
        from repro.core.api import _SCHEDULES

        _SCHEDULES.pop("seven", None)


def test_legacy_runner_receives_relabeled_graph():
    """Runners registered without ``plans_itself`` keep the pre-pipeline
    contract: ``count_triangles`` relabels before dispatch, so
    ``reorder=True`` still applies the paper's §5.3 degree ordering."""
    import numpy as np

    from repro.core.api import register_schedule
    from repro.pipeline import relabel_stage

    seen = {}

    def runner(graph, mesh, ctx):
        seen["graph"] = graph
        # the relabel options were consumed before dispatch
        assert ctx.reorder is False and ctx.cyclic_p is None
        return 0, None

    register_schedule("legacy", runner)
    try:
        g = _graph("karate")
        expected, _ = relabel_stage(g, reorder=True, cyclic_p=None)

        count_triangles(g, q=1, schedule="legacy", reorder=True)
        np.testing.assert_array_equal(seen["graph"].edges, expected.edges)

        count_triangles(g, q=1, schedule="legacy", reorder=False)
        np.testing.assert_array_equal(seen["graph"].edges, g.edges)
    finally:
        from repro.core.api import _SCHEDULES

        _SCHEDULES.pop("legacy", None)
