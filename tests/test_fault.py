"""Supervised fault-tolerant execution (DESIGN.md §8).

Every test here injects *deterministic* typed faults and asserts the
recovered count is byte-identical to the fault-free run within the
restart budget — recovery re-executes the deterministic pipeline, it
never patches partial state.  Single-device tests run inline; the
multi-device recovery/regrid matrix runs in subprocesses via
``distributed_runner`` (conftest keeps the main process at 1 device).

Run just this suite with ``pytest -m fault``.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.fault


# ----------------------------------------------------------------------
# fault plan: grammar + fire semantics
# ----------------------------------------------------------------------
def test_fault_spec_grammar():
    from repro.runtime.faultinject import (
        CkptCorrupt,
        DeviceLost,
        FaultPlan,
        StageFault,
        StepFault,
    )

    plan = FaultPlan.parse(
        "step@2;step@1=devicelost:5;fused=stepfault*-1;ckpt_save;"
        "plan_stage=stage_fault*3"
    )
    s = plan.sites
    assert (s[0].point, s[0].step, s[0].fault, s[0].times) == (
        "step", 2, StepFault, 1
    )
    assert (s[1].fault, s[1].lost) == (DeviceLost, 5)
    assert (s[2].fault, s[2].times) == (StepFault, -1)
    # point-only tokens take the point's default fault type
    assert s[3].fault is CkptCorrupt
    assert (s[4].fault, s[4].times) == (StageFault, 3)
    # describe() round-trips through parse()
    assert FaultPlan.parse(plan.describe()).describe() == plan.describe()

    with pytest.raises(ValueError, match="unknown injection point"):
        FaultPlan.parse("warp_core@3")
    with pytest.raises(ValueError, match="unknown fault type"):
        FaultPlan.parse("step=gremlin")
    with pytest.raises(ValueError, match="empty fault spec"):
        FaultPlan.parse(" ; ")


def test_fault_site_fire_semantics():
    from repro.runtime import faultinject as fi

    plan = fi.FaultPlan.parse("step@1;device_stage=stagefault*2")
    with fi.armed(plan):
        assert fi.is_armed()
        fi.fire("step", step=0)  # wrong step: no-op
        with pytest.raises(fi.StepFault):
            fi.fire("step", step=1)
        fi.fire("step", step=1)  # one-shot: spent after one firing
        for _ in range(2):
            with pytest.raises(fi.StageFault):
                fi.fire("device_stage")
        fi.fire("device_stage")  # times=2 exhausted
    assert not fi.is_armed()
    assert plan.spent()
    assert [e["point"] for e in plan.log] == [
        "step", "device_stage", "device_stage"
    ]
    # unarmed fire is a no-op even at a matching point
    fi.fire("step", step=1)


def test_live_step_indices_compose_with_compaction():
    from repro.core import rmat
    from repro.pipeline import plan_cannon
    from repro.runtime.faultinject import live_step_indices

    g = rmat(9, 8, seed=2)
    art = plan_cannon(g, 3)
    steps = live_step_indices(art.plan)
    assert steps and all(0 <= s < 3 for s in steps)
    if art.plan.compact is not None and art.plan.compact.n_elided > 0:
        assert steps == list(art.plan.compact.live_steps)
    # compaction off: every original step is live
    assert live_step_indices(art.plan, compact_enabled=False) == [0, 1, 2]


# ----------------------------------------------------------------------
# supervisor: backoff / budget / deadline with a fake clock
# ----------------------------------------------------------------------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, d):
        self.t += d


def _fake_supervisor(**kw):
    from repro.runtime import BackoffPolicy, Supervisor

    clk = _FakeClock()
    kw.setdefault(
        "backoff", BackoffPolicy(base=1.0, factor=2.0, max_delay=8.0,
                                 jitter=0.0)
    )
    return Supervisor(clock=clk, sleep=clk.sleep, **kw), clk


def test_supervisor_backoff_sequence_and_recovery():
    from repro.runtime import StepFault

    sup, clk = _fake_supervisor(max_restarts=5)
    calls = {"n": 0}

    def attempt(i, guard):
        calls["n"] += 1
        if calls["n"] <= 3:
            raise StepFault(f"boom {i}")
        return 42

    assert sup.run(attempt) == 42
    rep = sup.report
    assert rep.restarts == 3 and not rep.gave_up
    assert [a.outcome for a in rep.attempts] == [
        "fault", "fault", "fault", "ok"
    ]
    # exponential, jitter-free: 1, 2, 4
    assert [a.backoff for a in rep.attempts[:3]] == [1.0, 2.0, 4.0]
    assert rep.total_backoff_seconds == pytest.approx(7.0)
    assert clk.t == pytest.approx(7.0)


def test_supervisor_budget_exhaustion():
    from repro.runtime import StepFault

    sup, _ = _fake_supervisor(max_restarts=2)

    def attempt(i, guard):
        raise StepFault("always")

    with pytest.raises(StepFault):
        sup.run(attempt)
    assert sup.report.gave_up
    assert len(sup.report.attempts) == 3  # initial + 2 restarts


def test_supervisor_deadline_cooperative():
    sup, clk = _fake_supervisor(max_restarts=3, attempt_deadline=5.0)
    state = {"slow": True}

    def attempt(i, guard):
        if state["slow"]:
            state["slow"] = False
            clk.t += 10.0  # a slow first attempt blows the deadline
        guard()
        return "done"

    assert sup.run(attempt) == "done"
    assert [a.outcome for a in sup.report.attempts] == ["deadline", "ok"]
    assert sup.report.attempts[0].fault == "AttemptDeadlineExceeded"


def test_supervisor_non_retryable_propagates():
    sup, _ = _fake_supervisor(max_restarts=5)

    def attempt(i, guard):
        raise KeyError("not a fault")

    with pytest.raises(KeyError):
        sup.run(attempt)
    assert sup.report.restarts == 0  # never recorded as a restartable


def test_backoff_jitter_is_deterministic_per_seed():
    import random

    from repro.runtime import BackoffPolicy

    pol = BackoffPolicy(base=1.0, factor=2.0, max_delay=64.0, jitter=0.5)
    a = [pol.delay(i, random.Random(7)) for i in range(1, 5)]
    b = [pol.delay(i, random.Random(7)) for i in range(1, 5)]
    assert a == b
    assert all(1.0 * 2 ** (i - 1) <= d < 1.5 * 2 ** (i - 1)
               for i, d in enumerate(a, 1))


# ----------------------------------------------------------------------
# degradation ladder + cross-grid portability
# ----------------------------------------------------------------------
def test_next_demotion_ladder_order():
    from repro.runtime.supervisor import next_demotion

    cfg = dict(method="fused", reduce_strategy="tree", hub_split=True)
    rungs = []
    while True:
        demo = next_demotion(cfg)
        if demo is None:
            break
        rungs.append((demo["rung"], demo["frm"], demo["to"]))
    assert rungs == [
        ("method", "fused", "search2"),
        ("method", "search2", "search"),
        ("compact", "auto", "off"),
        ("reduce", "tree", "flat"),
        ("hub_split", "on", "off"),
    ]
    assert cfg == dict(
        method="search", compact=False, reduce_strategy="flat",
        hub_split=False,
    )
    # oned has no two-level kernel: fused demotes straight to search
    cfg = dict(method="fused", schedule="oned")
    assert next_demotion(cfg)["to"] == "search"


def test_check_partials_portable():
    from repro.runtime import GridTransferRefused
    from repro.runtime.supervisor import check_partials_portable

    check_partials_portable({"grid": "3x3"}, "3x3")
    check_partials_portable({}, "2x2")  # pre-PR-10 checkpoints: no sig
    with pytest.raises(GridTransferRefused, match="decomposition-specific"):
        check_partials_portable({"grid": "3x3"}, "2x2")


# ----------------------------------------------------------------------
# supervised_count: single-device recovery across schedules
# ----------------------------------------------------------------------
def test_supervised_count_recovers_every_point_inline():
    from repro.core import rmat, triangle_count_oracle
    from repro.runtime import FaultPlan, Supervisor
    from repro.runtime.supervisor import supervised_count

    g = rmat(8, 8, seed=3)
    exp = triangle_count_oracle(g)
    for schedule in ("cannon", "summa", "oned"):
        for compact in (None, False):
            for spec in ("plan_stage", "device_stage", "step@0"):
                sup = Supervisor(max_restarts=3)
                res = supervised_count(
                    g,
                    supervisor=sup,
                    fault_plan=FaultPlan.parse(spec),
                    q=1,
                    schedule=schedule,
                    compact=compact,
                )
                key = (schedule, compact, spec)
                assert res.triangles == exp, key
                assert res.supervision["restarts"] == 1, key
                assert not res.supervision["gave_up"], key
                assert res.supervision["fault_log"], key


def test_supervised_count_demotes_persistent_fused_fault():
    from repro.core import rmat, triangle_count_oracle
    from repro.runtime import FaultPlan, Supervisor
    from repro.runtime.supervisor import supervised_count

    g = rmat(8, 8, seed=3)
    exp = triangle_count_oracle(g)
    sup = Supervisor(max_restarts=5)
    res = supervised_count(
        g,
        supervisor=sup,
        fault_plan=FaultPlan.parse("fused=stepfault*-1"),
        q=1,
        schedule="cannon",
        method="fused",
        demote_after=2,
    )
    assert res.triangles == exp
    demos = res.supervision["demotions"]
    assert demos and demos[0]["rung"] == "method"
    assert demos[0]["frm"] == "fused" and demos[0]["to"] == "search2"
    assert "persistent StepFault" in demos[0]["reason"]
    assert res.method != "fused"


def test_supervised_count_gives_up_within_budget():
    from repro.core import rmat
    from repro.runtime import FaultPlan, StageFault, Supervisor
    from repro.runtime.supervisor import supervised_count

    g = rmat(8, 8, seed=3)
    sup = Supervisor(max_restarts=2)
    with pytest.raises(StageFault):
        supervised_count(
            g,
            supervisor=sup,
            fault_plan=FaultPlan.parse("plan_stage=stagefault*-1"),
            ladder=False,  # planning has no ladder rung to demote
            q=1,
        )
    assert sup.report.gave_up
    assert len(sup.report.attempts) == 3


# ----------------------------------------------------------------------
# checkpoint corruption: quarantine + fall back
# ----------------------------------------------------------------------
def test_restore_latest_quarantines_bitflipped_step(tmp_path):
    from repro.ckpt import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    for s in (1, 2):
        mgr.save(s, {"w": jnp.full((3,), float(s))},
                 extra={"next_step": s})
    payload = os.path.join(str(tmp_path), "step_0000000002.npz")
    size = os.path.getsize(payload)
    with open(payload, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    step, tree, extra = mgr.restore_latest({"w": jnp.zeros((3,))})
    assert step == 1 and float(tree["w"][0]) == 1.0
    corrupt = [f for f in os.listdir(tmp_path) if f.endswith(".corrupt")]
    assert len(corrupt) == 2  # both the .json and .npz of step 2
    # quarantine=False restores the pre-PR-10 crash-on-corruption
    mgr2 = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr2.save(3, {"w": jnp.full((3,), 3.0)}, extra={"next_step": 3})
    with open(os.path.join(str(tmp_path), "step_0000000003.npz"),
              "r+b") as f:
        f.seek(40)
        f.write(b"\xff\xfe")
    with pytest.raises(IOError, match="corruption"):
        mgr2.restore_latest({"w": jnp.zeros((3,))}, quarantine=False)


def test_ckpt_save_fault_corrupts_payload_post_write(tmp_path):
    from repro.ckpt import CheckpointManager
    from repro.runtime.faultinject import FaultPlan, armed

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, {"w": jnp.full((2,), 1.0)}, extra={"next_step": 1})
    plan = FaultPlan.parse("ckpt_save=ckptcorrupt")
    with armed(plan):
        # a CkptCorrupt site does NOT raise at save time: it flips a
        # byte of the just-written payload so *restore* pays
        mgr.save(2, {"w": jnp.full((2,), 2.0)}, extra={"next_step": 2})
    assert plan.spent()
    step, tree, _ = mgr.restore_latest({"w": jnp.zeros((2,))})
    assert step == 1 and float(tree["w"][0]) == 1.0


def test_restore_arity_mismatch_is_not_swallowed(tmp_path):
    """KeyError (cross-mode carry-arity detection) must pass through the
    quarantine net untouched — tc_run turns it into a loud refusal."""
    from repro.ckpt import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        mgr.restore_latest({"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".corrupt")]


# ----------------------------------------------------------------------
# async writer error surfacing
# ----------------------------------------------------------------------
def test_async_writer_error_surfaces_on_next_save(tmp_path, monkeypatch):
    import repro.ckpt.manager as M

    mgr = M.CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(0, {"w": jnp.zeros((2,))})
    mgr.wait()

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(M, "save_checkpoint", boom)
    mgr.save(1, {"w": jnp.zeros((2,))})
    mgr._q.join()
    with pytest.raises(RuntimeError, match="writer failed"):
        mgr.save(2, {"w": jnp.zeros((2,))})
    # the error was consumed: the manager is usable again
    monkeypatch.undo()
    mgr.save(3, {"w": jnp.zeros((2,))})
    mgr.close()


def test_async_writer_error_surfaces_on_close(tmp_path, monkeypatch):
    import repro.ckpt.manager as M

    mgr = M.CheckpointManager(str(tmp_path), keep=2, async_save=True)
    monkeypatch.setattr(
        M, "save_checkpoint",
        lambda *a, **k: (_ for _ in ()).throw(OSError("enospc")),
    )
    mgr.save(0, {"w": jnp.zeros((2,))})
    mgr._q.join()
    with pytest.raises(RuntimeError, match="writer failed"):
        mgr.close()


# ----------------------------------------------------------------------
# elastic re-plan through the pipeline
# ----------------------------------------------------------------------
def test_replan_elastic_pipeline_parity():
    """The elastic re-plan is *exactly* a cold pipeline plan at the new
    grid: masks, compaction and rebalance all survive (the legacy path
    silently dropped every one of them)."""
    from repro.core import rmat
    from repro.pipeline import PlanCache, plan_cannon
    from repro.runtime import replan_elastic

    g = rmat(9, 8, seed=2)
    cache = PlanCache(maxsize=8)
    sched, art, (r, c) = replan_elastic(
        g, 4, rebalance_trials=2, cache=cache
    )
    assert sched == "cannon" and (r, c) == (2, 2)
    cold = plan_cannon(g, 2, rebalance_trials=2, cache=PlanCache(0))
    assert art.plan.step_keep is not None
    np.testing.assert_array_equal(
        np.asarray(art.plan.step_keep), np.asarray(cold.plan.step_keep)
    )
    if cold.plan.compact is not None:
        assert art.plan.compact is not None
        assert tuple(art.plan.compact.live_steps) == tuple(
            cold.plan.compact.live_steps
        )
    assert art.rebalance is not None
    assert art.rebalance["best_seed"] == cold.rebalance["best_seed"]
    # same cache, same knobs: the second elastic re-plan is a cache hit
    misses = cache.stats()["misses"]
    replan_elastic(g, 4, rebalance_trials=2, cache=cache)
    assert cache.stats()["misses"] == misses
    assert cache.stats()["hits"] >= 1
    # rectangular survivor count falls back to SUMMA, still an artifact
    sched, art8, (r, c) = replan_elastic(g, 8, cache=cache)
    assert sched == "summa" and r * c <= 8
    assert art8.plan.step_keep is not None
    # forcing cannon squares down instead
    sched, _, (r, c) = replan_elastic(g, 8, schedule="cannon", cache=cache)
    assert sched == "cannon" and r == c == 2


def test_replan_elastic_legacy_path_deprecated():
    from repro.core import rmat
    from repro.runtime import replan_elastic

    g = rmat(9, 8, seed=2)
    with pytest.deprecated_call():
        sched, plan, (r, c) = replan_elastic(g, 4, legacy=True)
    assert sched == "cannon" and (r, c) == (2, 2)
    # the legacy raw plan is the old bare-planner output: no schedule
    # compaction (and no cache/rebalance) — which is why it is deprecated
    assert getattr(plan, "compact", None) is None


# ----------------------------------------------------------------------
# multi-device recovery matrix (subprocesses)
# ----------------------------------------------------------------------
def test_fault_at_every_live_step_all_schedules(distributed_runner):
    """A StepFault at each live step in turn, for all three schedules at
    their 9-device shapes, compacted and not: every run recovers to the
    byte-exact count with exactly one restart."""
    out = distributed_runner(
        """
        import jax
        jax.config.update("jax_enable_x64", True)
        from repro.core import rmat, triangle_count_oracle
        from repro.pipeline import plan_cannon, plan_oned, plan_summa
        from repro.runtime import FaultPlan, Supervisor
        from repro.runtime.faultinject import live_step_indices
        from repro.runtime.supervisor import supervised_count

        g = rmat(9, 8, seed=2)
        exp = triangle_count_oracle(g)
        plans = dict(
            cannon=plan_cannon(g, 3).plan,
            summa=plan_summa(g, 3, 3).plan,
            oned=plan_oned(g, 9).plan,
        )
        checked = 0
        for schedule, plan in plans.items():
            for compact in (None, False):
                kw = dict(q=3, schedule=schedule, compact=compact)
                if schedule == "oned":
                    kw.update(q=3, npods=1)
                steps = live_step_indices(plan, compact is not False)
                for s in steps:
                    sup = Supervisor(max_restarts=3)
                    res = supervised_count(
                        g, supervisor=sup,
                        fault_plan=FaultPlan.parse(f"step@{s}"), **kw,
                    )
                    key = (schedule, compact, s)
                    assert res.triangles == exp, (key, res.triangles, exp)
                    assert res.supervision["restarts"] == 1, key
                    checked += 1
        print("CHECKED", checked)
        """,
        9,
    )
    n = int(out.strip().split()[-1])
    assert n >= 12  # >= 2 live steps per (schedule, compact) pair


def test_devicelost_regrids_9_to_4(distributed_runner):
    """Losing 5 of 9 devices mid-count re-factorizes to 2x2 through the
    pipeline planner and recovers the exact count."""
    out = distributed_runner(
        """
        import json
        import jax
        jax.config.update("jax_enable_x64", True)
        from repro.core import rmat, triangle_count_oracle
        from repro.runtime import FaultPlan, Supervisor
        from repro.runtime.supervisor import supervised_count

        g = rmat(9, 8, seed=2)
        exp = triangle_count_oracle(g)
        sup = Supervisor(max_restarts=3)
        res = supervised_count(
            g, supervisor=sup,
            fault_plan=FaultPlan.parse("step@0=devicelost:5"),
            q=3, schedule="cannon",
        )
        assert res.triangles == exp, (res.triangles, exp)
        print(json.dumps(res.supervision))
        """,
        9,
    )
    sup = json.loads(out.strip().splitlines()[-1])
    assert sup["restarts"] == 1 and not sup["gave_up"]
    assert sup["regrids"] == [
        {"lost": 5, "grid": [2, 2], "schedule": "cannon"}
    ]


def test_tc_run_inject_faults_e2e(distributed_runner, tmp_path):
    """The CLI acceptance path: a checkpointed 4-device run with a step
    fault AND a checkpoint-corruption fault still reports the verified
    count, with the recovery visible in the report."""
    out = distributed_runner(
        f"""
        import sys
        sys.argv = [
            "tc_run", "--graph", "rmat:9", "--grid", "2",
            "--ckpt-dir", {str(tmp_path)!r},
            "--inject-faults", "step@1;ckpt_save=ckptcorrupt",
            "--verify", "--json",
        ]
        from repro.launch.tc_run import main
        main()
        """,
        4,
    )
    rep = json.loads(
        [ln for ln in out.splitlines() if ln.startswith("{")][-1]
    )
    assert rep["correct"] and rep["checkpointed"]
    assert rep["supervision_restarts"] >= 1
    assert any(
        e["fault"] == "CkptCorrupt" for e in rep["supervision_fault_log"]
    )
    corrupt = [f for f in os.listdir(tmp_path) if f.endswith(".corrupt")]
    assert corrupt  # the flipped step was quarantined, not reused
