"""Fused Pallas mega-kernel suite (DESIGN.md §5.1 / §4.6).

Marked ``fused`` so CI can run it as its own lane (``pytest -m fused``);
it also runs in tier-1, where the Pallas body executes under the
interpreter (single CPU device — see conftest).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_plan,
    count_triangles,
    erdos_renyi,
    graph_from_spec,
    named_graph,
    preprocess,
    rmat,
    triangle_count_oracle,
)

pytestmark = pytest.mark.fused


def _fixture(name):
    return {
        "edgeless": lambda: erdos_renyi(24, 0.0, seed=0),
        "star": lambda: named_graph("star"),
        "cliques": lambda: graph_from_spec("cliques:2,10"),
        "rmat": lambda: rmat(8, 8, seed=5),
    }[name]()


# ----------------------------------------------------------------------
# count equivalence: fused ≡ incumbent ≡ oracle on every schedule
# ----------------------------------------------------------------------
@pytest.mark.parametrize("schedule", ["cannon", "summa", "oned"])
@pytest.mark.parametrize("fixture", ["edgeless", "star", "cliques", "rmat"])
def test_fused_matches_incumbent_q1(schedule, fixture):
    g = _fixture(fixture)
    exp = triangle_count_oracle(g)
    got = count_triangles(g, q=1, schedule=schedule, method="fused")
    assert got.triangles == exp, (schedule, fixture)
    # the incumbent must agree: two-level search2 on Cannon, plain
    # search on the ring (global ids, no row-encoded keys) and on SUMMA
    # (which never wired explicit search2 at the api level)
    incumbent = "search2" if schedule == "cannon" else "search"
    ref = count_triangles(g, q=1, schedule=schedule, method=incumbent)
    assert ref.triangles == exp, (schedule, fixture)


def test_fused_matches_dense_oracle_path():
    g = rmat(8, 8, seed=2)
    exp = triangle_count_oracle(g)
    assert count_triangles(g, q=1, method="fused").triangles == exp
    assert count_triangles(g, q=1, method="dense").triangles == exp


def test_fused_distributed_q3(distributed_runner):
    code = """
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import count_triangles, rmat, triangle_count_oracle
g = rmat(9, 8, seed=42)
exp = triangle_count_oracle(g)
for schedule in ("cannon", "summa", "oned"):
    r = count_triangles(g, q=3, schedule=schedule, method="fused")
    assert r.triangles == exp, (schedule, r.triangles, exp)
print("OK", exp)
"""
    out = distributed_runner(code, ndev=9)
    assert "OK" in out


# ----------------------------------------------------------------------
# interpreter-mode parity: Pallas body vs the independent lax reference
# ----------------------------------------------------------------------
def _random_csr(rng, nrows, maxd, n, pad=7):
    rows = [
        np.sort(rng.choice(n, size=rng.integers(0, maxd + 1), replace=False))
        for _ in range(nrows)
    ]
    indptr = np.zeros(nrows + 1, np.int32)
    indptr[1:] = np.cumsum([len(r) for r in rows])
    idx = np.concatenate(rows + [np.zeros(pad)]).astype(np.int32)
    return jnp.asarray(indptr), jnp.asarray(idx)


def test_short_panel_interpret_parity():
    from repro.kernels.tc_fused.ref import fused_short_ref
    from repro.kernels.tc_fused.tc_fused import fused_short_counts

    rng = np.random.default_rng(0)
    nrows, maxd, n = 40, 12, 500
    ap, ai = _random_csr(rng, nrows, maxd, n)
    bp, bi = _random_csr(rng, nrows, maxd, n)
    ti = jnp.asarray(rng.integers(0, nrows, 300).astype(np.int32))
    tj = jnp.asarray(rng.integers(0, nrows, 300).astype(np.int32))
    # dense oracle over the same blocks
    A = np.zeros((nrows, n)), np.asarray(ap), np.asarray(ai)
    dense = {}
    for tag, (ptr, idx) in (("a", (ap, ai)), ("b", (bp, bi))):
        m = np.zeros((nrows, n))
        ptr, idx = np.asarray(ptr), np.asarray(idx)
        for r in range(nrows):
            m[r, idx[ptr[r]:ptr[r + 1]]] = 1
        dense[tag] = m
    for tcount in (0, 1, 250):
        exp = int(
            sum(
                (dense["a"][i] * dense["b"][j]).sum()
                for i, j in zip(
                    np.asarray(ti)[:tcount], np.asarray(tj)[:tcount]
                )
            )
        )
        ref = int(
            fused_short_ref(ap, ai, bp, bi, ti, tj, tcount, d=maxd, tile=32)
        )
        pal = int(
            jnp.sum(
                fused_short_counts(
                    ap, ai, bp, bi, ti, tj, tcount,
                    tile=32, d=maxd, interpret=True,
                )
            )
        )
        assert exp == ref == pal, (tcount, exp, ref, pal)


def test_engine_fused_pallas_interpret_matches():
    g = rmat(8, 8, seed=2)
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, method="fused", fused_impl="pallas-interpret")
    assert r.triangles == exp


# ----------------------------------------------------------------------
# guard rails: the fused kernel refuses plans it would miscount on
# ----------------------------------------------------------------------
def test_check_fused_split_refuses_probe_split():
    from repro.core.engine import check_fused_split

    g2, _ = preprocess(rmat(7, 8, seed=3))
    plan = build_plan(g2, 1)  # no autotune report at all
    with pytest.raises(ValueError, match="maxfrag"):
        check_fused_split(plan)


def test_fused_factory_requires_split_fields():
    from repro.core.engine import make_csr_kernel

    with pytest.raises(ValueError, match="maxfrag"):
        make_csr_kernel(
            "fused", dpad=8, chunk=8, probe_shorter=True,
            count_dtype=jnp.int32, sentinel=9,
            n_long=None, d_small=None,
        )


def test_plan_split_fields_are_real_dataclass_fields():
    from repro.core.onedim import OneDPlan
    from repro.core.plan import TCPlan
    from repro.core.summa import SummaPlan

    for cls in (TCPlan, SummaPlan, OneDPlan):
        names = {f.name for f in dataclasses.fields(cls)}
        assert {"n_long", "d_small"} <= names, cls
    assert "bucket_stats" in {f.name for f in dataclasses.fields(TCPlan)}


def test_two_sided_split_report():
    from repro.pipeline import plan_cannon

    g = graph_from_spec("cliques:2,10")
    art = plan_cannon(g, 1, chunk=64, autotune="fused")
    plan = art.plan
    assert plan.autotune["split"] == "maxfrag"
    assert plan.n_long == plan.autotune["n_long"]
    assert plan.d_small == plan.autotune["d_small"]


# ----------------------------------------------------------------------
# measured autotune: table keying, cold/warm persistence, roofline
# ----------------------------------------------------------------------
def test_measured_table_key_buckets():
    from repro.kernels.tc_fused.autotune import measured_table_key

    base = dict(
        kind="cannon", backend="cpu", dtype="int32", nb=100,
        nnz_pad=1000, tmax=500, dmax=64, d_small=16, tail_heavy=False,
    )
    k = measured_table_key(**base)
    # same power-of-two bucket -> same key (reusable across graphs of
    # the same size class); crossing the bucket or changing a split
    # parameter or backend re-keys
    assert measured_table_key(**{**base, "nnz_pad": 900}) == k
    assert measured_table_key(**{**base, "nnz_pad": 1025}) != k
    assert measured_table_key(**{**base, "d_small": 24}) != k
    assert measured_table_key(**{**base, "backend": "tpu"}) != k
    assert measured_table_key(**{**base, "tail_heavy": True}) != k


def test_measured_table_cold_then_warm(tmp_path):
    g = graph_from_spec("cliques:2,12")
    exp = triangle_count_oracle(g)
    r1 = count_triangles(
        g, q=1, method="auto", autotune="measured",
        measured_dir=str(tmp_path),
    )
    assert r1.autotune_mode == "measured"
    assert r1.measured_table_hit is False
    assert r1.triangles == exp
    assert len(list(tmp_path.glob("*.json"))) == 1
    r2 = count_triangles(
        g, q=1, method="auto", autotune="measured",
        measured_dir=str(tmp_path),
    )
    assert r2.measured_table_hit is True
    assert r2.triangles == exp
    assert len(list(tmp_path.glob("*.json"))) == 1


def test_measured_entry_requires_split(tmp_path):
    from repro.kernels.tc_fused.autotune import measured_entry

    g2, _ = preprocess(rmat(7, 8, seed=3))
    plan = build_plan(g2, 1)
    with pytest.raises(ValueError, match="maxfrag"):
        measured_entry(plan, table_dir=str(tmp_path))


def test_roofline_prediction_matches_measurement(tmp_path):
    """On the dense-ish bench fixture the analytic roofline and the
    measured table must agree on the winner (and it is the fused
    kernel — the acceptance bar the benchmark records)."""
    from repro.kernels.tc_fused.autotune import (
        measured_entry,
        predict_fused_wins,
    )
    from repro.pipeline import plan_cannon

    g = graph_from_spec("cliques:3,60")
    art = plan_cannon(g, 1, chunk=512, autotune="fused")
    entry, hit = measured_entry(art.plan, table_dir=str(tmp_path), force=True)
    assert not hit
    assert entry["winner"] == "fused"
    assert entry["roofline"]["predicted_winner"] == "fused"
    assert predict_fused_wins(entry)
