"""Hub-split decomposition tests (DESIGN.md §4.8).

The invariant under test everywhere: counts with ``hub_split`` on are
byte-identical to counts with it off — across schedules, methods,
compaction, rebalance, grids, and the delta ladder.  The suite also
pins the satellite bugfixes that rode along: the spec-list splitter's
greedy comma parse, the fused VMEM gate's hub-driven diagnosis, and the
delta path's loud refusal to splice hub-split artifacts.
"""
import numpy as np
import pytest

from repro.core import count_triangles, graph_from_spec, triangle_count_oracle
from repro.core.generators import split_specs
from repro.core.graph import Graph
from repro.pipeline import plan_cannon, plan_oned, plan_summa
from repro.pipeline.delta import EdgeDelta, apply_delta
from repro.pipeline.hubsplit import (
    DEFAULT_HUB_C,
    detect_hub_cut,
    hubsplit_stage,
    normalize_hub_split,
)

SPECS = ["powerlaw:600,2.2", "powerlaw:600,1.8", "star:50", "cliques:6,8"]


# ----------------------------------------------------------------------
# knob + cut detection
# ----------------------------------------------------------------------
def test_normalize_hub_split():
    assert normalize_hub_split(False) is None
    assert normalize_hub_split(None) is None
    assert normalize_hub_split(True) == DEFAULT_HUB_C
    assert normalize_hub_split(3) == 3.0
    assert normalize_hub_split(0.0) == 0.0
    with pytest.raises(ValueError):
        normalize_hub_split(-1.0)


def test_detect_hub_cut_degenerates():
    from repro.core.preprocess import degree_order

    g = Graph.from_edges(10, [], [])
    assert detect_hub_cut(g, DEFAULT_HUB_C) == g.n  # edgeless: no hubs
    g = graph_from_spec("karate")
    # c=0: every vertex with degree > 0 is a hub (threshold 0)
    h0 = detect_hub_cut(g.relabel(degree_order(g)), 0.0)
    assert h0 == int((g.degrees() == 0).sum())


def test_hubsplit_stage_noop_below_threshold():
    from repro.core.preprocess import degree_order

    # karate's max degree (17) is under 8x its average degree: no-op
    g = graph_from_spec("karate")
    g2 = g.relabel(degree_order(g))
    res, hub = hubsplit_stage(g2, (2, 2))
    assert hub is None and res is g2


def test_hubsplit_residual_plus_hub_partition_edges():
    from repro.core.preprocess import degree_order

    g = graph_from_spec("powerlaw:600,2.2")
    g2 = g.relabel(degree_order(g))
    res, hub = hubsplit_stage(g2, (3, 3))
    assert hub is not None
    assert res.edges.shape[0] + hub.hub_nnz == g2.m
    assert (res.edges[:, 1] < hub.h0).all()
    assert hub.hub_rows == g2.n - hub.h0
    rep = hub.report()
    assert rep["hub_rows"] == hub.hub_rows
    assert 0.0 < rep["hub_nnz_frac"] <= 1.0


# ----------------------------------------------------------------------
# count parity: hub on == hub off (single device; grids in the
# distributed test below)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("schedule", ["cannon", "summa", "oned"])
def test_hub_split_count_parity(spec, schedule):
    g = graph_from_spec(spec)
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, schedule=schedule, hub_split=True)
    assert r.triangles == exp
    # threshold sweep, incl. c=0 (everything with degree > 0 is a hub)
    for c in (0.0, 2.0):
        assert count_triangles(
            g, q=1, schedule=schedule, hub_split=c
        ).triangles == exp


@pytest.mark.parametrize("method", ["search", "search2", "global", "fused"])
def test_hub_split_methods_parity(method):
    g = graph_from_spec("powerlaw:600,2.2")
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, method=method, hub_split=True)
    assert r.triangles == exp
    assert r.hub is not None and r.hub["hub_rows"] > 0


@pytest.mark.parametrize("compact", [None, False])
def test_hub_split_compact_parity(compact):
    g = graph_from_spec("powerlaw:600,1.8")
    exp = triangle_count_oracle(g)
    assert count_triangles(
        g, q=1, hub_split=True, compact=compact
    ).triangles == exp


def test_hub_split_edgeless_and_empty_residual():
    g = Graph.from_edges(16, [], [])
    assert count_triangles(g, q=1, hub_split=True).triangles == 0
    # c=0 on a star: the residual keeps no triangle apexes below the cut
    g = graph_from_spec("star:50")
    assert count_triangles(g, q=1, hub_split=0.0).triangles == 0


def test_hub_split_with_rebalance_stays_exact():
    g = graph_from_spec("powerlaw:600,2.2")
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, hub_split=True, rebalance_trials=3)
    assert r.triangles == exp
    assert r.hub is not None and r.hub.get("residual_mcp") is not None


def test_hub_report_in_result():
    g = graph_from_spec("powerlaw:600,2.2")
    r = count_triangles(g, q=1, hub_split=True)
    assert r.hub["hub_rows"] > 0 and 0 < r.hub["hub_nnz_frac"] < 1
    assert r.artifact.hubsplit["h0"] == r.hub["h0"]
    # flag off -> no report
    assert count_triangles(g, q=1).hub is None


# ----------------------------------------------------------------------
# validation: loud rejections
# ----------------------------------------------------------------------
def test_hub_split_requires_reorder():
    g = graph_from_spec("powerlaw:600,2.2")
    with pytest.raises(ValueError, match="reorder"):
        plan_cannon(g, 1, hub_split=True, reorder=False)


def test_hub_split_rejects_cyclic_p():
    g = graph_from_spec("powerlaw:600,2.2")
    with pytest.raises(ValueError, match="cyclic_p"):
        plan_summa(g, 1, 1, hub_split=True, cyclic_p=2)


def test_hub_split_rejects_caller_plan():
    g = graph_from_spec("powerlaw:600,2.2")
    plan = plan_cannon(g, 1).plan
    with pytest.raises(ValueError, match="hub_split"):
        count_triangles(g, q=1, plan=plan, hub_split=True)


@pytest.mark.parametrize("method", ["dense", "tile"])
def test_hub_split_rejects_blockwise_stores(method):
    g = graph_from_spec("powerlaw:600,2.2")
    with pytest.raises(ValueError, match="hub-split"):
        count_triangles(g, q=1, method=method, hub_split=True)


def test_hub_split_rejects_batched_engine():
    from repro.core.engine import HubCount

    art = plan_cannon(graph_from_spec("powerlaw:600,2.2"), 1, hub_split=True)
    assert art.plan.hub is not None
    from repro.core.cannon import build_cannon_fn
    from repro.core.api import make_grid_mesh

    with pytest.raises(AssertionError, match="batched"):
        build_cannon_fn(art.plan, make_grid_mesh(1), batched=True)
    assert HubCount.from_plan(art.plan) is not None


# ----------------------------------------------------------------------
# residual padding shrinks (the fused gate's "hub-driven dmax" claim)
# ----------------------------------------------------------------------
def test_residual_dmax_shrinks_under_hub_split():
    g = graph_from_spec("powerlaw:600,2.2")
    full = plan_cannon(g, 1, autotune=True).plan
    split = plan_cannon(g, 1, hub_split=True, autotune=True).plan
    assert split.hub is not None
    assert split.dmax < full.dmax  # hub rows no longer inflate padding
    if full.d_small is not None and split.d_small is not None:
        assert split.d_small <= full.d_small
    # dmax is the true block-local maximum fragment length, not a stale
    # whole-graph bound: per-block padding claims hold in both modes
    for plan in (full, split):
        frag = max(
            int(np.diff(plan.a_indptr, axis=-1).max()),
            int(np.diff(plan.b_indptr, axis=-1).max()),
        )
        assert plan.dmax == frag


def test_fused_gate_flags_hub_driven_overflow():
    from repro.kernels.tc_fused import VMEM_BUDGET_BYTES, fused_gate

    big = VMEM_BUDGET_BYTES  # npads alone blow the budget
    over = fused_gate(big, big, 8, 4, dmax=512, d_small=4)
    assert not over["fits"] and over["hub_driven"]
    assert over["need_bytes"] > over["budget_bytes"]
    uniform = fused_gate(big, big, 8, 4, dmax=8, d_small=4)
    assert not uniform["fits"] and not uniform["hub_driven"]
    small = fused_gate(64, 64, 8, 4, dmax=512, d_small=4)
    assert small["fits"] and small["hub_driven"]


def test_fused_pallas_overflow_error_names_hub_split():
    import jax.numpy as jnp

    from repro.kernels.tc_fused import VMEM_BUDGET_BYTES, count_pair_fused

    npad = VMEM_BUDGET_BYTES // 4  # index arrays alone exceed the budget
    indptr = jnp.zeros(3, jnp.int32)
    indices = jnp.zeros(npad, jnp.int32)
    t = jnp.zeros(8, jnp.int32)
    with pytest.raises(ValueError, match="hub_split=True"):
        count_pair_fused(
            indptr, indices, indptr, indices, t, t, jnp.int32(0),
            n_long=0, d_small=4, dpad_long=512, chunk=64, impl="pallas",
        )


def test_fused_auto_demotion_warns(monkeypatch):
    import jax.numpy as jnp

    from repro.kernels.tc_fused import ops

    # force the auto resolution to "pallas" so the gate runs on CPU
    monkeypatch.setattr(ops, "resolve_fused_impl", lambda impl: "pallas")
    npad = ops.VMEM_BUDGET_BYTES // 4
    indptr = jnp.zeros(3, jnp.int32)
    indices = jnp.zeros(npad, jnp.int32)
    t = jnp.zeros(8, jnp.int32)
    with pytest.warns(RuntimeWarning, match="demoted to the lax reference"):
        out = ops.count_pair_fused(
            indptr, indices, indptr, indices, t, t, jnp.int32(0),
            n_long=0, d_small=4, dpad_long=512, chunk=64, impl="auto",
        )
    assert int(out) == 0


# ----------------------------------------------------------------------
# delta ladder regressions: hub-row deltas must never splice
# ----------------------------------------------------------------------
def _hub_delta(g):
    """A delta that adds an edge onto the heaviest (hub) row and removes
    one existing edge."""
    deg = np.bincount(g.edges.reshape(-1), minlength=g.n)
    hub_v = int(np.argmax(deg))
    have = set(map(tuple, g.edges.tolist()))
    add = next(
        [min(u, hub_v), max(u, hub_v)]
        for u in range(g.n)
        if u != hub_v and (min(u, hub_v), max(u, hub_v)) not in have
    )
    return EdgeDelta(add=[add], remove=[g.edges[0].tolist()])


def _mutated(g, delta):
    keep = np.array(
        [e for e in g.edges.tolist()
         if tuple(e) not in set(map(tuple, delta.remove.tolist()))]
    ).reshape(-1, 2)
    e2 = np.concatenate([keep, delta.add.reshape(-1, 2)])
    return Graph.from_edges(g.n, e2[:, 0], e2[:, 1])


def test_delta_refuses_splice_on_hub_plan():
    g = graph_from_spec("powerlaw:600,2.2")
    art = plan_cannon(g, 1, hub_split=True)
    assert art.plan.hub is not None
    d = _hub_delta(g)
    art2 = apply_delta(art, d)
    rep = art2.delta_report
    assert rep["level"] == "repack"  # never "splice"
    assert rep["reason"] == "hub_split"
    assert "hubsplit" in rep["replanned_stages"]
    assert art2.plan.hub is not None
    assert art2.plan.hub.h0 == art.plan.hub.h0  # parent cut reused
    exp = triangle_count_oracle(_mutated(g, d))
    assert count_triangles(art2.graph, q=1, plan=art2).triangles == exp


def test_delta_rebases_misaligned_hub_plan():
    # planning is host-side: a 3x3 plan needs no devices, and on this
    # fixture the rebalancer picks a non-identity seed, so the hub side
    # is misaligned with the artifact id space (the exactness of the
    # rebased count itself runs in the distributed parity test below)
    g = graph_from_spec("powerlaw:600,2.2")
    art = plan_cannon(g, 3, hub_split=True, rebalance_trials=3)
    assert not art.plan.hub.aligned, "fixture drift: rebalance kept seed 0"
    d = _hub_delta(g)
    art2 = apply_delta(art, d)
    rep = art2.delta_report
    assert rep["level"] == "rebase"
    assert rep["reason"] == "hub_split_misaligned"
    # the rebased plan carries a fresh hub side (possibly again
    # misaligned if its own rebalance won a non-identity seed — exact
    # for counting either way; the ladder will rebase the next delta)
    assert art2.plan.hub is not None


def test_delta_hub_free_plan_still_splices():
    # guard against over-refusal: a hub-free cannon artifact keeps its
    # splice fast path even when the cfg carries hub_split (no-op split)
    g = graph_from_spec("karate")
    art = plan_cannon(g, 1, hub_split=True)
    assert art.plan.hub is None  # no row crossed the threshold
    d = EdgeDelta(add=[[0, 21]], remove=[[0, 1]])
    art2 = apply_delta(art, d)
    assert art2.delta_report["level"] in ("splice", "repack")
    assert "reason" not in art2.delta_report
    exp = triangle_count_oracle(_mutated(g, d))
    assert count_triangles(art2.graph, q=1, plan=art2).triangles == exp


def test_delta_stream_on_hub_plan_stays_exact():
    g = graph_from_spec("powerlaw:600,1.8")
    art = plan_cannon(g, 1, hub_split=True)
    rng = np.random.default_rng(7)
    g_cur = g
    for i in range(4):
        have = set(map(tuple, g_cur.edges.tolist()))
        while True:
            u, v = sorted(rng.integers(0, g.n, size=2).tolist())
            if u != v and (u, v) not in have:
                break
        d = EdgeDelta(
            add=[[u, v]],
            remove=[g_cur.edges[int(rng.integers(g_cur.m))].tolist()],
        )
        art = apply_delta(art, d)
        g_cur = _mutated(g_cur, d)
        exp = triangle_count_oracle(g_cur)
        got = count_triangles(art.graph, q=1, plan=art).triangles
        assert got == exp, (i, got, exp)


# ----------------------------------------------------------------------
# spec-list splitter (front-end bugfix)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("specs,want", [
    ("karate", ["karate"]),
    ("rmat:10,8,1", ["rmat:10,8,1"]),
    ("rmat:10,8,1;karate", ["rmat:10,8,1", "karate"]),
    ("karate,powerlaw:600,2.2", ["karate", "powerlaw:600,2.2"]),
    ("delta:5,0,powerlaw:600,2.2", ["delta:5,0,powerlaw:600,2.2"]),
    ("karate,delta:5,0,powerlaw:600,2.2",
     ["karate", "delta:5,0,powerlaw:600,2.2"]),
    ("powerlaw:600,2.2,star:50,cliques:6,8",
     ["powerlaw:600,2.2", "star:50", "cliques:6,8"]),
    ("er:100,5,karate", ["er:100,5", "karate"]),
])
def test_split_specs_greedy_longest_match(specs, want):
    got = split_specs(specs)
    assert got == want
    # round-trip: every split element is itself a one-element list
    for s in got:
        assert split_specs(s) == [s]


def test_split_specs_bad_fragment_surfaces_loudly():
    from repro.core.generators import graphs_from_specs

    assert split_specs("karate,bogus:1") == ["karate", "bogus:1"]
    with pytest.raises(ValueError, match="bogus"):
        graphs_from_specs("karate,bogus:1")


# ----------------------------------------------------------------------
# multi-device parity (subprocess grids)
# ----------------------------------------------------------------------
def test_distributed_hub_split_parity(distributed_runner):
    code = """
from repro.core import count_triangles, graph_from_spec, \\
    triangle_count_oracle
for spec in ("powerlaw:600,2.2", "star:50"):
    g = graph_from_spec(spec)
    exp = triangle_count_oracle(g)
    for sched in ("cannon", "summa", "oned"):
        for hs in (True, 0.0):
            r = count_triangles(g, q=2, schedule=sched, hub_split=hs,
                                rebalance_trials=2)
            assert r.triangles == exp, (spec, sched, hs, r.triangles, exp)
print("OK")
"""
    assert "OK" in distributed_runner(code, ndev=4)


def test_distributed_delta_on_misaligned_hub_plan(distributed_runner):
    # the q=3 fixture rebalances to a non-identity seed: the hub-row
    # delta must route through the loud rebase and stay exact
    code = """
import numpy as np
from repro.core import count_triangles, graph_from_spec, \\
    triangle_count_oracle
from repro.core.graph import Graph
from repro.pipeline.delta import EdgeDelta, apply_delta
from repro.pipeline import plan_cannon

g = graph_from_spec("powerlaw:600,2.2")
art = plan_cannon(g, 3, hub_split=True, rebalance_trials=3)
assert not art.plan.hub.aligned
deg = np.bincount(g.edges.reshape(-1), minlength=g.n)
hub_v = int(np.argmax(deg))
have = set(map(tuple, g.edges.tolist()))
add = next([min(u, hub_v), max(u, hub_v)] for u in range(g.n)
           if u != hub_v and (min(u, hub_v), max(u, hub_v)) not in have)
d = EdgeDelta(add=[add], remove=[g.edges[0].tolist()])
art2 = apply_delta(art, d)
assert art2.delta_report["reason"] == "hub_split_misaligned"
keep = np.array([e for e in g.edges.tolist()
                 if tuple(e) != tuple(g.edges[0].tolist())]).reshape(-1, 2)
e2 = np.concatenate([keep, np.array([add])])
g2 = Graph.from_edges(g.n, e2[:, 0], e2[:, 1])
exp = triangle_count_oracle(g2)
got = count_triangles(art2.graph, q=3, plan=art2).triangles
assert got == exp, (got, exp)
print("OK")
"""
    assert "OK" in distributed_runner(code, ndev=9)
