"""Per-kernel tests: interpret-mode Pallas vs the pure-jnp oracle,
swept over tile counts / densities / modes, plus pack/unpack properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tc_tile.ops import tile_pair_count
from repro.kernels.tc_tile.ref import tile_triple_counts_ref
from repro.kernels.tc_tile.tc_tile import (
    TILE,
    WORDS,
    tile_triple_counts,
    unpack_bits_tile,
)


def _random_tiles(key, n, density=0.5):
    """Random bit tiles with approximately the given bit density."""
    u = jax.random.uniform(key, (n, TILE, WORDS, 32))
    bits = (u < density).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


@pytest.mark.parametrize("mode", ["popcount", "mxu"])
@pytest.mark.parametrize("ntiles,ntrips", [(1, 1), (3, 4), (8, 16)])
@pytest.mark.parametrize("density", [0.02, 0.3, 0.9])
def test_kernel_matches_ref(mode, ntiles, ntrips, density):
    ka, kb, km, kt = jax.random.split(jax.random.key(ntiles * 31 + ntrips), 4)
    A = _random_tiles(ka, ntiles, density)
    B = _random_tiles(kb, ntiles, density)
    M = _random_tiles(km, ntiles, min(0.5, density * 2))
    slots = jax.random.randint(kt, (ntrips, 3), 0, ntiles)
    valid = (jnp.arange(ntrips) % 3 != 2).astype(jnp.int32)
    trips = jnp.concatenate([slots, valid[:, None]], axis=1).astype(jnp.int32)
    out_k = tile_triple_counts(trips, A, B, M, mode=mode, interpret=True)
    out_r = tile_triple_counts_ref(trips, A, B, M)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_modes_agree():
    ka, kb, km = jax.random.split(jax.random.key(7), 3)
    A = _random_tiles(ka, 4, 0.4)
    B = _random_tiles(kb, 4, 0.4)
    M = _random_tiles(km, 4, 0.2)
    trips = jnp.array(
        [[0, 1, 2, 1], [3, 3, 3, 1], [1, 0, 2, 1]], dtype=jnp.int32
    )
    a = tile_triple_counts(trips, A, B, M, mode="popcount", interpret=True)
    b = tile_triple_counts(trips, A, B, M, mode="mxu", interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_invalid_triples_are_zero():
    A = _random_tiles(jax.random.key(0), 2, 0.9)
    trips = jnp.array([[0, 0, 0, 0], [1, 1, 1, 0]], dtype=jnp.int32)
    out = tile_triple_counts(trips, A, A, A, mode="popcount", interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(2, np.int32))


def test_pair_count_sums():
    ka, kb, km = jax.random.split(jax.random.key(9), 3)
    A = _random_tiles(ka, 3, 0.5)
    B = _random_tiles(kb, 3, 0.5)
    M = _random_tiles(km, 3, 0.5)
    trips = jnp.array([[0, 1, 2, 1], [2, 0, 1, 1]], dtype=jnp.int32)
    per = tile_triple_counts_ref(trips, A, B, M)
    tot = tile_pair_count(trips, A, B, M, mode="popcount", interpret=True)
    assert int(tot) == int(np.sum(np.asarray(per)))


def test_unpack_bits_tile_exact():
    words = np.zeros((TILE, WORDS), dtype=np.uint32)
    words[5, 0] = 1  # bit 0 -> column 0
    words[7, 1] = 0x80000000  # bit 31 of word 1 -> column 63
    out = np.asarray(unpack_bits_tile(jnp.asarray(words), jnp.int32))
    assert out[5, 0] == 1 and out[7, 63] == 1
    assert out.sum() == 2


def test_pack_unpack_roundtrip_via_planner():
    """pack_block_tiles followed by unpack reproduces the dense block."""
    from repro.core import rmat, preprocess
    from repro.core.decomp import cyclic_blocks
    from repro.core.tiles import pack_block_tiles

    g, _ = preprocess(rmat(8, 8, seed=13))
    blk = cyclic_blocks(g, 2, 2)[1][0]
    packed, ids = pack_block_tiles(blk)
    dense = np.zeros((blk.n_rows, blk.n_cols), dtype=np.int32)
    rows = np.repeat(np.arange(blk.n_rows), np.diff(blk.indptr))
    dense[rows, blk.indices] = 1
    rebuilt = np.zeros_like(dense)
    for t, (tr, tc) in enumerate(ids):
        tile = np.asarray(unpack_bits_tile(jnp.asarray(packed[t]), jnp.int32))
        r0, c0 = tr * TILE, tc * TILE
        rr = min(TILE, blk.n_rows - r0)
        cc = min(TILE, blk.n_cols - c0)
        rebuilt[r0 : r0 + rr, c0 : c0 + cc] = tile[:rr, :cc]
    np.testing.assert_array_equal(dense, rebuilt)
