"""Tests for the §Perf hillclimb code paths (H1a/H1b/H2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_plan, preprocess, rmat, triangle_count_oracle
from repro.core.api import make_grid_mesh
from repro.core.cannon import build_cannon_fn
from repro.core.count import (
    build_aug_keys,
    count_pair_search,
    count_pair_search_global,
)
from repro.core.plan import bucketize_plan


def _plan(seed=3, q=1):
    g = rmat(9, 8, seed=seed)
    exp = triangle_count_oracle(g)
    g2, _ = preprocess(g)
    return g, exp, build_plan(g2, q)


def test_global_search_matches_flat():
    _, _, plan = _plan()
    a = plan.device_arrays()
    args = [
        jnp.asarray(a[k][0, 0])
        for k in ("a_indptr", "a_indices", "b_indptr", "b_indices",
                  "m_ti", "m_tj")
    ] + [jnp.asarray(a["m_cnt"][0, 0])]
    flat = count_pair_search(*args, dpad=plan.dmax, chunk=128)
    glob = count_pair_search_global(*args, dpad=plan.dmax, chunk=128)
    assert int(flat) == int(glob)


def test_aug_keys_sorted_and_unique_rows():
    _, _, plan = _plan()
    aug = np.asarray(
        build_aug_keys(
            jnp.asarray(plan.b_indptr[0, 0]), jnp.asarray(plan.b_indices[0, 0])
        )
    )
    assert np.all(np.diff(aug) >= 0)  # sorted => binary search is valid


@pytest.mark.parametrize("d_small", [4, 16, 64])
def test_bucketed_matches_oracle(d_small):
    g, exp, plan = _plan(seed=7, q=1)
    bplan = bucketize_plan(plan, d_small=d_small)
    mesh = make_grid_mesh(1)
    fn = build_cannon_fn(bplan, mesh, method="search2")
    got = int(fn(**{k: jnp.asarray(v) for k, v in bplan.device_arrays().items()}))
    assert got == exp


def test_compressed_blob_matches_oracle(distributed_runner):
    code = """
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import build_plan, preprocess, rmat, triangle_count_oracle
from repro.core.api import make_grid_mesh
from repro.core.cannon import build_cannon_fn
from repro.core.plan import bucketize_plan
g = rmat(10, 8, seed=11)
exp = triangle_count_oracle(g)
g2, _ = preprocess(g)
plan = bucketize_plan(build_plan(g2, 2), d_small=32)
mesh = make_grid_mesh(2)
for kw in (dict(method="search", compress_lengths=True),
           dict(method="search2", compress_lengths=True)):
    fn = build_cannon_fn(plan, mesh, count_dtype=jnp.int64, **kw)
    got = int(fn(**{k: jnp.asarray(v) for k, v in plan.device_arrays().items()}))
    assert got == exp, (kw, got, exp)
print("OK")
"""
    assert "OK" in distributed_runner(code, ndev=4)


def test_attention_seq_parallel_specs_numerically_equal():
    """H2 constraints must not change results (1x1 mesh degenerate case)."""
    from repro.configs import get_config
    from repro.models.transformer import lm_init, lm_loss
    from repro.models.steps import _inject_attn_specs

    cfg = get_config("qwen2-0.5b-smoke")
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    cfg2 = _inject_attn_specs(cfg, mesh)
    params = lm_init(jax.random.key(0), cfg)
    toks = jnp.ones((2, 32), jnp.int32)
    l1, _ = lm_loss(params, cfg, toks, toks)
    l2, _ = lm_loss(params, cfg2, toks, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_causal_attention_vmap_matches_reference():
    """Flash-style schedule vs plain softmax attention."""
    from repro.models.attention import causal_attention

    rng = np.random.default_rng(0)
    b, s, h, kv, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    out = causal_attention(q, k, v, q_chunk=16, kv_chunk=32)
    # reference: dense masked softmax
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    sc = jnp.einsum("bqkgd,bckd->bqkgc", qg, k) * (dh ** -0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, :, None, None, :], sc, -jnp.inf)
    w = jax.nn.softmax(sc, axis=-1)
    ref = jnp.einsum("bqkgc,bckd->bqkgd", w, v).reshape(b, s, h, dh)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3
    )


def test_causal_attention_nq_multiple():
    from repro.models.attention import causal_attention

    rng = np.random.default_rng(1)
    b, s, h, dh = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    a = causal_attention(q, k, v, q_chunk=64, kv_chunk=64, nq_multiple=1)
    b_ = causal_attention(q, k, v, q_chunk=64, kv_chunk=64, nq_multiple=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-5)


# NOTE: the hypothesis-based bucketed-probe property test lives in
# test_property.py so this module stays collectible without hypothesis.
