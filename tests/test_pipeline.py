"""Pipeline tests: vectorized packer byte-equivalence, plan-cache
hit/miss semantics, cyclic-relabel round trip, and the batched
front-end (``count_triangles_many``) against per-graph counts."""
import numpy as np
import pytest

from repro.core import (
    Graph,
    build_plan,
    count_triangles,
    count_triangles_many,
    named_graph,
    preprocess,
    rmat,
    triangle_count_oracle,
)
from repro.core.plan import _build_plan_loops
from repro.core.preprocess import cyclic_relabel
from repro.pipeline import (
    PlanCache,
    count_triangles_many as pipeline_many,
    graph_digest,
    plan_cannon,
    plan_oned,
    plan_summa,
)

GRAPHS = ["bull", "karate", "rmat"]


def _graph(name):
    if name == "rmat":
        return rmat(9, 8, seed=42)
    return named_graph(name)


# ======================================================================
# vectorized packer == loop reference, byte for byte
# ======================================================================
@pytest.mark.parametrize("graph_name", GRAPHS)
@pytest.mark.parametrize("q", [1, 2, 3])
@pytest.mark.parametrize("skew", [True, False])
def test_vectorized_packer_byte_identical(graph_name, q, skew):
    g, _ = preprocess(_graph(graph_name))
    fast = build_plan(g, q, skew=skew)
    ref = _build_plan_loops(g, q, skew=skew)
    assert (fast.nb, fast.nnz_pad, fast.tmax, fast.dmax, fast.chunk) == (
        ref.nb, ref.nnz_pad, ref.tmax, ref.dmax, ref.chunk
    )
    for name, arr in fast.device_arrays().items():
        refarr = ref.device_arrays()[name]
        assert arr.dtype == refarr.dtype, name
        assert arr.shape == refarr.shape, name
        assert arr.tobytes() == refarr.tobytes(), (graph_name, q, skew, name)


def test_vectorized_packer_stats_and_blocks_match():
    g, _ = preprocess(_graph("rmat"))
    fast = build_plan(g, 3)
    ref = _build_plan_loops(g, 3)
    assert np.array_equal(
        fast.stats.tasks_per_device, ref.stats.tasks_per_device
    )
    assert np.array_equal(
        fast.stats.probe_work_per_device_shift,
        ref.stats.probe_work_per_device_shift,
    )
    assert (
        fast.stats.intersection_tasks_total
        == ref.stats.intersection_tasks_total
    )
    for x in range(3):
        for y in range(3):
            fb, rb = fast.blocks[x][y], ref.blocks[x][y]
            assert np.array_equal(fb.indptr, rb.indptr)
            assert np.array_equal(fb.indices, rb.indices)
            assert np.array_equal(fb.active_rows, rb.active_rows)


# ======================================================================
# content-addressed plan cache
# ======================================================================
def test_graph_digest_is_content_addressed():
    g = rmat(8, 8, seed=0)
    # same edge set, shuffled construction order -> same digest
    rng = np.random.default_rng(0)
    order = rng.permutation(g.m)
    g_shuffled = Graph.from_edges(
        g.n, g.edges[order, 1], g.edges[order, 0], name="other"
    )
    assert graph_digest(g) == graph_digest(g_shuffled)
    # one edge edit -> different digest
    g_edit = Graph.from_edges(
        g.n,
        np.concatenate([g.edges[:, 0], [0]]),
        np.concatenate([g.edges[:, 1], [g.n - 1]]),
    )
    assert graph_digest(g) != graph_digest(g_edit)


def test_plan_cache_hit_and_miss_semantics():
    cache = PlanCache()
    g = rmat(8, 8, seed=1)
    a1 = plan_cannon(g, 2, cache=cache)
    assert not a1.cache_hit and cache.stats()["hits"] == 0
    a2 = plan_cannon(g, 2, cache=cache)
    assert a2 is a1 and a2.cache_hit and cache.stats()["hits"] == 1

    # different planning params -> miss (relabel is still shared)
    a3 = plan_cannon(g, 3, cache=cache)
    assert a3 is not a1
    assert a3.graph is a1.graph  # relabel stage hit the cache

    # edge edit -> digest change -> miss
    g_edit = Graph.from_edges(
        g.n,
        np.concatenate([g.edges[:, 0], [0]]),
        np.concatenate([g.edges[:, 1], [g.n - 1]]),
    )
    a4 = plan_cannon(g_edit, 2, cache=cache)
    assert a4 is not a1 and a4.digest != a1.digest

    # other plan kinds cache independently but share the relabel
    s1 = plan_summa(g, 2, 2, cache=cache)
    o1 = plan_oned(g, 4, cache=cache)
    assert s1.graph is a1.graph and o1.graph is a1.graph


def test_plan_cache_disabled_and_lru():
    g = rmat(7, 8, seed=2)
    off = PlanCache(maxsize=0)
    a1 = plan_cannon(g, 2, cache=off)
    a2 = plan_cannon(g, 2, cache=off)
    assert a2 is not a1 and len(off) == 0

    tiny = PlanCache(maxsize=2)
    plan_cannon(g, 2, cache=tiny)  # relabel + plan entries
    plan_cannon(g, 3, cache=tiny)
    assert tiny.stats()["evictions"] > 0


def test_cache_hit_skips_planning_and_staging():
    cache = PlanCache()
    g = rmat(9, 8, seed=3)
    r1 = count_triangles(g, q=1, cache=cache)
    r2 = count_triangles(g, q=1, cache=cache)
    assert r2.triangles == r1.triangles
    assert r2.plan is r1.plan  # same artifact -> same plan object
    # warm re-plan is drastically cheaper than the cold one
    assert r2.preprocess_seconds < r1.preprocess_seconds


# ======================================================================
# cyclic relabel stage (paper §5.3 step 1)
# ======================================================================
@pytest.mark.parametrize("n,p", [(12, 4), (256, 3), (10, 3)])
def test_cyclic_relabel_round_trip(n, p):
    perm = cyclic_relabel(n, p)
    assert np.array_equal(np.sort(perm), np.arange(n))  # true permutation
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    assert np.array_equal(inv[perm], np.arange(n))
    if n % p == 0:  # exact paper positions when p | n
        v = np.arange(n)
        assert np.array_equal(perm, (v % p) * (n // p) + v // p)


def test_cyclic_relabel_graph_round_trip_and_count():
    g = rmat(8, 8, seed=4)
    perm = cyclic_relabel(g.n, 3)
    inv = np.empty(g.n, dtype=np.int64)
    inv[perm] = np.arange(g.n)
    back = g.relabel(perm).relabel(inv)
    assert np.array_equal(back.edges, g.edges)
    # wired into the pipeline as the optional first stage
    exp = triangle_count_oracle(g)
    assert count_triangles(g, q=1, cyclic_p=3).triangles == exp
    art = plan_cannon(g, 2, cyclic_p=4, cache=PlanCache())
    assert art.perm is not None
    assert np.array_equal(np.sort(art.perm), np.arange(g.n))


# ======================================================================
# batched front-end
# ======================================================================
def _mixed_batch():
    return [
        named_graph("bull"),
        named_graph("karate"),
        rmat(8, 8, seed=2),
        rmat(7, 8, seed=3),
    ]


@pytest.mark.parametrize("schedule", ["cannon", "summa", "oned"])
def test_count_triangles_many_matches_individual(schedule):
    graphs = _mixed_batch()
    expected = [
        count_triangles(g, q=1, schedule=schedule).triangles for g in graphs
    ]
    assert expected == [triangle_count_oracle(g) for g in graphs]
    res = count_triangles_many(graphs, q=1, schedule=schedule)
    assert res.triangles == expected
    assert res.batch == len(graphs)
    assert res.padding_overhead >= 0.0


def test_count_triangles_many_program_cache_and_search2():
    cache = PlanCache()
    graphs = _mixed_batch()
    expected = [triangle_count_oracle(g) for g in graphs]
    r1 = pipeline_many(graphs, q=1, method="search2", cache=cache)
    assert r1.triangles == expected and not r1.cache_hit
    r2 = pipeline_many(graphs, q=1, method="search2", cache=cache)
    assert r2.triangles == expected and r2.cache_hit

    with pytest.raises(ValueError, match="CSR methods"):
        pipeline_many(graphs, q=1, method="dense")
    with pytest.raises(ValueError, match="cannon-schedule"):
        pipeline_many(graphs, q=1, schedule="summa", method="search2")


def test_split_specs_heuristics():
    """Launch-layer spec lists: ';' separates; a lone comma-parameter
    spec stays whole; comma-separated simple specs still split."""
    from repro.core.generators import graphs_from_specs, split_specs

    assert split_specs("rmat:10,8,1") == ["rmat:10,8,1"]
    assert split_specs("rmat:10,karate") == ["rmat:10", "karate"]
    assert split_specs("rmat:10,8,1;karate") == ["rmat:10,8,1", "karate"]
    assert split_specs("karate") == ["karate"]
    assert [g.n for g in graphs_from_specs("rmat:8,8,1;bull")] == [256, 5]


DIST_BATCH_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import (count_triangles_many, named_graph, rmat,
                        triangle_count_oracle)

graphs = [named_graph("bull"), named_graph("karate"),
          rmat(8, 8, seed=2), rmat(7, 8, seed=3)]
expected = [triangle_count_oracle(g) for g in graphs]
for schedule in ("cannon", "summa", "oned"):
    res = count_triangles_many(graphs, q=2, schedule=schedule)
    assert res.triangles == expected, (schedule, res.triangles, expected)
    print(f"{schedule}: {res.triangles} ok")
print("ALL-OK")
"""


def test_count_triangles_many_distributed(distributed_runner):
    out = distributed_runner(DIST_BATCH_CODE, ndev=4, timeout=1200)
    assert "ALL-OK" in out
