"""Unit tests for preprocessing, decomposition, planning, and the blob."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_plan, degree_order, preprocess, rmat, erdos_renyi
from repro.core.decomp import cyclic_blocks
from repro.core.generators import named_graph
from repro.core.blob import blob_layout, pack_blob, unpack_blob
from repro.core.cannon import pod_stack_arrays


def test_degree_order_nondecreasing():
    g = rmat(9, 8, seed=0)
    perm = degree_order(g)
    deg = g.degrees()
    new_deg = np.zeros_like(deg)
    new_deg[perm] = deg
    assert np.all(np.diff(new_deg) >= 0)
    # perm is a permutation
    assert np.array_equal(np.sort(perm), np.arange(g.n))


def test_degree_order_stability():
    g = named_graph("star")
    perm = degree_order(g)
    leaves = np.arange(1, 8)
    # all leaves have degree 1 and keep their relative order
    assert np.all(np.diff(perm[leaves]) > 0)


def test_preprocess_u_rows_shrink():
    """After degree ordering, U-row lengths are bounded by the ordering
    property: row i only points to later (>= degree) vertices."""
    g = rmat(10, 8, seed=1)
    g2, _ = preprocess(g)
    u = g2.upper_csr()
    # max U row length should be <= max degree and typically much smaller
    assert np.max(np.diff(u.indptr)) <= np.max(g.degrees())


def test_cyclic_blocks_cover_all_edges():
    g = rmat(8, 8, seed=2)
    for r, c in [(2, 2), (3, 3), (2, 4)]:
        blocks = cyclic_blocks(g, r, c)
        total = sum(blocks[x][y].nnz for x in range(r) for y in range(c))
        assert total == g.m
        # ownership: each edge's block is (i % r, j % c)
        for x in range(r):
            for y in range(c):
                blk = blocks[x][y]
                rows = np.repeat(
                    np.arange(blk.n_rows), np.diff(blk.indptr)
                )
                gi = rows * r + x
                gj = blk.indices * c + y
                assert np.all(gi < gj)  # U is strictly upper triangular


def test_plan_balance_stats():
    g = rmat(10, 8, seed=3)
    g2, _ = preprocess(g)
    plan = build_plan(g2, 4)
    st = plan.stats
    # paper Table 3: cyclic task imbalance should be small (<6% there;
    # allow slack for our smaller graphs)
    assert st.task_imbalance < 1.6
    assert st.intersection_tasks_total > 0
    assert 0.0 <= st.padding_fraction_indices < 0.9


def test_plan_cannon_pairing_identity():
    """A/B pre-skew: at shift s the device holds U_{x,(x+y+s)%q} and
    U_{y,(x+y+s)%q} — verified by replaying the ppermute on the host."""
    g = rmat(8, 8, seed=4)
    g2, _ = preprocess(g)
    q = 3
    plan = build_plan(g2, q)
    blocks = plan.blocks
    a = plan.a_indptr.copy()
    b = plan.b_indptr.copy()
    for s in range(q):
        for x in range(q):
            for y in range(q):
                z = (x + y + s) % q
                assert np.array_equal(a[x, y], blocks[x][z].indptr)
                assert np.array_equal(b[x, y], blocks[y][z].indptr)
        a = np.roll(a, -1, axis=1)  # shift left along grid columns
        b = np.roll(b, -1, axis=0)  # shift up along grid rows


def test_pod_stack_covers_all_shifts():
    g = rmat(8, 8, seed=5)
    g2, _ = preprocess(g)
    q, npods = 4, 2
    plan = build_plan(g2, q)
    arrays = pod_stack_arrays(plan.device_arrays(), npods, q)
    blocks = plan.blocks
    for t in range(npods):
        a = arrays["a_indptr"][t].copy()
        for s_local in range(q // npods):
            s = t + s_local * npods
            for x in range(q):
                for y in range(q):
                    z = (x + y + s) % q
                    assert np.array_equal(a[x, y], blocks[x][z].indptr)
            a = np.roll(a, -npods, axis=1)


def test_blob_roundtrip():
    arrs = [
        jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
        jnp.arange(5, dtype=jnp.int32),
        jnp.ones((2, 2, 2), dtype=jnp.int32),
    ]
    layout, total = blob_layout([a.shape for a in arrs])
    blob = pack_blob(arrs)
    assert blob.shape == (total,)
    back = unpack_blob(blob, layout)
    for a, b in zip(arrs, back):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_distributed_counting_sort_matches_host(distributed_runner):
    code = """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.preprocess import distributed_degree_rank, degree_order
from repro.core import rmat
g = rmat(6, 6, seed=9)
deg = g.degrees()
p = 4
n = g.n
from repro import compat
mesh = compat.make_mesh((p,), ("x",))
chunk = n // p
fn = jax.jit(compat.shard_map(
    lambda d: distributed_degree_rank(d, "x"),
    mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
ranks = np.asarray(fn(jnp.asarray(deg, dtype=jnp.int32)))
perm = degree_order(g)
assert np.array_equal(ranks, perm), (ranks[:10], perm[:10])
print("OK")
"""
    out = distributed_runner(code, ndev=4)
    assert "OK" in out


def test_analytic_plan_shapes():
    from repro.core import analytic_plan

    plan = analytic_plan(n=1 << 20, m=1 << 24, q=16, dmax_block=512)
    structs = plan.shape_structs()
    assert structs["a_indices"].shape == (16, 16, plan.nnz_pad)
    assert plan.nnz_pad == int(np.ceil((1 << 24) / 256 * 1.25))
