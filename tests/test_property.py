"""Property-based tests (hypothesis) for system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    Graph,
    build_plan,
    count_triangles,
    preprocess,
    triangle_count_oracle,
)
from repro.core.decomp import cyclic_blocks
from repro.core.graph import triangle_count_dense_oracle


@st.composite
def small_graphs(draw):
    n = draw(st.integers(min_value=3, max_value=40))
    m = draw(st.integers(min_value=0, max_value=3 * n))
    src = draw(
        st.lists(
            st.integers(min_value=0, max_value=n - 1), min_size=m, max_size=m
        )
    )
    dst = draw(
        st.lists(
            st.integers(min_value=0, max_value=n - 1), min_size=m, max_size=m
        )
    )
    return Graph.from_edges(n, src, dst)


@given(small_graphs())
@settings(max_examples=25, deadline=None)
def test_count_matches_dense_oracle(g):
    exp = triangle_count_dense_oracle(g)
    assert count_triangles(g, q=1).triangles == exp


@given(small_graphs(), st.randoms())
@settings(max_examples=15, deadline=None)
def test_count_invariant_under_permutation(g, rnd):
    perm = np.arange(g.n)
    rnd.shuffle(perm)
    g2 = g.relabel(perm)
    assert triangle_count_oracle(g) == triangle_count_oracle(g2)
    assert (
        count_triangles(g, q=1).triangles
        == count_triangles(g2, q=1).triangles
    )


@given(small_graphs(), st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=5))
@settings(max_examples=25, deadline=None)
def test_cyclic_blocks_partition_edges(g, r, c):
    """Every U edge lands in exactly one block with correct local ids."""
    blocks = cyclic_blocks(g, r, c)
    seen = set()
    for x in range(r):
        for y in range(c):
            blk = blocks[x][y]
            rows = np.repeat(np.arange(blk.n_rows), np.diff(blk.indptr))
            for li, lj in zip(rows, blk.indices):
                gi, gj = li * r + x, lj * c + y
                assert gi < gj
                seen.add((int(gi), int(gj)))
    expected = {(int(i), int(j)) for i, j in g.edges}
    assert seen == expected


@given(small_graphs())
@settings(max_examples=15, deadline=None)
def test_plan_tasks_equal_edges(g):
    g2, _ = preprocess(g)
    plan = build_plan(g2, 2)
    assert int(plan.m_cnt.sum()) == g.m


@given(st.integers(min_value=2, max_value=64), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_degree_order_is_permutation(n, seed):
    from repro.core import erdos_renyi, degree_order

    g = erdos_renyi(n, min(4.0, n / 2), seed=seed)
    perm = degree_order(g)
    assert np.array_equal(np.sort(perm), np.arange(n))


@given(st.integers(0, 2**31 - 1), st.integers(min_value=2, max_value=6))
@settings(max_examples=10, deadline=None)
def test_bucketed_property(seed, dsmall):
    """§Perf H1a bucketed probes agree with the oracle for any bucket cut
    (moved here from test_perf_paths.py: it is the only hypothesis-based
    perf test, and this module already skips without hypothesis)."""
    import jax.numpy as jnp

    from repro.core import erdos_renyi
    from repro.core.api import make_grid_mesh
    from repro.core.cannon import build_cannon_fn
    from repro.core.plan import bucketize_plan

    g = erdos_renyi(80, 6.0, seed=seed)
    exp = triangle_count_oracle(g)
    g2, _ = preprocess(g)
    plan = bucketize_plan(build_plan(g2, 1), d_small=dsmall)
    mesh = make_grid_mesh(1)
    fn = build_cannon_fn(plan, mesh, method="search2")
    got = int(fn(**{k: jnp.asarray(v) for k, v in plan.device_arrays().items()}))
    assert got == exp


# ----------------------------------------------------------------------
# skip-aware rebalance invariants (DESIGN.md §4.3)
# ----------------------------------------------------------------------
@given(small_graphs(), st.integers(min_value=1, max_value=6))
@settings(max_examples=15, deadline=None)
def test_rebalance_trial_perms_are_degree_monotone_permutations(g, trials):
    """Every trial perm is a true permutation; degrees stay non-decreasing
    in the relabeled order; seed 0 is the identity baseline."""
    from repro.pipeline import relabel_stage
    from repro.pipeline.rebalance import rebalance_trial_perm

    g2, _ = relabel_stage(g)
    deg = g2.degrees()
    for seed in range(trials):
        tp = rebalance_trial_perm(deg, seed)
        assert np.array_equal(np.sort(tp), np.arange(g.n))
        if seed == 0:
            assert np.array_equal(tp, np.arange(g.n))
        d2 = g2.relabel(tp).degrees()
        assert np.all(np.diff(d2) >= 0)


@given(small_graphs(), st.integers(min_value=1, max_value=3))
@settings(max_examples=6, deadline=None)
def test_rebalance_counts_invariant_across_seeds_and_schedules(g, trials):
    """Triangle counts are invariant across trial seeds x schedules."""
    from repro.pipeline import PlanCache

    exp = triangle_count_oracle(g)
    cache = PlanCache(maxsize=0)
    for schedule in ("cannon", "summa", "oned"):
        got = count_triangles(
            g, q=1, schedule=schedule, rebalance_trials=trials, cache=cache
        ).triangles
        assert got == exp, (schedule, trials)


@given(small_graphs(), st.integers(min_value=2, max_value=6))
@settings(max_examples=10, deadline=None)
def test_rebalance_best_never_worse_than_seed0(g, trials):
    """The chosen seed's masked critical path is <= the seed-0 baseline
    (seed 0 is the identity, so the search cannot lose), for all three
    plan families; the winning relabel preserves the triangle count."""
    from repro.pipeline import PlanCache, plan_cannon, plan_oned, plan_summa

    exp = triangle_count_oracle(g)
    cache = PlanCache(maxsize=0)
    arts = (
        plan_cannon(
            g, 2, keep_blocks=False, rebalance_trials=trials, cache=cache
        ),
        plan_summa(g, 2, 2, rebalance_trials=trials, cache=cache),
        plan_oned(g, 3, rebalance_trials=trials, cache=cache),
    )
    for art in arts:
        rb = art.rebalance
        assert len(rb["trials"]) == trials
        assert (
            rb["best_masked_critical_path"]
            <= rb["baseline_masked_critical_path"]
        )
        assert rb["improvement"] >= 1.0
        assert triangle_count_oracle(art.graph) == exp


@given(small_graphs(), st.integers(min_value=1, max_value=4))
@settings(max_examples=10, deadline=None)
def test_rebalance_plan_cache_keying(g, trials):
    """Same graph + same trials -> warm hit; different trials -> a
    distinct cache key (a miss)."""
    from repro.pipeline import PlanCache, plan_cannon

    cache = PlanCache(maxsize=8)
    a1 = plan_cannon(g, 2, rebalance_trials=trials, cache=cache)
    assert not a1.cache_hit
    a2 = plan_cannon(g, 2, rebalance_trials=trials, cache=cache)
    assert a2.cache_hit and a2 is a1
    a3 = plan_cannon(g, 2, rebalance_trials=trials + 1, cache=cache)
    assert not a3.cache_hit and a3.key != a1.key


@given(small_graphs(),
       st.sampled_from(["cannon", "summa", "oned"]),
       st.sampled_from([True, 0.0, 2.0]),
       st.sampled_from([None, False]))
@settings(max_examples=20, deadline=None)
def test_hub_split_count_parity_property(g, schedule, hub_split, compact):
    """Counts are byte-identical with the hub-split stage on and off,
    for arbitrary small graphs (edgeless and all-hub degenerates
    included via c=0) across schedules and compaction — DESIGN.md §4.8."""
    base = count_triangles(g, q=1, schedule=schedule, compact=compact)
    split = count_triangles(
        g, q=1, schedule=schedule, compact=compact, hub_split=hub_split
    )
    assert split.triangles == base.triangles


@given(small_graphs())
@settings(max_examples=15, deadline=None)
def test_hub_split_residual_partitions_edges(g):
    """residual nnz + hub nnz == m, the residual holds every U edge
    below the cut, and the suffix cut is exact at ANY h0 (not just the
    detected one): T(residual) + hub partial == T(G)."""
    from repro.core.preprocess import degree_order
    from repro.pipeline.hubsplit import hubsplit_stage

    g2 = g.relabel(degree_order(g))
    exp = triangle_count_oracle(g2)
    for h0 in {0, g2.n // 2, max(0, g2.n - 3)}:
        res, hub = hubsplit_stage(g2, (2, 2), h0=h0)
        if hub is None:
            assert triangle_count_oracle(res) == exp
            continue
        assert res.edges.shape[0] + hub.hub_nnz == g2.m
        assert (res.edges[:, 1] < h0).all()
        # host-side oracle of the decomposition: residual triangles plus
        # per-task high-fragment intersections
        hi = g2.edges[g2.edges[:, 1] >= h0]
        frag = {}
        for v, k in hi:
            frag.setdefault(int(v), set()).add(int(k))
        partial = sum(
            len(frag.get(int(i), set()) & frag.get(int(j), set()))
            for i, j in g2.edges
        )
        assert triangle_count_oracle(res) + partial == exp
