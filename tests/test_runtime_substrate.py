"""Tests: checkpointing, optimizers, compression, elastic, sparse, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import load_checkpoint, save_checkpoint

    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.int32)},
    }
    save_checkpoint(str(tmp_path), 7, tree, extra={"x": 1})
    out, extra = load_checkpoint(str(tmp_path), 7, tree)
    assert extra == {"x": 1}
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"])
    )


def test_checkpoint_corruption_detected(tmp_path):
    from repro.ckpt import load_checkpoint, save_checkpoint

    tree = {"a": jnp.ones((4,))}
    path = save_checkpoint(str(tmp_path), 1, tree)
    payload = os.path.join(str(tmp_path), "step_0000000001.npz")
    with open(payload, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError, match="corruption"):
        load_checkpoint(str(tmp_path), 1, tree)


def test_manager_rotation_and_restore(tmp_path):
    from repro.ckpt import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in range(5):
        mgr.save(s, {"w": jnp.full((3,), float(s))}, extra={"next_step": s})
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 2  # rotated
    step, tree, extra = mgr.restore_latest({"w": jnp.zeros((3,))})
    assert step == 4 and float(tree["w"][0]) == 4.0


def test_async_manager(tmp_path):
    from repro.ckpt import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    for s in range(3):
        mgr.save(s, {"w": jnp.full((2,), float(s))})
    mgr.wait()
    mgr.close()
    assert len([f for f in os.listdir(tmp_path) if f.endswith(".npz")]) == 3


def test_run_with_restarts(tmp_path):
    from repro.runtime import run_with_restarts

    calls = {"failures": 0}

    def injector(step):
        if step == 5 and calls["failures"] == 0:
            calls["failures"] += 1
            raise RuntimeError("injected device loss")

    state = run_with_restarts(
        lambda: {"x": jnp.zeros(())},
        lambda st, i: {"x": st["x"] + 1.0},
        n_steps=10,
        ckpt_dir=str(tmp_path),
        ckpt_every=2,
        fault_injector=injector,
    )
    assert float(state["x"]) == 10.0
    assert calls["failures"] == 1


# ----------------------------------------------------------------------
# optimizers + compression
# ----------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    from repro.optim import adamw_init, adamw_update

    params = {"w": jnp.array([3.0, -2.0])}
    st = adamw_init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adamw_update(
            grads, st, params, i, lr=0.05, weight_decay=0.0
        )
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adafactor_reduces_quadratic_matrix():
    from repro.optim import adafactor_init, adafactor_update

    params = {"w": jnp.ones((4, 5)) * 2.0}
    st = adafactor_init(params)
    for i in range(300):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adafactor_update(grads, st, params, i, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    # factored state shapes
    assert st["v"]["w"]["vr"].shape == (4,)
    assert st["v"]["w"]["vc"].shape == (5,)


def test_grad_compression_accuracy():
    from repro.optim.compress import _dequantize, _quantize

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(333, 7)).astype(np.float32)) * 0.01
    q, s = _quantize(g)
    back = _dequantize(q, s, g.shape, g.size)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert rel < 0.01, rel


def test_compressed_psum_matches_plain(distributed_runner):
    code = """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro import compat
mesh = compat.make_mesh((4,), ("d",))
x = jnp.arange(64, dtype=jnp.float32).reshape(4, 16) * 0.01
def f(x):
    g = {"w": x.reshape(16)}
    out = compressed_psum(g, "d")
    ref = jax.tree.map(lambda v: jax.lax.psum(v, "d"), g)
    return out["w"], ref["w"]
fn = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P(),
                              check_vma=False))
got, ref = fn(x)
rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
assert rel < 0.02, rel
print("OK", rel)
"""
    assert "OK" in distributed_runner(code, ndev=4)


# ----------------------------------------------------------------------
# elastic + rebalance
# ----------------------------------------------------------------------
def test_best_grid():
    from repro.runtime import best_grid

    assert best_grid(16) == (4, 4)
    assert best_grid(8) == (2, 4)
    # 12 = 3x4 violates the SUMMA panel-slot constraint (4 % 3 != 0);
    # the most-square admissible factorization is 2x6
    assert best_grid(12) == (2, 6)
    assert best_grid(256) == (16, 16)
    assert best_grid(255, require_square=True) == (15, 15)


def test_replan_elastic_counts_correctly():
    from repro.core import rmat, triangle_count_oracle
    from repro.runtime import replan_elastic

    g = rmat(9, 8, seed=2)
    sched, plan, (r, c) = replan_elastic(g, 4)
    assert sched == "cannon" and (r, c) == (2, 2)
    sched, plan, (r, c) = replan_elastic(g, 8)
    assert sched == "summa" and r * c <= 8


def test_rebalance_improves_or_equal():
    from repro.core import rmat
    from repro.pipeline import PlanCache
    from repro.runtime import rebalance_plan

    g = rmat(10, 8, seed=1)
    plan, report = rebalance_plan(g, 3, trials=4, cache=PlanCache(0))
    # seed 0 is the identity baseline, so the search can never lose
    assert report["improvement"] >= 1.0
    assert (
        report["best_masked_critical_path"]
        <= report["baseline_masked_critical_path"]
    )
    assert "skipped_steps" in report
    assert [t["seed"] for t in report["trials"]] == [0, 1, 2, 3]
    assert plan.stats is not None and plan.step_keep is not None


def test_rebalance_lowers_masked_critical_path_all_schedules():
    """Acceptance fixture: on the skewed powerlaw graph every schedule's
    rebalance search strictly beats the seed-0 masked critical path, and
    the winning relabel preserves the triangle count."""
    from repro.core import powerlaw, triangle_count_oracle
    from repro.pipeline import PlanCache, plan_cannon, plan_oned, plan_summa

    g = powerlaw(600, 2.2, seed=0)
    exp = triangle_count_oracle(g)
    cache = PlanCache(maxsize=0)
    arts = dict(
        cannon=plan_cannon(
            g, 3, keep_blocks=False, rebalance_trials=8, cache=cache
        ),
        summa=plan_summa(g, 2, 3, rebalance_trials=8, cache=cache),
        oned=plan_oned(g, 4, rebalance_trials=8, cache=cache),
    )
    for name, art in arts.items():
        rb = art.rebalance
        assert rb["best_seed"] != 0, name
        assert (
            rb["best_masked_critical_path"]
            < rb["baseline_masked_critical_path"]
        ), (name, rb)
        assert rb["improvement"] > 1.0, name
        assert triangle_count_oracle(art.graph) == exp, name
        deg = art.graph.degrees()
        assert np.all(deg[1:] >= deg[:-1]), name


def test_tc_run_rebalance_end_to_end():
    """tc_run --rebalance on the skewed fixture: the report carries the
    rebalance fields and the count matches the unrebalanced run."""
    import json
    import subprocess
    import sys

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=4",
        PYTHONPATH=os.path.join(repo, "src"),
    )

    def run(extra):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.tc_run",
             "--graph", "powerlaw:600,2.2", "--grid", "2", "--verify",
             "--json", *extra],
            env=env, capture_output=True, text=True, timeout=600,
        )
        assert out.returncode == 0, out.stdout[-800:] + out.stderr[-800:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    rb = run(["--rebalance", "4"])
    plain = run([])
    assert rb["correct"] and plain["correct"]
    assert rb["triangles"] == plain["triangles"]
    assert rb["rebalance_trials"] == 4
    assert rb["rebalance_improvement"] >= 1.0
    assert (
        rb["rebalance_masked_critical_path"]
        <= rb["rebalance_baseline_critical_path"]
    )
    assert rb["rebalance_skipped_delta"] >= 0
    assert "rebalance_best_seed" in rb
    assert "rebalance_improvement" not in plain


# ----------------------------------------------------------------------
# sparse substrate
# ----------------------------------------------------------------------
def test_embedding_bag_matches_dense():
    from repro.sparse import embedding_bag
    from repro.sparse.embedding_bag import flatten_ids, table_offsets

    rng = np.random.default_rng(0)
    sizes = (7, 13, 5)
    offs = table_offsets(sizes)
    table = jnp.asarray(rng.normal(size=(sum(sizes), 4)).astype(np.float32))
    ids = jnp.asarray(
        np.stack(
            [rng.integers(0, s, size=(6, 2)) for s in sizes], axis=1
        ).astype(np.int32)
    )  # (B=6, F=3, H=2)
    out = embedding_bag(table, flatten_ids(ids, offs))
    # dense one-hot oracle
    flat = np.asarray(flatten_ids(ids, offs))
    expect = np.zeros((6, 3, 4), np.float32)
    for b in range(6):
        for f in range(3):
            for h in range(2):
                expect[b, f] += np.asarray(table)[flat[b, f, h]]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


def test_segment_softmax_normalizes():
    from repro.sparse import segment_softmax

    logits = jnp.asarray([1.0, 2.0, 3.0, 1.0, -1.0])
    seg = jnp.asarray([0, 0, 0, 1, 1])
    out = segment_softmax(logits, seg, 2)
    sums = jax.ops.segment_sum(out, seg, num_segments=2)
    np.testing.assert_allclose(np.asarray(sums), [1.0, 1.0], rtol=1e-6)


def test_spmm_edges_matches_matmul():
    from repro.sparse import spmm_edges

    rng = np.random.default_rng(1)
    n, e, d = 10, 40, 3
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    x = rng.normal(size=(n, d)).astype(np.float32)
    adj = np.zeros((n, n), np.float32)
    for s, t in zip(src, dst):
        adj[t, s] += 1.0
    out = spmm_edges(
        jnp.asarray(x), jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32), n
    )
    np.testing.assert_allclose(np.asarray(out), adj @ x, rtol=1e-5, atol=1e-5)


def test_neighbor_sampler_shapes_and_validity():
    from repro.core import rmat
    from repro.sparse.sampler import sample_neighbors

    g = rmat(9, 8, seed=0)
    adj = g.adjacency_csr()
    rng = np.random.default_rng(0)
    sub = sample_neighbors(adj.indptr, adj.indices, np.arange(16), (5, 3), rng)
    assert sub.n_nodes >= 16
    valid = sub.node_ids >= 0
    assert valid.sum() >= 16
    # every sampled edge's endpoints are real nodes
    assert sub.edge_src.max() < valid.sum() + 1
    assert sub.edge_dst.max() < valid.sum() + 1


def test_token_pipeline_deterministic_replay():
    from repro.data.pipeline import TokenPipeline

    p1 = TokenPipeline(1000, 4, 16, seed=3)
    b1 = p1.next_batch()
    st = p1.state_dict()
    b2 = p1.next_batch()
    p2 = TokenPipeline(1000, 4, 16, seed=3)
    p2.load_state(st)
    b2r = p2.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])
