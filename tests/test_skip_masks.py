"""Sparsity-aware step skipping + the double-buffered Cannon engine.

Covers: mask derivation (shapes, exactness on block-sparse fixtures),
masked-vs-unmasked equivalence across (schedule × operand store) on
graphs with empty blocks, the int32 hash-key-width guard, and the
stepper's double-buffered-carry checkpoint round trip.
"""
import numpy as np
import pytest

from repro.core import (
    Graph,
    build_plan,
    count_triangles,
    count_triangles_many,
    named_graph,
    preprocess,
    residue_cliques,
    rmat,
    star,
    triangle_count_oracle,
)
from repro.core.cannon import pod_stack_arrays
from repro.core.count import aug_key_dtype
from repro.core.onedim import build_oned_plan
from repro.core.summa import build_summa_plan

# graphs engineered to leave blocks empty under the cyclic decomposition:
# karate (q=3 does not divide n=34), a star (all edges in the hub's block
# column), residue cliques (block-diagonal: only q of q^2 blocks live)
SPARSE_FIXTURES = {
    "karate": lambda: named_graph("karate"),
    "star": lambda: star(37),
    "cliques": lambda: residue_cliques(3, 8),
    "rmat": lambda: rmat(8, 8, seed=6),
}

COMBOS = [
    ("cannon", "search"),
    ("cannon", "global"),
    ("cannon", "dense"),
    ("cannon", "tile"),
    ("summa", "search"),
    ("oned", "search"),
]


# ======================================================================
# mask derivation
# ======================================================================
def test_mask_shapes_and_staging():
    g, _ = preprocess(residue_cliques(3, 8))
    q = 3
    plan = build_plan(g, q)
    assert plan.step_keep is not None
    assert plan.step_keep.shape == (q, q, q)
    assert plan.step_keep.dtype == np.bool_
    assert "step_keep" in plan.device_arrays()
    splan = build_summa_plan(g, 2, 2)
    assert splan.step_keep.shape == (2, 2, 2)
    oplan = build_oned_plan(g, 4)
    assert oplan.step_keep.shape == (4, 4)

    nomask = build_plan(g, q, step_masks=False)
    assert nomask.step_keep is None
    assert "step_keep" not in nomask.device_arrays()


def test_block_diagonal_mask_is_maximally_sparse():
    """On residue cliques over q classes, each diagonal device has
    exactly one live shift: q of q^3 (device, shift) entries survive."""
    q = 3
    g, _ = preprocess(residue_cliques(q, 10))
    plan = build_plan(g, q)
    assert int(plan.step_keep.sum()) == q
    assert int(plan.step_keep.size) == q ** 3


def test_mask_is_exact_no_live_step_dropped():
    """Every (device, shift) with non-zero probe work must be kept —
    the mask may only drop provably-zero steps."""
    g, _ = preprocess(rmat(8, 8, seed=9))
    plan = build_plan(g, 3)
    probe = plan.stats.probe_work_per_device_shift
    assert np.all(plan.step_keep[probe > 0])
    assert not np.any(plan.step_keep[probe == 0])


def test_resolve_step_mask_demands_masks():
    import jax.numpy as jnp  # noqa: F401

    from repro.core.api import make_grid_mesh
    from repro.core.cannon import build_cannon_fn

    g, _ = preprocess(named_graph("karate"))
    plan = build_plan(g, 1, step_masks=False)
    with pytest.raises(ValueError, match="no step_keep"):
        build_cannon_fn(plan, make_grid_mesh(1), use_step_mask=True)


def test_pod_stack_strides_mask():
    """Pod t's local step s is global shift t + s*npods."""
    g, _ = preprocess(rmat(7, 8, seed=5))
    q, npods = 4, 2
    plan = build_plan(g, q)
    arrays = pod_stack_arrays(plan.device_arrays(), npods, q)
    sk = arrays["step_keep"]
    assert sk.shape == (npods, q, q, q // npods)
    for t in range(npods):
        for sl in range(q // npods):
            assert np.array_equal(
                sk[t, :, :, sl], plan.step_keep[:, :, t + sl * npods]
            )


# ======================================================================
# masked == unmasked equivalence (q=1 in-process; q=2,3 subprocesses)
# ======================================================================
@pytest.mark.parametrize("graph_name", sorted(SPARSE_FIXTURES))
@pytest.mark.parametrize("schedule,method", COMBOS)
def test_masked_equals_unmasked_q1(graph_name, schedule, method):
    g = SPARSE_FIXTURES[graph_name]()
    exp = triangle_count_oracle(g)
    masked = count_triangles(g, q=1, schedule=schedule, method=method)
    unmasked = count_triangles(
        g, q=1, schedule=schedule, method=method, use_step_mask=False
    )
    assert masked.triangles == unmasked.triangles == exp


def test_masked_engine_on_edgeless_graph():
    """m=0 masks off every step — the cond's zero branch must run."""
    g = Graph.from_edges(6, [], [], name="empty")
    for schedule in ("cannon", "summa", "oned"):
        assert count_triangles(g, q=1, schedule=schedule).triangles == 0


def test_single_buffer_body_matches():
    g = SPARSE_FIXTURES["cliques"]()
    exp = triangle_count_oracle(g)
    r = count_triangles(g, q=1, schedule="cannon", double_buffer=False)
    assert r.triangles == exp


def test_batched_engine_with_sparse_fixtures():
    graphs = [residue_cliques(2, 6), star(13), named_graph("karate")]
    expected = [triangle_count_oracle(g) for g in graphs]
    res = count_triangles_many(graphs, q=1)
    assert res.triangles == expected


DIST_MASK_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core import (count_triangles, residue_cliques, star, named_graph,
                        triangle_count_oracle)

q = {q}
fixtures = [residue_cliques(q, 12), star(10 * q + 1), named_graph("karate")]
for g in fixtures:
    exp = triangle_count_oracle(g)
    for schedule, method in {combos}:
        m = count_triangles(g, q=q, schedule=schedule, method=method)
        u = count_triangles(g, q=q, schedule=schedule, method=method,
                            use_step_mask=False)
        s = count_triangles(g, q=q, schedule=schedule, method=method,
                            double_buffer=False)
        assert m.triangles == u.triangles == s.triangles == exp, (
            g.name, schedule, method, m.triangles, u.triangles, s.triangles, exp)
        sk = getattr(m.plan, "step_keep", None)
        assert sk is not None
        if g.name.startswith("cliques"):
            assert sk.size - sk.sum() > 0, (g.name, schedule, "no skips")
        print(f"{{g.name}}/{{schedule}}/{{method}} ok")
print("ALL-OK")
"""


@pytest.mark.parametrize("q", [2, 3])
def test_masked_equivalence_distributed(q, distributed_runner):
    combos = [("cannon", "search"), ("cannon", "global"),
              ("summa", "search"), ("oned", "search")]
    out = distributed_runner(
        DIST_MASK_CODE.format(q=q, combos=combos), ndev=q * q, timeout=1200
    )
    assert "ALL-OK" in out


# ======================================================================
# stepper: double-buffered carry checkpoint round trip
# ======================================================================
DIST_STEPPER_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from repro.core import build_plan, preprocess, rmat, triangle_count_oracle
from repro.core.api import make_grid_mesh
from repro.core.cannon import build_cannon_fn, build_cannon_stepper

q = 2
g = rmat(8, 8, seed=11)
exp = triangle_count_oracle(g)
g2, _ = preprocess(g)
plan = build_plan(g2, q)
mesh = make_grid_mesh(q)
stepper = build_cannon_stepper(plan, mesh)
arrays = {k: jnp.asarray(v) for k, v in plan.device_arrays().items()}
statics = {k: arrays[k] for k in ("m_ti", "m_tj", "m_cnt", "step_keep")}

carry = list(stepper.prime(arrays))
assert stepper.n_carry == 8  # double-buffered: 2 generations x 4 arrays
acc = jnp.zeros((q, q), jnp.int64)

saved = None
for s in range(q):
    if s == 1:  # checkpoint mid-loop: host numpy round trip, bytes exact
        saved = ([np.asarray(c).copy() for c in carry], np.asarray(acc).copy())
    out = stepper(tuple(carry) + (acc,), statics, step=s)
    carry, acc = list(out[:-1]), out[-1]
total_uninterrupted = int(np.asarray(acc).sum())

# resume from the step-1 checkpoint and replay the tail
carry2 = [jnp.asarray(c) for c in saved[0]]
acc2 = jnp.asarray(saved[1])
for s in range(1, q):
    out = stepper(tuple(carry2) + (acc2,), statics, step=s)
    carry2, acc2 = list(out[:-1]), out[-1]
total_resumed = int(np.asarray(acc2).sum())

assert total_uninterrupted == total_resumed == exp, (
    total_uninterrupted, total_resumed, exp)
# the resumed double-buffered carry must be byte-identical to the
# uninterrupted one
for a, b in zip(carry, carry2):
    assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

# and the stepper agrees with the scan engine
fn = build_cannon_fn(plan, mesh)
assert int(fn(**arrays)) == exp
print("STEPPER-OK")
"""


def test_stepper_double_buffer_checkpoint_roundtrip(distributed_runner):
    out = distributed_runner(DIST_STEPPER_CODE, ndev=4, timeout=1200)
    assert "STEPPER-OK" in out


# ======================================================================
# hash-key width guard (int32 truncation regression)
# ======================================================================
def test_aug_key_dtype_boundary():
    import jax.numpy as jnp

    from repro import compat

    assert aug_key_dtype(46340) == jnp.int32  # 46340^2 - 1 < 2^31
    if compat.x64_enabled():
        assert aug_key_dtype(46341) == jnp.int64
    else:
        with pytest.raises(OverflowError, match="int64"):
            aug_key_dtype(46341)


DIST_KEY_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
import jax.numpy as jnp
from repro.core.count import aug_key_dtype, count_pair_search_global

assert aug_key_dtype(46341) == jnp.int64

# synthetic CSR block just past the int32 key boundary: rows near nb
# produce keys row * (nb+1) + col > 2^31, which int32 keys would wrap
# into collisions/mis-sorts
nb = 50000
rows_b = {46290: [10, 20, 30], 49000: [5, 10, 40]}
rows_a = {7: [10, 20, 999], 8: [5, 40, 41]}

def to_csr(rows, nnz_pad):
    indptr = np.zeros(nb + 1, dtype=np.int32)
    for r, cols in rows.items():
        indptr[r + 1] = len(cols)
    indptr = np.cumsum(indptr, dtype=np.int32)
    indices = np.full(nnz_pad, nb, dtype=np.int32)
    at = 0
    for r in sorted(rows):
        cols = rows[r]
        indices[at:at + len(cols)] = cols
        at += len(cols)
    return jnp.asarray(indptr), jnp.asarray(indices)

a_ptr, a_idx = to_csr(rows_a, 8)
b_ptr, b_idx = to_csr(rows_b, 8)
tasks = [(7, 46290), (7, 49000), (8, 49000), (8, 46290)]
expected = sum(
    len(set(rows_a[i]) & set(rows_b[j])) for i, j in tasks
)
ti = jnp.asarray(np.array([t[0] for t in tasks], np.int32))
tj = jnp.asarray(np.array([t[1] for t in tasks], np.int32))
got = int(count_pair_search_global(
    a_ptr, a_idx, b_ptr, b_idx, ti, tj, jnp.asarray(len(tasks)),
    dpad=4, chunk=4,
))
assert got == expected, (got, expected)
print("KEYS-OK", got)
"""


def test_global_keys_past_int32_boundary(distributed_runner):
    out = distributed_runner(DIST_KEY_CODE, ndev=1, timeout=600)
    assert "KEYS-OK" in out
