"""System tests: every schedule/path must match the exact oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_plan,
    count_triangles,
    erdos_renyi,
    named_graph,
    preprocess,
    rmat,
    triangle_count_oracle,
)
from repro.core.graph import triangle_count_dense_oracle

NAMED = ["triangle", "k4", "k10", "star", "path", "bull", "karate"]


@pytest.mark.parametrize("name", NAMED)
def test_named_graphs_q1(name):
    g = named_graph(name)
    exp = triangle_count_dense_oracle(g)
    assert count_triangles(g, q=1).triangles == exp


@pytest.mark.parametrize("name", ["k10", "karate"])
def test_oracles_agree(name):
    g = named_graph(name)
    assert triangle_count_dense_oracle(g) == triangle_count_oracle(g)


def test_networkx_oracle_agreement():
    import networkx as nx

    g = rmat(8, 8, seed=11)
    nxg = nx.Graph()
    nxg.add_nodes_from(range(g.n))
    nxg.add_edges_from(map(tuple, g.edges))
    exp = sum(nx.triangles(nxg).values()) // 3
    assert triangle_count_oracle(g) == exp
    assert count_triangles(g, q=1).triangles == exp


def test_rmat_q1_search_and_probe_directions():
    g = rmat(9, 8, seed=1)
    exp = triangle_count_oracle(g)
    assert count_triangles(g, q=1, probe_shorter=True).triangles == exp
    assert count_triangles(g, q=1, probe_shorter=False).triangles == exp


def test_reorder_invariance():
    """Degree reordering must not change the count (paper §5.3)."""
    g = erdos_renyi(300, 12.0, seed=7)
    assert (
        count_triangles(g, q=1, reorder=True).triangles
        == count_triangles(g, q=1, reorder=False).triangles
    )


def test_dense_oracle_path_matches_search():
    from repro.core.cannon import build_cannon_dense_fn
    from repro.core.api import make_grid_mesh

    g = rmat(8, 8, seed=2)
    g2, _ = preprocess(g)
    plan = build_plan(g2, 1)
    mesh = make_grid_mesh(1)
    dense = plan.dense_blocks()  # includes the step_keep skip mask
    fn = build_cannon_dense_fn(plan, mesh)
    got = int(fn(*(jnp.asarray(dense[k]) for k in fn.ordered)))
    assert got == triangle_count_oracle(g)


def test_tile_path_matches():
    from repro.core.tiles import build_tile_plan
    from repro.core.cannon import build_cannon_tile_fn
    from repro.core.api import make_grid_mesh

    g = rmat(8, 8, seed=3)
    exp = triangle_count_oracle(g)
    g2, _ = preprocess(g)
    plan = build_plan(g2, 1)
    tp = build_tile_plan(plan)
    mesh = make_grid_mesh(1)
    for mode in ("popcount", "mxu"):
        fn = build_cannon_tile_fn(plan, tp, mesh, mode=mode, interpret=True)
        got = int(fn(**{k: jnp.asarray(v) for k, v in tp.device_arrays().items()}))
        assert got == exp, mode


def test_summa_q1():
    g = rmat(8, 8, seed=4)
    exp = triangle_count_oracle(g)
    assert count_triangles(g, q=1, schedule="summa").triangles == exp


def test_oned_p1():
    g = rmat(8, 8, seed=4)
    exp = triangle_count_oracle(g)
    assert count_triangles(g, q=1, schedule="oned").triangles == exp


# ----------------------------------------------------------------------
# multi-device (subprocess) system tests
# ----------------------------------------------------------------------
DIST_CODE = """
import jax
jax.config.update("jax_enable_x64", True)
from repro.core import count_triangles, rmat, triangle_count_oracle
g = rmat(9, 8, seed=42)
exp = triangle_count_oracle(g)
{body}
print("OK", exp)
"""


def test_distributed_cannon_q3(distributed_runner):
    body = """
r = count_triangles(g, q=3)
assert r.triangles == exp, (r.triangles, exp)
"""
    out = distributed_runner(DIST_CODE.format(body=body), ndev=9)
    assert "OK" in out


def test_distributed_cannon_q4_and_pods(distributed_runner):
    body = """
r = count_triangles(g, q=4)
assert r.triangles == exp, (r.triangles, exp)
r = count_triangles(g, q=2, npods=2)
assert r.triangles == exp
r = count_triangles(g, q=2, npods=2)
"""
    out = distributed_runner(DIST_CODE.format(body=body), ndev=16)
    assert "OK" in out


def test_distributed_summa_rect(distributed_runner):
    body = """
from repro import compat
mesh = compat.make_mesh((2, 4), ("data", "model"))
r = count_triangles(g, mesh=mesh, schedule="summa")
assert r.triangles == exp, (r.triangles, exp)
"""
    out = distributed_runner(DIST_CODE.format(body=body), ndev=8)
    assert "OK" in out


def test_distributed_oned(distributed_runner):
    body = """
r = count_triangles(g, q=2, schedule="oned")  # p = 4 ring
assert r.triangles == exp, (r.triangles, exp)
"""
    out = distributed_runner(DIST_CODE.format(body=body), ndev=4)
    assert "OK" in out


def test_distributed_tile_kernel(distributed_runner):
    body = """
import jax.numpy as jnp
from repro.core import build_plan, preprocess
from repro.core.tiles import build_tile_plan
from repro.core.cannon import build_cannon_tile_fn
from repro.core.api import make_grid_mesh
g2, _ = preprocess(g)
plan = build_plan(g2, 2)
tp = build_tile_plan(plan)
fn = build_cannon_tile_fn(plan, tp, make_grid_mesh(2), interpret=True)
got = int(fn(**{k: jnp.asarray(v) for k, v in tp.device_arrays().items()}))
assert got == exp, (got, exp)
"""
    out = distributed_runner(DIST_CODE.format(body=body), ndev=4)
    assert "OK" in out


# ----------------------------------------------------------------------
# skewed-degree fixture (powerlaw) — generator + spec parsing
# ----------------------------------------------------------------------
def test_powerlaw_generator_is_skewed_and_deterministic():
    from repro.core import powerlaw

    g = powerlaw(400, 2.3, seed=3)
    assert g.n == 400
    deg = g.degrees()
    # heavy-tailed: the hub dwarfs the mean, yet most vertices tie at
    # low degree (the regime the rebalancer's within-degree shuffles
    # need); deterministic given the seed
    assert deg.max() > 10 * deg.mean()
    assert np.array_equal(g.edges, powerlaw(400, 2.3, seed=3).edges)
    assert not np.array_equal(g.edges, powerlaw(400, 2.3, seed=4).edges)
    assert count_triangles(g, q=1).triangles == triangle_count_oracle(g)


def test_powerlaw_spec_parsing():
    from repro.core import graph_from_spec, powerlaw
    from repro.core.generators import split_specs

    g = graph_from_spec("powerlaw:300,2.5")
    assert g.n == 300 and np.array_equal(g.edges, powerlaw(300, 2.5).edges)
    g7 = graph_from_spec("powerlaw:300,2.5,7")
    assert np.array_equal(g7.edges, powerlaw(300, 2.5, seed=7).edges)
    # well-formed single specs survive comma-splitting heuristics
    assert split_specs("powerlaw:300,2.5") == ["powerlaw:300,2.5"]
    assert split_specs("powerlaw:300,2.5,7") == ["powerlaw:300,2.5,7"]
    assert split_specs("powerlaw:300,2.5;karate") == [
        "powerlaw:300,2.5", "karate",
    ]
    with pytest.raises(ValueError):
        graph_from_spec("powerlaw:")
